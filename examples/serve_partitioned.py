"""Serve a small model with batched requests: prefill + greedy decode.

Demonstrates the serving substrate (KV/state caches, ring-buffered sliding
window, batched decode) that the decode_32k / long_500k dry-run shapes
exercise at production scale.

Run:  PYTHONPATH=src python examples/serve_partitioned.py
      [--arch xlstm-125m] [--new-tokens 32] [--batch 4] [--window 0]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get  # noqa: E402
from repro.data.lm import synthetic_token_stream  # noqa: E402
from repro.launch.steps import build_decode_step, build_prefill_step  # noqa: E402
from repro.models import model as M  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--window", type=int, default=0,
                    help=">0 enables the ring-buffered sliding window")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get(args.arch, smoke=True)
    if args.window:
        cfg = cfg.replace(sliding_window=args.window)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    stream = synthetic_token_stream(args.batch * args.prompt_len + 1,
                                    cfg.vocab_size, seed=0)
    prompts = jnp.asarray(
        stream[: args.batch * args.prompt_len].reshape(args.batch, -1))
    batch = {"tokens": prompts}
    if cfg.enc_dec:
        batch["frames"] = jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model))
    if cfg.frontend == "vision":
        batch["image_embeds"] = jnp.zeros(
            (args.batch, cfg.vision_tokens, cfg.d_model))

    cache_len = args.prompt_len + args.new_tokens \
        + (cfg.vision_tokens if cfg.frontend == "vision" else 0)
    prefill = jax.jit(build_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(build_decode_step(cfg))

    t0 = time.perf_counter()
    logits, cache, pos = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    def sample(lg, key):
        lg = lg[:, : cfg.vocab_size]
        if args.temperature <= 0:
            return jnp.argmax(lg, -1).astype(jnp.int32)
        return jax.random.categorical(key, lg / args.temperature, -1) \
            .astype(jnp.int32)

    key = jax.random.PRNGKey(7)
    tok = sample(logits, key)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.new_tokens - 1):
        key, sk = jax.random.split(key)
        logits, cache = decode(params, cache, tok, pos + i)
        tok = sample(logits, sk)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.stack(out, axis=1)
    ptoks = args.batch * args.prompt_len
    dtoks = args.batch * (args.new_tokens - 1)
    print(f"arch={cfg.name} (reduced)  window={cfg.sliding_window or 'full'}")
    print(f"prefill: {ptoks} tokens in {t_prefill*1e3:.0f}ms "
          f"({ptoks/t_prefill:.0f} tok/s)")
    print(f"decode : {dtoks} tokens in {t_decode*1e3:.0f}ms "
          f"({dtoks/max(t_decode,1e-9):.0f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"request {b}: ...{prompts[b, -8:].tolist()} -> "
              f"{gen[b, :12].tolist()}...")


if __name__ == "__main__":
    main()
