"""Serve paper partitions as deployable stages via the `repro.serve` API.

Demonstrates what the old script-level loops could not express: one
`Engine.generate` call over MIXED-LENGTH prompts with per-request sampling
configs, continuously batched into a slot pool — first against the joined
model, then against the same weights split by a 2-stage `PartitionPlan`
and served without joining (token-identical at temperature 0).

Run:  PYTHONPATH=src python examples/serve_partitioned.py
      [--arch qwen2-1.5b] [--new-tokens 16] [--slots 2] [--window 0]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs import get  # noqa: E402
from repro.core import partition  # noqa: E402
from repro.data.lm import synthetic_token_stream  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.serve import Engine, GenerationConfig, Request  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--window", type=int, default=0,
                    help=">0 enables the ring-buffered sliding window")
    args = ap.parse_args()

    cfg = get(args.arch, smoke=True)
    if args.window:
        cfg = cfg.replace(sliding_window=args.window)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    stream = synthetic_token_stream(4096, cfg.vocab_size, seed=0)

    # mixed-length prompts, heterogeneous per-request configs
    requests = [
        Request(tokens=stream[:48], id="long-greedy",
                gen=GenerationConfig(max_new_tokens=args.new_tokens)),
        Request(tokens=stream[100:116], id="short-greedy",
                gen=GenerationConfig(max_new_tokens=args.new_tokens)),
        Request(tokens=stream[200:232], id="sampled",
                gen=GenerationConfig(max_new_tokens=args.new_tokens,
                                     temperature=0.8, top_k=40, top_p=0.95,
                                     seed=7)),
        Request(tokens=stream[300:308], id="tiny",
                gen=GenerationConfig(max_new_tokens=4)),
    ]

    joined = Engine(cfg, params, max_slots=args.slots)
    t0 = time.perf_counter()
    outs = joined.generate(requests)
    dt = time.perf_counter() - t0
    n = sum(c.n_generated for c in outs)
    print(f"joined engine: {n} tokens in {dt*1e3:.0f}ms "
          f"({n/dt:.0f} tok/s, slots={args.slots}, "
          f"window={cfg.sliding_window or 'full'})")
    for c in outs:
        print(f"  {c.id}: prompt[{c.n_prompt}] -> "
              f"{list(c.tokens[:10])}{'...' if c.n_generated > 10 else ''} "
              f"[{c.finish_reason}]")

    # the same weights, partitioned into 2 deployable stages, never joined
    plan = partition.make_plan(cfg, 2)
    stage_params = [partition.slice_stage_params(cfg, plan, params, k)
                    for k in range(plan.n_stages)]
    staged = Engine(cfg, plan=plan, stage_params=stage_params,
                    max_slots=args.slots)
    outs2 = staged.generate(requests)
    print("staged engine (2 stages): token-identical per request =",
          [a.tokens == b.tokens for a, b in zip(outs, outs2)])


if __name__ == "__main__":
    main()
