"""PNN on a transformer LM: the paper's scheme lifted to the assigned archs.

Partitions a (reduced) qwen2 into 2 stages; stage 0 trains against a random
(d_model x vocab) SIL table with the fused MSE loss, stage 1 trains with CE
on the frozen stage-0 boundary; then a recovery phase fine-tunes stage 0
end-to-end.  Compares against end-to-end training of the same model and
prints per-step losses + final perplexities.

Run:  PYTHONPATH=src python examples/pnn_transformer.py [--arch qwen2-1.5b]
      [--steps 30] [--stages 2]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get  # noqa: E402
from repro.core import losses, partition  # noqa: E402
from repro.data.lm import lm_batches, synthetic_token_stream  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.optim import make_optimizer  # noqa: E402
from repro.train import StageSpec, TrainSpec, recipes  # noqa: E402


def eval_ppl(cfg, params, batches):
    tot, cnt = 0.0, 0
    for b in batches:
        logits, _ = M.forward(cfg, params, b, remat=False)
        ce = losses.cross_entropy(logits, b["labels"],
                                  vocab_size=cfg.vocab_size)
        tot += float(ce)
        cnt += 1
    return float(np.exp(tot / cnt))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--parallel", action="store_true",
                    help="Fig.-5 mode: all stages train concurrently on SIL "
                         "inputs/targets (paper deems it impractical)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = get(args.arch, smoke=True)
    plan = partition.make_plan(cfg, args.stages)
    print(f"arch={cfg.name} (reduced) groups={M.n_groups(cfg)} "
          f"stage bounds={plan.bounds}")

    stream = synthetic_token_stream(200_000, cfg.vocab_size, seed=0)
    it = lm_batches(stream, args.batch, args.seq, seed=0)
    train_batches = [
        {k: jnp.asarray(v) for k, v in next(it).items()} for _ in range(32)]
    eval_batches = [
        {k: jnp.asarray(v) for k, v in next(it).items()} for _ in range(4)]

    key = jax.random.PRNGKey(0)
    params0 = M.init_params(cfg, key)

    # --- PNN (sequential = Fig. 3 lifted to LMs; --parallel = Fig. 5) ------
    spec = TrainSpec(
        n_stages=args.stages, kappa=1.0,
        stages=tuple(StageSpec(steps=args.steps, lr=1e-3, optimizer="adamw")
                     for _ in range(args.stages)),
        recovery=None if args.parallel else StageSpec(
            steps=args.steps // 2, lr=2e-4, optimizer="adamw"))
    run = recipes.run_lm_parallel if args.parallel \
        else recipes.run_lm_sequential
    joined, hist = run(cfg, plan, params0, lambda i: train_batches[i % 32],
                       spec, jax.random.PRNGKey(1))
    for k in range(args.stages):
        ls = hist.column("loss", stage=k)
        print(f"  stage {k}: loss {ls[0]:.3f} -> {ls[-1]:.3f}")
    rec = hist.column("loss", phase="recovery")
    if rec:
        print(f"  recovery: loss {rec[0]:.3f} -> {rec[-1]:.3f}")
    ppl_pnn = eval_ppl(cfg, joined, eval_batches)

    # --- end-to-end baseline (same total steps) ------------------------------
    opt = make_optimizer("adamw", 1e-3)
    state = opt.init(params0)

    @jax.jit
    def step(p, st, b):
        def loss_fn(p_):
            logits, aux = M.forward(cfg, p_, b)
            loss, _ = losses.train_objective(cfg, logits, b["labels"], aux)
            return loss
        l, g = jax.value_and_grad(loss_fn)(p)
        p2, st2 = opt.update(g, st, p)
        return p2, st2, l

    pb = params0
    total = args.steps * args.stages + args.steps // 2
    for i in range(total):
        pb, state, l = step(pb, state, train_batches[i % 32])
    ppl_base = eval_ppl(cfg, pb, eval_batches)

    print(f"\nfinal eval perplexity: PNN={ppl_pnn:.1f} "
          f"baseline(e2e, same steps)={ppl_base:.1f} "
          f"(vocab={cfg.vocab_size}, random={cfg.vocab_size:.0f})")
    print("note: PNN trains each stage with only that stage's params + "
          "optimizer state resident — the paper's memory claim; see "
          "EXPERIMENTS.md §PNN-vs-MP for the measured per-chip numbers.")


if __name__ == "__main__":
    main()
