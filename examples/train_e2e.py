"""End-to-end training driver: data pipeline -> model -> optimizer ->
checkpointing -> eval, for any assigned architecture.

Default is a CPU-sized model (a few hundred steps finish in minutes);
``--params 100m --steps 300`` builds a ~100M-param decoder for the full
deliverable-scale run on real hardware.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 200]
      [--arch qwen2-1.5b] [--params tiny|100m] [--pnn]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint import restore_checkpoint, save_checkpoint  # noqa: E402
from repro.configs import get  # noqa: E402
from repro.core import losses, partition  # noqa: E402
from repro.data.lm import lm_batches, synthetic_token_stream  # noqa: E402
from repro.launch.steps import build_train_step  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.optim import cosine_warmup, make_optimizer  # noqa: E402
from repro.train import StageSpec, TrainSpec, recipes  # noqa: E402


def sized_config(arch: str, size: str):
    cfg = get(arch, smoke=True)
    if size == "100m":
        # ~100M-param decoder in the same family
        cfg = cfg.replace(n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
                          d_ff=2048, vocab_size=32768)
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--params", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="results/ckpt_e2e")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--eval-every", type=int, default=50)
    ap.add_argument("--pnn", action="store_true",
                    help="train via PNN stages instead of end-to-end")
    args = ap.parse_args()

    cfg = sized_config(args.arch, args.params)
    n_params_est = cfg.param_counts()["total"]
    print(f"arch={cfg.name} ~{n_params_est/1e6:.1f}M params "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    stream = synthetic_token_stream(2_000_000, cfg.vocab_size, seed=0)
    it = lm_batches(stream, args.batch, args.seq, seed=0)
    eval_it = lm_batches(stream, args.batch, args.seq, seed=999)
    eval_batches = [{k: jnp.asarray(v) for k, v in next(eval_it).items()}
                    for _ in range(4)]

    params = M.init_params(cfg, jax.random.PRNGKey(0))

    if args.pnn:
        plan = partition.make_plan(cfg, 2)
        spec = TrainSpec(
            n_stages=2, kappa=1.0,
            stages=tuple(StageSpec(steps=args.steps // 2, lr=args.lr,
                                   optimizer="adamw") for _ in range(2)),
            recovery=StageSpec(steps=args.steps // 4, lr=args.lr / 10,
                               optimizer="adamw"))
        t0 = time.time()
        params, hist = recipes.run_lm_sequential(
            cfg, plan, params,
            lambda i: {k: jnp.asarray(v) for k, v in next(it).items()},
            spec, jax.random.PRNGKey(1))
        print(f"PNN training done in {time.time()-t0:.0f}s; "
              f"final stage losses: "
              f"{[round(l, 3) for l in hist.column('loss')[-3:]]}")
    else:
        opt = make_optimizer("adamw", cosine_warmup(args.lr, 20, args.steps))
        state = opt.init(params)
        step_fn = jax.jit(build_train_step(cfg, opt, accum=args.accum))
        t0 = time.time()
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            params, state, metrics = step_fn(params, state, batch)
            if (i + 1) % args.eval_every == 0 or i == 0:
                ce = float(metrics["ce"])
                toks = args.batch * args.seq * (i + 1)
                print(f"step {i+1:4d}  ce={ce:.3f} "
                      f"({toks/(time.time()-t0):.0f} tok/s)")
            if (i + 1) % args.ckpt_every == 0:
                path = save_checkpoint(args.ckpt_dir, i + 1,
                                       {"params": params})
                print(f"  checkpoint -> {path}")

    # eval
    tot = 0.0
    for b in eval_batches:
        logits, _ = M.forward(cfg, params, b, remat=False)
        tot += float(losses.cross_entropy(logits, b["labels"],
                                          vocab_size=cfg.vocab_size))
    print(f"eval: ce={tot/len(eval_batches):.3f} "
          f"ppl={np.exp(tot/len(eval_batches)):.1f} "
          f"(uniform={np.log(cfg.vocab_size):.3f})")

    # restore check
    if not args.pnn and os.path.isdir(args.ckpt_dir):
        restored = restore_checkpoint(args.ckpt_dir, {"params": params})
        same = all(np.array_equal(a, b) for a, b in zip(
            jax.tree_util.tree_leaves(restored["params"]),
            jax.tree_util.tree_leaves(params)))
        print(f"checkpoint restore verified: {same}")


if __name__ == "__main__":
    main()
