"""Quickstart: the paper's experiment end-to-end in ~2 minutes on CPU.

Trains the 6-layer EMNIST classifier (784-80-60-60-60-47) two ways:
  1. conventional baseline (N_B epochs, the paper's Fig. 6 grey curve)
  2. PNN: left partition vs synthetic intermediate labels (Eq. 1), boundary
     materialization, right partition on stored activations, then the §5
     recovery phase.

Run:  PYTHONPATH=src python examples/quickstart.py [--full]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.core import pnn  # noqa: E402
from repro.data.images import load_emnist  # noqa: E402
from repro.models.mlp import MLPConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-fidelity sizes (slower)")
    args = ap.parse_args()

    cfg = MLPConfig()  # the paper's exact network, cut after layer 2
    n = 112800 if args.full else 28200
    data = load_emnist(n_train=n, n_test=4700, seed=0, noise=0.5)
    hp = pnn.PaperHP(
        n_left=5, n_right=160 if args.full else 80,
        n_baseline=40 if args.full else 20,
        n_recovery=10 if args.full else 5,
        batch_size=1410, lr=0.01, lr_right=0.003, kappa=10.0)

    print(f"== baseline ({hp.n_baseline} epochs) ==")
    _, hb = pnn.train_mlp_baseline(cfg, data, hp, jax.random.PRNGKey(0),
                                   eval_every=5)
    for m, a in zip(hb["macs"], hb["acc"]):
        print(f"  {m/1e9:8.1f} GMACs  acc={a:.3f}")

    print(f"== PNN (N_L={hp.n_left}, N_R={hp.n_right}, "
          f"kappa={hp.kappa}, recovery={hp.n_recovery}) ==")
    _, hp_hist = pnn.train_mlp_pnn(cfg, data, hp, jax.random.PRNGKey(1),
                                   eval_every=10)
    for ph, m, a in zip(hp_hist["phase"], hp_hist["macs"], hp_hist["acc"]):
        print(f"  [{ph:9s}] {m/1e9:8.1f} GMACs  acc={a:.3f}")

    print("\nsummary:")
    print(f"  baseline: acc={hb['acc'][-1]:.3f} at {hb['macs'][-1]/1e9:.0f} GMACs")
    best_within = max(a for a, m in zip(hp_hist["acc"], hp_hist["macs"])
                      if m <= hb["macs"][-1])
    print(f"  PNN     : acc={best_within:.3f} within the same MACs budget, "
          f"final {hp_hist['acc'][-1]:.3f} (after recovery)")


if __name__ == "__main__":
    main()
