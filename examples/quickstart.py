"""Quickstart: the paper's experiment end-to-end in ~2 minutes on CPU.

Trains the 6-layer EMNIST classifier (784-80-60-60-60-47) two ways through
the `repro.train` phase API:
  1. conventional baseline — phase list [BaselinePhase()]
  2. PNN (paper Fig. 3 + §5) — [SilStagePhase(0), BoundaryMaterializePhase,
     FrozenPrefixPhase(1), RecoveryPhase(0)]: left partition vs synthetic
     intermediate labels (Eq. 1), one boundary materialization, right
     partition on stored activations, recovery.

Run:  PYTHONPATH=src python examples/quickstart.py [--full]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.data.images import load_emnist  # noqa: E402
from repro.models.mlp import MLPConfig  # noqa: E402
from repro.train import recipes  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-fidelity sizes (slower)")
    args = ap.parse_args()

    cfg = MLPConfig()  # the paper's exact network, cut after layer 2
    n = 112800 if args.full else 28200
    data = load_emnist(n_train=n, n_test=4700, seed=0, noise=0.5)
    n_left, n_right = 5, 160 if args.full else 80
    n_base = 40 if args.full else 20
    n_rec = 10 if args.full else 5
    # unshuffled epoch order, as the legacy trainers ran it (the verify
    # paper-parity gate shuffles instead: it needs the momentum baseline
    # to converge rather than oscillate before judging the accuracy gap)
    spec = recipes.paper_spec(n_left=n_left, n_right=n_right,
                              n_baseline=n_base, n_recovery=n_rec,
                              shuffle=False)

    print(f"== baseline ({n_base} epochs) ==")
    _, hist_b = recipes.run_mlp_baseline(cfg, data, spec,
                                         jax.random.PRNGKey(0), eval_every=5)
    hb = hist_b.to_mlp_legacy()
    for m, a in zip(hb["macs"], hb["acc"]):
        print(f"  {m/1e9:8.1f} GMACs  acc={a:.3f}")

    print(f"== PNN (N_L={n_left}, N_R={n_right}, "
          f"kappa={spec.kappa}, recovery={n_rec}) ==")
    _, hist_p = recipes.run_mlp_fig3(cfg, data, spec, jax.random.PRNGKey(1),
                                     eval_every=10)
    hp_hist = hist_p.to_mlp_legacy()
    for ph, m, a in zip(hp_hist["phase"], hp_hist["macs"], hp_hist["acc"]):
        print(f"  [{ph:9s}] {m/1e9:8.1f} GMACs  acc={a:.3f}")

    print("\nsummary:")
    print(f"  baseline: acc={hb['acc'][-1]:.3f} at {hb['macs'][-1]/1e9:.0f} GMACs")
    best_within = max(a for a, m in zip(hp_hist["acc"], hp_hist["macs"])
                      if m <= hb["macs"][-1])
    print(f"  PNN     : acc={best_within:.3f} within the same MACs budget, "
          f"final {hp_hist['acc'][-1]:.3f} (after recovery)")


if __name__ == "__main__":
    main()
