"""Roofline reporter: turns results/dryrun.json into the §Roofline tables.

    compute_s    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory_s     = HLO_bytes / HBM_bw               (per chip)
    collective_s = collective_bytes / ICI link bw   (per chip)

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
HLO_bytes comes from XLA's cost model ("bytes accessed") and over-counts
reuse (it is op-level logical traffic, not DRAM traffic) — treat memory_s as
an upper bound; the iteration log tracks its *delta*, which is meaningful.
"""
from __future__ import annotations

import json
import sys
from typing import Dict

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ICI_BW = 50e9
COLLECTIVE_LATENCY_S = 1e-6  # per-op ICI latency floor (launch+hop)


def coll_seconds(analysis):
    """Bandwidth + per-op latency model (tiny-collective regimes are
    latency-bound; bytes/BW alone hides that)."""
    c = analysis["collectives"]
    return (c["total_bytes"] / ICI_BW
            + c["total_count"] * COLLECTIVE_LATENCY_S)


def load(path="results/dryrun.json") -> Dict:
    with open(path) as f:
        return json.load(f)


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def table(results, mesh="single", mode="baseline", variant="plain"):
    rows = []
    seen = {}
    for key, rec in results.items():
        arch, shape, m, md, var = key.split("|")
        if m != mesh or md != mode or var != variant:
            continue
        seen[(arch, shape)] = rec
    for (arch, shape), rec in sorted(seen.items(),
                                     key=lambda kv: (kv[0][0],
                                                     ORDER.index(kv[0][1]))):
        if rec["status"] == "skipped":
            rows.append([arch, shape, "skipped", "", "", "", "", "", ""])
            continue
        if rec["status"] != "ok":
            rows.append([arch, shape, "ERROR", "", "", "", "", "", ""])
            continue
        a = rec["analysis"]
        mf = rec.get("model_flops_per_chip", 0)
        ratio = rec.get("useful_flops_ratio", 0)
        cs = coll_seconds(a)
        terms = {"compute": a["compute_s"], "memory": a["memory_s"],
                 "collective": cs}
        rows.append([
            arch, shape,
            fmt_s(a["compute_s"]), fmt_s(a["memory_s"]),
            fmt_s(cs),
            max(terms, key=terms.get),
            f"{ratio:.2f}" if ratio else "-",
            f"{rec.get('params_bytes_per_chip', 0)/2**30:.2f}",
            str(a["collectives"]["total_count"]),
        ])
    return rows


def markdown(rows, title):
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "useful-FLOPs | params GiB/chip | #coll |")
    sep = "|" + "---|" * 9
    lines = [f"### {title}", "", hdr, sep]
    for r in rows:
        lines.append("| " + " | ".join(str(c) for c in r) + " |")
    return "\n".join(lines)


def pnn_table(results):
    lines = ["### PNN stage steps vs conventional baseline (train_4k, "
             "single pod)", "",
             "| arch | stage | params GiB/chip | opt GiB/chip | "
             "collective | #coll |", "|---|---|---|---|---|---|"]
    for key, rec in sorted(results.items()):
        arch, shape, m, md, var = key.split("|")
        if md != "pnn" or shape != "train_4k" or rec.get("status") != "ok":
            continue
        for st in rec.get("pnn_stages", []):
            a = st["analysis"]
            lines.append(
                f"| {arch} | {st['stage']} | "
                f"{st['stage_params_bytes_per_chip']/2**30:.2f} | "
                f"{st['stage_opt_bytes_per_chip']/2**30:.2f} | "
                f"{fmt_s(a['collective_s'])} | "
                f"{a['collectives']['total_count']} |")
    return "\n".join(lines)


def fit_table(results, mesh="single"):
    """Analytic HBM-peak fit check vs the 16 GiB v5e budget."""
    import sys as _sys
    _sys.path.insert(0, "src")
    from repro.configs import INPUT_SHAPES, get
    from repro.launch.hlo_analysis import analytic_peak_bytes_per_chip
    from repro.launch.specs import arch_for_shape
    lines = ["### HBM fit (analytic peak, v5e = 16 GiB/chip)", "",
             "| arch | shape | peak GiB/chip | fits |", "|---|---|---|---|"]
    for key, rec in sorted(results.items()):
        arch, shape, m, md, var = key.split("|")
        if m != mesh or md != "baseline" or var != "plain" \
                or rec.get("status") != "ok":
            continue
        cfg = arch_for_shape(get(arch), INPUT_SHAPES[shape])
        peak = analytic_peak_bytes_per_chip(
            cfg, INPUT_SHAPES[shape], rec["n_chips"],
            params_bytes_per_chip=rec.get("params_bytes_per_chip", 0),
            opt_bytes_per_chip=rec.get("opt_bytes_per_chip", 0),
            cache_bytes_per_chip=rec.get("cache_bytes_per_chip", 0),
            accum=rec.get("accum", 1)) / 2 ** 30
        lines.append(f"| {arch} | {shape} | {peak:.2f} | "
                     f"{'YES' if peak <= 16 else '**NO**'} |")
    return "\n".join(lines)


def precision_lines() -> str:
    """Machine-balance header: peak FLOP/s and the roofline ridge point
    (FLOP/byte where compute overtakes HBM) per compute precision.  bf16
    doubles MXU throughput AND halves activation/cache bytes, so the same
    workload sits at twice the arithmetic intensity against a ridge only 2x
    further out — the whole point of the repro.precision bf16 policy."""
    import sys as _sys
    _sys.path.insert(0, "src")
    from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16, PEAK_FLOPS_FP32
    lines = ["### Machine balance (TPU v5e, per chip)", "",
             "| precision | peak FLOP/s | HBM B/s | ridge FLOP/byte |",
             "|---|---|---|---|"]
    for name, peak in (("bf16", PEAK_FLOPS_BF16), ("fp32", PEAK_FLOPS_FP32)):
        lines.append(f"| {name} | {peak/1e12:.1f}T | {HBM_BW/1e9:.0f}G | "
                     f"{peak/HBM_BW:.0f} |")
    lines.append("")
    lines.append("(bf16 activations also halve the *bytes* side of every "
                 "memory_s term below; pair `--precision bf16` and "
                 "`--precision fp32` dry-run variants to see the delta.)")
    return "\n".join(lines)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    results = load(path)
    print(precision_lines())
    print()
    print(markdown(table(results, "single"), "Single-pod 16x16 (256 chips)"))
    print()
    print(markdown(table(results, "multi"),
                   "Multi-pod 2x16x16 (512 chips)"))
    print()
    print(pnn_table(results))
    print()
    print(fit_table(results))


if __name__ == "__main__":
    main()
