"""One benchmark per paper figure (Figures 1, 6, 7, 8, 9, 10).

Default mode is *reduced* (fewer repeats/epochs, smaller train set) so the
whole harness runs on CPU in minutes; ``--full`` restores the paper's counts
(300 retrains, 10 repeats, 112800 samples, N_R=160).

Dataset note: real EMNIST is not shipped offline; the synthetic EMNIST-like
task (repro.data.images) is used, with the right-phase/recovery learning
rates adapted for stability (see DESIGN.md §2.4 and PaperHP docstring).
Claims are validated *qualitatively* against the paper's figures and recorded
in EXPERIMENTS.md §Paper-claims.
"""
from __future__ import annotations

import time
import jax
import numpy as np

from repro.core.losses import cross_entropy
from repro.core.pnn import PaperHP
from repro.data.images import emnist_like
from repro.models import mlp as MLP
from repro.models.mlp import MLPConfig
from repro.optim import make_optimizer
from repro.train import recipes, spec_from_paper_hp


def _data(full):
    n = 112800 if full else 28200
    return emnist_like(n_train=n, n_test=4700, seed=0, noise=0.5)


def _hp(full, **kw):
    base = dict(n_left=5, n_right=160 if full else 60,
                n_baseline=40 if full else 20, batch_size=1410,
                lr=0.01, lr_right=0.003, kappa=10.0)
    base.update(kw)
    return PaperHP(**base)


def _train_baseline(cfg, data, hp, key, eval_every=1):
    _, h = recipes.run_mlp_baseline(cfg, data, spec_from_paper_hp(hp), key,
                                    eval_every=eval_every)
    return None, h.to_mlp_legacy()


def _train_pnn(cfg, data, hp, key, eval_every=1):
    """Fig. 3 (+ recovery) through the repro.train phase API."""
    _, h = recipes.run_mlp_fig3(cfg, data, spec_from_paper_hp(hp), key,
                                eval_every=eval_every)
    return None, h.to_mlp_legacy()


# -- Figure 1: weight randomness after training -----------------------------

def fig1_weight_randomness(full=False, seed=0):
    """Retrain a 3-layer (100, 50, 10) net repeatedly; histogram stats of the
    intermediate layer's max/min weight.  Claim C0: the spread stays wide
    (training does not erase init randomness)."""
    n_runs = 300 if full else 12
    epochs = 15 if full else 5
    cfg = MLPConfig(sizes=(784, 100, 50, 10), cut=1, n_classes=10)
    tx, ty, _, _ = emnist_like(n_train=11280, n_test=10, seed=seed)
    ty = ty % 10
    maxw, minw = [], []
    for r in range(n_runs):
        params = MLP.init_params(cfg, jax.random.PRNGKey(1000 + r))
        opt = make_optimizer("sgdm", 0.01, momentum=0.9)
        st = opt.init(params)

        @jax.jit
        def step(p, s_, x, y):
            def loss_fn(p_):
                return cross_entropy(MLP.forward(cfg, p_, x), y)
            l, g = jax.value_and_grad(loss_fn)(p)
            p2, s2 = opt.update(g, s_, p)
            return p2, s2, l
        for ep in range(epochs):
            for i in range(0, 11280 - 256, 256):
                params, st, _ = step(params, st, tx[i:i+256], ty[i:i+256])
        w = np.asarray(params[1]["w"])  # intermediate layer
        maxw.append(w.max())
        minw.append(w.min())
    return {
        "n_runs": n_runs,
        "max_weight_mean": float(np.mean(maxw)),
        "max_weight_std": float(np.std(maxw)),
        "min_weight_mean": float(np.mean(minw)),
        "min_weight_std": float(np.std(minw)),
        "range_mean": float(np.mean(np.array(maxw) - np.array(minw))),
        "randomness_persists": bool(np.std(maxw) > 1e-3),
    }


# -- Figure 6: PNN vs baseline accuracy-vs-MACs ------------------------------

def fig6_pnn_vs_baseline(full=False, repeats=None):
    reps = repeats or (10 if full else 3)
    data = _data(full)
    hp = _hp(full)
    accs_b, accs_p, curves = [], [], []
    for r in range(reps):
        _, hb = _train_baseline(MLPConfig(), data, hp,
                                       jax.random.PRNGKey(r), eval_every=5)
        _, hpn = _train_pnn(MLPConfig(), data, hp,
                                   jax.random.PRNGKey(100 + r),
                                   eval_every=10)
        accs_b.append(hb["acc"][-1])
        accs_p.append(hpn["acc"][-1])
        curves.append(hpn)
    return {
        "baseline_acc_mean": float(np.mean(accs_b)),
        "baseline_acc_std": float(np.std(accs_b)),
        "pnn_acc_mean": float(np.mean(accs_p)),
        "pnn_acc_std": float(np.std(accs_p)),
        "pnn_macs": curves[0]["macs"][-1],
        "baseline_macs": None,
        "comparable": bool(np.mean(accs_p) > 0.8 * np.mean(accs_b)),
    }


# -- Figure 7: effect of N_L ------------------------------------------------

def fig7_nl_sweep(full=False):
    data = _data(full)
    out = {}
    for kappa in (2.0, 10.0):
        accs = []
        for n_l in ([1, 2, 5, 10, 20] if full else [1, 3, 8]):
            # right-phase lr scaled by the kappa<->lr analogy so both kappa
            # settings train stably (boundary scale ~ kappa)
            hp = _hp(full, n_left=n_l, kappa=kappa, lr_right=0.03 / kappa)
            _, h = _train_pnn(MLPConfig(), data, hp,
                                     jax.random.PRNGKey(n_l), eval_every=1000)
            accs.append((n_l, h["acc"][-1]))
        out[f"kappa={kappa}"] = accs
    return out


# -- Figure 8: effect of kappa ----------------------------------------------

def fig8_kappa_sweep(full=False):
    data = _data(full)
    kappas = [0.1, 0.5, 1, 2, 5, 10, 20, 50, 200] if full \
        else [0.1, 1, 10, 50]
    accs = []
    for k in kappas:
        hp = _hp(full, kappa=k)
        _, h = _train_pnn(MLPConfig(), data, hp,
                                 jax.random.PRNGKey(7), eval_every=1000)
        accs.append((k, h["acc"][-1]))
    best = max(a for _, a in accs)
    lo = accs[0][1]
    return {"sweep": accs, "optimum_exists":
            bool(best > lo + 0.02 and best > accs[-1][1] - 0.05)}


# -- Figure 9: kappa <-> learning-rate equivalence ---------------------------

def fig9_kappa_lr_equivalence(full=False):
    """Paper claim C4: (kappa=10, lr=0.01) vs (kappa=1, lr=0.1) curves match
    with R^2 > 0.99 on EMNIST.

    FINDING: on the synthetic EMNIST substitute this equivalence does NOT
    reproduce (R^2 << 0) — kappa=10 makes the right phase unstable at any
    matched lr while kappa=1 + lr=0.1 trains cleanly, i.e. the analogy is
    data-dependent, not structural.  The analytic core (SIL-MSE loss, hence
    gradient scale, goes as kappa^2) IS validated in
    tests/test_property.py::test_sil_loss_scales_quadratically.  Reported
    honestly in EXPERIMENTS.md §Paper-claims."""
    data = _data(full)
    hp_a = _hp(full, kappa=10.0, lr=0.01, lr_right=None)   # paper-exact pair
    hp_b = _hp(full, kappa=1.0, lr=0.1, lr_right=None)
    _, ha = _train_pnn(MLPConfig(), data, hp_a, jax.random.PRNGKey(0),
                              eval_every=5)
    _, hb = _train_pnn(MLPConfig(), data, hp_b, jax.random.PRNGKey(0),
                              eval_every=5)
    a = np.array(ha["acc"])
    b = np.array(hb["acc"])
    n = min(len(a), len(b))
    a, b = a[:n], b[:n]
    ss_res = np.sum((a - b) ** 2)
    ss_tot = np.sum((a - np.mean(a)) ** 2) + 1e-12
    r2 = 1.0 - ss_res / ss_tot
    return {"r2": float(r2), "final_a": float(a[-1]), "final_b": float(b[-1]),
            "reproduced": bool(r2 > 0.9),
            "note": "kappa-lr analogy is data-dependent; see docstring"}


# -- Figure 10: recovery phase ----------------------------------------------

def fig10_recovery(full=False):
    data = _data(full)
    hp = _hp(full, n_recovery=10 if full else 5,
             n_right=160 if full else 100, lr_recovery=1e-4)
    _, h = _train_pnn(MLPConfig(), data, hp, jax.random.PRNGKey(0),
                             eval_every=10)
    acc_right = max(a for a, ph in zip(h["acc"], h["phase"])
                    if ph == "right")
    acc_rec = h["acc"][-1]
    return {"acc_after_right": float(acc_right),
            "acc_after_recovery": float(acc_rec),
            "recovery_improves": bool(acc_rec >= acc_right - 0.005)}


ALL_FIGURES = {
    "fig1_weight_randomness": fig1_weight_randomness,
    "fig6_pnn_vs_baseline": fig6_pnn_vs_baseline,
    "fig7_nl_sweep": fig7_nl_sweep,
    "fig8_kappa_sweep": fig8_kappa_sweep,
    "fig9_kappa_lr_equivalence": fig9_kappa_lr_equivalence,
    "fig10_recovery": fig10_recovery,
}


if __name__ == "__main__":
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="results/paper_figures.json")
    args = ap.parse_args()
    results = {}
    for name, fn in ALL_FIGURES.items():
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        results[name] = fn(full=args.full)
        results[name]["elapsed_s"] = round(time.time() - t0, 1)
        print(name, json.dumps(results[name], default=str))
    import os
    os.makedirs("results", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
