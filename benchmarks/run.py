"""Benchmark harness entry point: one function per paper figure plus the
wall-clock microbenches of the core training paths.

Prints ``name,us_per_call,derived`` CSV (one line per benchmark) and writes
the same rows — plus the fp32-vs-reduced-precision pairs — as machine-
readable JSON (``results/BENCH_4.json``, uploaded as a CI artifact so the
perf trajectory persists across PRs).  The paper figures run in reduced mode
here (minutes on CPU); ``python -m benchmarks.paper_figures --full``
reproduces the paper-fidelity versions.  Roofline tables come from ``python
-m benchmarks.roofline`` (reads the dry-run JSON).

The ``dist`` group (sequential-vs-concurrent stage ticks + per-device
bytes) needs 8 forced host devices, so it runs ``repro.dist.bench`` in a
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` —
this process keeps its single real CPU device.

Usage:
  python benchmarks/run.py [--only core,precision,dist] [--precision bf16]
      [--json results/BENCH_4.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# repo root (for `import benchmarks.*` when run as a script) + src
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def _timeit(fn, *args, reps=5, warmup=2):
    for _ in range(warmup):
        r = fn(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_figures():
    from benchmarks import paper_figures as F
    rows = []
    spec = [
        ("fig1_weight_randomness",
         lambda r: f"max_w_std={r['max_weight_std']:.4f};"
                   f"persists={r['randomness_persists']}"),
        ("fig6_pnn_vs_baseline",
         lambda r: f"pnn={r['pnn_acc_mean']:.3f}+-{r['pnn_acc_std']:.3f};"
                   f"base={r['baseline_acc_mean']:.3f}"),
        ("fig7_nl_sweep",
         lambda r: ";".join(f"k{k.split('=')[1]}:"
                            + "/".join(f"{a:.2f}" for _, a in v)
                            for k, v in r.items())),
        ("fig8_kappa_sweep",
         lambda r: "optimum=" + str(r["optimum_exists"]) + ";" + ";".join(
             f"k{k}={a:.2f}" for k, a in r["sweep"])),
        ("fig9_kappa_lr_equivalence",
         lambda r: f"r2={r['r2']:.3f}"),
        ("fig10_recovery",
         lambda r: f"right={r['acc_after_right']:.3f};"
                   f"rec={r['acc_after_recovery']:.3f};"
                   f"improves={r['recovery_improves']}"),
    ]
    for name, derive in spec:
        t0 = time.time()
        res = F.ALL_FIGURES[name](full=False)
        us = (time.time() - t0) * 1e6
        rows.append((name, us, derive(res)))
    return rows


def bench_core_paths():
    """Wall-clock per-call microbenches of the production step builders."""
    from repro.configs import get
    from repro.core import partition
    from repro.launch.steps import (build_decode_step, build_pnn_stage_step,
                                    build_prefill_step, build_train_step)
    from repro.models import model as M
    from repro.optim import make_optimizer

    rows = []
    cfg = get("qwen2-1.5b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer("adamw", 1e-3)
    state = opt.init(params)
    batch = {"tokens": jnp.ones((4, 128), jnp.int32),
             "labels": jnp.ones((4, 128), jnp.int32)}
    step = jax.jit(build_train_step(cfg, opt))
    us = _timeit(step, params, state, batch)
    toks = 4 * 128
    rows.append(("train_step_smoke", us, f"tokens_per_s={toks/us*1e6:.0f}"))

    prefill = jax.jit(build_prefill_step(cfg, cache_len=160))
    us = _timeit(prefill, params, {"tokens": batch["tokens"]})
    rows.append(("prefill_smoke", us, f"tokens_per_s={toks/us*1e6:.0f}"))

    _, cache, pos = prefill(params, {"tokens": batch["tokens"]})
    decode = jax.jit(build_decode_step(cfg))
    tok = jnp.ones((4,), jnp.int32)
    us = _timeit(decode, params, cache, tok, pos)
    rows.append(("decode_step_smoke", us, f"tokens_per_s={4/us*1e6:.0f}"))

    plan = partition.make_plan(cfg, 2)
    sp = partition.slice_stage_params(cfg, plan, params, 0)
    sopt = make_optimizer("adamw", 1e-3)
    sstate = sopt.init(sp)
    sil = jnp.ones((cfg.d_model, cfg.vocab_padded), jnp.float32)
    sstep = jax.jit(build_pnn_stage_step(cfg, plan, 0, sopt))
    us = _timeit(sstep, sp, sstate, {"tokens": batch["tokens"]},
                 batch["labels"], sil)
    rows.append(("pnn_stage0_step_smoke", us,
                 f"tokens_per_s={toks/us*1e6:.0f}"))
    return rows


def bench_train_api():
    """Scan-based epochs (repro.train) vs the legacy per-step python loop
    with a blocking float(loss) host sync — the quickstart MLP baseline
    workload.  Derived column reports steps/s for both and the speedup."""
    from repro.data.images import emnist_like
    from repro.models import mlp as MLP
    from repro.models.mlp import MLPConfig
    from repro.core import losses
    from repro.optim import make_optimizer
    from repro.train import MLPBackend, StageSpec, TrainSpec
    from repro.train.backends import scanned_epoch_fn

    cfg = MLPConfig()
    data = emnist_like(n_train=28200, n_test=470, seed=0, noise=0.5)
    tx, ty = data[0], data[1]
    epochs = 3
    spec = TrainSpec(batch_size=1410,
                     baseline=StageSpec(epochs=epochs, lr=0.01,
                                        optimizer="sgdm"))
    be = MLPBackend(cfg, data, spec)
    n_steps = be.batches_per_epoch * epochs
    params0 = MLP.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer("sgdm", 0.01, momentum=0.9)

    @jax.jit
    def step(p, s, x, y):
        def loss_fn(p_):
            return losses.cross_entropy(
                MLP.forward_range(cfg, p_, x, 0, cfg.n_layers), y)
        l, g = jax.value_and_grad(loss_fn)(p)
        p2, s2 = opt.update(g, s, p)
        return p2, s2, l

    def fresh_params():
        # per-call copy: scanned_epoch_fn donates its inputs on accelerators
        return jax.tree_util.tree_map(jnp.copy, params0)

    def legacy_loop():
        """The pre-redesign inner loop: python batches + per-step host sync."""
        params = fresh_params()
        st = opt.init(params)
        bs = spec.batch_size
        n = be.samples_per_epoch
        for ep in range(epochs):
            for i in range(0, n, bs):
                params, st, loss = step(params, st, tx[i:i + bs],
                                        ty[i:i + bs])
                float(loss)              # the old per-step host sync
        return params

    epoch_fn = scanned_epoch_fn(be.build_baseline_step(opt))
    batches = be.epoch_arrays(0, shuffle=False)

    def scan_loop():
        params = fresh_params()
        st = opt.init(params)
        for ep in range(epochs):
            params, st, _ = epoch_fn(params, st, batches)
        return params

    def timed(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        return time.perf_counter() - t0

    # interleaved min-of-reps: both loops see the same scheduler noise
    legacy_loop(), scan_loop()   # warmup/compile
    us_legacy = us_scan = float("inf")
    for _ in range(5):
        us_legacy = min(us_legacy, timed(legacy_loop) * 1e6)
        us_scan = min(us_scan, timed(scan_loop) * 1e6)
    sps_legacy = n_steps / us_legacy * 1e6
    sps_scan = n_steps / us_scan * 1e6
    return [("mlp_epoch_legacy_hostsync", us_legacy,
             f"steps_per_s={sps_legacy:.0f}"),
            ("mlp_epoch_scan_device_metrics", us_scan,
             f"steps_per_s={sps_scan:.0f};speedup={us_legacy/us_scan:.2f}x")]


def bench_serve():
    """Engine (fused scan decode, continuous batching) vs the legacy script
    loop (python per-token decode with host-side sampling) on the smoke
    config.  Derived column reports decode_toks_per_s for both."""
    import jax.numpy as jnp
    from repro.configs import get
    from repro.launch.steps import build_decode_step, build_prefill_step
    from repro.models import model as M
    from repro.serve import Engine, GenerationConfig, Request

    cfg = get("qwen2-1.5b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch, prompt_len, new_tokens = 4, 64, 32
    toks = np.random.RandomState(0).randint(
        0, cfg.vocab_size, size=(batch, prompt_len)).astype(np.int32)

    # -- legacy: the pre-engine serve.py inner loop, verbatim shape ---------
    lc = prompt_len + new_tokens
    prefill = jax.jit(build_prefill_step(cfg, cache_len=lc))
    decode = jax.jit(build_decode_step(cfg))

    def legacy():
        logits, cache, pos = prefill(params, {"tokens": jnp.asarray(toks)})
        key = jax.random.PRNGKey(0)
        tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
        for i in range(new_tokens - 1):
            key, _ = jax.random.split(key)
            logits, cache = decode(params, cache, tok, pos + i)
            tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
        return tok

    # -- engine: same requests through repro.serve --------------------------
    engine = Engine(cfg, params, max_slots=batch)
    gen = GenerationConfig(max_new_tokens=new_tokens)
    requests = [Request(tokens=toks[i], gen=gen) for i in range(batch)]

    def timed(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        return time.perf_counter() - t0

    # interleaved min-of-reps: both paths see the same scheduler noise
    legacy(), engine.generate(requests)          # warmup/compile
    us_legacy = us_engine = float("inf")
    for _ in range(5):
        us_legacy = min(us_legacy, timed(legacy) * 1e6)
        us_engine = min(us_engine, timed(lambda: engine.generate(requests))
                        * 1e6)
    n = batch * new_tokens
    rows = [("serve_decode_legacy_loop", us_legacy,
             f"decode_toks_per_s={n/us_legacy*1e6:.0f}"),
            ("serve_decode_engine", us_engine,
             f"decode_toks_per_s={n/us_engine*1e6:.0f};"
             f"speedup={us_legacy/us_engine:.2f}x")]

    # continuous batching over mixed lengths/durations (legacy loops cannot
    # express this shape at all)
    mixed = [Request(tokens=toks[i, : 16 + 16 * i],
                     gen=GenerationConfig(max_new_tokens=8 + 8 * i))
             for i in range(batch)]
    eng2 = Engine(cfg, params, max_slots=2)
    eng2.generate(mixed)                         # warmup/compile
    us_mixed = min(timed(lambda: eng2.generate(mixed)) * 1e6
                   for _ in range(3))
    nm = sum(8 + 8 * i for i in range(batch))
    rows.append(("serve_batch_mixed_2slots", us_mixed,
                 f"decode_toks_per_s={nm/us_mixed*1e6:.0f}"))
    return rows


def bench_kernels():
    from repro.kernels.flash_attention.kernel import flash_attention_tpu
    from repro.kernels.flash_attention import ref as fa_ref
    from repro.kernels.sil_mse.kernel import sil_mse_fwd_tpu
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 512, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 512, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 512, 2, 64), jnp.float32)
    us_ref = _timeit(lambda: fa_ref.chunked_attention(q, k, v), reps=3)
    rows.append(("flash_attention_jnp_ref", us_ref, "512tok_interpret_basis"))
    us_pal = _timeit(lambda: flash_attention_tpu(q, k, v), reps=1, warmup=1)
    rows.append(("flash_attention_pallas_interpret", us_pal,
                 "correctness_mode_not_perf"))
    act = jax.random.normal(ks[0], (2048, 256), jnp.float32)
    sil = jax.random.uniform(ks[1], (256, 1024)) * 10
    lab = jax.random.randint(ks[2], (2048,), 0, 1024)
    us = _timeit(lambda: sil_mse_fwd_tpu(act, sil, lab), reps=1, warmup=1)
    rows.append(("sil_mse_pallas_interpret", us, "fused_loss+grad"))
    return rows


def bench_precision(precision="bf16"):
    """fp32 vs reduced-precision pairs for the three serving/training hot
    paths (train step, prefill, decode) on the smoke config.

    The paired rows land in the BENCH json so the precision win (a ~2x
    activation/cache-bandwidth cut, structural on real accelerators) is
    tracked across PRs.  On this 2-core CPU container XLA emulates bf16
    matmuls, so wall-clock parity — not speedup — is the expected outcome
    here; the memory halving is asserted directly (cache bytes).
    """
    from repro.configs import get
    from repro.launch.steps import (build_decode_step, build_prefill_step,
                                    build_train_step)
    from repro.models import model as M
    from repro.optim import make_optimizer
    from repro.precision import get_policy, tree_bytes

    base = get("qwen2-1.5b", smoke=True)
    params = M.init_params(base, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((4, 128), jnp.int32),
             "labels": jnp.ones((4, 128), jnp.int32)}
    toks = 4 * 128
    rows, pairs = [], {}

    def run_policy(name):
        cfg = get_policy(name).apply_to_model(base)
        opt = make_optimizer("adamw", 1e-3)
        state = opt.init(params)
        step = jax.jit(build_train_step(cfg, opt))
        t_us = _timeit(step, params, state, batch)
        prefill = jax.jit(build_prefill_step(cfg, cache_len=160))
        p_us = _timeit(prefill, params, {"tokens": batch["tokens"]})
        _, cache, pos = prefill(params, {"tokens": batch["tokens"]})
        decode = jax.jit(build_decode_step(cfg))
        tok = jnp.ones((4,), jnp.int32)
        d_us = _timeit(decode, params, cache, tok, pos)
        return {"train_step": t_us, "prefill": p_us, "decode": d_us,
                "cache_bytes": int(tree_bytes(cache))}

    r32 = run_policy("fp32")
    rlo = run_policy(precision)
    for path, n_tok in (("train_step", toks), ("prefill", toks),
                        ("decode", 4)):
        tps32 = n_tok / r32[path] * 1e6
        tpslo = n_tok / rlo[path] * 1e6
        ratio = tpslo / tps32
        rows.append((f"{path}_fp32", r32[path],
                     f"tokens_per_s={tps32:.0f}"))
        rows.append((f"{path}_{precision}", rlo[path],
                     f"tokens_per_s={tpslo:.0f};vs_fp32={ratio:.2f}x"))
        pairs[path] = {"fp32_us": r32[path], f"{precision}_us": rlo[path],
                       "tokens_per_s_fp32": tps32,
                       f"tokens_per_s_{precision}": tpslo,
                       "ratio_vs_fp32": ratio}
    cache_ratio = r32["cache_bytes"] / max(rlo["cache_bytes"], 1)
    rows.append((f"kv_cache_bytes_{precision}", float(rlo["cache_bytes"]),
                 f"fp32_bytes={r32['cache_bytes']};"
                 f"reduction={cache_ratio:.2f}x"))
    pairs["kv_cache_bytes"] = {"fp32": r32["cache_bytes"],
                               precision: rlo["cache_bytes"],
                               "reduction": cache_ratio}
    return rows, pairs


def bench_dist():
    """Sequential-vs-concurrent stage ticks (repro.dist) under 8 forced
    host devices — in a subprocess, because the device count is fixed at
    first backend touch and this process must stay single-device."""
    import subprocess
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = env.get("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-m", "repro.dist.bench"],
                         capture_output=True, text=True, env=env)
    if out.returncode != 0:
        raise RuntimeError(f"repro.dist.bench failed:\n{out.stderr[-2000:]}")
    payload = json.loads(out.stdout)
    return [(r["name"], r["us"], r["derived"]) for r in payload["rows"]]


GROUPS = {
    "core": lambda a: bench_core_paths(),
    "train_api": lambda a: bench_train_api(),
    "serve": lambda a: bench_serve(),
    "kernels": lambda a: bench_kernels(),
    "figures": lambda a: bench_figures(),
    "dist": lambda a: bench_dist(),
    "precision": None,  # handled specially (also returns pairs)
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated bench groups "
                         f"({','.join(GROUPS)}); default: all")
    ap.add_argument("--precision", default="bf16",
                    choices=["bf16", "fp16"],
                    help="reduced-precision side of the precision pairs")
    ap.add_argument("--json", default="results/BENCH_4.json",
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args(argv)
    selected = list(GROUPS) if not args.only else args.only.split(",")
    for g in selected:
        if g not in GROUPS:
            raise SystemExit(f"unknown group {g!r}; choose from "
                             f"{','.join(GROUPS)}")

    all_rows, pairs = [], {}
    print("name,us_per_call,derived")
    for g in selected:
        if g == "precision":
            rows, pairs = bench_precision(args.precision)
        else:
            rows = GROUPS[g](args)
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived}", flush=True)
            all_rows.append({"name": name, "us": us, "derived": derived,
                             "group": g})

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        payload = {
            "bench_schema": 1,
            "backend": jax.default_backend(),
            "precision": args.precision,
            "groups": selected,
            "rows": all_rows,
            "precision_pairs": pairs,
            # CPU context note: bf16 matmuls are emulated on this container,
            # so the wall-clock pairs document parity; the bandwidth/memory
            # win (cache bytes halved) is the structural signal
            "note": ("ratios measured on CPU are structural-parity checks; "
                     "bf16 throughput >= fp32 is expected on TPU/GPU where "
                     "reduced precision maps to hardware"),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
