"""Checkpointing: pytree <-> npz + JSON manifest.

Arrays are gathered to host (sharded arrays included — restore re-shards via
``jax.device_put`` with the target sharding when provided).

Durability contract (repro.resilience): writes are **atomic** — both the
array archive and the manifest go through temp-file + fsync + ``os.replace``,
and the manifest (written last) is the commit record, so a crash mid-save
can never leave a checkpoint that *looks* complete.  Every leaf's CRC32 is
recorded in the manifest and verified on restore; ``restore_checkpoint`` with
``step=None`` falls back across corrupt/torn steps to the most recent
checkpoint that actually validates (``CheckpointCorruptError`` marks the
skipped ones).  ``keep_last=N`` bounds retention without ever deleting the
step just written.
"""
from __future__ import annotations

import json
import os
import re
import zlib
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.events import default_log
from repro.obs.registry import default_registry

_BF16 = jnp.bfloat16.dtype


class CheckpointCorruptError(ValueError):
    """A checkpoint step that exists on disk but does not validate
    (torn write, truncated archive/manifest, checksum mismatch).  Distinct
    from caller errors (mismatched ``like`` trees) so the fallback path
    knows which failures an older checkpoint can cure."""


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def _atomic_write(path: str, write_fn) -> None:
    """temp-file + fsync + os.replace: the file at ``path`` is either the
    old content or the complete new content, never a torn prefix."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        write_fn(f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    # best-effort directory fsync so the rename itself is durable
    try:
        dfd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
    except OSError:
        pass


def _npz_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:08d}.npz")


def _manifest_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:08d}.json")


def save_checkpoint(directory: str, step: int, tree: Any, metadata=None,
                    keep_last: Optional[int] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    # npz can't roundtrip ml_dtypes (bfloat16 etc.) — store as uint16 views
    # and record the real dtype in the manifest
    stored = {k: (v.view(np.uint16) if v.dtype == _BF16 else v)
              for k, v in arrays.items()}
    path = _npz_path(directory, step)
    _atomic_write(path, lambda f: np.savez(f, **stored))
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        # CRC32 of the stored bytes (uint16 view for bf16) per leaf —
        # restore verifies every leaf it reads against these
        "checksums": {k: zlib.crc32(v.tobytes()) for k, v in stored.items()},
        "metadata": metadata or {},
    }
    # the manifest commits the step: it is written strictly after the
    # arrays, so a crash between the two leaves a detectable torn step
    _atomic_write(_manifest_path(directory, step),
                  lambda f: f.write(json.dumps(manifest, indent=1)
                                    .encode("utf-8")))
    if keep_last:
        prune_checkpoints(directory, keep_last)
    # observability (module-level functions -> the process-wide stream)
    default_registry().counter("checkpoint_saves_total").inc()
    default_log().emit("checkpoint_save", step=step, directory=directory,
                       leaves=len(arrays))
    return path


def prune_checkpoints(directory: str, keep_last: int) -> List[int]:
    """Delete all but the newest ``keep_last`` steps; returns the pruned
    step numbers."""
    steps = available_steps(directory)
    drop = steps[:-keep_last] if keep_last > 0 else []
    for s in drop:
        for p in (_npz_path(directory, s), _manifest_path(directory, s)):
            try:
                os.remove(p)
            except FileNotFoundError:
                pass
    return drop


def _leaf_placements(flat_like, shardings):
    """Per-leaf placement targets for restore.

    ``shardings`` may be a pytree matching ``like`` (per-leaf Shardings or
    Devices), or a SINGLE ``jax.Device`` / ``jax.sharding.Sharding``
    broadcast to every leaf — the repro.dist per-stage case, where one
    device owns a stage's whole tree.  (A bare Device used to flatten into
    a one-leaf tree whose path never matched any manifest key, so
    single-device sharded restores silently failed.)"""
    if isinstance(shardings, (jax.Device, jax.sharding.Sharding)):
        return {k: shardings for k in flat_like}
    flat_shard, _ = _flatten_with_paths(shardings)
    missing = [k for k in flat_like if k not in flat_shard]
    if missing:
        raise ValueError(f"shardings tree lacks leaves for {missing[:3]}... "
                         "pass a matching pytree, or one Device/Sharding "
                         "to broadcast")
    return flat_shard


def _load_step(directory: str, like: Any, step: int, shardings: Any) -> Any:
    """Restore one specific step, validating archive + manifest + per-leaf
    checksums.  Raises ``CheckpointCorruptError`` for anything an older
    checkpoint could cure, plain ``ValueError`` for caller errors."""
    npz_path = _npz_path(directory, step)
    manifest_path = _manifest_path(directory, step)
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except FileNotFoundError:
        raise CheckpointCorruptError(
            f"missing manifest {manifest_path} (crash mid-save: arrays "
            "written, step never committed)") from None
    except json.JSONDecodeError as e:
        raise CheckpointCorruptError(
            f"corrupt/truncated manifest {manifest_path}: {e}") from None
    try:
        z = np.load(npz_path)
        files = set(z.files)
    except Exception as e:
        raise CheckpointCorruptError(
            f"corrupt/truncated checkpoint archive {npz_path}: {e}"
        ) from None
    flat_like, treedef = _flatten_with_paths(like)
    saved_keys = set(manifest.get("keys", ()))
    torn = [k for k in flat_like if k in saved_keys and k not in files]
    if torn:
        raise CheckpointCorruptError(
            f"checkpoint step {step} in {directory} archive lacks arrays "
            f"the manifest committed: {torn[:3]}")
    missing = [k for k in flat_like if k not in files]
    if missing:
        raise ValueError(
            f"checkpoint step {step} in {directory} lacks arrays for "
            f"{missing[:3]}{'...' if len(missing) > 3 else ''} "
            f"(restore `like` tree does not match the saved tree)")
    checksums = manifest.get("checksums")  # absent in pre-resilience ckpts
    leaves = []
    flat_shard = None
    if shardings is not None:
        flat_shard = _leaf_placements(flat_like, shardings)
    for key in flat_like:
        try:
            arr = z[key]
        except Exception as e:
            raise CheckpointCorruptError(
                f"corrupt array {key!r} in {npz_path}: {e}") from None
        if checksums is not None and key in checksums:
            crc = zlib.crc32(arr.tobytes())
            if crc != checksums[key]:
                raise CheckpointCorruptError(
                    f"checksum mismatch for {key!r} in {npz_path}: "
                    f"stored {checksums[key]}, read {crc}")
        if manifest["dtypes"].get(key) == "bfloat16":
            # undo the uint16 storage view BEFORE placement so the device
            # buffer carries the real dtype
            arr = arr.view(_BF16)
        if flat_shard is not None:
            arr = jax.device_put(arr, flat_shard[key])
        leaves.append(arr)
    # rebuild in treedef order: _flatten_with_paths preserves flatten order
    return jax.tree_util.tree_unflatten(treedef,
                                        [leaves[i] for i in range(len(leaves))])


def restore_latest_valid(directory: str, like: Any,
                         shardings: Any = None) -> Tuple[Any, int]:
    """``(tree, step)`` from the most recent step that VALIDATES — torn or
    corrupt steps are skipped (newest-first) until one loads cleanly.  The
    newest step's corruption error is re-raised when nothing validates."""
    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    errors: List[CheckpointCorruptError] = []
    for step in reversed(steps):
        try:
            tree = _load_step(directory, like, step, shardings)
            default_registry().counter("checkpoint_restores_total").inc()
            default_log().emit("checkpoint_restore", step=step,
                               directory=directory, skipped=len(errors))
            return tree, step
        except CheckpointCorruptError as e:
            errors.append(e)
    tail = f" ({len(errors) - 1} older step(s) also invalid)" \
        if len(errors) > 1 else ""
    raise CheckpointCorruptError(str(errors[0]) + tail) from None


def restore_checkpoint(directory: str, like: Any, step: Optional[int] = None,
                       shardings: Any = None) -> Any:
    """Restore ``like``-shaped tree.  ``step=None`` takes the most recent
    *valid* step (falling back across corrupt ones); an explicit ``step``
    is pinned — corruption there raises instead of silently substituting
    different training state."""
    if step is None:
        tree, _ = restore_latest_valid(directory, like, shardings)
        return tree
    tree = _load_step(directory, like, int(step), shardings)
    default_registry().counter("checkpoint_restores_total").inc()
    default_log().emit("checkpoint_restore", step=int(step),
                       directory=directory, skipped=0)
    return tree


def available_steps(directory: str) -> List[int]:
    """All step numbers with an array archive on disk, ascending (validity
    is judged at restore time)."""
    if not os.path.isdir(directory):
        return []
    return sorted(int(m.group(1)) for f in os.listdir(directory)
                  if (m := re.match(r"ckpt_(\d+)\.npz$", f)))


def latest_step(directory: str) -> Optional[int]:
    steps = available_steps(directory)
    return steps[-1] if steps else None
