"""Checkpointing: pytree <-> npz + JSON manifest.

Arrays are gathered to host (sharded arrays included — restore re-shards via
``jax.device_put`` with the target sharding when provided).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = jnp.bfloat16.dtype


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_checkpoint(directory: str, step: int, tree: Any, metadata=None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    # npz can't roundtrip ml_dtypes (bfloat16 etc.) — store as uint16 views
    # and record the real dtype in the manifest
    stored = {k: (v.view(np.uint16) if v.dtype == _BF16 else v)
              for k, v in arrays.items()}
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez(path, **stored)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "metadata": metadata or {},
    }
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return path


def _leaf_placements(flat_like, shardings):
    """Per-leaf placement targets for restore.

    ``shardings`` may be a pytree matching ``like`` (per-leaf Shardings or
    Devices), or a SINGLE ``jax.Device`` / ``jax.sharding.Sharding``
    broadcast to every leaf — the repro.dist per-stage case, where one
    device owns a stage's whole tree.  (A bare Device used to flatten into
    a one-leaf tree whose path never matched any manifest key, so
    single-device sharded restores silently failed.)"""
    if isinstance(shardings, (jax.Device, jax.sharding.Sharding)):
        return {k: shardings for k in flat_like}
    flat_shard, _ = _flatten_with_paths(shardings)
    missing = [k for k in flat_like if k not in flat_shard]
    if missing:
        raise ValueError(f"shardings tree lacks leaves for {missing[:3]}... "
                         "pass a matching pytree, or one Device/Sharding "
                         "to broadcast")
    return flat_shard


def restore_checkpoint(directory: str, like: Any, step: Optional[int] = None,
                       shardings: Any = None) -> Any:
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    z = np.load(os.path.join(directory, f"ckpt_{step:08d}.npz"))
    manifest_path = os.path.join(directory, f"ckpt_{step:08d}.json")
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except json.JSONDecodeError as e:
        raise ValueError(f"corrupt/truncated manifest {manifest_path}: {e}"
                         ) from None
    flat_like, treedef = _flatten_with_paths(like)
    missing = [k for k in flat_like if k not in z.files]
    if missing:
        raise ValueError(
            f"checkpoint step {step} in {directory} lacks arrays for "
            f"{missing[:3]}{'...' if len(missing) > 3 else ''} "
            f"(restore `like` tree does not match the saved tree)")
    leaves = []
    flat_shard = None
    if shardings is not None:
        flat_shard = _leaf_placements(flat_like, shardings)
    for key in flat_like:
        arr = z[key]
        if manifest["dtypes"].get(key) == "bfloat16":
            # undo the uint16 storage view BEFORE placement so the device
            # buffer carries the real dtype
            arr = arr.view(_BF16)
        if flat_shard is not None:
            arr = jax.device_put(arr, flat_shard[key])
        leaves.append(arr)
    # rebuild in treedef order: _flatten_with_paths preserves flatten order
    return jax.tree_util.tree_unflatten(treedef,
                                        [leaves[i] for i in range(len(leaves))])


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None
