from .checkpoint import (  # noqa: F401
    CheckpointCorruptError, available_steps, latest_step, prune_checkpoints,
    restore_checkpoint, restore_latest_valid, save_checkpoint)
