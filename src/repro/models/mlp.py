"""The paper's fully-connected classification network (§3).

Base network: 784 -> 80 -> 60 -> 60 -> 60 -> 47, ReLU activations except the
final (identity) layer.  Exposes layer-granular forward so core/pnn.py can cut
it at any boundary (the paper cuts after the 2nd hidden layer: left =
[784->80->60], right = [60->60->60->47]).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class MLPConfig:
    name: str = "paper_mlp"
    sizes: Tuple[int, ...] = (784, 80, 60, 60, 60, 47)  # paper §3
    cut: int = 2          # partition boundary: after hidden layer `cut`
    n_classes: int = 47

    @property
    def n_layers(self) -> int:
        return len(self.sizes) - 1

    @property
    def boundary_width(self) -> int:
        return self.sizes[self.cut]


def init_params(cfg: MLPConfig, key) -> List[dict]:
    """PyTorch-default-style init (U(-1/sqrt(fan_in), 1/sqrt(fan_in)))."""
    params = []
    keys = jax.random.split(key, cfg.n_layers)
    for i, k in enumerate(keys):
        fan_in = cfg.sizes[i]
        bound = 1.0 / math.sqrt(fan_in)
        kw, kb = jax.random.split(k)
        params.append({
            "w": jax.random.uniform(kw, (fan_in, cfg.sizes[i + 1]),
                                    jnp.float32, -bound, bound),
            "b": jax.random.uniform(kb, (cfg.sizes[i + 1],),
                                    jnp.float32, -bound, bound),
        })
    return params


def forward_range(cfg: MLPConfig, params: Sequence[dict], x, lo: int, hi: int,
                  *, final_identity: bool = True, compute_dtype=None):
    """Apply layers [lo, hi). ReLU after every layer except the network's last
    (identity, per the paper).

    compute_dtype: optional mixed-precision compute dtype (repro.precision):
    inputs and weights are cast to it at each matmul boundary while the
    stored params stay fp32.  None (default) is the paper-exact fp32 path.
    """
    if compute_dtype is not None:
        x = x.astype(compute_dtype)
    for i in range(lo, hi):
        w, b = params[i - lo]["w"], params[i - lo]["b"]
        if compute_dtype is not None:
            w, b = w.astype(compute_dtype), b.astype(compute_dtype)
        x = x @ w + b
        if i < cfg.n_layers - 1 or not final_identity:
            x = jax.nn.relu(x)
    return x


def forward(cfg: MLPConfig, params, x):
    return forward_range(cfg, params, x, 0, cfg.n_layers)


def macs(cfg: MLPConfig, lo: int = 0, hi: int = None) -> int:
    """Multiply-accumulate ops per sample for layers [lo, hi) — paper's cost
    unit (matches their ptflops accounting: weights + biases)."""
    hi = cfg.n_layers if hi is None else hi
    return sum(cfg.sizes[i] * cfg.sizes[i + 1] + cfg.sizes[i + 1]
               for i in range(lo, hi))
