"""Neural network blocks (pure-functional, params-as-pytrees).

Covers every block family the assigned architectures need:

* norms (RMSNorm / LayerNorm)
* GQA attention with (partial) RoPE, optional QKV bias, sliding window,
  KV-cache decode, and cross-attention (whisper)
* SwiGLU / GELU MLPs
* token-choice MoE with capacity-based gather/scatter dispatch (GShard-style
  capacity, but gather-based so dispatch FLOPs stay proportional to expert
  compute rather than T*E*C*d einsums)
* Mamba-1 selective-SSM block (chunked scan; see kernels/selective_scan)
* xLSTM blocks: chunkwise mLSTM (bounded sigmoid gating — see DESIGN.md for
  the deviation from exponential gating) and recurrent sLSTM (exponential
  gating with the max-stabilizer)

All `apply` functions are shape-polymorphic over batch/seq and jit-safe.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import (flash_attention, decode_attention,
                                           paged_decode_attention)
from repro.kernels.selective_scan import selective_scan, selective_scan_step


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def _dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype, bias=False, scale=None):
    p = {"w": _dense_init(key, d_in, d_out, dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    """Promote-at-boundary matmul: the weight is cast to the activation's
    (compute) dtype right at the op — params keep their storage dtype, the
    cast is never persisted (repro.precision policy contract)."""
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def residual_add(x, out):
    """Residual adds accumulate in fp32 and round once back to the compute
    dtype (PrecisionPolicy.accum_dtype contract).  For a single binary add
    this matches hardware behavior bit-for-bit; it guards the chained
    attention+cross+ffn adds against double rounding under bf16/fp16."""
    if x.dtype == jnp.float32:
        return x + out
    return (x.astype(jnp.float32) + out.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def norm_init(kind, d, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE (supports partial rotary fraction, e.g. chatglm3 / stablelm)
# --------------------------------------------------------------------------

def rope_dim(head_dim: int, fraction: float) -> int:
    r = int(head_dim * fraction)
    return max(2, r - (r % 2))


def rope_tables(positions, head_dim, fraction, theta):
    """positions: (S,) int -> cos/sin tables (S, rot/2)."""
    rot = rope_dim(head_dim, fraction)
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def rope_apply(x, cos, sin, *, per_batch=False):
    """x: (B, S, H, D); cos/sin: (S, rot/2), or (B, rot/2) with
    per_batch=True (ragged decode: one position per request, S == 1).
    Rotates the first `rot` dims."""
    rot2 = cos.shape[-1]
    xr, xp = x[..., : 2 * rot2], x[..., 2 * rot2:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    if per_batch:
        c = cos[:, None, None, :].astype(jnp.float32)
        s = sin[:, None, None, :].astype(jnp.float32)
    else:
        c = cos[None, :, None, :].astype(jnp.float32)
        s = sin[None, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = x1f * c - x2f * s
    o2 = x2f * c + x1f * s
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if xp.shape[-1] else out


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def attention_init(key, cfg, dtype, cross=False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, h * hd, dtype, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, kv * hd, dtype, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, kv * hd, dtype, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], h * hd, d, dtype,
                         scale=1.0 / math.sqrt(h * hd * max(cfg.n_layers, 1))),
    }


def _split_heads(x, n):
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1)


def attention_apply(p, x, cfg, *, rope_cs=None, causal=True, window=0,
                    kv_override=None):
    """Full-sequence attention (train / prefill / encoder / cross).

    kv_override: (keys_src,) — cross-attention attends to this sequence
    (non-causal) instead of x.
    Returns (out, (k, v)) so callers can build caches.
    """
    h, kv = cfg.n_heads, cfg.n_kv_heads
    q = _split_heads(dense(p["wq"], x), h)
    src = kv_override if kv_override is not None else x
    k = _split_heads(dense(p["wk"], src), kv)
    v = _split_heads(dense(p["wv"], src), kv)
    if rope_cs is not None and kv_override is None:
        cos, sin = rope_cs
        q = rope_apply(q, cos, sin)
        k = rope_apply(k, cos, sin)
    if cfg.context_sharding is not None and kv_override is None:
        # sequence-parallel attention: Q (and the per-token output) stay
        # seq-sharded over the model axis; only the (narrow, GQA) K/V get
        # gathered.  Pure sharding hints — the math is unchanged.
        from jax.sharding import PartitionSpec as P
        ent = cfg.context_sharding
        bent = ent if len(ent) > 1 else ent[0]
        q = jax.lax.with_sharding_constraint(q, P(bent, "model", None, None))
        k = jax.lax.with_sharding_constraint(k, P(bent, None, None, None))
        v = jax.lax.with_sharding_constraint(v, P(bent, None, None, None))
    out = flash_attention(q, k, v, causal=causal and kv_override is None,
                          window=window)
    if cfg.context_sharding is not None and kv_override is None:
        from jax.sharding import PartitionSpec as P
        ent = cfg.context_sharding
        bent = ent if len(ent) > 1 else ent[0]
        out = jax.lax.with_sharding_constraint(
            out, P(bent, "model", None, None))
    return dense(p["wo"], out.reshape(*x.shape[:2], -1)), (k, v)


def attention_decode(p, x, cfg, cache_kv, pos, *, rope_cs=None, window=0,
                     cross_kv=None, paged=None):
    """One-token decode. x: (B,1,d). cache_kv: (k,v) each (B,Lc,KV,hd) —
    or, when ``paged`` is set, physical block pools (NB,BS,KV,hd).

    pos: scalar int32 OR per-request (B,) vector (ragged batches — each
    request writes its own cache slot and masks its own history).
    paged: optional ``(block_tables, logical_len)`` — block_tables (B,nb)
    int32, logical_len the static logical cache length (the ring modulus
    when window>0; free/pad table entries point at the garbage block, which
    is written but never read thanks to the ``slot < logical_len`` mask).
    Returns (out, new_cache_kv). For cross attention pass cross_kv
    (precomputed encoder k/v) and cache_kv=None.
    """
    h, kv = cfg.n_heads, cfg.n_kv_heads
    b = x.shape[0]
    q = _split_heads(dense(p["wq"], x), h)
    if cross_kv is not None:
        ck, cv = cross_kv
        out = decode_attention(q, ck, cv, ck.shape[1] - 1)  # all slots valid
        return dense(p["wo"], out.reshape(*x.shape[:2], -1)), None
    k = _split_heads(dense(p["wk"], x), kv)
    v = _split_heads(dense(p["wv"], x), kv)
    if rope_cs is not None:
        cos, sin = rope_cs  # tables for the current position(s)
        per_batch = cos.ndim == 2 and cos.shape[0] == b and jnp.ndim(pos) == 1
        q = rope_apply(q, cos, sin, per_batch=per_batch)
        k = rope_apply(k, cos, sin, per_batch=per_batch)
    kc, vc = cache_kv
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (b,))
    if paged is not None:
        bt, lc = paged
        bs = kc.shape[1]
        slot = (pos_b % lc) if window else jnp.minimum(pos_b, lc - 1)
        phys = bt[jnp.arange(b), slot // bs]
        off = slot % bs
        kc = kc.at[phys, off].set(k[:, 0].astype(kc.dtype))
        vc = vc.at[phys, off].set(v[:, 0].astype(vc.dtype))
        out = paged_decode_attention(q, kc, vc, bt, pos,
                                     logical_len=lc, window=window)
        return dense(p["wo"], out.reshape(*x.shape[:2], -1)), (kc, vc)
    lc = kc.shape[1]
    slot = (pos_b % lc) if window else jnp.minimum(pos_b, lc - 1)
    kc = kc.at[jnp.arange(b), slot].set(k[:, 0].astype(kc.dtype))
    vc = vc.at[jnp.arange(b), slot].set(v[:, 0].astype(vc.dtype))
    out = decode_attention(q, kc, vc, pos, window=window)
    return dense(p["wo"], out.reshape(*x.shape[:2], -1)), (kc, vc)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def mlp_init(key, d, ff, kind, dtype):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"wg": dense_init(ks[0], d, ff, dtype),
                "wu": dense_init(ks[1], d, ff, dtype),
                "wd": dense_init(ks[2], ff, d, dtype)}
    return {"w1": dense_init(ks[0], d, ff, dtype, bias=True),
            "w2": dense_init(ks[1], ff, d, dtype, bias=True)}


def mlp_apply(p, x):
    if "wg" in p:
        return dense(p["wd"], jax.nn.silu(dense(p["wg"], x)) * dense(p["wu"], x))
    return dense(p["w2"], jax.nn.gelu(dense(p["w1"], x)))


# --------------------------------------------------------------------------
# MoE (token-choice top-k, capacity-bounded, gather/scatter dispatch)
# --------------------------------------------------------------------------

def moe_init(key, cfg, dtype):
    d, ff, m = cfg.d_model, cfg.d_ff, cfg.moe
    ks = jax.random.split(key, 4)
    e = m.num_experts
    p = {"router": _dense_init(ks[0], d, e, jnp.float32)}
    if cfg.mlp_type == "swiglu":
        p["wg"] = jax.random.normal(ks[1], (e, d, ff), jnp.float32).astype(dtype) / math.sqrt(d)
        p["wu"] = jax.random.normal(ks[2], (e, d, ff), jnp.float32).astype(dtype) / math.sqrt(d)
        p["wd"] = jax.random.normal(ks[3], (e, ff, d), jnp.float32).astype(dtype) / math.sqrt(ff)
    else:
        p["w1"] = jax.random.normal(ks[1], (e, d, ff), jnp.float32).astype(dtype) / math.sqrt(d)
        p["w2"] = jax.random.normal(ks[2], (e, ff, d), jnp.float32).astype(dtype) / math.sqrt(ff)
    return p


def moe_capacity(tokens: int, moe_cfg) -> int:
    c = math.ceil(moe_cfg.capacity_factor * tokens * moe_cfg.top_k
                  / moe_cfg.num_experts)
    return max(8, c + (-c) % 8)


def _gather_expert_weights(p, gather: bool):
    """Constrain expert weights to (data-)gathered form before the matmuls.

    With FSDP sharding the contracted d dim, every expert matmul psums its
    (E, C, ff) hidden activations — far larger than the weights themselves
    (EXPERIMENTS.md §Perf, grok iteration).  Gathering the weight shard
    (keeping the ff model-shard: ~hundreds of MB transient) replaces TBs of
    activation all-reduces with GBs of weight all-gathers.
    """
    if not gather:
        return p
    from jax.sharding import PartitionSpec as P
    try:
        out = dict(p)
        for k in ("wg", "wu", "w1"):
            if k in out:
                out[k] = jax.lax.with_sharding_constraint(
                    out[k], P(None, None, "model"))
        for k in ("wd", "w2"):
            if k in out:
                out[k] = jax.lax.with_sharding_constraint(
                    out[k], P(None, "model", None))
        return out
    except Exception:  # no mesh context (single-device tests): no-op
        return p


def _moe_dispatch_one(p, xt, moe_cfg, c):
    """Token-choice dispatch+compute+combine for one token group.

    xt: (T, d).  Returns (out (T, d) fp32, lb_loss, z_loss).
    """
    t, d = xt.shape
    e, k = moe_cfg.num_experts, moe_cfg.top_k
    logits = (xt.astype(jnp.float32) @ p["router"])  # (T, E) fp32 routing
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, k)                      # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = eid.reshape(-1)                                  # (T*K,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < c
    flat_t = jnp.repeat(jnp.arange(t), k)
    # scatter token ids into (E, C) slots; kicked-out tokens -> slot C (drop)
    slot_tok = jnp.full((e, c), t, dtype=jnp.int32)
    slot_tok = slot_tok.at[flat_e, jnp.where(keep, pos_in_e, c)].set(
        flat_t, mode="drop")
    x_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    ein = x_pad[slot_tok]                                     # (E, C, d)

    if "wg" in p:
        hgate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ein,
                                       p["wg"].astype(ein.dtype)))
        hup = jnp.einsum("ecd,edf->ecf", ein, p["wu"].astype(ein.dtype))
        eout = jnp.einsum("ecf,efd->ecd", hgate * hup,
                          p["wd"].astype(ein.dtype))
    else:
        hmid = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", ein,
                                      p["w1"].astype(ein.dtype)))
        eout = jnp.einsum("ecf,efd->ecd", hmid, p["w2"].astype(ein.dtype))

    # combine back: each (t, k) reads its slot (if kept) weighted by its gate
    safe_pos = jnp.minimum(pos_in_e, c - 1)
    out_flat = eout[flat_e, safe_pos]                         # (T*K, d)
    w = (keep.astype(jnp.float32) * gate.reshape(-1))[:, None]
    out = (out_flat.astype(jnp.float32) * w).reshape(t, k, d).sum(axis=1)

    # aux losses (switch-style load balance + router z-loss)
    me = probs.mean(axis=0)                                   # (E,)
    ce = onehot.reshape(t, k, e).sum(axis=1).astype(jnp.float32).mean(axis=0)
    lb = e * jnp.sum(me * ce) / k
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out, lb, z


def moe_apply(p, x, moe_cfg, *, capacity=None, groups: int = 1,
              gather_weights: bool = False):
    """x: (B, S, d) -> (out, aux) with aux = {lb_loss, z_loss}.

    Gather/scatter dispatch: tokens routed to (expert, slot) pairs bounded by
    `capacity`; overflow tokens are dropped (standard token-choice MoE).

    groups > 1 ("locality-grouped dispatch", EXPERIMENTS.md §Perf): tokens
    are split into `groups` independent dispatch groups with per-group
    capacity.  When `groups` equals the data-parallel shard count and the
    group dim is sharded over it, every cumsum/scatter/gather in the dispatch
    is chip-local — GSPMD no longer gathers all tokens to every chip.
    Per-group capacity is how production MoE systems bound hotspots anyway.
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    p = _gather_expert_weights(p, gather_weights)
    if groups > 1 and t % groups == 0:
        tg = t // groups
        cg = capacity if capacity is not None else moe_capacity(tg, moe_cfg)
        out, lb, z = jax.vmap(
            lambda xg: _moe_dispatch_one(p, xg, moe_cfg, cg))(
                xt.reshape(groups, tg, d))
        out = out.reshape(t, d)
        lb, z = lb.mean(), z.mean()
    else:
        c = capacity if capacity is not None else moe_capacity(t, moe_cfg)
        out, lb, z = _moe_dispatch_one(p, xt, moe_cfg, c)
    aux = {"lb_loss": lb, "z_loss": z}
    return out.reshape(b, s, d).astype(x.dtype), aux


# --------------------------------------------------------------------------
# Mamba-1 block
# --------------------------------------------------------------------------

def mamba_dims(cfg):
    ssm = cfg.ssm
    d_in = ssm.expand * cfg.d_model
    dt_rank = ssm.dt_rank or -(-cfg.d_model // 16)
    return d_in, dt_rank, ssm.d_state, ssm.d_conv


def mamba_init(key, cfg, dtype):
    d = cfg.d_model
    d_in, dt_rank, n, d_conv = mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (d_in, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_in), jnp.float32)
                   / math.sqrt(d_conv)).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(ks[2], d_in, dt_rank + 2 * n, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_in, dtype, bias=True,
                              scale=dt_rank ** -0.5),
        "A_log": jnp.log(a),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], d_in, d, dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,Di), w: (K,Di)."""
    k = w.shape[0]
    w = w.astype(x.dtype)
    out = jnp.zeros_like(x)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi * w[i][None, None]
    return out + b.astype(x.dtype)[None, None]


def mamba_apply(p, x, cfg, *, state=None):
    """Full-sequence mamba. x: (B,S,d). Returns (out, final_state).

    final_state = (conv_state (B, K-1, Di), ssm_state (B, Di, N)).
    """
    d_in, dt_rank, n, d_conv = mamba_dims(cfg)
    xz = dense(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)
    h0 = None
    if state is not None:
        conv_st, h0 = state
        xi_ext = jnp.concatenate([conv_st.astype(xi.dtype), xi], axis=1)
    else:
        xi_ext = xi
    xc = _causal_conv(xi_ext, p["conv_w"], p["conv_b"])[:, -xi.shape[1]:]
    xc = jax.nn.silu(xc)
    xdb = dense(p["x_proj"], xc)
    dt_r, bmat, cmat = jnp.split(xdb, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(dense(p["dt_proj"], dt_r).astype(jnp.float32))
    a = -jnp.exp(p["A_log"])
    y, h_last = selective_scan(xc, dt, a, bmat.astype(jnp.float32),
                               cmat.astype(jnp.float32), p["D"], h0=h0)
    y = y * jax.nn.silu(z)
    out = dense(p["out_proj"], y)
    # next conv state = last (d_conv - 1) raw inputs (front-padded for short S)
    padded = jnp.concatenate(
        [jnp.zeros((xi.shape[0], d_conv - 1, d_in), xi.dtype), xi_ext], axis=1)
    new_conv = padded[:, -(d_conv - 1):]
    return out, (new_conv, h_last)


def mamba_decode(p, x, cfg, state):
    """One-token decode. x: (B,1,d); state from mamba_apply/init_cache."""
    d_in, dt_rank, n, d_conv = mamba_dims(cfg)
    conv_st, h = state  # (B, K-1, Di), (B, Di, N)
    xz = dense(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)          # (B,1,Di)
    window = jnp.concatenate([conv_st.astype(xi.dtype), xi], axis=1)  # (B,K,Di)
    xc = jnp.einsum("bkd,kd->bd", window, p["conv_w"].astype(xi.dtype)) \
        + p["conv_b"].astype(xi.dtype)[None]
    xc = jax.nn.silu(xc)                        # (B, Di)
    xdb = xc @ p["x_proj"]["w"].astype(xc.dtype)
    dt_r, bvec, cvec = jnp.split(xdb, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        (dt_r @ p["dt_proj"]["w"].astype(xc.dtype)
         + p["dt_proj"]["b"].astype(xc.dtype)).astype(jnp.float32))
    a = -jnp.exp(p["A_log"])
    y, h_new = selective_scan_step(xc.astype(jnp.float32), dt, a,
                                   bvec.astype(jnp.float32),
                                   cvec.astype(jnp.float32), p["D"], h)
    y = (y[:, None] * jax.nn.silu(z)).astype(x.dtype)
    out = dense(p["out_proj"], y)
    new_conv = window[:, 1:]
    return out, (new_conv, h_new)


def _pin_batch(cfg, x, batch_dim=0):
    """Pin a recurrent tensor to batch-only sharding (perf knob; see
    ModelConfig.recurrent_sharding).  No-op when the knob is unset."""
    if cfg.recurrent_sharding is None:
        return x
    from jax.sharding import PartitionSpec as P
    ent = cfg.recurrent_sharding
    ent = ent if len(ent) > 1 else ent[0]
    spec = [None] * x.ndim
    spec[batch_dim] = ent
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _pin_tree(cfg, tree, batch_dim=0):
    return jax.tree_util.tree_map(
        lambda t: _pin_batch(cfg, t, batch_dim), tree)


# --------------------------------------------------------------------------
# xLSTM blocks
# --------------------------------------------------------------------------

def mlstm_init(key, cfg, dtype):
    d = cfg.d_model
    x = cfg.xlstm
    d_up = int(x.proj_factor * d)
    ks = jax.random.split(key, 7)
    return {
        "up": dense_init(ks[0], d, 2 * d_up, dtype),
        "wq": dense_init(ks[1], d_up, d_up, dtype),
        "wk": dense_init(ks[2], d_up, d_up, dtype),
        "wv": dense_init(ks[3], d_up, d_up, dtype),
        "w_i": dense_init(ks[4], d, cfg.n_heads, jnp.float32, bias=True),
        "w_f": dense_init(ks[5], d, cfg.n_heads, jnp.float32, bias=True),
        "down": dense_init(ks[6], d_up, d, dtype),
    }


def _mlstm_chunk(q, k, v, i_g, f_g, state, nstate):
    """One chunk of the gated-linear-attention recurrence.

    q,k,v: (B,c,H,dh); i_g,f_g: (B,c,H) in (0,1);
    state: (B,H,dh,dh); nstate: (B,H,dh). Returns (h, state', nstate').
    """
    logf = jnp.log(f_g + 1e-9)
    cf = jnp.cumsum(logf, axis=1)                      # (B,c,H)
    # inter-chunk: decay from chunk start
    dec0 = jnp.exp(cf)                                 # (B,c,H)
    h_inter = jnp.einsum("bchd,bhde->bche", q * dec0[..., None], state)
    n_inter = jnp.einsum("bchd,bhd->bch", q * dec0[..., None], nstate)
    # intra-chunk
    c = q.shape[1]
    rel = cf[:, :, None] - cf[:, None, :]              # (B,c_t,c_j,H)
    mask = (jnp.arange(c)[:, None] >= jnp.arange(c)[None, :])
    # mask BEFORE exp: exp of masked (positive) entries would overflow and
    # poison the backward pass with 0 * inf = NaN
    rel = jnp.where(mask[None, :, :, None], rel, -jnp.inf)
    w = jnp.exp(rel)
    w = w * i_g[:, None, :, :]                         # gate at source j
    s = jnp.einsum("bthd,bjhd->btjh", q, k)            # (B,c,c,H)
    sw = s * w
    h_intra = jnp.einsum("btjh,bjhd->bthd", sw, v)
    n_intra = jnp.einsum("btjh->bth", sw)              # sum of weights
    h = h_inter + h_intra
    n = n_inter + n_intra
    denom = jnp.maximum(jnp.abs(n), 1.0)[..., None]
    h = h / denom
    # state update to end of chunk
    decT = jnp.exp(cf[:, -1])                          # (B,H) total decay
    src_dec = jnp.exp(cf[:, -1:, :] - cf)              # (B,c,H) decay j->end
    kv = jnp.einsum("bchd,bche->bhde", k * (i_g * src_dec)[..., None], v)
    state = state * decT[:, :, None, None] + kv
    nstate = nstate * decT[:, :, None] + \
        jnp.einsum("bchd->bhd", k * (i_g * src_dec)[..., None])
    return h, state, nstate


def mlstm_apply(p, x, cfg, *, state=None):
    """Chunkwise mLSTM. x: (B,S,d) -> (out, (C_state, n_state))."""
    b, s, d = x.shape
    hn = cfg.n_heads
    xc = cfg.xlstm
    up = dense(p["up"], x)
    xin, z = jnp.split(up, 2, axis=-1)                 # (B,S,d_up)
    d_up = xin.shape[-1]
    dh = d_up // hn
    q = dense(p["wq"], xin).reshape(b, s, hn, dh) * dh ** -0.5
    k = dense(p["wk"], xin).reshape(b, s, hn, dh) * dh ** -0.5
    v = dense(p["wv"], xin).reshape(b, s, hn, dh)
    i_g = jax.nn.sigmoid(dense(p["w_i"], x.astype(jnp.float32)))
    f_g = jax.nn.sigmoid(dense(p["w_f"], x.astype(jnp.float32)))

    chunk = min(xc.chunk_size, s)
    pad = (-s) % chunk

    def padseq(t, value=0.0):
        if not pad:
            return t
        widths = ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2)
        return jnp.pad(t, widths, constant_values=value)

    # padded steps: f=1 (no decay), i=0 (no write) — state unaffected
    qp = padseq(q).astype(jnp.float32)
    kp = padseq(k).astype(jnp.float32)
    vp = padseq(v).astype(jnp.float32)
    ip = padseq(i_g, 0.0)
    fp = padseq(f_g, 1.0)
    nc = (s + pad) // chunk

    def chunk_fold(t):
        folded = t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
        return _pin_batch(cfg, folded, 1)  # (nc, B, c, ...): batch dim 1

    if state is None:
        st = jnp.zeros((b, hn, dh, dh), jnp.float32)
        nst = jnp.zeros((b, hn, dh), jnp.float32)
    else:
        st, nst = state
    st = _pin_batch(cfg, st, 0)
    nst = _pin_batch(cfg, nst, 0)

    def body(carry, xs):
        st, nst = carry
        qc, kc, vc, ic, fc = xs
        h, st, nst = _mlstm_chunk(qc, kc, vc, ic, fc, st, nst)
        return (_pin_batch(cfg, st, 0), _pin_batch(cfg, nst, 0)), h

    (st, nst), hs = jax.lax.scan(
        body, (st, nst), (chunk_fold(qp), chunk_fold(kp), chunk_fold(vp),
                          chunk_fold(ip), chunk_fold(fp)))
    h = hs.swapaxes(0, 1).reshape(b, s + pad, hn, dh)[:, :s]
    h = h.reshape(b, s, d_up).astype(x.dtype)
    out = dense(p["down"], h * jax.nn.sigmoid(z))
    return out, (st, nst)


def mlstm_decode(p, x, cfg, state):
    """One-token mLSTM decode via the same chunk math with c=1."""
    out, new_state = mlstm_apply(p, x, cfg, state=state)
    return out, new_state


def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    hn = cfg.n_heads
    dh = d // hn
    ks = jax.random.split(key, 3)
    return {
        "w_in": dense_init(ks[0], d, 4 * d, dtype, bias=True),
        # block-diagonal recurrent weights, one (dh, 4dh) block per head
        "r": (jax.random.normal(ks[1], (hn, dh, 4 * dh), jnp.float32)
              / math.sqrt(dh)).astype(dtype),
        "out": dense_init(ks[2], d, d, dtype),
    }


def _slstm_step(p, cfg, xt, state):
    """xt: (B, 4d) pre-projected input; state: (h, c, n, m) each (B, d)."""
    hn = cfg.n_heads
    b = xt.shape[0]
    d = xt.shape[-1] // 4
    dh = d // hn
    h, c, n, m = state
    hr = h.reshape(b, hn, dh)
    rec = jnp.einsum("bhd,hde->bhe", hr.astype(jnp.float32),
                     p["r"].astype(jnp.float32)).reshape(b, 4 * d)
    zifo = xt.astype(jnp.float32) + rec
    z_t, i_t, f_t, o_t = jnp.split(zifo, 4, axis=-1)
    z_t = jnp.tanh(z_t)
    o_t = jax.nn.sigmoid(o_t)
    m_new = jnp.maximum(f_t + m, i_t)          # log-space stabilizer
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(f_t + m - m_new)
    c_new = f_p * c + i_p * z_t
    n_new = f_p * n + i_p
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, c_new, n_new, m_new


def slstm_apply(p, x, cfg, *, state=None):
    """Recurrent sLSTM over the sequence. x: (B,S,d) -> (out, state).

    Chunked: the lax.scan iterates over chunks of `cfg.xlstm.chunk_size`
    timesteps with the inner steps unrolled — 64x fewer loop iterations means
    64x fewer per-iteration gradient all-reduces for the (replicated)
    recurrent weights, and better TPU loop overhead.
    """
    b, s, d = x.shape
    xin = dense(p["w_in"], x)                   # (B,S,4d)
    if state is None:
        z = jnp.zeros((b, d), jnp.float32)
        state = (z, z, z, jnp.full((b, d), -1e9, jnp.float32))
    state = _pin_tree(cfg, state, 0)
    xin = _pin_batch(cfg, xin, 0)

    # NOTE (§Perf, refuted hypothesis): unrolling 64-step chunks inside the
    # scan body converts the per-step gradient all-reduces into the same
    # volume of all-to-alls (no byte win) and inflates compile time ~10x —
    # reverted to the per-step scan.  See EXPERIMENTS.md §Perf iteration 2.
    def body(st, xt):
        st = _slstm_step(p, cfg, xt, st)
        return _pin_tree(cfg, st, 0), st[0]

    state, hs = jax.lax.scan(body, state, xin.swapaxes(0, 1))
    out = dense(p["out"], hs.swapaxes(0, 1).astype(x.dtype))
    return out, state


def slstm_decode(p, x, cfg, state):
    xin = dense(p["w_in"], x)[:, 0]
    st = _slstm_step(p, cfg, xin, state)
    out = dense(p["out"], st[0][:, None].astype(x.dtype))
    return out, st
