"""Model assembly: scan-over-layer-groups transformers for every assigned family.

Layers are stacked into *groups* — the smallest repeating pattern of block
kinds (1 for uniform dense/MoE stacks, 2 for xLSTM 'ms', 8 for jamba's
attn:mamba 1:7 interleave) — and the stack of groups is driven by
``jax.lax.scan`` so compile time is independent of depth (88-layer models
lower as fast as 2-layer ones).

PNN stages (core/partition.py) cut the model at *group* boundaries: stage k
runs groups [g_k, g_{k+1}).  Stage 0 owns the embedding (+ encoder/frontend),
the last stage owns the final norm + unembedding.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


# --------------------------------------------------------------------------
# group structure
# --------------------------------------------------------------------------

def group_size(cfg: ModelConfig) -> int:
    """Smallest g dividing n_layers such that (kind, is_moe) repeats mod g."""
    pattern = [(cfg.block_kind(l), cfg.layer_is_moe(l)) for l in range(cfg.n_layers)]
    for g in range(1, cfg.n_layers + 1):
        if cfg.n_layers % g:
            continue
        if all(pattern[l] == pattern[l % g] for l in range(cfg.n_layers)):
            return g
    return cfg.n_layers


def slot_spec(cfg: ModelConfig):
    """[(kind, is_moe, has_ffn)] for each slot inside a group."""
    g = group_size(cfg)
    out = []
    for l in range(g):
        kind = cfg.block_kind(l)
        has_ffn = kind in ("attn", "mamba") and cfg.d_ff > 0
        out.append((kind, cfg.layer_is_moe(l) and has_ffn, has_ffn))
    return out


def n_groups(cfg: ModelConfig) -> int:
    return cfg.n_layers // group_size(cfg)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _slot_init(key, cfg, kind, is_moe, has_ffn, dtype, cross=False):
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": L.norm_init(cfg.norm, cfg.d_model, dtype)}
    if kind == "attn":
        p["attn"] = L.attention_init(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = L.mamba_init(ks[0], cfg, dtype)
    elif kind == "mlstm":
        p["mlstm"] = L.mlstm_init(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["slstm"] = L.slstm_init(ks[0], cfg, dtype)
    if cross:
        p["norm_x"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
        p["cross"] = L.attention_init(ks[1], cfg, dtype)
    if has_ffn:
        p["norm2"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
        if is_moe:
            p["moe"] = L.moe_init(ks[2], cfg, dtype)
        else:
            p["mlp"] = L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    return p


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    slots = slot_spec(cfg)
    g = n_groups(cfg)

    def stack_groups(base_key):
        gkeys = jax.random.split(base_key, g)

        def one_group(k):
            sk = jax.random.split(k, len(slots))
            return {
                f"slot_{i}": _slot_init(sk[i], cfg, kind, is_moe, has_ffn, dtype,
                                        cross=cfg.enc_dec)
                for i, (kind, is_moe, has_ffn) in enumerate(slots)
            }
        return jax.vmap(one_group)(gkeys)

    params: Dict[str, Any] = {
        "tok_embed": (jax.random.normal(keys[0],
                                        (cfg.vocab_padded, cfg.d_model),
                                        jnp.float32) * 0.02).astype(dtype),
        "final_norm": L.norm_init(cfg.norm, cfg.d_model, dtype),
        "groups": stack_groups(keys[1]),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(
            keys[2], (cfg.d_model, cfg.vocab_padded), jnp.float32)
            / math.sqrt(cfg.d_model)).astype(dtype)
    if cfg.enc_dec:
        ekeys = jax.random.split(keys[3], cfg.enc_layers)

        def enc_group(k):
            return {"slot_0": _slot_init(k, cfg, "attn", False, cfg.d_ff > 0,
                                         dtype, cross=False)}
        params["encoder"] = jax.vmap(enc_group)(ekeys)
        params["enc_norm"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
        params["dec_pos"] = (jax.random.normal(
            keys[4], (cfg.max_seq, cfg.d_model), jnp.float32) * 0.02).astype(dtype)
    if cfg.frontend == "vision":
        params["img_proj"] = L.dense_init(keys[5], cfg.d_model, cfg.d_model, dtype)
    return params


# --------------------------------------------------------------------------
# embeddings / frontends
# --------------------------------------------------------------------------

def sinusoidal(seq, d):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    ang = pos * div[None, :]
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe[:, :d]


def embed_tokens(cfg, params, tokens, dtype):
    return params["tok_embed"].astype(dtype)[tokens]


def encode_audio(cfg, params, frames):
    """Whisper encoder over precomputed (stub-frontend) frames (B, T_enc, d)."""
    dtype = cfg.activation_dtype()
    x = frames.astype(dtype) + sinusoidal(frames.shape[1], cfg.d_model).astype(dtype)

    def body(carry, pgroup):
        x, = carry
        sp = pgroup["slot_0"]
        h = L.norm_apply(sp["norm1"], x)
        out, _ = L.attention_apply(sp["attn"], h, cfg, rope_cs=None, causal=False)
        x = L.residual_add(x, out)
        if "norm2" in sp:
            x = L.residual_add(
                x, L.mlp_apply(sp["mlp"], L.norm_apply(sp["norm2"], x)))
        return (x,), None

    (x,), _ = jax.lax.scan(body, (x,), params["encoder"])
    return L.norm_apply(params["enc_norm"], x)


def embed_inputs(cfg, params, batch):
    """Returns (x (B,S,d), enc_out or None, n_prefix) for training/prefill."""
    dtype = cfg.activation_dtype()
    enc_out = None
    n_prefix = 0
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens, dtype)
    if cfg.enc_dec:
        enc_out = encode_audio(cfg, params, batch["frames"])
        s = tokens.shape[1]
        x = x + params["dec_pos"].astype(dtype)[None, :s]
    elif cfg.frontend == "vision":
        img = L.dense(params["img_proj"], batch["image_embeds"].astype(dtype))
        x = jnp.concatenate([img, x], axis=1)
        n_prefix = img.shape[1]
    return x, enc_out, n_prefix


# --------------------------------------------------------------------------
# block application (shared by train / prefill / decode)
# --------------------------------------------------------------------------

def _apply_slot_full(cfg, sp, kind, is_moe, has_ffn, x, rope_cs, enc_out,
                     collect_cache):
    """Full-sequence slot application. Returns (x, aux, cache_slot or None)."""
    aux = {"lb_loss": 0.0, "z_loss": 0.0}
    cache = {}
    h = L.norm_apply(sp["norm1"], x)
    window = cfg.sliding_window
    if kind == "attn":
        out, (k, v) = L.attention_apply(sp["attn"], h, cfg, rope_cs=rope_cs,
                                        causal=True, window=window)
        if collect_cache:
            cache["k"], cache["v"] = k, v
    elif kind == "mamba":
        out, st = L.mamba_apply(sp["mamba"], h, cfg)
        if collect_cache:
            cache["conv"], cache["ssm"] = st
    elif kind == "mlstm":
        out, st = L.mlstm_apply(sp["mlstm"], h, cfg)
        if collect_cache:
            cache["C"], cache["n"] = st
    elif kind == "slstm":
        out, st = L.slstm_apply(sp["slstm"], h, cfg)
        if collect_cache:
            cache["h"], cache["c"], cache["sn"], cache["m"] = st
    x = L.residual_add(x, out)
    if cfg.enc_dec and enc_out is not None:
        hx = L.norm_apply(sp["norm_x"], x)
        outx, (ck, cv) = L.attention_apply(sp["cross"], hx, cfg,
                                           kv_override=enc_out)
        x = L.residual_add(x, outx)
        if collect_cache:
            cache["cross_k"], cache["cross_v"] = ck, cv
    if has_ffn:
        h2 = L.norm_apply(sp["norm2"], x)
        if is_moe:
            out2, a = L.moe_apply(sp["moe"], h2, cfg.moe,
                                  groups=cfg.moe_dispatch_groups or 1,
                                  gather_weights=cfg.moe_gather_weights)
            aux = {k2: aux[k2] + a[k2] for k2 in aux}
        else:
            out2 = L.mlp_apply(sp["mlp"], h2)
        x = L.residual_add(x, out2)
    return x, aux, (cache if collect_cache else None)


def _apply_slot_decode(cfg, sp, kind, is_moe, has_ffn, x, rope_cs, pos,
                       cache_slot, paged=None):
    """One-token slot application with cache update.

    paged: optional ``(block_tables, logical_len)`` routing the attention
    K/V through a block-paged pool (see ``layers.attention_decode``); all
    other slot kinds stay slot-resident and ignore it.
    """
    h = L.norm_apply(sp["norm1"], x)
    window = cfg.sliding_window
    new_cache = dict(cache_slot)
    if kind == "attn":
        out, (kc, vc) = L.attention_decode(
            sp["attn"], h, cfg, (cache_slot["k"], cache_slot["v"]), pos,
            rope_cs=rope_cs, window=window, paged=paged)
        new_cache["k"], new_cache["v"] = kc, vc
    elif kind == "mamba":
        out, st = L.mamba_decode(sp["mamba"], h, cfg,
                                 (cache_slot["conv"], cache_slot["ssm"]))
        new_cache["conv"], new_cache["ssm"] = st
    elif kind == "mlstm":
        out, st = L.mlstm_decode(sp["mlstm"], h, cfg,
                                 (cache_slot["C"], cache_slot["n"]))
        new_cache["C"], new_cache["n"] = st
    elif kind == "slstm":
        out, st = L.slstm_decode(
            sp["slstm"], h, cfg,
            (cache_slot["h"], cache_slot["c"], cache_slot["sn"], cache_slot["m"]))
        new_cache["h"], new_cache["c"], new_cache["sn"], new_cache["m"] = st
    x = L.residual_add(x, out)
    if cfg.enc_dec:
        hx = L.norm_apply(sp["norm_x"], x)
        outx, _ = L.attention_decode(
            sp["cross"], hx, cfg, None, pos,
            cross_kv=(cache_slot["cross_k"], cache_slot["cross_v"]))
        x = L.residual_add(x, outx)
    if has_ffn:
        h2 = L.norm_apply(sp["norm2"], x)
        if is_moe:
            out2, _ = L.moe_apply(sp["moe"], h2, cfg.moe,
                                  groups=cfg.moe_dispatch_groups or 1,
                                  gather_weights=cfg.moe_gather_weights)
        else:
            out2 = L.mlp_apply(sp["mlp"], h2)
        x = L.residual_add(x, out2)
    return x, new_cache


# --------------------------------------------------------------------------
# full-sequence forward over a group range (train / prefill / PNN stages)
# --------------------------------------------------------------------------

def forward_groups(cfg, groups_params, x, *, rope_cs, enc_out=None,
                   g0=0, g1=None, collect_cache=False, remat=True,
                   shard_x=None):
    """Runs groups [g0, g1) over x. Returns (x, aux, cache or None).

    shard_x: optional callable applied to the residual stream at every group
    boundary (sequence-parallel sharding constraint — see launch/steps.py).
    """
    slots = slot_spec(cfg)
    g1 = n_groups(cfg) if g1 is None else g1
    sub = jax.tree_util.tree_map(lambda a: a[g0:g1], groups_params)

    def body(carry, pgroup):
        x, lb, z = carry
        if shard_x is not None:
            x = shard_x(x)
        cache_g = {}
        for i, (kind, is_moe, has_ffn) in enumerate(slots):
            x, aux, cache = _apply_slot_full(
                cfg, pgroup[f"slot_{i}"], kind, is_moe, has_ffn, x, rope_cs,
                enc_out, collect_cache)
            lb = lb + aux["lb_loss"]
            z = z + aux["z_loss"]
            if collect_cache:
                cache_g[f"slot_{i}"] = cache
        return (x, lb, z), (cache_g if collect_cache else None)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    zero = jnp.zeros((), jnp.float32)
    (x, lb, z), caches = jax.lax.scan(body, (x, zero, zero), sub)
    return x, {"lb_loss": lb, "z_loss": z}, caches


def rope_for(cfg, positions):
    if cfg.enc_dec:
        return None  # whisper uses learned positions
    return L.rope_tables(positions, cfg.hd, cfg.rope_fraction, cfg.rope_theta)


def forward(cfg, params, batch, *, remat=True, shard_x=None):
    """Training forward: returns (logits, aux)."""
    x, enc_out, n_prefix = embed_inputs(cfg, params, batch)
    s = x.shape[1]
    rope_cs = rope_for(cfg, jnp.arange(s))
    x, aux, _ = forward_groups(cfg, params["groups"], x, rope_cs=rope_cs,
                               enc_out=enc_out, remat=remat, shard_x=shard_x)
    x = L.norm_apply(params["final_norm"], x)
    logits = unembed(cfg, params, x)
    aux["n_prefix"] = n_prefix
    return logits, aux


def norm_apply_final(cfg, params, x):
    return L.norm_apply(params["final_norm"], x)


def unembed(cfg, params, x):
    dtype = x.dtype
    if cfg.tie_embeddings:
        w = params["tok_embed"].astype(dtype).T
    else:
        w = params["unembed"].astype(dtype)
    return x @ w


# --------------------------------------------------------------------------
# caches / prefill / decode
# --------------------------------------------------------------------------

def init_cache(cfg, batch_size, cache_len):
    """Zero cache pytree (stacked over groups)."""
    dtype = cfg.activation_dtype()
    slots = slot_spec(cfg)
    g = n_groups(cfg)
    kv, hd = cfg.n_kv_heads, cfg.hd
    lc = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    cache = {}
    for i, (kind, _, _) in enumerate(slots):
        c = {}
        if kind == "attn":
            c["k"] = jnp.zeros((g, batch_size, lc, kv, hd), dtype)
            c["v"] = jnp.zeros((g, batch_size, lc, kv, hd), dtype)
            if cfg.enc_dec:
                c["cross_k"] = jnp.zeros((g, batch_size, cfg.enc_seq, kv, hd), dtype)
                c["cross_v"] = jnp.zeros((g, batch_size, cfg.enc_seq, kv, hd), dtype)
        elif kind == "mamba":
            d_in, _, n, d_conv = L.mamba_dims(cfg)
            c["conv"] = jnp.zeros((g, batch_size, d_conv - 1, d_in), dtype)
            c["ssm"] = jnp.zeros((g, batch_size, d_in, n), jnp.float32)
        elif kind == "mlstm":
            d_up = int(cfg.xlstm.proj_factor * cfg.d_model)
            dh = d_up // cfg.n_heads
            c["C"] = jnp.zeros((g, batch_size, cfg.n_heads, dh, dh), jnp.float32)
            c["n"] = jnp.zeros((g, batch_size, cfg.n_heads, dh), jnp.float32)
        elif kind == "slstm":
            d = cfg.d_model
            c["h"] = jnp.zeros((g, batch_size, d), jnp.float32)
            c["c"] = jnp.zeros((g, batch_size, d), jnp.float32)
            c["sn"] = jnp.zeros((g, batch_size, d), jnp.float32)
            c["m"] = jnp.full((g, batch_size, d), -1e9, jnp.float32)
        cache[f"slot_{i}"] = c
    return cache


def _ring_pack(k, lc, window):
    """Pack full-seq keys (B,S,KV,hd) into a cache of length lc.

    With a window, key at absolute pos p lands at slot p % lc (ring layout
    consistent with decode); otherwise the first lc keys land at their pos.
    """
    s = k.shape[1]
    if s <= lc:
        pad = ((0, 0), (0, lc - s), (0, 0), (0, 0))
        return jnp.pad(k, pad)
    tail = k[:, -lc:]
    if not window:
        return tail
    slots = (jnp.arange(s - lc, s)) % lc
    out = jnp.zeros((k.shape[0], lc) + k.shape[2:], k.dtype)
    return out.at[:, slots].set(tail)


def repack_prefill_cache(cfg, caches, cache_len):
    """Repack full-seq prefill kv into fixed cache slots (ring layout when a
    sliding window is set); carry states pass through unchanged."""
    lc = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    slots = slot_spec(cfg)
    cache = {}
    for i, (kind, _, _) in enumerate(slots):
        c = dict(caches[f"slot_{i}"]) if caches[f"slot_{i}"] else {}
        if kind == "attn":
            c["k"] = jax.vmap(lambda kk: _ring_pack(kk, lc, cfg.sliding_window))(c["k"])
            c["v"] = jax.vmap(lambda vv: _ring_pack(vv, lc, cfg.sliding_window))(c["v"])
        cache[f"slot_{i}"] = c
    return cache


def prefill(cfg, params, batch, cache_len):
    """Forward over the prompt, building the decode cache.

    Returns (last_token_logits (B,V), cache, next_pos scalar).
    """
    x, enc_out, n_prefix = embed_inputs(cfg, params, batch)
    s = x.shape[1]
    rope_cs = rope_for(cfg, jnp.arange(s))
    x, _, caches = forward_groups(cfg, params["groups"], x, rope_cs=rope_cs,
                                  enc_out=enc_out, collect_cache=True,
                                  remat=False)
    cache = repack_prefill_cache(cfg, caches, cache_len)
    xl = L.norm_apply(params["final_norm"], x[:, -1:])
    logits = unembed(cfg, params, xl)[:, 0]
    return logits, cache, jnp.int32(s)


def decode_embed(cfg, params, token, pos):
    """Embed the current token(s) for decode; returns (x (B,1,d), rope_cs).

    `params` needs only the embedding-owning keys (stage 0 under a
    PartitionPlan)."""
    dtype = cfg.activation_dtype()
    x = embed_tokens(cfg, params, token[:, None], dtype)
    if cfg.enc_dec:
        pe = params["dec_pos"].astype(dtype)[pos]  # (d,) or (B, d)
        x = x + (pe[None, None] if jnp.ndim(pos) == 0 else pe[:, None])
        rope_cs = None
    else:
        rope_cs = L.rope_tables(pos[None] if jnp.ndim(pos) == 0 else pos,
                                cfg.hd, cfg.rope_fraction, cfg.rope_theta)
    return x, rope_cs


def decode_groups(cfg, groups_params, cache, x, rope_cs, pos, paged=None):
    """One decode step over a (sub)stack of layer groups.

    groups_params / cache are stacked over the same leading group dim (the
    whole model, or one PartitionPlan stage's slice).  With ``paged``, the
    attention K/V leaves are (G, NB, BS, KV, hd) physical block pools and
    the one block table (a scan constant, shared across groups) routes each
    request's reads/writes.  Returns (x, new_cache).
    """
    slots = slot_spec(cfg)

    def body(x, xs):
        pgroup, cache_g = xs
        new_cache_g = {}
        for i, (kind, is_moe, has_ffn) in enumerate(slots):
            x, nc = _apply_slot_decode(cfg, pgroup[f"slot_{i}"], kind, is_moe,
                                       has_ffn, x, rope_cs, pos,
                                       cache_g[f"slot_{i}"], paged=paged)
            new_cache_g[f"slot_{i}"] = nc
        return x, new_cache_g

    return jax.lax.scan(body, x, (groups_params, cache))


def decode_step(cfg, params, cache, token, pos, paged=None):
    """One decode step. token: (B,) int32; pos: scalar int32 OR per-request
    (B,) int32 vector (ragged batches: each request at its own position).

    paged: optional ``(block_tables, logical_len)`` for block-paged K/V.
    Returns (logits (B,V), new_cache).
    """
    x, rope_cs = decode_embed(cfg, params, token, pos)
    x, new_cache = decode_groups(cfg, params["groups"], cache, x, rope_cs,
                                 pos, paged=paged)
    x = L.norm_apply(params["final_norm"], x)
    logits = unembed(cfg, params, x)[:, 0]
    return logits, new_cache
