from repro.models import layers, model, mlp  # noqa: F401
