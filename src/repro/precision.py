"""End-to-end mixed-precision policy (param / compute / accum dtypes).

One ``PrecisionPolicy`` is the single source of truth for numerics across the
train and serve hot paths:

* **param_dtype** — storage dtype of the weights (``ModelConfig.param_dtype``).
  Under the built-in policies params stay fp32; the fp16 policy keeps fp32
  *master* weights inside the optimizer wrapper when params are stored half.
* **compute_dtype** — activations, matmul inputs, KV/state caches, and SIL
  boundary spills.  Weights are cast to it at each matmul boundary (the
  promote-at-boundary idiom: the cast happens next to the op that needs it,
  never persisted).
* **accum_dtype** — loss/metric accumulation, gradient accumulation across
  microbatches, optimizer moments, norm statistics, softmax/attention logits,
  and residual adds.  Always fp32 in the built-in policies.

``loss_scale`` / ``dynamic_scale`` configure (dynamic) loss scaling for
fp16 — gradients are computed on ``loss * scale`` and unscaled inside the
``repro.optim.mixed_precision`` wrapper, which also skips steps whose
unscaled gradients are non-finite.  bf16 shares fp32's exponent range, so the
bf16 policy runs with scale 1 (a mathematical no-op kept bit-exact).

Invariants enforced by tests/test_precision.py:

* params keep ``param_dtype`` through any number of steps under any policy
* norms, softmax/attention logits, and residual adds accumulate in fp32
* ``loss_scale=1`` gradients bit-match the unscaled step
* the Pallas kernels accept compute-dtype inputs with fp32 accumulators
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Union

import jax
import jax.numpy as jnp

# itemsize by dtype string, resolvable without importing ml_dtypes-aware
# numpy (np.dtype("bfloat16") raises on plain numpy)
_ITEMSIZE = {
    "float64": 8, "float32": 4, "float16": 2, "bfloat16": 2,
    "float8_e4m3fn": 1, "float8_e5m2": 1,
}


def dtype_itemsize(dtype: Union[str, jnp.dtype]) -> int:
    """Bytes per element for a dtype given as string or jnp dtype."""
    s = str(dtype)
    if s in _ITEMSIZE:
        return _ITEMSIZE[s]
    return jnp.dtype(dtype).itemsize


@dataclass(frozen=True)
class PrecisionPolicy:
    """param/compute/accum dtypes + loss-scaling knobs (see module doc)."""
    name: str = "fp32"
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    accum_dtype: str = "float32"
    # loss scaling (fp16): grads are computed on loss * loss_scale and
    # unscaled in the optimizer wrapper; dynamic_scale halves on overflow and
    # doubles after scale_growth_interval clean steps
    loss_scale: float = 1.0
    dynamic_scale: bool = False
    scale_growth_interval: int = 200

    # -- dtypes ------------------------------------------------------------

    @property
    def param_jnp(self):
        return jnp.dtype(self.param_dtype)

    @property
    def compute_jnp(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def accum_jnp(self):
        return jnp.dtype(self.accum_dtype)

    @property
    def compute_itemsize(self) -> int:
        return dtype_itemsize(self.compute_dtype)

    @property
    def param_itemsize(self) -> int:
        return dtype_itemsize(self.param_dtype)

    @property
    def wraps_optimizer(self) -> bool:
        """Whether the step needs the mixed_precision optimizer wrapper
        (loss scaling and/or fp32 master weights for half-precision params)."""
        return (self.loss_scale != 1.0 or self.dynamic_scale
                or self.param_jnp != jnp.float32)

    # -- casts -------------------------------------------------------------

    def cast_compute(self, tree):
        """Cast floating leaves to compute_dtype (ints/bools untouched)."""
        return cast_floating(tree, self.compute_jnp)

    def cast_param(self, tree):
        return cast_floating(tree, self.param_jnp)

    def cast_accum(self, tree):
        return cast_floating(tree, self.accum_jnp)

    # -- config threading --------------------------------------------------

    def apply_to_model(self, cfg):
        """ModelConfig with activations in this policy's compute dtype.

        param_dtype is left as the config declares it — storage precision is
        an architecture decision (grok/jamba ship bf16 checkpoints), compute
        precision is a launch decision."""
        if cfg.dtype == self.compute_dtype:
            return cfg
        return cfg.replace(dtype=self.compute_dtype)


PRESETS = {
    "fp32": PrecisionPolicy(name="fp32"),
    "bf16": PrecisionPolicy(name="bf16", compute_dtype="bfloat16"),
    # fp16 needs loss scaling: 5 exponent bits underflow activations-scale
    # gradients long before bf16 would
    "fp16": PrecisionPolicy(name="fp16", compute_dtype="float16",
                            loss_scale=float(2 ** 15), dynamic_scale=True),
}


def get_policy(p: Union[None, str, PrecisionPolicy],
               default: str = "fp32") -> PrecisionPolicy:
    """Resolve a policy from a preset name / policy / None (-> default)."""
    if p is None:
        p = default
    if isinstance(p, PrecisionPolicy):
        return p
    try:
        return PRESETS[p]
    except KeyError:
        raise ValueError(f"unknown precision {p!r}; "
                         f"presets: {sorted(PRESETS)}") from None


def policy_for(cfg) -> PrecisionPolicy:
    """Derive the policy a ModelConfig is effectively running (its dtype /
    param_dtype fields), for memory accounting."""
    return replace(PRESETS["fp32"], name="derived",
                   compute_dtype=cfg.dtype, param_dtype=cfg.param_dtype)


# --------------------------------------------------------------------------
# tree helpers
# --------------------------------------------------------------------------

def cast_floating(tree, dtype):
    """Cast every inexact leaf of a pytree to `dtype`; other leaves pass
    through (labels/masks/counters keep their integer dtypes)."""
    dtype = jnp.dtype(dtype)

    def cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact) \
                and x.dtype != dtype:
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(cast, tree)


def read_loss_scale(opt_state):
    """The live loss scale carried in a mixed_precision optimizer state
    (1.0 for unwrapped optimizers) — step builders multiply the loss by this
    so gradients arrive pre-scaled at ``opt.update``."""
    if isinstance(opt_state, dict) and "loss_scale" in opt_state:
        return opt_state["loss_scale"]
    return 1.0


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays (policy-visible memory accounting)."""
    return sum(x.size * dtype_itemsize(x.dtype)
               for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "size"))
