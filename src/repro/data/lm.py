"""Synthetic language-model data: a deterministic Markov/induction corpus.

Structure (so training loss actually decreases):
* a class-conditional bigram backbone: token t+1 ~ M[t] over a sparse
  transition table, plus
* induction patterns: random earlier spans are repeated verbatim, rewarding
  models with working context.
"""
from __future__ import annotations

import numpy as np


def synthetic_token_stream(n_tokens: int, vocab: int, seed: int = 0,
                           branch: int = 16, repeat_p: float = 0.1,
                           span: int = 32) -> np.ndarray:
    rng = np.random.RandomState(seed)
    # sparse deterministic transition table: each token has `branch` successors
    succ = rng.randint(0, vocab, size=(min(vocab, 4096), branch))
    out = np.empty(n_tokens, dtype=np.int64)
    t = rng.randint(vocab)
    i = 0
    while i < n_tokens:
        if i > 2 * span and rng.rand() < repeat_p:
            start = rng.randint(0, i - span)
            ln = rng.randint(4, span)
            ln = min(ln, n_tokens - i)
            out[i:i + ln] = out[start:start + ln]
            i += ln
            t = int(out[i - 1])
            continue
        out[i] = t
        t = int(succ[t % succ.shape[0], rng.randint(branch)])
        i += 1
    return out.astype(np.int32) % vocab


def lm_batches(stream: np.ndarray, batch: int, seq: int, seed: int = 0):
    """Yields {'tokens': (B,S), 'labels': (B,S)} forever (labels = next token)."""
    n = (len(stream) - 1) // seq
    rng = np.random.RandomState(seed)
    while True:
        idx = rng.randint(0, n, size=batch)
        toks = np.stack([stream[i * seq:(i + 1) * seq] for i in idx])
        labs = np.stack([stream[i * seq + 1:(i + 1) * seq + 1] for i in idx])
        yield {"tokens": toks, "labels": labs}


def lm_batch_at(stream: np.ndarray, batch: int, seq: int, step: int,
                seed: int = 0) -> dict:
    """Batch for step `step` as a PURE function of the index — the same
    (tokens, labels) no matter the call order or how often it is called.

    This is the replay-determinism contract ``repro.dist`` needs: a resumed
    stage re-requests ticks t..n and must see exactly the batches the other
    stages consumed at those ticks.  (``lm_batches`` is a stateful iterator
    and cannot honor that.)"""
    n = (len(stream) - 1) // seq
    rng = np.random.Generator(np.random.PCG64(
        np.random.SeedSequence((seed, step))))
    idx = rng.integers(0, n, size=batch)
    toks = np.stack([stream[i * seq:(i + 1) * seq] for i in idx])
    labs = np.stack([stream[i * seq + 1:(i + 1) * seq + 1] for i in idx])
    return {"tokens": toks, "labels": labs}
