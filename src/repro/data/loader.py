"""Batched host->device loading with optional shuffling and sharding.

The paper trains the left partition *without shuffling* so SIL targets stay
aligned with sample order; we instead key SIL by label id (order-free), but
``shuffle=False`` reproduces the paper's exact regime.
"""
from __future__ import annotations

from typing import Iterator

import jax
import numpy as np


class Batches:
    """Epoch iterator over aligned arrays.

    Per-epoch shuffle streams are drawn from a ``np.random.SeedSequence``
    spawned per (seed, epoch), so distinct (seed, epoch) pairs never collide
    (the old ``RandomState(seed + epoch)`` scheme made ``seed=0, epoch=1``
    and ``seed=1, epoch=0`` identical).  ``legacy_seeding=True`` pins the old
    behavior for bit-exact reproduction of pre-existing runs.
    """

    def __init__(self, arrays, batch_size: int, *, shuffle: bool = True,
                 seed: int = 0, drop_last: bool = True, sharding=None,
                 legacy_seeding: bool = False):
        self.arrays = [np.asarray(a) for a in arrays]
        self.n = len(self.arrays[0])
        assert all(len(a) == self.n for a in self.arrays)
        self.batch_size = batch_size
        self.shuffle = shuffle
        self.seed = seed
        self.drop_last = drop_last
        self.sharding = sharding
        self.legacy_seeding = legacy_seeding

    def __len__(self):
        return self.n // self.batch_size if self.drop_last else \
            -(-self.n // self.batch_size)

    def epoch(self, epoch_idx: int = 0) -> Iterator:
        order = np.arange(self.n)
        if self.shuffle:
            if self.legacy_seeding:
                np.random.RandomState(self.seed + epoch_idx).shuffle(order)
            else:
                seq = np.random.SeedSequence(self.seed,
                                             spawn_key=(epoch_idx,))
                np.random.default_rng(seq).shuffle(order)
        stop = self.n - (self.n % self.batch_size) if self.drop_last else self.n
        for i in range(0, stop, self.batch_size):
            idx = order[i:i + self.batch_size]
            out = [a[idx] for a in self.arrays]
            if self.sharding is not None:
                out = [jax.device_put(a, self.sharding) for a in out]
            yield tuple(out)
