from .images import emnist_like, load_emnist  # noqa: F401
from .lm import synthetic_token_stream, lm_batches  # noqa: F401
from .loader import Batches  # noqa: F401
