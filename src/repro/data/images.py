"""Image-classification data pipeline.

The paper uses EMNIST-balanced (47 classes, 28x28).  EMNIST is not shipped in
this container, so the default pipeline is a *deterministic synthetic
EMNIST-like* task: each class has a smooth random prototype image and samples
are prototype + structured noise, giving a task with the same input/label
geometry and a learnable but non-trivial decision boundary.  If a real
``emnist.npz`` exists (keys: train_x, train_y, test_x, test_y) it is used
instead.  See DESIGN.md §2.4 (dataset substitution).
"""
from __future__ import annotations

import os
from typing import Tuple

import numpy as np

N_CLASSES = 47
IMG_DIM = 784


def _smooth(rng, n, size=28, blur=3):
    """Random smooth 2D patterns (box-blurred noise)."""
    img = rng.standard_normal((n, size + 2 * blur, size + 2 * blur))
    out = np.zeros((n, size, size))
    for dx in range(2 * blur + 1):
        for dy in range(2 * blur + 1):
            out += img[:, dx:dx + size, dy:dy + size]
    out /= (2 * blur + 1) ** 2
    return out


def emnist_like(n_train: int = 112800, n_test: int = 18800, seed: int = 0,
                noise: float = 0.9) -> Tuple[np.ndarray, ...]:
    """Deterministic EMNIST-like dataset.

    Returns (train_x (N,784) float32 in [0,1]-ish, train_y, test_x, test_y).
    Sized like EMNIST-balanced by default (112800 train / 18800 test).
    """
    rng = np.random.RandomState(seed)
    protos = _smooth(rng, N_CLASSES)                      # (47, 28, 28)
    protos = (protos - protos.min(axis=(1, 2), keepdims=True))
    protos /= np.maximum(protos.max(axis=(1, 2), keepdims=True), 1e-6)

    def make(n, seed_off):
        r = np.random.RandomState(seed + 1 + seed_off)
        y = r.randint(0, N_CLASSES, size=n)
        base = protos[y]
        # structured noise: per-sample smooth deformation + pixel noise
        pix = r.standard_normal(base.shape) * noise * 0.25
        gain = 1.0 + 0.2 * r.standard_normal((n, 1, 1))
        x = np.clip(base * gain + pix, 0.0, 1.5)
        return x.reshape(n, IMG_DIM).astype(np.float32), y.astype(np.int32)

    tx, ty = make(n_train, 0)
    vx, vy = make(n_test, 1)
    return tx, ty, vx, vy


def load_emnist(path: str = "data/emnist.npz", **kw):
    """Real EMNIST if available, synthetic otherwise."""
    if os.path.exists(path):
        z = np.load(path)
        return (z["train_x"].reshape(-1, IMG_DIM).astype(np.float32) / 255.0,
                z["train_y"].astype(np.int32),
                z["test_x"].reshape(-1, IMG_DIM).astype(np.float32) / 255.0,
                z["test_y"].astype(np.int32))
    return emnist_like(**kw)
