"""Model partitioning for PNN (paper §2, Figures 2-4).

A ``PartitionPlan`` cuts a transformer's group stack into `n_stages`
contiguous stages.  Stage 0 owns the embedding (and encoder/frontend); the
last stage owns the final norm + unembedding.  Boundaries are residual-stream
activations (width d_model) — the fixed-width interface every assigned
architecture exposes (DESIGN.md §4.1).

The MLP variant (the paper's own experiment) cuts at layer granularity.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


@dataclass(frozen=True)
class PartitionPlan:
    n_stages: int
    bounds: Tuple[Tuple[int, int], ...]  # group ranges [g0, g1) per stage

    @property
    def cuts(self) -> int:
        return self.n_stages - 1


def make_plan(cfg: ModelConfig, n_stages: int, strategy: str = "uniform",
              **search_kw) -> PartitionPlan:
    """Cut the group stack into ``n_stages`` contiguous stages.

    strategy="uniform" (default) is the balanced contiguous divmod split;
    strategy="auto" routes through the ``repro.plan`` cost-model searcher
    (``search_kw`` — batch/seq/optimizer/objective — feeds its cost table).
    """
    g = M.n_groups(cfg)
    if n_stages > g:
        raise ValueError(f"{n_stages} stages > {g} groups for {cfg.name}")
    if strategy == "auto":
        # lazy import: repro.plan imports PartitionPlan from this module
        from repro import plan as plan_lib
        return plan_lib.auto_plan(cfg, n_stages, **search_kw)
    if strategy != "uniform":
        raise ValueError(f"unknown partition strategy {strategy!r}; "
                         "expected 'uniform' or 'auto'")
    # balanced contiguous split
    base, rem = divmod(g, n_stages)
    bounds = []
    start = 0
    for k in range(n_stages):
        size = base + (1 if k < rem else 0)
        bounds.append((start, start + size))
        start += size
    return PartitionPlan(n_stages, tuple(bounds))


def stage_param_keys(cfg: ModelConfig, plan: PartitionPlan, k: int) -> List[str]:
    keys = ["groups"]
    if k == 0:
        keys.append("tok_embed")
        if cfg.enc_dec:
            keys += ["encoder", "enc_norm", "dec_pos"]
        if cfg.frontend == "vision":
            keys.append("img_proj")
    if k == plan.n_stages - 1:
        keys.append("final_norm")
        if not cfg.tie_embeddings:
            keys.append("unembed")
        elif "tok_embed" not in keys:
            # Tied unembedding on a stage that does NOT own the embedding:
            # a FROZEN copy.  Giving the last stage a trainable "tok_embed"
            # would let two stages train divergent copies of the same tensor,
            # with join_stage_params silently keeping whichever came last.
            keys.append("tied_unembed")
    return keys


def slice_stage_params(cfg: ModelConfig, plan: PartitionPlan, params,
                       k: int) -> Dict[str, Any]:
    """Extract exactly the parameters stage k trains (paper: each partition
    holds only its own params + optimizer state).  ``tied_unembed`` is a
    frozen snapshot of the embedding, not a trainable copy."""
    g0, g1 = plan.bounds[k]
    out: Dict[str, Any] = {}
    for key in stage_param_keys(cfg, plan, k):
        if key == "groups":
            out[key] = jax.tree_util.tree_map(lambda a: a[g0:g1],
                                              params["groups"])
        elif key == "tied_unembed":
            out[key] = params["tok_embed"]
        else:
            out[key] = params[key]
    return out


def refresh_tied_unembed(cfg: ModelConfig, plan: PartitionPlan,
                         stage_params: List[Dict[str, Any]]) -> None:
    """Sync the last stage's frozen tied-unembedding snapshot with stage 0's
    (possibly already trained) embedding.  Call before training the last
    stage in a sequential schedule so its CE phase sees the same table the
    deployed joined network will use."""
    if plan.n_stages > 1 and cfg.tie_embeddings:
        last = stage_params[plan.n_stages - 1]
        if "tied_unembed" in last:
            # a COPY, not an alias: the last stage's train step donates its
            # param buffers on accelerators, and donating an alias of stage
            # 0's trainable embedding would delete it out from under the
            # prefix forward and the final join
            last["tied_unembed"] = jnp.copy(stage_params[0]["tok_embed"])


def join_stage_params(cfg: ModelConfig, plan: PartitionPlan,
                      stage_params: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Rebuild the full param tree from per-stage trees (paper: "the
    partitions can be joined after this stage, to use the network").  Frozen
    ``tied_unembed`` snapshots are dropped: the joined network's tied
    unembedding is stage 0's trained embedding."""
    full: Dict[str, Any] = {}
    groups = [sp["groups"] for sp in stage_params]
    full["groups"] = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *groups)
    for k, sp in enumerate(stage_params):
        for key, val in sp.items():
            if key not in ("groups", "tied_unembed"):
                full[key] = val
    return full


def stage_forward(cfg: ModelConfig, plan: PartitionPlan, k: int, stage_params,
                  batch_or_x, *, remat=True, shard_x=None):
    """Forward of stage k alone.

    Stage 0 consumes the raw batch (dict); later stages consume the boundary
    activation (B, S, d).  Returns (output, aux): output is the boundary
    activation for interior stages or logits for the last stage.
    """
    g0, g1 = plan.bounds[k]
    n = g1 - g0
    enc_out = None
    n_prefix = 0
    if k == 0:
        x, enc_out, n_prefix = M.embed_inputs(cfg, stage_params, batch_or_x)
    elif cfg.enc_dec:
        # boundary payload for enc-dec models carries encoder output too
        x, enc_out = batch_or_x
    else:
        x = batch_or_x
    s = x.shape[1]
    rope_cs = M.rope_for(cfg, jnp.arange(s))
    x, aux, _ = M.forward_groups(cfg, stage_params["groups"], x,
                                 rope_cs=rope_cs, enc_out=enc_out,
                                 g0=0, g1=n, remat=remat, shard_x=shard_x)
    aux["n_prefix"] = n_prefix
    if k == plan.n_stages - 1:
        x = M.norm_apply_final(cfg, stage_params, x)
        if "tied_unembed" in stage_params:
            # frozen snapshot of the embedding: gradients must not flow into
            # it (stage 0 owns the trainable copy)
            up = dict(stage_params)
            up["tok_embed"] = jax.lax.stop_gradient(up.pop("tied_unembed"))
            return M.unembed(cfg, up, x), aux
        return M.unembed(cfg, stage_params, x), aux
    if cfg.enc_dec:
        return (x, enc_out), aux
    return x, aux
