"""Synthetic Intermediate Labels (paper §2, Eq. 1).

    SIL[i, j] ~ kappa * U(0, 1),   SIL in R^{N_P x M}

Column j is the synthetic target activation (width N_P = boundary features)
for every sample of class j.  For language models the "class" of a token
position is its next-token id, so M = vocab and the SIL is structurally a
random unembedding table; the table is keyed by label id, which makes it
order-free (the paper instead relies on unshuffled data order — equivalent,
see DESIGN.md §2.4).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def make_sil(key, n_features: int, n_classes: int, kappa: float,
             dtype=jnp.float32):
    """Eq. 1: (N_P, M) matrix with entries kappa * U(0,1)."""
    return (kappa * jax.random.uniform(key, (n_features, n_classes),
                                       jnp.float32)).astype(dtype)


def make_stage_sils(key, widths: Sequence[int], n_classes: int, kappa: float,
                    dtype=jnp.float32):
    """One SIL per interior cut. widths[k] = boundary feature count of cut k
    (the output width of stage k, for k = 0..n_stages-2)."""
    keys = jax.random.split(key, max(len(widths), 1))
    return [make_sil(k, w, n_classes, kappa, dtype)
            for k, w in zip(keys, widths)]


def sil_lookup(sil, labels):
    """Synthetic target activations for `labels` (any int shape) -> (*, N_P)."""
    return jnp.moveaxis(sil[:, labels], 0, -1)
