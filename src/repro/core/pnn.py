"""DEPRECATED compatibility shim over ``repro.train``.

The five bespoke trainers that used to live here (``train_mlp_baseline``,
``train_mlp_pnn``, ``train_mlp_parallel_sil``, ``pnn_train_lm``,
``pnn_parallel_train_lm``) are now thin wrappers around the composable phase
API in ``repro.train`` — one ``Trainer`` running a short phase list per
schedule (see ``repro.train.recipes``).  New code should use ``repro.train``
directly; these wrappers preserve the legacy signatures, RNG key schedules,
and history formats, and are pinned against the new engine by
tests/test_train_api.py (bit-exact for the standard decoder configs).

Two deliberate behavior changes vs the deleted loops: (1) tied-embedding
models no longer train a second divergent copy of ``tok_embed`` in the last
stage (see partition.stage_param_keys); (2) the engine applies MoE auxiliary
losses and vision-token trimming consistently in BOTH sequential and
parallel modes (the legacy parallel loop skipped MoE aux, and neither loop
trimmed vision tokens).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.core import losses, partition
from repro.models import mlp as MLP
from repro.train import recipes, spec_from_lm_config, spec_from_paper_hp
from repro.train.backends import mlp_test_accuracy  # noqa: F401  (re-export)


# ==========================================================================
# legacy configs (converted to repro.train.TrainSpec internally)
# ==========================================================================

@dataclass
class PaperHP:
    """Paper hyperparameters (§3, Fig. 6).

    lr_right: the paper highlights per-partition hyperparameters as a core
    advantage (§2.1 "Computation demand"); on the synthetic EMNIST substitute
    the right phase is stable at 0.003 where the paper's 0.01 oscillates
    (boundary magnitudes differ from real EMNIST — see EXPERIMENTS.md)."""
    kappa: float = 10.0
    n_left: int = 5          # N_L
    n_right: int = 160       # N_R
    n_baseline: int = 40     # N_B
    n_recovery: int = 0      # §5 uses 10
    batch_size: int = 1410
    lr: float = 0.01
    lr_right: Optional[float] = None
    lr_recovery: Optional[float] = None   # default: (lr_right or lr) / 10
    momentum: float = 0.9
    shuffle: bool = False    # paper trains the left phase unshuffled


@dataclass
class PNNStageHP:
    steps: int
    lr: float = 1e-3
    optimizer: str = "adamw"


@dataclass
class PNNLMConfig:
    n_stages: int = 2
    kappa: float = 1.0
    stages: Optional[List[PNNStageHP]] = None
    recovery_steps: int = 0
    recovery_lr: float = 1e-4


# ==========================================================================
# helpers kept for callers that built their own loops
# ==========================================================================

def _batches(x, y, bs, *, shuffle, seed):
    """Batch iterator.  NOTE: silently drops the last partial batch — use
    dropped_sample_count() to surface how many samples that is; the
    repro.train engine records it as history meta 'dropped_per_epoch'."""
    n = (len(x) // bs) * bs
    order = np.arange(len(x))
    if shuffle:
        np.random.RandomState(seed).shuffle(order)
    for i in range(0, n, bs):
        idx = order[i:i + bs]
        yield x[idx], y[idx]


def dropped_sample_count(n: int, bs: int) -> int:
    """How many tail samples _batches drops per epoch for dataset size n."""
    return n - (n // bs) * bs


def _make_left_step(cfg: MLP.MLPConfig, opt):
    @jax.jit
    def step(params, state, x, y, sil):
        def loss_fn(p):
            h = MLP.forward_range(cfg, p, x, 0, cfg.cut)
            return losses.sil_stage_loss(h, sil, y)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss
    return step


def _make_right_step(cfg: MLP.MLPConfig, opt):
    @jax.jit
    def step(params, state, h, y):
        def loss_fn(p):
            logits = MLP.forward_range(cfg, p, h, cfg.cut, cfg.n_layers)
            return losses.cross_entropy(logits, y)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss
    return step


# ==========================================================================
# the five legacy trainers, as phase lists
# ==========================================================================

def train_mlp_baseline(cfg, data, hp: PaperHP, key, eval_every=1):
    """Conventional training of the unpartitioned network (paper baseline)."""
    spec = spec_from_paper_hp(hp)
    params, hist = recipes.run_mlp_baseline(cfg, data, spec, key,
                                            eval_every=eval_every)
    return params, hist.to_mlp_legacy()


def train_mlp_pnn(cfg, data, hp: PaperHP, key, eval_every=1):
    """The paper's PNN procedure (Fig. 3 + §5 recovery).

    Returns (joined_params, history).  History logs test accuracy of the
    *joined* network after every epoch, against cumulative per-sample MACs —
    the x-axis of the paper's Figures 6/9/10.
    """
    spec = spec_from_paper_hp(hp)
    params, hist = recipes.run_mlp_fig3(cfg, data, spec, key,
                                        eval_every=eval_every)
    return params, hist.to_mlp_legacy()


def train_mlp_parallel_sil(cfg, data, hp: PaperHP, key, n_stages=3,
                           epochs=40):
    """Fig. 5 mode: every stage trains simultaneously (no dependencies);
    interior stages use SIL as both input and label.  The paper deems this
    impractical (needs many epochs) — implemented for completeness."""
    from dataclasses import replace as _rp
    from repro.train import StageSpec
    spec = spec_from_paper_hp(hp)
    spec = _rp(spec, n_stages=n_stages,
               stages=tuple(StageSpec(epochs=epochs, lr=hp.lr,
                                      optimizer="sgdm", momentum=hp.momentum)
                            for _ in range(n_stages)))
    joined, hist = recipes.run_mlp_fig5(cfg, data, spec, key,
                                        n_stages=n_stages)
    return joined, hist.column("acc", phase="parallel")[-1]


def pnn_train_lm(cfg, plan, params, batch_fn: Callable[[int], dict],
                 pnn: PNNLMConfig, key):
    """Stage-sequential PNN training of a transformer LM.

    batch_fn(step) -> {'tokens', 'labels', ...}.  Returns (joined params,
    history).  Each stage holds ONLY its own params + optimizer state while
    training (the paper's memory claim); earlier stages are frozen inputs.
    """
    spec = spec_from_lm_config(pnn, plan.n_stages)
    joined, hist = recipes.run_lm_sequential(cfg, plan, params, batch_fn,
                                             spec, key)
    return joined, hist.to_lm_legacy()


def pnn_parallel_train_lm(cfg, plan, params, batch_fn: Callable[[int], dict],
                          pnn: PNNLMConfig, key):
    """Fig.-5 mode at transformer scale: ALL stages train simultaneously.

    Interior stage k consumes synthetic inputs SIL_{k-1}[:, y_t] (broadcast
    over positions) and regresses to SIL_k[:, y_t]; stage 0 consumes the real
    batch; the last stage consumes SIL_{last-1}[:, y_t] and trains with CE.
    Zero inter-stage dependencies — on the multi-pod mesh every pod trains
    its stage concurrently with NO communication at all (the paper deems the
    mode impractical for accuracy; implemented for completeness and measured
    in examples/pnn_transformer.py --parallel).
    """
    spec = spec_from_lm_config(pnn, plan.n_stages)
    joined, hist = recipes.run_lm_parallel(cfg, plan, params, batch_fn,
                                           spec, key)
    return joined, hist.to_lm_legacy()


# Kept importable for external callers; the engine equivalents live in
# repro.train.backends.LMBackend.
def build_stage_step(cfg, plan, k, stage_sil, opt):
    """Jitted train step for stage k of a transformer (legacy signature)."""
    last = k == plan.n_stages - 1

    @jax.jit
    def step(stage_params, opt_state, xin, labels, mask=None):
        def loss_fn(p):
            out, aux = partition.stage_forward(cfg, plan, k, p, xin)
            if last:
                loss, _ = losses.train_objective(cfg, out, labels, aux, mask)
                return loss
            bound = out[0] if cfg.enc_dec else out
            loss = losses.sil_stage_loss(bound, stage_sil, labels)
            if cfg.moe is not None:
                loss = loss + cfg.moe.load_balance_loss * aux["lb_loss"] \
                    + cfg.moe.router_z_loss * aux["z_loss"]
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(stage_params)
        stage_params, opt_state = opt.update(grads, opt_state, stage_params)
        return stage_params, opt_state, loss

    return step


def build_prefix_forward(cfg, plan, k):
    """Jitted frozen forward of stages < k (legacy signature)."""
    @jax.jit
    def fwd(prefix_params: tuple, batch):
        x = batch
        for j in range(k):
            x, _ = partition.stage_forward(cfg, plan, j, prefix_params[j], x,
                                           remat=False)
        return x
    return fwd
