"""PNN training (paper §2-§5): sequential stage training with SIL targets,
boundary materialization, recovery epochs, and the Fig.-5 parallel mode.

Two concrete trainers:

* the **faithful MLP reproduction** (paper §3-§5: 6-layer FC net, EMNIST-47,
  SGD+momentum, kappa, N_L/N_R, recovery) — used by benchmarks/paper_figures
  and examples/quickstart.py;
* the **transformer generalization** — stage-sequential SIL training of any
  assigned architecture via core/partition.py.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses, partition, sil as sil_lib
from repro.models import mlp as MLP
from repro.models import model as M
from repro.optim import make_optimizer


# ==========================================================================
# Faithful MLP reproduction (paper §3-§5)
# ==========================================================================

@dataclass
class PaperHP:
    """Paper hyperparameters (§3, Fig. 6).

    lr_right: the paper highlights per-partition hyperparameters as a core
    advantage (§2.1 "Computation demand"); on the synthetic EMNIST substitute
    the right phase is stable at 0.003 where the paper's 0.01 oscillates
    (boundary magnitudes differ from real EMNIST — see EXPERIMENTS.md)."""
    kappa: float = 10.0
    n_left: int = 5          # N_L
    n_right: int = 160       # N_R
    n_baseline: int = 40     # N_B
    n_recovery: int = 0      # §5 uses 10
    batch_size: int = 1410
    lr: float = 0.01
    lr_right: Optional[float] = None
    lr_recovery: Optional[float] = None   # default: (lr_right or lr) / 10
    momentum: float = 0.9
    shuffle: bool = False    # paper trains the left phase unshuffled


def _batches(x, y, bs, *, shuffle, seed):
    n = (len(x) // bs) * bs
    order = np.arange(len(x))
    if shuffle:
        np.random.RandomState(seed).shuffle(order)
    for i in range(0, n, bs):
        idx = order[i:i + bs]
        yield x[idx], y[idx]


def _make_left_step(cfg: MLP.MLPConfig, opt):
    @jax.jit
    def step(params, state, x, y, sil):
        def loss_fn(p):
            h = MLP.forward_range(cfg, p, x, 0, cfg.cut)
            return losses.sil_stage_loss(h, sil, y)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss
    return step


def _make_right_step(cfg: MLP.MLPConfig, opt):
    @jax.jit
    def step(params, state, h, y):
        def loss_fn(p):
            logits = MLP.forward_range(cfg, p, h, cfg.cut, cfg.n_layers)
            return losses.cross_entropy(logits, y)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss
    return step


def _make_baseline_step(cfg: MLP.MLPConfig, opt):
    @jax.jit
    def step(params, state, x, y):
        def loss_fn(p):
            logits = MLP.forward_range(cfg, p, x, 0, cfg.n_layers)
            return losses.cross_entropy(logits, y)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss
    return step


def _make_recovery_step(cfg: MLP.MLPConfig, opt):
    """§5: continue training the left part with the right part frozen."""
    @jax.jit
    def step(left, state, right, x, y):
        def loss_fn(pl):
            h = MLP.forward_range(cfg, pl, x, 0, cfg.cut)
            logits = MLP.forward_range(
                cfg, jax.lax.stop_gradient(right), h, cfg.cut, cfg.n_layers)
            return losses.cross_entropy(logits, y)
        loss, grads = jax.value_and_grad(loss_fn)(left)
        left, state = opt.update(grads, state, left)
        return left, state, loss
    return step


@functools.partial(jax.jit, static_argnums=(0,))
def _mlp_eval(cfg: MLP.MLPConfig, params, x, y):
    logits = MLP.forward_range(cfg, params, x, 0, cfg.n_layers)
    return losses.accuracy(logits, y)


def mlp_test_accuracy(cfg, params, tx, ty, bs=4096):
    accs = []
    for i in range(0, len(tx), bs):
        accs.append(float(_mlp_eval(cfg, params, tx[i:i + bs], ty[i:i + bs]))
                    * len(tx[i:i + bs]))
    return sum(accs) / len(tx)


def train_mlp_baseline(cfg, data, hp: PaperHP, key, eval_every=1):
    """Conventional training of the unpartitioned network (paper baseline)."""
    tx, ty, vx, vy = data
    params = MLP.init_params(cfg, key)
    opt = make_optimizer("sgdm", hp.lr, momentum=hp.momentum)
    state = opt.init(params)
    step = _make_baseline_step(cfg, opt)
    macs_ps = MLP.macs(cfg)
    hist = {"macs": [], "acc": [], "phase": []}
    cum = 0
    for ep in range(hp.n_baseline):
        for x, y in _batches(tx, ty, hp.batch_size, shuffle=hp.shuffle, seed=ep):
            params, state, _ = step(params, state, x, y)
            cum += macs_ps * len(x)
        if (ep + 1) % eval_every == 0 or ep == hp.n_baseline - 1:
            hist["macs"].append(cum)
            hist["acc"].append(mlp_test_accuracy(cfg, params, vx, vy))
            hist["phase"].append("baseline")
    return params, hist


def train_mlp_pnn(cfg, data, hp: PaperHP, key, eval_every=1):
    """The paper's PNN procedure (Fig. 3 + §5 recovery).

    Returns (joined_params, history).  History logs test accuracy of the
    *joined* network after every epoch, against cumulative per-sample MACs —
    the x-axis of the paper's Figures 6/9/10.
    """
    tx, ty, vx, vy = data
    kp, ks = jax.random.split(jax.random.PRNGKey(0) if key is None else key)
    params = MLP.init_params(cfg, kp)
    left, right = params[:cfg.cut], params[cfg.cut:]
    sil = sil_lib.make_sil(ks, cfg.boundary_width, cfg.n_classes, hp.kappa)

    opt_l = make_optimizer("sgdm", hp.lr, momentum=hp.momentum)
    opt_r = make_optimizer("sgdm", hp.lr_right or hp.lr, momentum=hp.momentum)
    st_l, st_r = opt_l.init(left), opt_r.init(right)
    lstep, rstep = _make_left_step(cfg, opt_l), _make_right_step(cfg, opt_r)

    macs_l = MLP.macs(cfg, 0, cfg.cut)
    macs_r = MLP.macs(cfg, cfg.cut, cfg.n_layers)
    hist = {"macs": [], "acc": [], "phase": []}
    cum = 0

    def log(phase):
        hist["macs"].append(cum)
        hist["acc"].append(mlp_test_accuracy(cfg, left + right, vx, vy))
        hist["phase"].append(phase)

    # -- phase 1: left partition vs SIL (N_L epochs) -----------------------
    for ep in range(hp.n_left):
        for x, y in _batches(tx, ty, hp.batch_size, shuffle=hp.shuffle, seed=ep):
            left, st_l, _ = lstep(left, st_l, x, y, sil)
            cum += macs_l * len(x)
        if (ep + 1) % eval_every == 0:
            log("left")

    # -- boundary materialization (stored once; the paper's only comm) -----
    fwd_left = jax.jit(lambda p, x: MLP.forward_range(cfg, p, x, 0, cfg.cut))
    stored = []
    for x, _ in _batches(tx, ty, hp.batch_size, shuffle=False, seed=0):
        stored.append(np.asarray(fwd_left(left, x)))
    boundary = np.concatenate(stored)
    ty_trunc = ty[: len(boundary)]

    # -- phase 2: right partition on (stored boundary, true labels) --------
    for ep in range(hp.n_right):
        for h, y in _batches(boundary, ty_trunc, hp.batch_size,
                             shuffle=hp.shuffle, seed=100 + ep):
            right, st_r, _ = rstep(right, st_r, h, y)
            cum += macs_r * len(h)
        if (ep + 1) % eval_every == 0 or ep == hp.n_right - 1:
            log("right")

    # -- §5 recovery: left fine-tuned end-to-end, right frozen -------------
    if hp.n_recovery:
        rec_lr = hp.lr_recovery or (hp.lr_right or hp.lr) / 10.0
        opt_rec = make_optimizer("sgdm", rec_lr, momentum=hp.momentum)
        st_rec = opt_rec.init(left)
        rec = _make_recovery_step(cfg, opt_rec)
        macs_full = MLP.macs(cfg)
        for ep in range(hp.n_recovery):
            for x, y in _batches(tx, ty, hp.batch_size, shuffle=hp.shuffle,
                                 seed=200 + ep):
                left, st_rec, _ = rec(left, st_rec, right, x, y)
                cum += macs_full * len(x)
            log("recovery")

    return left + right, hist


def train_mlp_parallel_sil(cfg, data, hp: PaperHP, key, n_stages=3,
                           epochs=40):
    """Fig. 5 mode: every stage trains simultaneously (no dependencies);
    interior stages use SIL as both input and label.  The paper deems this
    impractical (needs many epochs) — implemented for completeness."""
    tx, ty, vx, vy = data
    keys = jax.random.split(key, n_stages + 2)
    params = MLP.init_params(cfg, keys[0])
    # stage bounds at layer granularity (contiguous, balanced)
    base, rem = divmod(cfg.n_layers, n_stages)
    bounds, s = [], 0
    for k in range(n_stages):
        e = s + base + (1 if k < rem else 0)
        bounds.append((s, e))
        s = e
    sils = [sil_lib.make_sil(keys[1 + k], cfg.sizes[bounds[k][1]],
                             cfg.n_classes, hp.kappa)
            for k in range(n_stages - 1)]

    stages = [params[b0:b1] for b0, b1 in bounds]
    opts = [make_optimizer("sgdm", hp.lr, momentum=hp.momentum)
            for _ in range(n_stages)]
    states = [o.init(sp) for o, sp in zip(opts, stages)]

    def make_step(k):
        b0, b1 = bounds[k]

        @jax.jit
        def step(sp, st, xin, y):
            def loss_fn(p):
                h = MLP.forward_range(cfg, p, xin, b0, b1)
                if k == n_stages - 1:
                    return losses.cross_entropy(h, y)
                return losses.sil_stage_loss(h, sils[k], y)
            loss, grads = jax.value_and_grad(loss_fn)(sp)
            sp2, st2 = opts[k].update(grads, st, sp)
            return sp2, st2, loss
        return step

    steps = [make_step(k) for k in range(n_stages)]
    for ep in range(epochs):
        for x, y in _batches(tx, ty, hp.batch_size, shuffle=True, seed=ep):
            for k in range(n_stages):
                xin = x if k == 0 else sil_lib.sil_lookup(sils[k - 1], y)
                stages[k], states[k], _ = steps[k](stages[k], states[k], xin, y)
    joined = sum(stages, [])
    return joined, mlp_test_accuracy(cfg, joined, vx, vy)


# ==========================================================================
# Transformer generalization
# ==========================================================================

@dataclass
class PNNStageHP:
    steps: int
    lr: float = 1e-3
    optimizer: str = "adamw"


@dataclass
class PNNLMConfig:
    n_stages: int = 2
    kappa: float = 1.0
    stages: Optional[List[PNNStageHP]] = None
    recovery_steps: int = 0
    recovery_lr: float = 1e-4


def build_stage_step(cfg, plan, k, stage_sil, opt):
    """Jitted train step for stage k of a transformer.

    Interior stages: SIL-MSE on the boundary residual stream.
    Last stage: CE (+ MoE aux) through the real unembedding.
    """
    last = k == plan.n_stages - 1

    @jax.jit
    def step(stage_params, opt_state, xin, labels, mask=None):
        def loss_fn(p):
            out, aux = partition.stage_forward(cfg, plan, k, p, xin)
            if last:
                loss, _ = losses.train_objective(cfg, out, labels, aux, mask)
                return loss
            bound = out[0] if cfg.enc_dec else out
            loss = losses.sil_stage_loss(bound, stage_sil, labels)
            if cfg.moe is not None:
                loss = loss + cfg.moe.load_balance_loss * aux["lb_loss"] \
                    + cfg.moe.router_z_loss * aux["z_loss"]
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(stage_params)
        stage_params, opt_state = opt.update(grads, opt_state, stage_params)
        return stage_params, opt_state, loss

    return step


def build_prefix_forward(cfg, plan, k):
    """Jitted frozen forward of stages < k (boundary producer).

    This is the paper's sole inter-partition communication: the output of the
    previously-trained partitions feeding the current one.
    """
    @jax.jit
    def fwd(prefix_params: tuple, batch):
        x = batch
        for j in range(k):
            x, _ = partition.stage_forward(cfg, plan, j, prefix_params[j], x,
                                           remat=False)
        return x
    return fwd


def pnn_train_lm(cfg, plan, params, batch_fn: Callable[[int], dict],
                 pnn: PNNLMConfig, key):
    """Stage-sequential PNN training of a transformer LM.

    batch_fn(step) -> {'tokens', 'labels', ...}.  Returns (joined params,
    history).  Each stage holds ONLY its own params + optimizer state while
    training (the paper's memory claim); earlier stages are frozen inputs.
    """
    stage_hps = pnn.stages or [PNNStageHP(steps=50)] * plan.n_stages
    keys = jax.random.split(key, plan.n_stages)
    sils = [sil_lib.make_sil(keys[k], cfg.d_model, cfg.vocab_size, pnn.kappa)
            for k in range(plan.n_stages - 1)]

    stage_params = [partition.slice_stage_params(cfg, plan, params, k)
                    for k in range(plan.n_stages)]
    hist = {"stage": [], "step": [], "loss": []}
    step_idx = 0
    for k in range(plan.n_stages):
        hp = stage_hps[k]
        opt = make_optimizer(hp.optimizer, hp.lr)
        st = opt.init(stage_params[k])
        stage_sil = sils[k] if k < plan.n_stages - 1 else None
        step = build_stage_step(cfg, plan, k, stage_sil, opt)
        prefix = build_prefix_forward(cfg, plan, k)
        frozen = tuple(stage_params[:k])
        for i in range(hp.steps):
            batch = batch_fn(step_idx)
            xin = batch if k == 0 else prefix(frozen, batch)
            labels = batch["labels"]
            mask = batch.get("mask")
            stage_params[k], st, loss = step(stage_params[k], st, xin,
                                             labels, mask)
            hist["stage"].append(k)
            hist["step"].append(step_idx)
            hist["loss"].append(float(loss))
            step_idx += 1

    joined = partition.join_stage_params(cfg, plan, stage_params)

    # recovery (§5): fine-tune stage 0 end-to-end with the rest frozen
    # (see below)
    if pnn.recovery_steps:
        opt = make_optimizer("adamw", pnn.recovery_lr)
        st = opt.init(stage_params[0])

        @jax.jit
        def rec_step(p0, st, batch):
            def loss_fn(p0_):
                x = batch
                sp = [p0_] + [jax.lax.stop_gradient(s)
                              for s in stage_params[1:]]
                for j in range(plan.n_stages):
                    x, aux = partition.stage_forward(cfg, plan, j, sp[j], x)
                loss, _ = losses.train_objective(cfg, x, batch["labels"], aux,
                                                 batch.get("mask"))
                return loss
            loss, grads = jax.value_and_grad(loss_fn)(p0)
            p0, st2 = opt.update(grads, st, p0)
            return p0, st2, loss

        for i in range(pnn.recovery_steps):
            batch = batch_fn(step_idx)
            stage_params[0], st, loss = rec_step(stage_params[0], st, batch)
            hist["stage"].append(-1)  # recovery
            hist["step"].append(step_idx)
            hist["loss"].append(float(loss))
            step_idx += 1
        joined = partition.join_stage_params(cfg, plan, stage_params)

    return joined, hist


def pnn_parallel_train_lm(cfg, plan, params, batch_fn: Callable[[int], dict],
                          pnn: PNNLMConfig, key):
    """Fig.-5 mode at transformer scale: ALL stages train simultaneously.

    Interior stage k consumes synthetic inputs SIL_{k-1}[:, y_t] (broadcast
    over positions) and regresses to SIL_k[:, y_t]; stage 0 consumes the real
    batch; the last stage consumes SIL_{last-1}[:, y_t] and trains with CE.
    Zero inter-stage dependencies — on the multi-pod mesh every pod trains
    its stage concurrently with NO communication at all (the paper deems the
    mode impractical for accuracy; implemented for completeness and measured
    in examples/pnn_transformer.py --parallel).
    """
    stage_hps = pnn.stages or [PNNStageHP(steps=50)] * plan.n_stages
    keys = jax.random.split(key, plan.n_stages)
    sils = [sil_lib.make_sil(keys[k], cfg.d_model, cfg.vocab_size, pnn.kappa)
            for k in range(plan.n_stages - 1)]

    stage_params = [partition.slice_stage_params(cfg, plan, params, k)
                    for k in range(plan.n_stages)]
    opts = [make_optimizer(hp.optimizer, hp.lr) for hp in stage_hps]
    states = [opts[k].init(stage_params[k]) for k in range(plan.n_stages)]

    def make_step(k):
        last = k == plan.n_stages - 1
        opt = opts[k]

        @jax.jit
        def step(sp, st, xin, labels):
            def loss_fn(p):
                out, aux = partition.stage_forward(cfg, plan, k, p, xin)
                if last:
                    loss, _ = losses.train_objective(cfg, out, labels, aux)
                    return loss
                bound = out[0] if cfg.enc_dec else out
                return losses.sil_stage_loss(bound, sils[k], labels)
            loss, grads = jax.value_and_grad(loss_fn)(sp)
            sp2, st2 = opt.update(grads, st, sp)
            return sp2, st2, loss
        return step

    steps = [make_step(k) for k in range(plan.n_stages)]
    hist = {"stage": [], "step": [], "loss": []}
    n_steps = max(hp.steps for hp in stage_hps)
    for i in range(n_steps):
        batch = batch_fn(i)
        labels = batch["labels"]
        for k in range(plan.n_stages):
            if i >= stage_hps[k].steps:
                continue
            if k == 0:
                xin = batch
            else:
                syn = sil_lib.sil_lookup(sils[k - 1], labels).astype(
                    cfg.activation_dtype())
                xin = (syn, None) if cfg.enc_dec else syn
            stage_params[k], states[k], loss = steps[k](
                stage_params[k], states[k], xin, labels)
            hist["stage"].append(k)
            hist["step"].append(i)
            hist["loss"].append(float(loss))

    return partition.join_stage_params(cfg, plan, stage_params), hist
