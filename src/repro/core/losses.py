"""Losses: stable cross-entropy, the SIL-MSE stage loss, and the combined
training objective (CE + MoE auxiliaries)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sil_mse import sil_mse


def cross_entropy(logits, labels, mask=None, vocab_size=None):
    """Mean token CE. logits (..., V) any float dtype; labels int (...).

    vocab_size: real vocab when logits carry padded columns (masked out)."""
    lf = logits.astype(jnp.float32)
    if vocab_size is not None and vocab_size < lf.shape[-1]:
        pad_mask = jnp.arange(lf.shape[-1]) < vocab_size
        lf = jnp.where(pad_mask, lf, -1e30)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        m = mask.astype(jnp.float32)
        return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    return nll.mean()


def accuracy(logits, labels, mask=None):
    pred = jnp.argmax(logits, axis=-1)
    hit = (pred == labels).astype(jnp.float32)
    if mask is not None:
        m = mask.astype(jnp.float32)
        return (hit * m).sum() / jnp.maximum(m.sum(), 1.0)
    return hit.mean()


def sil_stage_loss(boundary_act, sil, labels):
    """Paper's left-partition loss: MSE(boundary, SIL[:, y]).

    boundary_act: (..., d); labels: int (...) matching leading dims.
    Tokens are flattened; goes through the fused kernel path.
    """
    d = boundary_act.shape[-1]
    act = boundary_act.reshape(-1, d)
    lab = labels.reshape(-1)
    return sil_mse(act, sil, lab)


def train_objective(cfg, logits, labels, aux, mask=None):
    """CE + MoE auxiliary losses (coefficients from the MoE config)."""
    loss = cross_entropy(logits, labels, mask,
                         vocab_size=getattr(cfg, "vocab_size", None))
    metrics = {"ce": loss}
    if cfg.moe is not None:
        loss = loss + cfg.moe.load_balance_loss * aux["lb_loss"] \
            + cfg.moe.router_z_loss * aux["z_loss"]
        metrics["lb"] = aux["lb_loss"]
        metrics["z"] = aux["z_loss"]
    metrics["loss"] = loss
    return loss, metrics
