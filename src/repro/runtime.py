"""Process-wide runtime knobs shared by the train and serve hot paths.

Buffer donation is the one invariant that used to be gated by two
independent hard-coded backend checks (``train.backends.donate_argnums``
and ``serve.Engine._donate``), which made the donation story invisible to
any CPU-hosted introspection: a trace on the CI container always saw zero
donated invars, so coverage regressions on TPU could never be caught before
they shipped.  Both sites now route through here, and
``REPRO_ASSUME_DONATION=1`` makes the jit wrappers *request* donation
regardless of backend — callers that only trace (``jax.make_jaxpr`` /
``jax.eval_shape``, e.g. ``repro.analysis``) see the real donation masks
without ever compiling, so no CPU "donation unimplemented" warnings fire.
"""
from __future__ import annotations

import contextlib
import os
from typing import Tuple

import jax

_ASSUME_ENV = "REPRO_ASSUME_DONATION"


def donation_assumed() -> bool:
    return os.environ.get(_ASSUME_ENV, "") == "1"


def donation_enabled() -> bool:
    """Whether jitted steps should request buffer donation: real backends
    that implement aliasing, or any backend under REPRO_ASSUME_DONATION=1
    (trace-only introspection)."""
    if donation_assumed():
        return True
    try:
        backend = jax.default_backend()
    except Exception:  # pragma: no cover
        return False
    return backend in ("gpu", "tpu")


def donate_argnums(*nums: int) -> Tuple[int, ...]:
    """The donate_argnums tuple to pass to jax.jit — ``nums`` where donation
    is enabled, ``()`` elsewhere (CPU would warn per call and ignore it)."""
    return tuple(nums) if donation_enabled() else ()


@contextlib.contextmanager
def assume_donation():
    """Force donation requests on for the duration (restores the prior env).

    Only safe around code that traces — executing a donate-jitted step on
    CPU under this context would emit XLA donation warnings."""
    prev = os.environ.get(_ASSUME_ENV)
    os.environ[_ASSUME_ENV] = "1"
    try:
        yield
    finally:
        if prev is None:
            os.environ.pop(_ASSUME_ENV, None)
        else:
            os.environ[_ASSUME_ENV] = prev
