"""xlstm-125m [ssm] — alternating sLSTM + mLSTM blocks, d_ff=0 [arXiv:2405.04517]."""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm", n_layers=12, d_model=768, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab_size=50304,
    xlstm=XLSTMConfig(pattern="ms", proj_factor=2.0, chunk_size=64),
    norm="layernorm", mlp_type="gelu", tie_embeddings=True,
    source="arXiv:2405.04517",
)


def smoke():
    return CONFIG.replace(n_layers=4, d_model=256, n_heads=4, n_kv_heads=4,
                          vocab_size=512, max_seq=4096)
