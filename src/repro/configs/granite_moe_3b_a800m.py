"""granite-moe-3b-a800m [moe] — 40 experts top-8, d_ff=512.

The assignment header says "MoE 40e top-8"; its trailing note says "32
experts top-8" — we follow the structured field (40e). See DESIGN.md §4.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, d_ff=512, vocab_size=49155,
    moe=MoEConfig(num_experts=40, top_k=8), norm="rmsnorm", mlp_type="swiglu",
    tie_embeddings=True, source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)


def smoke():
    return CONFIG.replace(n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=512,
                          moe=MoEConfig(num_experts=4, top_k=2), max_seq=4096)
