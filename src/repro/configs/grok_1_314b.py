"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144, n_heads=48,
    n_kv_heads=8, d_ff=32768, vocab_size=131072,
    moe=MoEConfig(num_experts=8, top_k=2), norm="rmsnorm", mlp_type="swiglu",
    param_dtype="bfloat16", source="hf:xai-org/grok-1",
)


def smoke():
    return CONFIG.replace(n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
                          d_ff=512, vocab_size=512, param_dtype="float32",
                          moe=MoEConfig(num_experts=4, top_k=2), max_seq=4096)
