"""llava-next-34b [vlm] — anyres tiling; vision encoder stubbed, patch
embeddings enter via input_specs [hf:llava-hf/llava-v1.6-mistral-7b-hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm", n_layers=60, d_model=7168, n_heads=56,
    n_kv_heads=8, d_ff=20480, vocab_size=64000, frontend="vision",
    vision_tokens=2880,  # anyres: 4 tiles + base, 576 patches each
    norm="rmsnorm", mlp_type="swiglu", param_dtype="bfloat16",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)


def smoke():
    return CONFIG.replace(n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
                          d_ff=512, vocab_size=512, vision_tokens=16,
                          param_dtype="float32", max_seq=4096)
