"""whisper-tiny [audio] — enc-dec, conv/mel frontend stubbed [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio", n_layers=4, d_model=384, n_heads=6,
    n_kv_heads=6, d_ff=1536, vocab_size=51865, norm="layernorm",
    mlp_type="gelu", enc_dec=True, enc_layers=4, enc_seq=1500,
    frontend="audio", max_seq=32768, source="arXiv:2212.04356",
)


def smoke():
    return CONFIG.replace(n_layers=2, enc_layers=2, d_model=128, n_heads=4,
                          n_kv_heads=4, d_ff=256, vocab_size=512, enc_seq=64,
                          max_seq=4096)
