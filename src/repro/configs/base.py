"""Config system for the repro framework.

Every assigned architecture gets one module in this package exposing
``CONFIG`` (the exact assigned shape) and ``smoke()`` (a reduced variant of
the same family for CPU tests).  ``repro.configs.get(name)`` resolves either.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # GShard-style capacity factor: tokens_per_expert = capacity_factor *
    # tokens * top_k / num_experts, rounded up to a multiple of 8.
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2
    # Apply MoE to every `every` FFN (1 = all layers).
    every: int = 1


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective SSM block parameters."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class XLSTMConfig:
    """Alternating sLSTM/mLSTM block pattern. 'm'/'s' per layer, cycled."""
    pattern: str = "ms"
    proj_factor: float = 2.0  # up-projection inside mLSTM blocks
    chunk_size: int = 64      # chunkwise-parallel mLSTM chunk


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm | mlp
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0   # chatglm3 "2d RoPE": rotary on half the head dim
    sliding_window: int = 0      # 0 = full attention; >0 enables window variant
    # block details
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    mlp_type: str = "swiglu"     # swiglu | gelu
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    # hybrid (jamba): one attention layer per `attn_period` layers, rest mamba
    attn_period: int = 0
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # encoder-decoder (whisper): decoder = n_layers, encoder = enc_layers
    enc_dec: bool = False
    enc_layers: int = 0
    enc_seq: int = 0             # fixed encoder frame count (whisper: 1500)
    # modality frontend stub: none | audio | vision
    frontend: str = "none"
    vision_tokens: int = 0       # VLM: patch-embedding token budget inside the sequence
    # perf knobs (set by the launch layer, not by arch configs)
    # mesh axes to pin recurrent-scan carries/inputs to on the batch dim
    # (everything else replicated) — kills per-timestep GSPMD resharding
    recurrent_sharding: Optional[Tuple[str, ...]] = None
    # sequence-parallel attention: batch axes tuple; Q stays sequence-sharded
    # over the model axis, only K/V are gathered (GQA: far narrower than the
    # residual) — see EXPERIMENTS.md §Perf
    context_sharding: Optional[Tuple[str, ...]] = None
    # locality-grouped MoE dispatch: split tokens into N independent dispatch
    # groups (align N with the data-shard count for chip-local routing)
    moe_dispatch_groups: int = 0
    # gather expert weights over the data axis before expert matmuls
    # (replaces (E,C,ff)-sized activation psums with weight-sized gathers)
    moe_gather_weights: bool = False
    # numerics — the model-side surface of the repro.precision policy:
    # `dtype` is the COMPUTE dtype (activations, matmul inputs, KV/state
    # caches, boundary spills; set via PrecisionPolicy.apply_to_model or the
    # launchers' --precision flag), `param_dtype` the weight STORAGE dtype.
    # Norms, softmax/attention logits, residual adds, and loss/grad
    # accumulation always run in fp32 (the policy's accum dtype).
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    max_seq: int = 131072
    # citation for the assigned config
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding/unembedding tables are padded to a multiple of 128 so the
        vocab dim always shards cleanly (labels never reach the pad rows)."""
        return -(-self.vocab_size // 128) * 128

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def block_kind(self, layer: int) -> str:
        """Kind of block at `layer`: attn | mamba | slstm | mlstm."""
        if self.family == "ssm" and self.xlstm is not None:
            c = self.xlstm.pattern[layer % len(self.xlstm.pattern)]
            return {"m": "mlstm", "s": "slstm"}[c]
        if self.attn_period and (layer % self.attn_period != self.attn_period - 1):
            return "mamba"
        return "attn"

    def layer_is_moe(self, layer: int) -> bool:
        return self.moe is not None and (layer % self.moe.every == 0)

    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for roofline MODEL_FLOPS = 6*N*D) ----
    def param_counts(self) -> dict:
        """Returns dict with total and active parameter counts (embeddings included
        in total, excluded from 'matmul' counts used for 6ND)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        embed = V * d * (1 if self.tie_embeddings else 2)
        per_layer_attn = d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d
        if self.qkv_bias:
            per_layer_attn += (H + 2 * KV) * hd
        if self.mlp_type == "swiglu":
            per_layer_ffn = 3 * d * ff
        else:
            per_layer_ffn = 2 * d * ff
        # mamba block params
        ssm = self.ssm or SSMConfig()
        d_in = ssm.expand * d
        dt_rank = ssm.dt_rank or -(-d // 16)
        per_mamba = (d * 2 * d_in + ssm.d_conv * d_in
                     + d_in * (dt_rank + 2 * ssm.d_state) + dt_rank * d_in
                     + d_in * d + 2 * d_in)
        # xlstm blocks
        x = self.xlstm or XLSTMConfig()
        d_up = int(x.proj_factor * d)
        per_mlstm = d * d_up * 2 + 3 * d_up * d_up + d_up * d  # up, q/k/v+gates, down
        per_slstm = 4 * d * d + 4 * d * d + d * d              # in/rec/out proj approx
        total = embed
        active = embed
        for l in range(self.n_layers):
            kind = self.block_kind(l)
            if kind == "attn":
                total += per_layer_attn
                active += per_layer_attn
            elif kind == "mamba":
                total += per_mamba
                active += per_mamba
            elif kind == "mlstm":
                total += per_mlstm
                active += per_mlstm
            elif kind == "slstm":
                total += per_slstm
                active += per_slstm
            if kind in ("attn", "mamba") and ff > 0:
                if self.layer_is_moe(l):
                    m = self.moe
                    total += m.num_experts * per_layer_ffn + d * m.num_experts
                    active += m.top_k * per_layer_ffn + d * m.num_experts
                else:
                    total += per_layer_ffn
                    active += per_layer_ffn
        if self.enc_dec:
            # encoder self-attn + gelu ffn; decoder cross-attn
            total += self.enc_layers * (per_layer_attn + 2 * d * ff)
            active += self.enc_layers * (per_layer_attn + 2 * d * ff)
            total += self.n_layers * per_layer_attn  # cross-attention
            active += self.n_layers * per_layer_attn
        return {"total": int(total), "active": int(active), "embed": int(embed)}


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

ARCH_NAMES = [
    "qwen2-1.5b", "mistral-large-123b", "stablelm-3b", "whisper-tiny",
    "chatglm3-6b", "grok-1-314b", "granite-moe-3b-a800m",
    "jamba-1.5-large-398b", "xlstm-125m", "llava-next-34b",
]


def get(name: str, smoke: bool = False) -> ModelConfig:
    """Resolve an architecture config by id (module name uses underscores)."""
    import importlib
    mod = importlib.import_module(
        "repro.configs." + name.replace("-", "_").replace(".", "_"))
    return mod.smoke() if smoke else mod.CONFIG
