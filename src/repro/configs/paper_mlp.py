"""The paper's own experiment config (§3): 6-layer FC net on EMNIST-47."""
from repro.models.mlp import MLPConfig

CONFIG = MLPConfig()

# paper hyperparameters (§3-§5)
KAPPA = 10.0
N_L = 5
N_R = 160
N_B = 40
N_RECOVERY = 10
BATCH_SIZE = 1410
LR = 0.01
MOMENTUM = 0.9


def smoke():
    return MLPConfig(sizes=(784, 32, 16, 16, 47), cut=2)
