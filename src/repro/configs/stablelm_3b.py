"""stablelm-3b [dense] — MHA kv=32, partial rotary [hf:stabilityai/stablelm-2-1_6b]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense", n_layers=32, d_model=2560, n_heads=32,
    n_kv_heads=32, d_ff=6912, vocab_size=50304, rope_fraction=0.25,
    norm="layernorm", mlp_type="swiglu",
    source="hf:stabilityai/stablelm-2-1_6b",
)


def smoke():
    return CONFIG.replace(n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
                          d_ff=512, vocab_size=512, max_seq=4096)
