"""qwen2-1.5b [dense] — GQA kv=2, QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense", n_layers=28, d_model=1536, n_heads=12,
    n_kv_heads=2, d_ff=8960, vocab_size=151936, qkv_bias=True,
    rope_theta=1e6, norm="rmsnorm", mlp_type="swiglu", tie_embeddings=True,
    source="arXiv:2407.10671",
)


def smoke():
    return CONFIG.replace(n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
                          d_ff=512, vocab_size=512, max_seq=4096)
