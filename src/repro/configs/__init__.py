from repro.configs.base import (  # noqa: F401
    ARCH_NAMES, INPUT_SHAPES, InputShape, ModelConfig, MoEConfig, SSMConfig,
    XLSTMConfig, get)
