"""mistral-large-123b [dense] — 88L GQA kv=8 [hf:mistralai/Mistral-Large-Instruct-2407]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense", n_layers=88, d_model=12288,
    n_heads=96, n_kv_heads=8, d_ff=28672, vocab_size=32768,
    rope_theta=1e6, norm="rmsnorm", mlp_type="swiglu",
    param_dtype="bfloat16", source="hf:mistralai/Mistral-Large-Instruct-2407",
)


def smoke():
    return CONFIG.replace(n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
                          d_ff=512, vocab_size=512, param_dtype="float32",
                          max_seq=4096)
