"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7, MoE 16e top-2 every other
layer [arXiv:2403.19887]."""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid", n_layers=72, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=24576, vocab_size=65536,
    moe=MoEConfig(num_experts=16, top_k=2, every=2), attn_period=8,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2), norm="rmsnorm",
    mlp_type="swiglu", param_dtype="bfloat16", source="arXiv:2403.19887",
)


def smoke():
    # attn_period reduced to 2 so a 2-layer smoke still exercises the full
    # block-kind pattern (1 mamba+MoE layer, 1 attn+dense layer)
    return CONFIG.replace(n_layers=4, attn_period=2, d_model=256, n_heads=4,
                          n_kv_heads=2, d_ff=512, vocab_size=512,
                          param_dtype="float32",
                          moe=MoEConfig(num_experts=4, top_k=2, every=2),
                          max_seq=4096)
