"""chatglm3-6b [dense] — 2d (half-dim) RoPE, GQA kv=2, QKV bias [arXiv:2406.12793]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense", n_layers=28, d_model=4096, n_heads=32,
    n_kv_heads=2, d_ff=13696, vocab_size=65024, qkv_bias=True,
    rope_fraction=0.5, norm="rmsnorm", mlp_type="swiglu",
    source="arXiv:2406.12793",
)


def smoke():
    return CONFIG.replace(n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
                          d_ff=512, vocab_size=512, max_seq=4096)
