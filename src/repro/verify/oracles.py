"""The registered equivalence oracles.

Every contract the codebase has historically asserted ad hoc — kernel ==
reference, concurrent == sequential, batched == sequential decode, fused ==
per-token, bf16 ~= fp32, resume+replay == uninterrupted, staged == joined —
lives here as one declarative registration.  Adding a feature with an
equivalence claim means adding one ``@register`` block; the pytest
collector and the ``launch/verify`` CLI pick it up automatically.

Naming: ``group/contract``.  Groups mirror the subsystems: ``kernel``,
``train``, ``serve``, ``precision``, ``checkpoint``, ``paper``.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.verify import scenarios
from repro.verify.compare import AccuracyGap, Allclose, Bitwise, TokensEqual
from repro.verify.oracle import Context, register

KEY = jax.random.PRNGKey(0)


# ==========================================================================
# kernels: each Pallas kernel (interpret mode off-TPU) vs its pure-jnp ref
# ==========================================================================

def _fa_shapes(preset: str):
    tiny = [(1, 64, 4, 2, 32, jnp.float32, True, 0),
            (1, 48, 4, 4, 32, jnp.bfloat16, True, 16),
            (1, 40, 2, 2, 32, jnp.float32, False, 0)]
    full = tiny + [(2, 256, 4, 2, 64, jnp.float32, True, 0),
                   (2, 200, 8, 2, 128, jnp.bfloat16, True, 64)]
    return full if preset == "full" else tiny


@register("kernel/flash_attention",
          "Pallas flash attention == naive attention reference "
          "(fp32 + bf16, causal/window variants)",
          Allclose(), tags=("kernel",))
def _flash_attention(ctx: Context):
    from repro.kernels.flash_attention import ref
    from repro.kernels.flash_attention.kernel import flash_attention_tpu
    ref_out, opt_out = {}, {}
    for b, s, h, kv, d, dtype, causal, window in _fa_shapes(ctx.preset):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, s, h, d), dtype)
        k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
        v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
        name = f"s{s}_{jnp.dtype(dtype).name}_c{int(causal)}_w{window}"
        opt_out[name] = flash_attention_tpu(q, k, v, causal=causal,
                                            window=window)
        ref_out[name] = ref.naive_attention(q, k, v, causal=causal,
                                            window=window)
    return ref_out, opt_out


@register("kernel/decode_attention",
          "Pallas decode attention over a KV cache == reference "
          "(scalar / ragged / ring-full position variants)",
          Allclose(), tags=("kernel", "serve"))
def _decode_attention(ctx: Context):
    from repro.kernels.flash_attention import ref
    from repro.kernels.flash_attention.kernel import decode_attention_tpu
    b, lc, h, kv, d = (2, 64, 8, 2, 64) if ctx.preset == "full" \
        else (2, 32, 4, 2, 32)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, lc, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, lc, kv, d), jnp.float32)
    ref_out, opt_out = {}, {}
    for name, pos in [("partial", lc // 2),
                      ("ragged", jnp.arange(b, dtype=jnp.int32) + 3),
                      ("ring_full", 2 * lc)]:
        opt_out[name] = decode_attention_tpu(q, k, v, pos, bk=16)
        ref_out[name] = ref.decode_attention(q, k, v, pos)
    return ref_out, opt_out


@register("kernel/selective_scan",
          "Pallas chunked selective scan == reference scan (outputs and "
          "final recurrent state)",
          Allclose(rtol=1e-4, atol=1e-4), tags=("kernel",))
def _selective_scan(ctx: Context):
    from repro.kernels.selective_scan import ref
    from repro.kernels.selective_scan.kernel import selective_scan_tpu
    ba, s, di, n = (2, 128, 64, 16) if ctx.preset == "full" \
        else (2, 64, 32, 8)
    ks = jax.random.split(KEY, 6)
    u = jax.random.normal(ks[0], (ba, s, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (ba, s, di)))
    A = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.5)
    B = jax.random.normal(ks[3], (ba, s, n))
    C = jax.random.normal(ks[4], (ba, s, n))
    D = jax.random.normal(ks[5], (di,))
    y, h = selective_scan_tpu(u, dt, A, B, C, D, chunk=32, bd=32)
    ey, eh = ref.selective_scan(u, dt, A, B, C, D, chunk=32)
    return {"y": ey, "h": eh}, {"y": y, "h": h}


@register("kernel/sil_mse",
          "Pallas fused SIL-MSE (loss + activation grad) == reference "
          "(fp32 + bf16 activations, fp32 accumulation)",
          Allclose(rtol=5e-2, atol=1e-4), tags=("kernel", "train"))
def _sil_mse(ctx: Context):
    from repro.kernels.sil_mse import ref
    from repro.kernels.sil_mse.kernel import sil_mse_fwd_tpu
    t, d, m = (256, 512, 1000) if ctx.preset == "full" else (64, 60, 47)
    ref_out, opt_out = {}, {}
    for dtype in (jnp.float32, jnp.bfloat16):
        ks = jax.random.split(KEY, 3)
        act = jax.random.normal(ks[0], (t, d), dtype)
        sil = jax.random.uniform(ks[1], (d, m), jnp.float32) * 10
        lab = jax.random.randint(ks[2], (t,), 0, m)
        loss, grad = sil_mse_fwd_tpu(act, sil, lab, bt=32, bd=32)
        name = jnp.dtype(dtype).name
        opt_out[name] = {"loss": loss,
                         "grad": grad.astype(jnp.float32)}
        ref_out[name] = {"loss": ref.sil_mse(act, sil, lab),
                         "grad": ref.sil_mse_grad_act(act, sil, lab)
                         .astype(jnp.float32)}
    return ref_out, opt_out


# ==========================================================================
# train: device-placed concurrent execution vs the sequential phase
# ==========================================================================

@register("train/mlp_dist_vs_sequential",
          "ParallelSilPhase through the dist.StageExecutor (device-placed, "
          "async ticks) == the sequential phase loop, MLP backend",
          Allclose(), tags=("train", "dist"))
def _mlp_dist_vs_sequential(ctx: Context):
    from repro.train import recipes
    n = 3 if ctx.preset == "tiny" else 4
    cfg, data, spec = scenarios.tiny_mlp(
        n_stages=n, epochs=(2,) * n,
        n_train=1024 if ctx.preset == "tiny" else 8192)
    key = jax.random.PRNGKey(0)
    p_seq, _ = recipes.run_mlp_fig5(cfg, data, spec, key, n_stages=n)
    p_con, _ = recipes.run_mlp_fig5(cfg, data, spec, key, n_stages=n,
                                    dist="round_robin")
    return p_seq, p_con


@register("train/lm_dist_vs_sequential",
          "ParallelSilPhase through the dist.StageExecutor == sequential, "
          "LM backend (params and drained loss curves)",
          Allclose(), tags=("train", "dist"), arch_aware=True)
def _lm_dist_vs_sequential(ctx: Context):
    from repro.train import recipes
    steps = 2 if ctx.preset == "tiny" else 4
    cfg, plan, batch_fn, spec, params = scenarios.tiny_lm(
        ctx.arch, steps=steps, n_stages=2)
    key = jax.random.PRNGKey(1)
    p_seq, h_seq = recipes.run_lm_parallel(cfg, plan, params, batch_fn,
                                           spec, key)
    p_con, h_con = recipes.run_lm_parallel(cfg, plan, params, batch_fn,
                                           spec, key, dist="round_robin")
    return ({"params": p_seq, "loss": h_seq.column("loss")},
            {"params": p_con, "loss": h_con.column("loss")})


# ==========================================================================
# serve: every engine optimization is a pure latency change, never tokens
# ==========================================================================

def _serve_world(ctx: Context):
    cfg = scenarios.serve_cfg(ctx.arch)
    params = scenarios.serve_params(cfg)
    lens, news = ((8, 12, 5, 10), (6, 9, 4, 7)) if ctx.preset == "full" \
        else ((8, 5, 10), (5, 4, 6))
    return cfg, params, scenarios.serve_requests(cfg, lens, news)


@register("serve/batched_vs_sequential",
          "Engine continuous batching (slot pool, batched admission) == "
          "one-request-at-a-time prefill+decode, token-identical",
          TokensEqual(), tags=("serve",), arch_aware=True)
def _batched_vs_sequential(ctx: Context):
    from repro.serve import Engine
    cfg, params, reqs = _serve_world(ctx)
    outs = Engine(cfg, params, max_slots=2, decode_block=4).generate(reqs)
    ref = [scenarios.greedy_reference(cfg, params, r) for r in reqs]
    return ref, [c.tokens for c in outs]


@register("serve/fused_chunk_vs_per_token",
          "Fused multi-token decode (lax.scan chunks, sampling folded in) "
          "== per-token decode (decode_block=1), token-identical",
          TokensEqual(), tags=("serve",), arch_aware=True)
def _fused_vs_per_token(ctx: Context):
    from repro.serve import Engine
    cfg, params, reqs = _serve_world(ctx)
    fused = Engine(cfg, params, max_slots=2, decode_block=8).generate(reqs)
    per_tok = Engine(cfg, params, max_slots=2, decode_block=1).generate(reqs)
    return [c.tokens for c in per_tok], [c.tokens for c in fused]


@register("serve/staged_vs_joined",
          "PartitionPlan-staged serving (partitions deployed unjoined) == "
          "serving the joined params, token-identical",
          TokensEqual(), tags=("serve", "dist"), arch_aware=True)
def _staged_vs_joined(ctx: Context):
    from repro.core import partition
    from repro.serve import Engine
    cfg, params, reqs = _serve_world(ctx)
    joined = Engine(cfg, params, max_slots=2, decode_block=4).generate(reqs)
    plan = partition.make_plan(cfg, 2)
    sp = [partition.slice_stage_params(cfg, plan, params, k)
          for k in range(plan.n_stages)]
    staged = Engine(cfg, plan=plan, stage_params=sp, max_slots=2,
                    decode_block=4).generate(reqs)
    return [c.tokens for c in joined], [c.tokens for c in staged]


@register("serve/paged_vs_contiguous",
          "Block-paged cache pool (block tables, shared-prefix reuse, "
          "garbage block) == the contiguous slot pool, token-identical — "
          "flat and sliding-window attention, joined and staged",
          TokensEqual(), tags=("serve",), arch_aware=True)
def _paged_vs_contiguous(ctx: Context):
    import numpy as np

    from repro.core import partition
    from repro.serve import Engine, Request
    cfg, params, reqs = _serve_world(ctx)
    # a shared-prefix pair: same leading 8 tokens (two full 4-token
    # blocks), so the second admission increfs the first one's blocks
    t0 = np.asarray(reqs[0].tokens, np.int32).reshape(-1)
    t1 = np.concatenate([t0[:8],
                         np.asarray(reqs[1].tokens, np.int32).reshape(-1)])
    reqs = list(reqs) + [Request(tokens=t1.tolist(), gen=reqs[1].gen)]
    want, got = [], []

    def run(paged_engine, contiguous_engine):
        want.extend(c.tokens for c in contiguous_engine.generate(reqs))
        got.extend(c.tokens for c in paged_engine.generate(reqs))

    run(Engine(cfg, params, max_slots=2, decode_block=4, paged=True,
               block_size=4),
        Engine(cfg, params, max_slots=2, decode_block=4))
    cfgw = scenarios.serve_cfg(ctx.arch, window=8)
    run(Engine(cfgw, params, max_slots=2, decode_block=4, paged=True,
               block_size=4),
        Engine(cfgw, params, max_slots=2, decode_block=4))
    plan = partition.make_plan(cfg, 2)
    sp = [partition.slice_stage_params(cfg, plan, params, k)
          for k in range(plan.n_stages)]
    run(Engine(cfg, plan=plan, stage_params=sp, max_slots=2, decode_block=4,
               paged=True, block_size=4),
        Engine(cfg, plan=plan, stage_params=sp, max_slots=2,
               decode_block=4))
    return want, got


# ==========================================================================
# precision: bf16 compute under the PrecisionPolicy reaches fp32 accuracy
# ==========================================================================

@register("precision/bf16_vs_fp32_train",
          "Baseline MLP training under the bf16 PrecisionPolicy (bf16 "
          "compute, fp32 accumulate) reaches fp32 test accuracy",
          AccuracyGap(budget=0.01, floor=0.85), tags=("precision", "train"))
def _bf16_vs_fp32(ctx: Context):
    from repro.models import mlp as MLP
    from repro.train import BaselinePhase, MLPBackend, Trainer
    n_train, epochs = (18800, 20) if ctx.preset == "full" else (9400, 15)
    accs = {}
    for prec in (None, "bf16"):
        cfg, data, spec = scenarios.tiny_mlp(
            n_stages=2, epochs=(), sizes=(784, 32, 16, 16, 47),
            n_train=n_train, n_test=940, batch_size=470, lr=0.02,
            precision=prec, baseline_epochs=epochs)
        be = MLPBackend(cfg, data, spec)
        _, hist = Trainer(be, spec).run([BaselinePhase()],
                                        params=MLP.init_params(cfg, KEY))
        accs[prec] = hist.column("acc")[-1]
    return accs[None], accs["bf16"]


# ==========================================================================
# checkpoint: per-stage resume + replay == uninterrupted training
# ==========================================================================

@register("checkpoint/resume_vs_uninterrupted",
          "Stage failure -> restore from its own checkpoint -> replay "
          "lost ticks == the uninterrupted run, bitwise",
          Bitwise(), tags=("checkpoint", "dist", "train"))
def _resume_vs_uninterrupted(ctx: Context):
    from repro.dist import StageExecutor, placement
    from repro.models import mlp as MLP
    from repro.train import MLPBackend
    from repro.train.backends import balanced_bounds, make_optimizer_for
    n_ticks = 3 if ctx.preset == "tiny" else 6
    cfg, data, spec = scenarios.tiny_mlp(n_stages=3,
                                         epochs=(n_ticks,) * 3)
    be = MLPBackend(cfg, data, spec, bounds=balanced_bounds(cfg, 3))
    params = MLP.init_params(cfg, jax.random.PRNGKey(0))
    sils = be.make_sils(jax.random.PRNGKey(3), spec.kappa)
    sp0 = be.split(params)
    hps = [spec.stage(k) for k in range(3)]
    pl = placement.round_robin(3)

    def make_ex(root, ckpt_every):
        opts = [make_optimizer_for(hp, spec) for hp in hps]
        return StageExecutor(be, pl, sp0, sils, opts, hps, shuffle=True,
                             ckpt_dir=root, ckpt_every=ckpt_every)

    # uninterrupted reference
    ref_ex = make_ex(os.path.join(ctx.workdir, "ref"), ckpt_every=0)
    ref_ex.run(n_ticks)
    ref = ref_ex.gather()

    # interrupted run: stage 1 dies after tick 1, resumes from ITS OWN
    # checkpoint, replays — stages 0/2 keep their live state
    root = os.path.join(ctx.workdir, "stages")
    ex = make_ex(root, ckpt_every=1)
    ex.run(1)
    ex.params[1] = jax.tree_util.tree_map(jnp.zeros_like, ex.params[1])
    assert ex.resume_stage(1, step=1) == 1
    ex.run(n_ticks, stages=[1])
    ex.run(n_ticks, stages=[0, 2])
    return ref, ex.gather()


# ==========================================================================
# resilience: faults injected, recovered, and provably invisible
# ==========================================================================

@register("resilience/crash_equivalence",
          "Training under an injected fault schedule (crash, transient, "
          "checkpoint corruption, straggler) self-heals and finishes "
          "bitwise-equal to the fault-free run",
          Bitwise(), tags=("resilience", "dist", "checkpoint", "train"))
def _crash_equivalence(ctx: Context):
    from repro.dist import StageExecutor, placement
    from repro.models import mlp as MLP
    from repro.resilience import (CheckpointCorruption, FakeClock,
                                  FaultSchedule, RetryPolicy, StageCrash,
                                  StragglerDelay, SupervisedExecutor,
                                  TransientError)
    from repro.train import MLPBackend
    from repro.train.backends import balanced_bounds, make_optimizer_for
    n_ticks = 4 if ctx.preset == "tiny" else 6
    cfg, data, spec = scenarios.tiny_mlp(n_stages=2,
                                         epochs=(n_ticks,) * 2,
                                         n_train=512, batch_size=128)
    be = MLPBackend(cfg, data, spec, bounds=balanced_bounds(cfg, 2))
    params = MLP.init_params(cfg, jax.random.PRNGKey(0))
    sils = be.make_sils(jax.random.PRNGKey(3), spec.kappa)
    sp0 = be.split(params)
    hps = [spec.stage(k) for k in range(2)]
    pl = placement.round_robin(2)

    def make_ex(root):
        opts = [make_optimizer_for(hp, spec) for hp in hps]
        return StageExecutor(be, pl, sp0, sils, opts, hps, shuffle=True,
                             ckpt_dir=root)

    ref_ex = make_ex(os.path.join(ctx.workdir, "ref"))
    ref_ex.run(n_ticks)
    ref = ref_ex.gather()

    # one of each recoverable fault kind, at fixed coordinates so the run
    # is replayable without even a seed
    schedule = FaultSchedule(faults=[
        TransientError(stage=0, tick=1, failures=2),
        StageCrash(stage=1, tick=2),
        StragglerDelay(stage=1, tick=3, delay=0.7),
        CheckpointCorruption(stage=0, tick=3, mode="truncate_manifest"),
    ])
    clk = FakeClock()
    ex = make_ex(os.path.join(ctx.workdir, "chaos"))
    sup = SupervisedExecutor(ex, schedule=schedule, clock=clk.monotonic,
                             sleep=clk.sleep, ckpt_every=1,
                             policy=RetryPolicy(max_retries=4), strict=True)
    sup.run(n_ticks)
    assert not sup.unrecovered, sup.report()
    assert len(sup.faults_seen) >= 4, sup.report()
    return ref, ex.gather()


@register("resilience/nan_skip",
          "A NaN/inf-poisoned batch under the step guard == the same run "
          "with the poisoned batch excised, bitwise (skip leaves params "
          "and optimizer state untouched)",
          Bitwise(), tags=("resilience", "train"))
def _nan_skip(ctx: Context):
    from dataclasses import replace

    import numpy as np

    from repro.models import mlp as MLP
    from repro.optim import read_skipped
    from repro.train import MLPBackend
    from repro.train.backends import (balanced_bounds, make_optimizer_for,
                                      scanned_epoch_fn)
    cfg, data, spec = scenarios.tiny_mlp(n_stages=2, epochs=(1, 1),
                                         n_train=512, batch_size=128)
    spec = replace(spec, nan_guard=True)
    be = MLPBackend(cfg, data, spec, bounds=balanced_bounds(cfg, 2))
    params = MLP.init_params(cfg, jax.random.PRNGKey(0))
    sils = be.make_sils(jax.random.PRNGKey(3), spec.kappa)
    p0 = be.split(params)[0]
    opt = make_optimizer_for(spec.stage(0), spec)
    assert opt.name.startswith("guard("), opt.name
    epoch_fn = scanned_epoch_fn(be.build_parallel_step(0, opt, sils,
                                                       accum=1))
    batches = be.epoch_arrays(0, shuffle=False)
    poison_idx = batches[0].shape[0] // 2
    x = np.asarray(batches[0]).copy()
    x[poison_idx, 0, 0] = np.inf          # one bad batch mid-epoch
    poisoned = (jnp.asarray(x),) + tuple(batches[1:])
    excised = tuple(jnp.concatenate([b[:poison_idx], b[poison_idx + 1:]])
                    for b in batches)

    p_ref, o_ref, _ = epoch_fn(p0, opt.init(be.trainable(p0)), excised)
    p_got, o_got, _ = epoch_fn(p0, opt.init(be.trainable(p0)), poisoned)
    assert int(read_skipped(o_got)) == 1, "guard did not skip the bad batch"
    assert int(read_skipped(o_ref)) == 0
    return p_ref, p_got


# ==========================================================================
# plan: the auto-partitioner's searched cut is as trainable as the hand cut
# ==========================================================================

def _plan_policy(ctx: Context):
    # budgets mirror the paper gate's presets: both runs sit on the same
    # (reduced or full) schedule, so the cut is the only variable
    return AccuracyGap(budget=0.05 if ctx.preset == "tiny" else 0.02,
                       floor=0.6)


@register("plan/auto_vs_hand",
          "Fig.-3 SIL training at the repro.plan searched cut matches the "
          "paper's hand-picked cut within the accuracy budget; on an "
          "equal-width MLP every balanced cut ties and the searcher "
          "reproduces the divmod hand bounds exactly",
          _plan_policy, tags=("plan", "train"))
def _plan_auto_vs_hand(ctx: Context):
    from repro import plan as plan_lib
    from repro.configs import get as get_cfg
    from repro.data.images import emnist_like
    from repro.models.mlp import MLPConfig
    from repro.train import recipes
    from repro.train.backends import mlp_default_bounds, mlp_test_accuracy

    # exact-tie determinism: an equal-width stack makes every balanced cut
    # tie at the optimal bottleneck, and the tie-break must reproduce the
    # hand (divmod) bounds bit-for-bit — auto is a drop-in there
    ucfg = MLPConfig(sizes=(32,) * 7, cut=3)
    for k in (1, 2, 3):
        auto_b = plan_lib.auto_mlp_bounds(ucfg, k)
        hand_b = mlp_default_bounds(ucfg, k)
        assert auto_b == hand_b, \
            f"tie-break drifted at K={k}: {auto_b} != {hand_b}"

    # accuracy parity on the paper's (non-uniform) MLP, where the searcher
    # picks its own cut: same data, spec, and key schedule for both runs.
    # The right stage ramps late (lr_right=0.003): ~80 epochs is where the
    # boundary-trained head separates from chance, so the tiny preset uses
    # the paper gate's own tiny schedule rather than a shorter one
    cfg = get_cfg("paper_mlp")
    n_right, n_recovery = (80, 20) if ctx.preset == "tiny" else (160, 10)
    data = emnist_like(n_train=28200, n_test=2820, seed=0, noise=0.5)
    spec = recipes.paper_spec(n_right=n_right, n_baseline=0,
                              n_recovery=n_recovery)
    key = jax.random.PRNGKey(1)
    p_hand, _ = recipes.run_mlp_fig3(cfg, data, spec, key)
    p_auto, _ = recipes.run_mlp_fig3(
        cfg, data, spec, key, bounds=plan_lib.auto_mlp_bounds(cfg, 2))
    return (mlp_test_accuracy(cfg, p_hand, data[2], data[3]),
            mlp_test_accuracy(cfg, p_auto, data[2], data[3]))


# ==========================================================================
# paper: the reproduction gate (EMNIST 6-layer / 2-stage SIL experiment)
# ==========================================================================

def _paper_policy(ctx: Context):
    from repro.verify import paper
    return paper.gap_policy(ctx.preset)


@register("paper/emnist_parity",
          "PNN (paper Fig. 3 schedule, 2 stages, SIL targets) matches "
          "conventional training accuracy on the EMNIST-like task within "
          "the paper's reported budget",
          _paper_policy,
          tags=("paper", "train"))
def _emnist_parity(ctx: Context):
    from repro.verify import paper
    res = paper.run_paper_parity(ctx.preset)
    return res["baseline_acc"], res["pnn_acc"]
