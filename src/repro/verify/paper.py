"""The paper-parity experiment runner — the repo's end-to-end reproduction
gate.

The paper's central experimental claim (§3-§5): a 6-layer fully-connected
network on EMNIST-balanced, cut into two partitions after the second hidden
layer and trained with synthetic intermediate labels (left vs SIL, one
boundary materialization, right on stored activations, §5 recovery),
reaches testing accuracy similar to conventional end-to-end training at a
fraction of the memory and compute.  This module executes exactly that
comparison through the ``repro.train`` phase API and asserts the accuracy
gap stays within a budget:

* ``tiny`` — CPU-container sized (reduced data and epochs, ~1 min); the
  gate run by CI and the ``paper/emnist_parity`` oracle.  Budget is looser
  because both runs are further from convergence.
* ``full`` — the paper's own sizes (EMNIST-balanced-scale data, N_L=5,
  N_R=160, N_B=40, 10 recovery epochs).  Budget 0.02: the paper reports the
  partitioned accuracy within ~1-2 points of conventional training.

CLI:  PYTHONPATH=src python -m repro.verify.paper --preset tiny
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from dataclasses import asdict, dataclass

import jax

from repro.data.images import emnist_like
from repro.models.mlp import MLPConfig
from repro.train import recipes
from repro.verify.compare import AccuracyGap


@dataclass(frozen=True)
class PaperPreset:
    """One fidelity level of the paper's EMNIST experiment."""
    n_train: int
    n_test: int
    noise: float
    n_left: int          # N_L: left-partition epochs vs SIL
    n_right: int         # N_R: right-partition epochs on the boundary
    n_baseline: int      # N_B: conventional end-to-end epochs
    n_recovery: int      # §5 recovery epochs (stage 0, rest frozen)
    lr_recovery: float   # §5 recovery learning rate
    budget: float        # |acc_baseline - acc_pnn| ceiling
    floor: float         # baseline must at least reach this (learned at all)


PRESETS = {
    # reduced but honest: both schedules train long enough to separate a
    # learned model from chance (floor) before the gap is judged.  Budgets
    # are calibrated against the synthetic EMNIST stand-in, where the
    # conventional baseline saturates (~0.99) — harsher on PNN than the
    # paper's real-EMNIST setting (~0.85 both sides)
    "tiny": PaperPreset(n_train=28200, n_test=2820, noise=0.5,
                        n_left=5, n_right=80, n_baseline=40, n_recovery=20,
                        lr_recovery=3e-4, budget=0.05, floor=0.60),
    # the paper's own schedule (§3: EMNIST-balanced sizes, §4-§5 epochs);
    # measured gap at this fidelity is ~0.001 (PNN slightly ahead), so the
    # 0.02 budget mirrors the paper's "similar testing accuracies" claim
    # with real margin
    "full": PaperPreset(n_train=112800, n_test=18800, noise=0.5,
                        n_left=5, n_right=160, n_baseline=40, n_recovery=10,
                        lr_recovery=3e-4, budget=0.02, floor=0.70),
}


def gap_policy(preset: str) -> AccuracyGap:
    p = PRESETS[preset]
    return AccuracyGap(budget=p.budget, floor=p.floor)


def run_paper_parity(preset: str = "tiny", *, seed: int = 0,
                     eval_every: int = 1000) -> dict:
    """Run baseline vs PNN (Fig. 3 + §5) and measure the accuracy gap.

    Returns a report dict; ``ok`` is the paper's claim verdict.  Both runs
    use the legacy-exact seed schedules (``recipes.run_mlp_*``), the
    paper's batch size/learning rates, and the same data."""
    p = PRESETS[preset]
    cfg = MLPConfig()                      # the paper's exact 6-layer net
    # the SYNTHETIC stand-in, always (not load_emnist): the budgets above
    # are calibrated against this exact distribution, and a stray real
    # data/emnist.npz would silently override the preset's n_train/n_test
    # and invalidate them — the gate must be deterministic everywhere
    data = emnist_like(n_train=p.n_train, n_test=p.n_test, seed=seed,
                       noise=p.noise)
    spec = recipes.paper_spec(n_left=p.n_left, n_right=p.n_right,
                              n_baseline=p.n_baseline,
                              n_recovery=p.n_recovery,
                              lr_recovery=p.lr_recovery)

    t0 = time.perf_counter()
    _, hist_b = recipes.run_mlp_baseline(cfg, data, spec,
                                         jax.random.PRNGKey(seed),
                                         eval_every=eval_every)
    t_base = time.perf_counter() - t0
    t0 = time.perf_counter()
    _, hist_p = recipes.run_mlp_fig3(cfg, data, spec,
                                     jax.random.PRNGKey(seed + 1),
                                     eval_every=eval_every)
    t_pnn = time.perf_counter() - t0

    acc_b = hist_b.column("acc")[-1]
    acc_p = hist_p.column("acc")[-1]
    macs_b = hist_b.column("macs")[-1]
    macs_p = hist_p.column("macs")[-1]
    verdict = gap_policy(preset).compare(acc_b, acc_p)
    return {
        "preset": preset,
        "config": asdict(p),
        "baseline_acc": float(acc_b),
        "pnn_acc": float(acc_p),
        "gap": abs(float(acc_b) - float(acc_p)),
        "budget": p.budget,
        "ok": verdict.ok,
        "detail": verdict.detail,
        # the paper's efficiency axis: cumulative per-sample MACs
        "baseline_macs": int(macs_b),
        "pnn_macs": int(macs_p),
        "macs_ratio": float(macs_p) / float(macs_b),
        "seconds": {"baseline": round(t_base, 1), "pnn": round(t_pnn, 1)},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="paper-parity gate: PNN vs conventional training on "
                    "the EMNIST 6-layer / 2-partition experiment")
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None,
                    help="also write the report to this path")
    args = ap.parse_args(argv)

    res = run_paper_parity(args.preset, seed=args.seed)
    status = "PASS" if res["ok"] else "FAIL"
    print(f"[{status}] paper parity ({args.preset}): "
          f"baseline={res['baseline_acc']:.4f} pnn={res['pnn_acc']:.4f} "
          f"gap={res['gap']:.4f} (budget {res['budget']}) "
          f"macs_ratio={res['macs_ratio']:.2f}")
    if not res["ok"]:
        print("  " + res["detail"])
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1)
        print(f"wrote {args.json}")
    return 0 if res["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
