"""Machine-readable conformance reports (``results/CONFORMANCE_*.json``).

One report = one sweep of the oracle registry under one (preset, arch)
context: environment stamp, per-oracle verdicts with measured errors and
wall-clock, and the pass/fail tallies CI gates on.  The schema is
versioned so downstream tooling (dashboards, the CI artifact diff) can
evolve without guessing.
"""
from __future__ import annotations

import json
import os
from typing import Optional, Sequence

import jax

from repro.verify.oracle import OracleResult

SCHEMA = "repro.verify/1"


def build_report(results: Sequence[OracleResult], *, preset: str,
                 arch: str, extra: Optional[dict] = None) -> dict:
    failed = [r.name for r in results if not r.ok]
    report = {
        "schema": SCHEMA,
        "preset": preset,
        "arch": arch,
        "env": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "n_devices": len(jax.devices()),
            "force_ref": os.environ.get("REPRO_FORCE_REF", ""),
        },
        "n_oracles": len(results),
        "n_passed": sum(r.ok for r in results),
        "n_failed": len(failed),
        "failed": failed,
        "oracles": [r.row() for r in results],
    }
    if extra:
        report.update(extra)
    return report


def write_report(path: str, results: Sequence[OracleResult], *, preset: str,
                 arch: str, extra: Optional[dict] = None) -> dict:
    report = build_report(results, preset=preset, arch=arch, extra=extra)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
    return report
