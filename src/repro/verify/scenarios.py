"""Shared tiny-config scenario builders.

One place for the hand-built mini worlds that the conformance oracles AND
the test suite both need: a reduced MLP training setup, a reduced
PartitionPlan'd LM setup, a serving world, and the one-request-at-a-time
greedy decode reference.  ``tests/conftest.py`` exposes these as fixtures;
``repro.verify.oracles`` calls them directly — so an oracle and its
corresponding test can never drift apart on setup.

Everything here is deterministic (fixed seeds, pure batch functions) so the
bitwise oracles stay bitwise.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.data.images import emnist_like
from repro.models import model as M
from repro.models.mlp import MLPConfig
from repro.train import StageSpec, TrainSpec


# --------------------------------------------------------------------------
# MLP world (the paper's experiment, reduced)
# --------------------------------------------------------------------------

def tiny_mlp(n_stages: int = 3, epochs: Sequence[int] = (2, 2, 2), *,
             n_train: int = 1024, n_test: int = 128, batch_size: int = 128,
             lr: float = 0.01, kappa: float = 10.0, noise: float = 0.5,
             sizes: Optional[Tuple[int, ...]] = None,
             precision=None, baseline_epochs: Optional[int] = None,
             seed: int = 0):
    """(cfg, data, spec) for a fast CPU-sized paper-MLP experiment.

    Defaults match the historical per-file setups in tests/test_dist.py;
    ``sizes`` overrides the network (e.g. the smoke (784,32,16,16,47))."""
    cfg = MLPConfig() if sizes is None else MLPConfig(sizes=sizes, cut=2)
    data = emnist_like(n_train=n_train, n_test=n_test, seed=seed, noise=noise)
    baseline = None if baseline_epochs is None else StageSpec(
        epochs=baseline_epochs, lr=lr, optimizer="sgdm")
    spec = TrainSpec(batch_size=batch_size, kappa=kappa, n_stages=n_stages,
                     precision=precision, baseline=baseline,
                     stages=tuple(StageSpec(epochs=e, lr=lr)
                                  for e in epochs))
    return cfg, data, spec


# --------------------------------------------------------------------------
# LM world (PartitionPlan over a smoke transformer)
# --------------------------------------------------------------------------

def tiny_lm(arch: str = "qwen2-1.5b", *, steps: int = 3, n_stages: int = 2,
            accum: int = 1, batch: int = 2, seq: int = 32,
            lr: float = 1e-3, kappa: float = 1.0, optimizer: str = "adamw",
            precision=None, param_seed: int = 0):
    """(cfg, plan, batch_fn, spec, params) on the arch's smoke config.

    ``batch_fn`` is a PURE function of the step index (the repro.dist
    replay contract), keyed exactly as the historical test_dist setup.
    ``precision`` (preset name / PrecisionPolicy / None) flows into the
    TrainSpec — LMBackend re-dtypes the stage forwards from it."""
    from repro.core import partition
    cfg = get(arch, smoke=True)
    plan = partition.make_plan(cfg, n_stages)

    def batch_fn(i):
        k = jax.random.PRNGKey(1000 + i)
        toks = jax.random.randint(k, (batch, seq), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": toks}

    spec = TrainSpec(n_stages=n_stages, kappa=kappa, precision=precision,
                     stages=tuple(StageSpec(steps=steps, lr=lr,
                                            optimizer=optimizer, accum=accum)
                                  for _ in range(n_stages)))
    params = M.init_params(cfg, jax.random.PRNGKey(param_seed))
    return cfg, plan, batch_fn, spec, params


# --------------------------------------------------------------------------
# serving world
# --------------------------------------------------------------------------

def serve_cfg(arch: str = "qwen2-1.5b", window: int = 0):
    """Smoke config pinned to fp32 compute (token-identity contracts must
    not ride on reduced-precision nondeterminism)."""
    cfg = get(arch, smoke=True).replace(dtype="float32")
    if window:
        cfg = cfg.replace(sliding_window=window)
    return cfg


def serve_params(cfg, seed: int = 0):
    return M.init_params(cfg, jax.random.PRNGKey(seed))


def serve_requests(cfg, lens: Sequence[int] = (8, 12, 5, 10),
                   news: Sequence[int] = (6, 9, 4, 7), *, seed: int = 0,
                   gen_kw: Optional[dict] = None):
    """Mixed-length prompts + mixed durations (staggers admits/retires)."""
    from repro.serve import GenerationConfig, Request
    rng = np.random.RandomState(seed)
    kw = gen_kw or {}
    return [Request(tokens=rng.randint(0, cfg.vocab_size, size=(ln,)),
                    gen=GenerationConfig(max_new_tokens=nn, **kw),
                    id=f"r{i}")
            for i, (ln, nn) in enumerate(zip(lens, news))]


def greedy_reference(cfg, params, req) -> Tuple[int, ...]:
    """One-request-at-a-time reference: prefill + per-token python decode.

    This is the trusted path every engine optimization (continuous batching,
    fused chunks, staged deployment) must reproduce token-for-token."""
    toks = jnp.asarray(np.asarray(req.tokens, np.int32)[None])
    lc = toks.shape[1] + req.gen.max_new_tokens \
        + (cfg.vision_tokens if cfg.frontend == "vision" else 0)
    batch = {"tokens": toks}
    if cfg.enc_dec:
        batch["frames"] = jnp.zeros((1, cfg.enc_seq, cfg.d_model))
    logits, cache, pos = M.prefill(cfg, params, batch, cache_len=lc)
    tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    out = [int(tok[0])]
    for i in range(req.gen.max_new_tokens - 1):
        logits, cache = M.decode_step(cfg, params, cache, tok, pos + i)
        tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
        out.append(int(tok[0]))
    return tuple(out)
