"""Comparison policies for the conformance oracles.

Every equivalence contract in the system falls into one of three strictness
tiers, and each tier is a small policy object with ``compare(ref, opt) ->
Verdict``:

* ``Bitwise``      — the two paths must produce identical bits.  Used where
                     the optimization is a pure scheduling change over the
                     same HLO (checkpoint resume+replay, loss-scale-1
                     wrappers, single-device placement).
* ``Allclose``     — dtype-aware float tolerance.  Tolerances default from
                     the WIDEST (least precise) dtype seen on either side,
                     so a bf16 oracle is automatically judged at bf16
                     tolerance while its fp32 twin stays tight.  Used for
                     kernel-vs-reference and cross-device equivalences
                     (different reduction orders, same math).
* ``AccuracyGap``  — the paper's own criterion: an end-metric (test
                     accuracy) may differ by at most ``budget`` absolute.
                     Used where the two paths are *different training
                     procedures* that the paper claims are equivalent in
                     outcome, not in bits.
* ``TokensEqual``  — exact equality of generated token sequences (serving
                     is a latency optimization, never a tokens change).

``ref`` / ``opt`` may be arbitrary pytrees; leaves are compared pairwise.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


@dataclass(frozen=True)
class Verdict:
    """Outcome of one comparison: pass/fail plus the measured error."""
    ok: bool
    policy: str
    detail: str = ""
    metrics: Dict[str, Any] = field(default_factory=dict)


# dtype -> (rtol, atol); keyed by string so ml_dtypes never needs importing.
# The table answers "how close must two runs of the same math in this dtype
# be" — fp32 tolerances match the repo's long-standing kernel/dist tests.
DTYPE_TOLERANCES: Dict[str, Tuple[float, float]] = {
    "float64": (1e-12, 1e-12),
    "float32": (1e-5, 1e-6),
    "float16": (1e-2, 1e-3),
    "bfloat16": (2e-2, 2e-2),
}
_WIDE_ORDER = ["float64", "float32", "float16", "bfloat16"]


def _leaves(tree):
    return [np.asarray(jax.device_get(x))
            for x in jax.tree_util.tree_leaves(tree)]


def tolerance_for(*dtypes) -> Tuple[float, float]:
    """(rtol, atol) for the least precise dtype among ``dtypes``."""
    worst = "float64"
    for d in dtypes:
        s = str(np.dtype(d)) if not isinstance(d, str) else d
        if s in _WIDE_ORDER and _WIDE_ORDER.index(s) > _WIDE_ORDER.index(worst):
            worst = s
    return DTYPE_TOLERANCES[worst]


class Bitwise:
    kind = "bitwise"

    def compare(self, ref, opt) -> Verdict:
        la, lb = _leaves(ref), _leaves(opt)
        if len(la) != len(lb):
            return Verdict(False, self.kind,
                           f"leaf count differs: {len(la)} vs {len(lb)}")
        for i, (a, b) in enumerate(zip(la, lb)):
            if a.shape != b.shape or a.dtype != b.dtype \
                    or not np.array_equal(a, b, equal_nan=True):
                diff = int(np.sum(a != b)) if a.shape == b.shape else -1
                return Verdict(False, self.kind,
                               f"leaf {i} differs ({diff} elements)",
                               {"leaf": i, "n_diff": diff})
        return Verdict(True, self.kind, metrics={"n_leaves": len(la)})


@dataclass(frozen=True)
class Allclose:
    """Dtype-aware float closeness; non-float leaves must match exactly.

    Explicit ``rtol``/``atol`` override the dtype table (for contracts whose
    error model is looser than one ulp-scale, e.g. long reductions)."""
    rtol: Optional[float] = None
    atol: Optional[float] = None
    kind = "allclose"

    def compare(self, ref, opt) -> Verdict:
        la, lb = _leaves(ref), _leaves(opt)
        if len(la) != len(lb):
            return Verdict(False, self.kind,
                           f"leaf count differs: {len(la)} vs {len(lb)}")
        max_abs = 0.0
        for i, (a, b) in enumerate(zip(la, lb)):
            if a.shape != b.shape:
                return Verdict(False, self.kind,
                               f"leaf {i} shape {a.shape} vs {b.shape}")
            if not (np.issubdtype(a.dtype, np.floating)
                    or str(a.dtype) in DTYPE_TOLERANCES):
                if not np.array_equal(a, b):
                    return Verdict(False, self.kind,
                                   f"non-float leaf {i} differs")
                continue
            rtol, atol = tolerance_for(a.dtype, b.dtype)
            rtol = self.rtol if self.rtol is not None else rtol
            atol = self.atol if self.atol is not None else atol
            af, bf = a.astype(np.float64), np.asarray(b).astype(np.float64)
            err = float(np.max(np.abs(af - bf))) if af.size else 0.0
            max_abs = max(max_abs, err)
            if not np.allclose(af, bf, rtol=rtol, atol=atol, equal_nan=True):
                return Verdict(
                    False, self.kind,
                    f"leaf {i} exceeds tolerance (max|err|={err:.3e}, "
                    f"rtol={rtol}, atol={atol})",
                    {"leaf": i, "max_abs_err": err, "rtol": rtol,
                     "atol": atol})
        return Verdict(True, self.kind, metrics={"max_abs_err": max_abs,
                                                 "n_leaves": len(la)})


@dataclass(frozen=True)
class AccuracyGap:
    """|ref_metric - opt_metric| <= budget (both scalars, e.g. accuracy).

    ``floor`` additionally requires the reference itself to have learned —
    a gap of 0 between two models at chance is not a reproduction."""
    budget: float = 0.02
    floor: float = 0.0
    kind = "accuracy_gap"

    def compare(self, ref, opt) -> Verdict:
        r, o = float(ref), float(opt)
        gap = abs(r - o)
        metrics = {"ref": r, "opt": o, "gap": gap, "budget": self.budget}
        if r < self.floor:
            return Verdict(False, self.kind,
                           f"reference metric {r:.4f} below floor "
                           f"{self.floor:.4f} (did not learn)", metrics)
        if gap > self.budget:
            return Verdict(False, self.kind,
                           f"gap {gap:.4f} exceeds budget {self.budget:.4f} "
                           f"(ref={r:.4f}, opt={o:.4f})", metrics)
        return Verdict(True, self.kind, metrics=metrics)


class TokensEqual:
    kind = "tokens_equal"

    def compare(self, ref, opt) -> Verdict:
        ref, opt = list(ref), list(opt)
        if len(ref) != len(opt):
            return Verdict(False, self.kind,
                           f"sequence count differs: {len(ref)} vs {len(opt)}")
        for i, (a, b) in enumerate(zip(ref, opt)):
            if tuple(a) != tuple(b):
                return Verdict(False, self.kind,
                               f"sequence {i} differs: {tuple(a)[:8]}... vs "
                               f"{tuple(b)[:8]}...", {"seq": i})
        n = sum(len(tuple(a)) for a in ref)
        return Verdict(True, self.kind, metrics={"n_sequences": len(ref),
                                                 "n_tokens": n})
