"""`repro.verify` — the declarative differential-oracle conformance
subsystem.

The paper's claim is an *equivalence* (partitioned SIL training matches
conventional training), and the codebase has accumulated many more:
kernels match their references, concurrent placement matches the
sequential schedule, batched serving matches sequential decode, bf16
matches fp32 within dtype tolerance, resume+replay matches uninterrupted
training.  Instead of one bespoke test per claim, every contract is a
registered ``Oracle`` — (reference path, optimized path, comparison
policy) — runnable from pytest, from the ``launch/verify`` CLI sweep, or
programmatically:

    from repro.verify import all_oracles, run_oracle, Context

    for oracle in all_oracles(tags=["serve"]):
        result = run_oracle(oracle, Context(preset="tiny",
                                            arch="qwen2-1.5b"))
        print(result.name, result.ok)

Modules:
* ``compare``    — the tolerance-policy tiers (Bitwise / dtype-aware
                   Allclose / AccuracyGap / TokensEqual).
* ``oracle``     — Oracle/Context/registry/run_oracle.
* ``scenarios``  — shared tiny-config builders (also the test fixtures).
* ``oracles``    — the registered contracts (importing this package
                   populates the registry).
* ``paper``      — the end-to-end paper-parity gate (EMNIST 6-layer,
                   2-stage SIL vs conventional; tiny and full presets).
* ``report``     — machine-readable conformance reports for ``results/``.

See docs/TESTING.md for how to add an oracle with a new feature.
"""
from repro.verify.compare import (AccuracyGap, Allclose, Bitwise,  # noqa: F401
                                  TokensEqual, Verdict, tolerance_for)
from repro.verify.oracle import (Context, Oracle, OracleResult,  # noqa: F401
                                 all_oracles, get, register, run_oracle)
from repro.verify.report import build_report, write_report  # noqa: F401

# importing the contract definitions populates the registry
from repro.verify import oracles as _oracles  # noqa: E402,F401

__all__ = [
    "AccuracyGap", "Allclose", "Bitwise", "TokensEqual", "Verdict",
    "tolerance_for", "Context", "Oracle", "OracleResult", "all_oracles",
    "get", "register", "run_oracle", "build_report", "write_report",
]
