"""The oracle registry: every equivalence contract as a named, runnable pair.

An ``Oracle`` is a declarative record of one equivalence the system promises:
a *reference path* (the trusted, simple implementation) against an
*optimized path* (kernel, placement, batching, precision, resume...), plus
the ``repro.verify.compare`` policy that judges them.  Registration makes a
contract executable from three surfaces at once:

* ``tests/test_verify_oracles.py`` auto-parametrizes every registered oracle
  into pytest — a new oracle is a test for free;
* ``python -m repro.launch.verify`` sweeps the registry from the CLI and
  writes a machine-readable conformance report into ``results/``;
* ``run_oracle`` is callable from anywhere (benchmarks, notebooks).

An oracle's ``run(ctx)`` returns ``(reference, optimized)`` pytrees; the
policy turns them into a ``Verdict``.  ``Context.preset`` selects problem
size ("tiny" for the 2-core CPU container, "full" for paper fidelity);
``Context.arch`` parameterizes LM-backed oracles over any
``repro.configs`` entry.
"""
from __future__ import annotations

import tempfile
import time
import traceback
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.verify.compare import Verdict

PRESETS = ("tiny", "full")


@dataclass
class Context:
    """Execution context handed to every oracle run."""
    preset: str = "tiny"
    arch: str = "qwen2-1.5b"          # repro.configs entry for LM oracles
    workdir: Optional[str] = None     # scratch dir (checkpoint oracles)

    def __post_init__(self):
        if self.preset not in PRESETS:
            raise ValueError(f"unknown preset {self.preset!r}; "
                             f"choose from {PRESETS}")


@dataclass(frozen=True)
class Oracle:
    """One registered equivalence contract."""
    name: str                          # "group/contract", unique
    contract: str                      # one-line statement of the promise
    run: Callable[[Context], Tuple[Any, Any]]   # -> (reference, optimized)
    # a compare policy instance, or a Callable[[Context], policy] when the
    # strictness depends on the preset (e.g. paper budgets)
    policy: Any = None
    tags: Tuple[str, ...] = ()
    arch_aware: bool = False           # honors Context.arch

    def resolve_policy(self, ctx: Context):
        return self.policy(ctx) if callable(self.policy) else self.policy


@dataclass(frozen=True)
class OracleResult:
    name: str
    ok: bool
    seconds: float
    verdict: Optional[Verdict] = None
    error: Optional[str] = None

    def row(self) -> Dict[str, Any]:
        """Flat dict for the conformance report."""
        out = {"name": self.name, "ok": self.ok,
               "seconds": round(self.seconds, 3)}
        if self.verdict is not None:
            out["policy"] = self.verdict.policy
            out["detail"] = self.verdict.detail
            out["metrics"] = self.verdict.metrics
        if self.error is not None:
            out["error"] = self.error
        return out


_REGISTRY: Dict[str, Oracle] = {}


def register(name: str, contract: str, policy, *, tags: Sequence[str] = (),
             arch_aware: bool = False):
    """Decorator: register ``fn(ctx) -> (reference, optimized)`` as an
    oracle.  Double registration under one name is a bug, not an update."""
    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"oracle {name!r} already registered")
        _REGISTRY[name] = Oracle(name=name, contract=contract, run=fn,
                                 policy=policy, tags=tuple(tags),
                                 arch_aware=arch_aware)
        return fn
    return deco


def get(name: str) -> Oracle:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"no oracle {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def all_oracles(tags: Optional[Sequence[str]] = None) -> List[Oracle]:
    """Registered oracles, name-sorted; ``tags`` filters to any match."""
    out = sorted(_REGISTRY.values(), key=lambda o: o.name)
    if tags:
        want = set(tags)
        out = [o for o in out if want & set(o.tags)]
    return out


def run_oracle(oracle: Oracle, ctx: Optional[Context] = None) -> OracleResult:
    """Execute one oracle under ``ctx`` and judge it with its policy.

    Exceptions are captured into a failed result (the conformance sweep must
    report every contract, not die on the first broken one)."""
    ctx = ctx or Context()
    t0 = time.perf_counter()
    tmp = None
    try:
        if ctx.workdir is None:
            tmp = tempfile.TemporaryDirectory(prefix="repro_verify_")
            ctx = Context(preset=ctx.preset, arch=ctx.arch,
                          workdir=tmp.name)
        ref, opt = oracle.run(ctx)
        verdict = oracle.resolve_policy(ctx).compare(ref, opt)
        return OracleResult(oracle.name, verdict.ok,
                            time.perf_counter() - t0, verdict=verdict)
    except Exception:
        return OracleResult(oracle.name, False, time.perf_counter() - t0,
                            error=traceback.format_exc(limit=8))
    finally:
        if tmp is not None:
            tmp.cleanup()
