"""The unified per-stage cost model behind the auto-partitioner.

One place answers "what does stage [lo, hi) cost" for BOTH backends, in the
same units ``launch/dryrun.py`` reports per PNN stage:

* **resident bytes** — params (storage dtype) + fp32 optimizer slots
  (``OPT_SLOTS[optimizer]`` per trainable element; the frozen
  ``tied_unembed`` snapshot counts param bytes but never slots) +
  activation stream + boundary spill, all dtype-aware via
  ``precision.dtype_itemsize``.
* **FLOPs** — 6ND training napkin math per unit, attention-score terms for
  attn slots, plus the unembed matmul on the last stage (the same formulas
  as ``launch/hlo_analysis.analytic_flops_per_chip``).

A *unit* is the searcher's atom: one layer for the MLP backend, one
parameter group for the transformer backend (groups are the smallest
repeating block pattern, so every unit in a model costs the same — the
non-uniformity the searcher exploits comes from the stage-0 embedding /
encoder / frontend overhead and the last stage's final-norm + unembedding).

``dist/placement.py`` delegates its ``_OPT_SLOTS`` byte estimate here, so
placement packing, dryrun tables, and boundary search can never disagree
on what a stage weighs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.precision import dtype_itemsize

# optimizer-state slots per trainable param (fp32 each).  adafactor's
# factored second moments are ~sqrt-sized: negligible here.
OPT_SLOTS = {"sgd": 0, "sgdm": 1, "adam": 2, "adamw": 2, "adafactor": 0}


def opt_slots(optimizer: str) -> int:
    """fp32 slots per trainable element; unknown optimizers assume 2."""
    return OPT_SLOTS.get(optimizer, 2)


def tree_param_bytes(tree, itemsize: Optional[int] = None) -> int:
    """Bytes of a param tree from shapes+dtypes alone — works for live
    arrays, numpy arrays, and ``jax.ShapeDtypeStruct`` stand-ins.
    ``itemsize`` overrides the per-leaf dtype width (e.g. 4 to size fp32
    optimizer slots over half-precision params)."""
    import jax
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        n = int(np.prod(leaf.shape)) if getattr(leaf, "shape", ()) else 1
        total += n * (itemsize if itemsize is not None
                      else dtype_itemsize(str(getattr(leaf, "dtype",
                                                      "float32"))))
    return total


def estimate_stage_bytes(stage_params, optimizer: str = "sgdm") -> int:
    """Resident bytes of one live training stage: params + fp32 optimizer
    slots (grads are transient under jit and excluded, matching the
    per-stage numbers ``launch/dryrun.py --mode pnn`` reports).  The frozen
    ``tied_unembed`` snapshot gets param bytes but no slots — LMBackend
    never allocates optimizer state for it."""
    slots = opt_slots(optimizer)
    total = tree_param_bytes(stage_params)
    if isinstance(stage_params, dict):
        trainable = {k: v for k, v in stage_params.items()
                     if k != "tied_unembed"}
    else:
        trainable = stage_params
    return total + slots * tree_param_bytes(trainable, itemsize=4)


# ==========================================================================
# model cost tables
# ==========================================================================

@dataclass(frozen=True)
class StageCost:
    """Predicted cost of one stage [lo, hi) in units."""
    stage: int
    lo: int
    hi: int
    params_bytes: int      # storage-dtype weights (incl. frozen snapshots)
    opt_bytes: int         # fp32 optimizer slots over trainable elements
    act_bytes: int         # activation stream saved across the stage
    boundary_bytes: int    # boundary spill emitted at the stage's cut
    flops: float

    @property
    def bytes_total(self) -> int:
        return (self.params_bytes + self.opt_bytes + self.act_bytes
                + self.boundary_bytes)

    def row(self) -> Dict[str, Any]:
        return {"stage": self.stage, "units": [self.lo, self.hi],
                "params_bytes": int(self.params_bytes),
                "opt_bytes": int(self.opt_bytes),
                "act_bytes": int(self.act_bytes),
                "boundary_bytes": int(self.boundary_bytes),
                "bytes_total": int(self.bytes_total),
                "flops": float(self.flops)}


@dataclass(frozen=True)
class ModelCosts:
    """Per-unit cost table + head/tail stage overheads for one model.

    ``stage_cost(lo, hi, k, n_stages)`` is O(1) via prefix sums, which is
    what lets the bottleneck DP stay O(n^2 K) overall.
    """
    kind: str                              # "mlp" | "lm"
    n_units: int
    optimizer: str
    # per-unit terms (len n_units each)
    unit_param_bytes: Tuple[int, ...]      # storage-dtype weight bytes
    unit_param_elems: Tuple[int, ...]      # trainable elements (slot sizing)
    unit_act_bytes: Tuple[int, ...]        # saved activations inside the unit
    unit_flops: Tuple[float, ...]
    unit_boundary_bytes: Tuple[int, ...]   # spill if the cut lands after unit
    # stage-0 overhead (embedding / encoder / frontend)
    head_param_bytes: int = 0
    head_param_elems: int = 0
    head_flops: float = 0.0
    # last-stage overhead (final norm + unembedding)
    tail_param_bytes: int = 0
    tail_param_elems: int = 0              # trainable tail elements
    tail_frozen_bytes: int = 0             # tied_unembed snapshot: no slots
    tail_flops: float = 0.0

    def __post_init__(self):
        n = self.n_units
        for f in ("unit_param_bytes", "unit_param_elems", "unit_act_bytes",
                  "unit_flops", "unit_boundary_bytes"):
            if len(getattr(self, f)) != n:
                raise ValueError(f"{f} has {len(getattr(self, f))} entries "
                                 f"for {n} units")
        object.__setattr__(self, "_pb", _prefix(self.unit_param_bytes))
        object.__setattr__(self, "_pe", _prefix(self.unit_param_elems))
        object.__setattr__(self, "_ab", _prefix(self.unit_act_bytes))
        object.__setattr__(self, "_fl", _prefix(self.unit_flops))

    @property
    def slots(self) -> int:
        return opt_slots(self.optimizer)

    def stage_cost(self, lo: int, hi: int, k: int, n_stages: int
                   ) -> StageCost:
        if not (0 <= lo < hi <= self.n_units):
            raise ValueError(f"bad stage range [{lo}, {hi}) over "
                             f"{self.n_units} units")
        first, last = k == 0, k == n_stages - 1
        pb = self._pb[hi] - self._pb[lo]
        pe = self._pe[hi] - self._pe[lo]
        ab = self._ab[hi] - self._ab[lo]
        fl = self._fl[hi] - self._fl[lo]
        frozen = 0
        if first:
            pb += self.head_param_bytes
            pe += self.head_param_elems
            fl += self.head_flops
        if last:
            pb += self.tail_param_bytes
            pe += self.tail_param_elems
            frozen = self.tail_frozen_bytes
            fl += self.tail_flops
        bb = 0 if last else self.unit_boundary_bytes[hi - 1]
        return StageCost(stage=k, lo=lo, hi=hi,
                         params_bytes=pb + frozen,
                         opt_bytes=self.slots * pe * 4,
                         act_bytes=ab, boundary_bytes=bb, flops=fl)

    def stage_costs(self, bounds: Sequence[Tuple[int, int]]
                    ) -> List[StageCost]:
        n = len(bounds)
        return [self.stage_cost(lo, hi, k, n)
                for k, (lo, hi) in enumerate(bounds)]


def _prefix(xs):
    out = [0]
    for x in xs:
        out.append(out[-1] + x)
    return tuple(out)


def predicted_imbalance(stage_costs: Sequence[StageCost]) -> float:
    """max stage bytes / mean stage bytes (1.0 = perfectly balanced)."""
    sizes = [c.bytes_total for c in stage_costs]
    mean = sum(sizes) / len(sizes)
    return max(sizes) / mean if mean else 1.0


# ==========================================================================
# builders
# ==========================================================================

def mlp_costs(cfg, *, batch_size: int = 1410, optimizer: str = "sgdm",
              compute_dtype: str = "float32") -> ModelCosts:
    """Cost table for the paper's MLP: one unit per layer.

    Weights are fp32 (the MLP backend's storage dtype); activations and the
    boundary spill follow ``compute_dtype`` (the PrecisionPolicy surface).
    FLOPs use the paper's own MAC counting x 6 (fwd+bwd training) x batch.
    """
    it = dtype_itemsize(compute_dtype)
    n = cfg.n_layers
    elems = [cfg.sizes[i] * cfg.sizes[i + 1] + cfg.sizes[i + 1]
             for i in range(n)]
    return ModelCosts(
        kind="mlp", n_units=n, optimizer=optimizer,
        unit_param_bytes=tuple(e * 4 for e in elems),
        unit_param_elems=tuple(elems),
        unit_act_bytes=tuple(batch_size * cfg.sizes[i + 1] * it
                             for i in range(n)),
        unit_flops=tuple(6.0 * batch_size
                         * cfg.sizes[i] * cfg.sizes[i + 1]
                         for i in range(n)),
        unit_boundary_bytes=tuple(batch_size * cfg.sizes[i + 1] * it
                                  for i in range(n)),
    )


def lm_costs(cfg, *, batch: int = 8, seq: int = 512,
             optimizer: str = "adamw") -> ModelCosts:
    """Cost table for a transformer config: one unit per parameter group.

    Group weight bytes come from ``jax.eval_shape`` over the real
    ``init_params`` tree (dtype-aware — exactly what
    ``hlo_analysis.dtype_byte_breakdown`` would report), divided by the
    group count: groups are stacked on a leading axis, so per-group cost is
    uniform by construction.  Head/tail overheads carry the non-uniformity:

    * head (stage 0): token embedding (+ encoder, enc_norm, dec_pos for
      enc-dec archs; + img_proj for vision) — trainable.
    * tail (last stage): final norm, plus either the trainable ``unembed``
      or — for tied embeddings — the FROZEN ``tied_unembed`` snapshot,
      which costs param bytes but zero optimizer slots (LMBackend excludes
      it from the trainable tree).

    FLOPs mirror ``hlo_analysis.analytic_flops_per_chip`` (6ND train +
    halved causal attention-score terms x3 for fwd+bwd + the unembed
    matmul), distributed over the units that own them.
    """
    import jax

    from repro.models import model as M

    struct = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    g = M.n_groups(cfg)
    tokens = batch * seq
    it = dtype_itemsize(cfg.dtype)

    def bytes_elems(tree):
        import jax as _j
        b = e = 0
        for leaf in _j.tree_util.tree_leaves(tree):
            n_ = int(np.prod(leaf.shape)) if leaf.shape else 1
            b += n_ * dtype_itemsize(str(leaf.dtype))
            e += n_
        return b, e

    gb, ge = bytes_elems(struct["groups"])
    group_bytes, group_elems = gb // g, ge // g

    head_keys = ["tok_embed"]
    if cfg.enc_dec:
        head_keys += ["encoder", "enc_norm", "dec_pos"]
    if cfg.frontend == "vision":
        head_keys.append("img_proj")
    hb = he = 0
    for k in head_keys:
        if k in struct:
            b, e = bytes_elems(struct[k])
            hb, he = hb + b, he + e

    tb, te = bytes_elems(struct["final_norm"])
    frozen_bytes = 0
    if cfg.tie_embeddings:
        frozen_bytes, _ = bytes_elems(struct["tok_embed"])
    elif "unembed" in struct:
        b, e = bytes_elems(struct["unembed"])
        tb, te = tb + b, te + e

    # FLOPs: 6 * tokens * active matmul params, split evenly over groups
    # (groups are homogeneous); attention-score terms per attn layer.
    pc = cfg.param_counts()
    active_mat = pc["active"] - pc["embed"]
    enc_flops = 0.0
    if cfg.enc_dec:
        d, ff = cfg.d_model, cfg.d_ff
        hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
        per_attn = d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d
        if cfg.qkv_bias:
            per_attn += (H + 2 * KV) * hd
        enc_params = cfg.enc_layers * (per_attn + 2 * d * ff)
        active_mat -= enc_params          # encoder lives on stage 0
        enc_tokens = batch * (cfg.enc_seq or seq)
        enc_flops = 6.0 * enc_params * enc_tokens \
            + 3.0 * cfg.enc_layers * (2.0 * batch * cfg.n_heads
                                      * (cfg.enc_seq or seq) ** 2
                                      * cfg.hd * 2)
    gsize = M.group_size(cfg)
    attn_per_group = sum(1 for l in range(gsize)
                         if cfg.block_kind(l) == "attn")
    span = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
    attn_flops = 3.0 * attn_per_group \
        * (2.0 * batch * cfg.n_heads * seq * span * cfg.hd * 2) * 0.5
    group_flops = 6.0 * (active_mat / g) * tokens + attn_flops
    tail_flops = 6.0 * tokens * cfg.d_model * cfg.vocab_padded

    bb = tokens * cfg.d_model * it          # residual-stream spill at a cut
    if cfg.enc_dec:
        # the boundary payload carries the encoder output too
        bb += batch * (cfg.enc_seq or seq) * cfg.d_model * it
    act = gsize * tokens * cfg.d_model * it  # one residual save per layer

    return ModelCosts(
        kind="lm", n_units=g, optimizer=optimizer,
        unit_param_bytes=(group_bytes,) * g,
        unit_param_elems=(group_elems,) * g,
        unit_act_bytes=(act,) * g,
        unit_flops=(group_flops,) * g,
        unit_boundary_bytes=(bb,) * g,
        head_param_bytes=hb, head_param_elems=he, head_flops=enc_flops,
        tail_param_bytes=tb, tail_param_elems=te,
        tail_frozen_bytes=frozen_bytes, tail_flops=tail_flops,
    )


def costs_for(cfg, **kw) -> ModelCosts:
    """Dispatch on config type: MLPConfig -> mlp_costs, else lm_costs."""
    from repro.models.mlp import MLPConfig
    if isinstance(cfg, MLPConfig):
        for drop in ("batch", "seq"):
            kw.pop(drop, None)
        return mlp_costs(cfg, **kw)
    for drop in ("batch_size", "compute_dtype"):
        kw.pop(drop, None)
    return lm_costs(cfg, **kw)
