"""repro.plan — cost-model-driven auto-partitioner.

The paper hand-picks one 6-layer/2-stage split; this subsystem searches
stage boundaries for every arch instead.  Module map:

* ``costs``  — the unified per-stage cost model (params + optimizer slots +
  activation/boundary bytes, FLOPs; dtype-aware).  Single source of truth
  shared with ``dist/placement`` and the dryrun tables.
* ``search`` — bottleneck DP over the cost table (head/tail-overhead-aware
  chains-on-chains), deterministic uniform tie-break, rejected-frontier
  enumeration.

Entry points (this module):

* ``auto_plan(cfg, n_stages)``      -> searched ``PartitionPlan`` (LM)
* ``auto_mlp_bounds(cfg, n_stages)``-> searched layer bounds (MLP)
* ``plan_report(cfg, n_stages)``    -> the PLAN_7.json per-arch record
* ``parse_stages("auto:4")``        -> ("auto", 4) — the CLI surface

Wired end-to-end: ``core/partition.make_plan(..., strategy="auto")``,
``train/backends.balanced_bounds(..., costs=...)``, ``--stages auto[:K]``
on ``launch/train.py`` / ``launch/dryrun.py``, and the ``launch/plan`` CLI
that writes ``results/PLAN_7.json``.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

from repro.plan.costs import (ModelCosts, OPT_SLOTS, StageCost, costs_for,
                              estimate_stage_bytes, lm_costs, mlp_costs,
                              opt_slots, predicted_imbalance,
                              tree_param_bytes)
from repro.plan.search import (Bounds, brute_force_bounds, frontier,
                               search_report, solve, uniform_bounds)

__all__ = [
    "ModelCosts", "OPT_SLOTS", "StageCost", "costs_for",
    "estimate_stage_bytes", "lm_costs", "mlp_costs", "opt_slots",
    "predicted_imbalance", "tree_param_bytes",
    "Bounds", "brute_force_bounds", "frontier", "search_report", "solve",
    "uniform_bounds",
    "auto_bounds", "auto_mlp_bounds", "auto_plan", "parse_stages",
    "plan_report",
]

# the workload the default LM cost tables assume (overridable everywhere);
# small enough that byte terms stay param-dominated, matching how SIL
# stages actually train (per-stage batches, not the 4k-seq pretrain shape)
DEFAULT_BATCH = 8
DEFAULT_SEQ = 512


def auto_bounds(costs: ModelCosts, n_stages: int, *,
                objective: str = "bytes") -> Bounds:
    """Searched bounds over a prebuilt cost table."""
    return solve(costs, n_stages, objective=objective)


def auto_plan(cfg, n_stages: int, *, batch: int = DEFAULT_BATCH,
              seq: int = DEFAULT_SEQ, optimizer: str = "adamw",
              objective: str = "bytes"):
    """Searched ``PartitionPlan`` for a transformer config."""
    from repro.core.partition import PartitionPlan
    table = lm_costs(cfg, batch=batch, seq=seq, optimizer=optimizer)
    return PartitionPlan(n_stages, solve(table, n_stages,
                                         objective=objective))


def auto_mlp_bounds(cfg, n_stages: int, *, batch_size: int = 1410,
                    optimizer: str = "sgdm", compute_dtype: str = "float32",
                    objective: str = "bytes") -> Bounds:
    """Searched layer bounds for the MLP backend."""
    table = mlp_costs(cfg, batch_size=batch_size, optimizer=optimizer,
                      compute_dtype=compute_dtype)
    return solve(table, n_stages, objective=objective)


def plan_report(cfg, n_stages: int, *, batch: Optional[int] = None,
                seq: int = DEFAULT_SEQ, optimizer: Optional[str] = None,
                objective: str = "bytes") -> dict:
    """The per-arch PLAN_7 record (see ``search.search_report``)."""
    from repro.models.mlp import MLPConfig
    if isinstance(cfg, MLPConfig):
        table = mlp_costs(cfg, batch_size=batch or 1410,
                          optimizer=optimizer or "sgdm")
        arch_row = {"arch": cfg.name, "kind": "mlp",
                    "batch_size": batch or 1410}
    else:
        table = lm_costs(cfg, batch=batch or DEFAULT_BATCH, seq=seq,
                         optimizer=optimizer or "adamw")
        arch_row = {"arch": cfg.name, "kind": "lm",
                    "batch": batch or DEFAULT_BATCH, "seq": seq}
    rep = search_report(table, n_stages, objective=objective)
    rep.update(arch_row)
    return rep


def parse_stages(value: Union[str, int], *, default_k: int = 2
                 ) -> Tuple[str, int]:
    """CLI ``--stages`` surface: ``"3"`` -> ("uniform", 3), ``"auto"`` ->
    ("auto", default_k), ``"auto:4"`` -> ("auto", 4)."""
    if isinstance(value, int):
        return "uniform", value
    s = value.strip().lower()
    if s.startswith("auto"):
        rest = s[4:]
        if not rest:
            return "auto", default_k
        if rest.startswith(":") and rest[1:].isdigit():
            return "auto", int(rest[1:])
        raise ValueError(f"bad --stages value {value!r}; expected N, "
                         "'auto', or 'auto:K'")
    if s.isdigit():
        return "uniform", int(s)
    raise ValueError(f"bad --stages value {value!r}; expected N, 'auto', "
                     "or 'auto:K'")
