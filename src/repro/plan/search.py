"""Balanced K-way cut search over a ``ModelCosts`` table.

The partition problem is the classic *chains-on-chains* bottleneck
minimization: place K-1 cuts in an ordered sequence of units so the most
expensive stage is as cheap as possible.  Stage cost is NOT a pure interval
sum here — stage 0 carries the embedding/encoder overhead and the last
stage carries the final-norm/unembedding overhead — but only the first and
last stages are special, so a suffix DP over (start unit, stages remaining)
still solves it exactly in O(n^2 K) O(1)-cost evaluations.

Determinism/tie-breaking: among all optimal-bottleneck solutions the
searcher picks cuts greedily left-to-right, each as close as possible to
the *uniform* (divmod-balanced) cut — so on a perfectly uniform model
(e.g. an equal-width MLP, where every split of the right sizes ties) it
reproduces ``partition.make_plan``'s hand bounds exactly.  That exact-tie
determinism is pinned by the ``plan/auto_vs_hand`` oracle.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.plan.costs import ModelCosts, StageCost, predicted_imbalance

Bounds = Tuple[Tuple[int, int], ...]

# float-sum noise guard when re-checking DP-optimal feasibility
_EPS = 1e-9


def uniform_bounds(n_units: int, n_stages: int) -> Bounds:
    """The divmod-balanced contiguous split (``partition.make_plan``'s
    scheme: earlier stages take the remainder)."""
    base, rem = divmod(n_units, n_stages)
    bounds, start = [], 0
    for k in range(n_stages):
        size = base + (1 if k < rem else 0)
        bounds.append((start, start + size))
        start += size
    return tuple(bounds)


def stage_objective(costs: ModelCosts, objective: str = "bytes"
                    ) -> Callable[[int, int, int, int], float]:
    """(lo, hi, k, n_stages) -> scalar stage cost under the objective.

    * ``bytes`` (default) — resident params + optimizer slots + activation
      stream + boundary spill.  This is what device memory actually caps,
      and what the LPT packing in ``dist/placement`` bins by.
    * ``flops`` — per-stage training FLOPs (use when stages share devices
      and compute, not memory, is the bottleneck).
    """
    if objective == "bytes":
        return lambda lo, hi, k, n: float(
            costs.stage_cost(lo, hi, k, n).bytes_total)
    if objective == "flops":
        return lambda lo, hi, k, n: costs.stage_cost(lo, hi, k, n).flops
    raise ValueError(f"unknown objective {objective!r}; "
                     "expected 'bytes' or 'flops'")


def solve(costs: ModelCosts, n_stages: int, *, objective: str = "bytes"
          ) -> Bounds:
    """Optimal-bottleneck bounds, tie-broken toward the uniform split."""
    n = costs.n_units
    if not 1 <= n_stages <= n:
        raise ValueError(f"{n_stages} stages over {n} units")
    if n_stages == 1:
        return ((0, n),)
    cost = stage_objective(costs, objective)

    # suffix[j][m]: minimal bottleneck of splitting units [j, n) into the
    # FINAL m stages (so the last of them carries the tail overhead; none
    # carries the head).  Stage index passed to `cost` only distinguishes
    # first/interior/last, so k=1 stands in for "interior".
    K = n_stages
    suffix = [[float("inf")] * (K + 1) for _ in range(n + 1)]
    for j in range(n):
        suffix[j][1] = cost(j, n, K - 1, K)
    for m in range(2, K):
        for j in range(n - m + 1):
            best = float("inf")
            for hi in range(j + 1, n - m + 2):
                c = max(cost(j, hi, 1, K), suffix[hi][m - 1])
                if c < best:
                    best = c
            suffix[j][m] = best

    # bottleneck with the head-overhead first stage
    bstar = min(max(cost(0, hi, 0, K), suffix[hi][K - 1])
                for hi in range(1, n - K + 2))

    # greedy reconstruction: each cut as close to the uniform target as
    # possible while staying feasible at the optimal bottleneck
    targets = [hi for _, hi in uniform_bounds(n, K)[:-1]]
    limit = bstar * (1 + _EPS) + _EPS
    bounds: List[Tuple[int, int]] = []
    lo = 0
    for k in range(K - 1):
        remaining = K - 1 - k
        feasible = []
        for hi in range(lo + 1, n - remaining + 1):
            if cost(lo, hi, k, K) <= limit and suffix[hi][remaining] <= limit:
                feasible.append(hi)
        if not feasible:   # numerically unreachable; keep a hard fallback
            feasible = [lo + 1]
        hi = min(feasible, key=lambda h: (abs(h - targets[k]), h))
        bounds.append((lo, hi))
        lo = hi
    bounds.append((lo, n))
    return tuple(bounds)


def frontier(costs: ModelCosts, n_stages: int, chosen: Bounds, *,
             objective: str = "bytes", limit: int = 16) -> List[Dict]:
    """The rejected alternatives the searcher weighed, for PLAN_7.json.

    Full enumeration when the cut lattice is small (C(n-1, K-1) <= 512);
    otherwise every single-cut perturbation of the chosen bounds.  Entries
    are sorted by bottleneck cost and capped at ``limit`` (the cap is
    recorded by the caller — no silent truncation)."""
    n = costs.n_units
    cost = stage_objective(costs, objective)
    chosen_cuts = tuple(hi for _, hi in chosen[:-1])

    def bounds_of(cuts: Sequence[int]) -> Bounds:
        edges = [0, *cuts, n]
        return tuple((edges[i], edges[i + 1]) for i in range(len(edges) - 1))

    def bottleneck(b: Bounds) -> float:
        return max(cost(lo, hi, k, n_stages)
                   for k, (lo, hi) in enumerate(b))

    from itertools import combinations
    from math import comb
    cand: List[Tuple[int, ...]] = []
    if n_stages > 1 and comb(n - 1, n_stages - 1) <= 512:
        cand = [c for c in combinations(range(1, n), n_stages - 1)
                if c != chosen_cuts]
    else:
        seen = {chosen_cuts}
        for i in range(len(chosen_cuts)):
            for delta in (-1, 1):
                c = list(chosen_cuts)
                c[i] += delta
                lo_ok = c[i] > (c[i - 1] if i else 0)
                hi_ok = c[i] < (c[i + 1] if i + 1 < len(c) else n)
                t = tuple(c)
                if lo_ok and hi_ok and t not in seen:
                    seen.add(t)
                    cand.append(t)
    base = bottleneck(chosen)
    rows = []
    for cuts in cand:
        b = bounds_of(cuts)
        bn = bottleneck(b)
        rows.append({"bounds": [list(x) for x in b],
                     "bottleneck": float(bn),
                     "vs_chosen": float(bn / base) if base else 1.0})
    rows.sort(key=lambda r: (r["bottleneck"], r["bounds"]))
    return rows[:limit]


def search_report(costs: ModelCosts, n_stages: int, *,
                  objective: str = "bytes",
                  frontier_limit: int = 16) -> Dict:
    """One arch's full search result: chosen bounds + per-stage predicted
    costs, the uniform split's for comparison, imbalance ratios, and the
    rejected frontier."""
    chosen = solve(costs, n_stages, objective=objective)
    uni = uniform_bounds(costs.n_units, n_stages)
    chosen_sc = costs.stage_costs(chosen)
    uni_sc = costs.stage_costs(uni)

    def side(bounds: Bounds, sc: List[StageCost]) -> Dict:
        return {
            "bounds": [list(b) for b in bounds],
            "cuts": [hi for _, hi in bounds[:-1]],
            "stages": [c.row() for c in sc],
            "bottleneck_bytes": int(max(c.bytes_total for c in sc)),
            "bottleneck_flops": float(max(c.flops for c in sc)),
            "imbalance": round(predicted_imbalance(sc), 6),
        }

    rej = frontier(costs, n_stages, chosen, objective=objective,
                   limit=frontier_limit)
    return {
        "objective": objective,
        "n_units": costs.n_units,
        "n_stages": n_stages,
        "optimizer": costs.optimizer,
        "auto": side(chosen, chosen_sc),
        "uniform": side(uni, uni_sc),
        "auto_le_uniform": max(c.bytes_total for c in chosen_sc)
        <= max(c.bytes_total for c in uni_sc),
        "rejected_frontier": rej,
        "frontier_truncated_to": frontier_limit,
    }


def brute_force_bounds(costs: ModelCosts, n_stages: int, *,
                       objective: str = "bytes") -> Tuple[float, Bounds]:
    """Exhaustive reference solver (tests only): (bottleneck, some argmin)."""
    from itertools import combinations
    n = costs.n_units
    cost = stage_objective(costs, objective)
    best, best_b = float("inf"), None
    for cuts in combinations(range(1, n), n_stages - 1):
        edges = [0, *cuts, n]
        b = tuple((edges[i], edges[i + 1]) for i in range(len(edges) - 1))
        bn = max(cost(lo, hi, k, n_stages) for k, (lo, hi) in enumerate(b))
        if bn < best:
            best, best_b = bn, b
    return best, best_b


def searched_bounds_for_sequence(unit_costs: Sequence[float],
                                 n_stages: int) -> Bounds:
    """Bottleneck-optimal bounds over a bare per-unit scalar cost sequence
    (no head/tail overheads) — the ``balanced_bounds(..., costs=[...])``
    entry point."""
    seq = [float(c) for c in unit_costs]
    mc = ModelCosts(kind="mlp", n_units=len(seq), optimizer="sgd",
                    unit_param_bytes=tuple(int(c) for c in seq),
                    unit_param_elems=(0,) * len(seq),
                    unit_act_bytes=(0,) * len(seq),
                    unit_flops=tuple(seq),
                    unit_boundary_bytes=(0,) * len(seq))
    return solve(mc, n_stages, objective="flops")
