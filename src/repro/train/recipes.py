"""The paper's training procedures as phase lists.

Each procedure that used to be a bespoke ~60-line trainer in
``repro.core.pnn`` is now a short list over one ``Trainer``:

    baseline   [BaselinePhase()]
    Fig. 3     [SilStagePhase(0), BoundaryMaterializePhase(1),
                FrozenPrefixPhase(1), RecoveryPhase(0)]
    Fig. 5     [ParallelSilPhase()]
    LM seq.    [SilStagePhase(k) for interior k] + [FrozenPrefixPhase(last,
                source='live'), RecoveryPhase(0)]

The ``run_*`` helpers additionally reproduce the legacy trainers' exact RNG
key schedules (param init + SIL derivation), so histories are comparable
seed-for-seed with the pre-redesign functions — that equivalence is pinned
by tests/test_train_api.py.
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.core import sil as sil_lib
from repro.models import mlp as MLP
from repro.train.backends import LMBackend, MLPBackend, balanced_bounds
from repro.train.phases import (BaselinePhase, BoundaryMaterializePhase,
                                FrozenPrefixPhase, ParallelSilPhase,
                                RecoveryPhase, SilStagePhase)
from repro.train.spec import TrainSpec
from repro.train.trainer import Trainer


# --------------------------------------------------------------------------
# phase lists
# --------------------------------------------------------------------------

def baseline_phases() -> list:
    return [BaselinePhase()]


def fig3_phases(n_stages: int = 2) -> list:
    """Paper Fig. 3 + §5: left-vs-SIL, one boundary materialization, right
    on stored activations, recovery.  (n_stages=2 is the paper's setup.)"""
    return [SilStagePhase(stage=0),
            BoundaryMaterializePhase(upto=n_stages - 1),
            FrozenPrefixPhase(stage=n_stages - 1, source="cache"),
            RecoveryPhase(stage=0)]


def fig5_phases() -> list:
    return [ParallelSilPhase()]


def lm_sequential_phases(n_stages: int, recovery: bool = True) -> list:
    """Transformer stage-sequential PNN: interior stages vs SIL on the live
    frozen prefix, last stage CE on the live frozen prefix, then §5."""
    phases: list = [SilStagePhase(stage=k) for k in range(n_stages - 1)]
    phases.append(FrozenPrefixPhase(stage=n_stages - 1, source="live"))
    if recovery:
        phases.append(RecoveryPhase(stage=0))
    return phases


def paper_spec(*, n_left: int = 5, n_right: int = 160, n_baseline: int = 40,
               n_recovery: int = 10, lr: float = 0.01, lr_right: float = 0.003,
               lr_recovery: float = 3e-4, batch_size: int = 1410,
               kappa: float = 10.0, momentum: float = 0.9,
               shuffle: bool = True) -> TrainSpec:
    """The paper's §3-§5 hyperparameters as one TrainSpec (defaults are the
    published values; shrink the epoch counts for reduced-fidelity runs).
    Shared by examples/quickstart.py and the repro.verify paper-parity
    gate so the experiment definition can never fork.

    shuffle defaults True (unlike the legacy trainers): with the fixed
    epoch order the momentum baseline oscillates instead of converging on
    the synthetic EMNIST stand-in, which would make every parity
    comparison noise."""
    from repro.train.spec import StageSpec
    return TrainSpec(
        kappa=kappa, batch_size=batch_size, shuffle=shuffle,
        stages=(StageSpec(epochs=n_left, lr=lr, optimizer="sgdm",
                          momentum=momentum),
                StageSpec(epochs=n_right, lr=lr_right, optimizer="sgdm",
                          momentum=momentum)),
        baseline=StageSpec(epochs=n_baseline, lr=lr, optimizer="sgdm",
                           momentum=momentum),
        recovery=StageSpec(epochs=n_recovery, lr=lr_recovery,
                           optimizer="sgdm", momentum=momentum))


# --------------------------------------------------------------------------
# MLP entry points (legacy key schedules preserved)
# --------------------------------------------------------------------------

def run_mlp_baseline(cfg: MLP.MLPConfig, data, spec: TrainSpec, key,
                     eval_every: int = 1):
    spec = _with_eval(spec, eval_every)
    backend = MLPBackend(cfg, data, spec)
    params = MLP.init_params(cfg, key)
    return Trainer(backend, spec).run(baseline_phases(), params=params)


def run_mlp_fig3(cfg: MLP.MLPConfig, data, spec: TrainSpec, key,
                 eval_every: int = 1, *, bounds=None):
    """Fig. 3 (+ §5 recovery when spec.recovery has epochs).

    Key schedule (legacy-exact): kp, ks = split(key); params from kp, the
    single cut's SIL from ks.

    bounds: stage bounds override — e.g. ``repro.plan.auto_mlp_bounds``'s
    searched cut instead of the paper's hand cut (the SIL width follows
    the boundary automatically)."""
    spec = _with_eval(spec, eval_every)
    backend = MLPBackend(cfg, data, spec, bounds=bounds)
    kp, ks = jax.random.split(
        jax.random.PRNGKey(0) if key is None else key)  # repro: allow-const-key
    params = MLP.init_params(cfg, kp)
    sil = sil_lib.make_sil(ks, backend.boundary_width(0), cfg.n_classes,
                           spec.kappa)
    return Trainer(backend, spec).run(fig3_phases(backend.n_stages),
                                      params=params, sils=[sil])


def run_mlp_fig5(cfg: MLP.MLPConfig, data, spec: TrainSpec, key,
                 n_stages: int = 3, *, bounds=None, dist=None,
                 dist_devices=None, ckpt_dir=None, ckpt_every: int = 0):
    """Fig. 5 all-parallel mode.  Key schedule (legacy-exact):
    split(key, n_stages + 2); params from keys[0], SIL k from keys[1 + k].

    bounds: stage bounds override (e.g. a ``repro.plan`` searched cut);
    default keeps the legacy balanced layer-count split.
    dist: a ``repro.dist`` PlacementPlan or strategy name — routes the
    parallel phase through the device-placed ``StageExecutor``."""
    backend = MLPBackend(cfg, data, spec,
                         bounds=bounds if bounds is not None
                         else balanced_bounds(cfg, n_stages))
    keys = jax.random.split(key, n_stages + 2)
    params = MLP.init_params(cfg, keys[0])
    sils = [sil_lib.make_sil(keys[1 + k], backend.boundary_width(k),
                             cfg.n_classes, spec.kappa)
            for k in range(n_stages - 1)]
    phases = [ParallelSilPhase(plan=dist, devices=dist_devices,
                               ckpt_dir=ckpt_dir, ckpt_every=ckpt_every)]
    return Trainer(backend, spec).run(phases, params=params,
                                      sils=sils)


def _with_eval(spec: TrainSpec, eval_every: int) -> TrainSpec:
    from dataclasses import replace
    return replace(spec, eval_every=eval_every)


# --------------------------------------------------------------------------
# transformer entry points
# --------------------------------------------------------------------------

def resolve_plan(cfg, plan):
    """Accept a PartitionPlan as-is, or a spec for one: an int (uniform
    K-way split) or ``"auto"`` / ``"auto:K"`` (the ``repro.plan`` searched
    cut).  Both LM entry points route through this, so callers can hand the
    CLI's ``--stages`` string straight in."""
    from repro.core import partition
    if isinstance(plan, partition.PartitionPlan):
        return plan
    from repro.plan import parse_stages
    strategy, k = parse_stages(plan)
    return partition.make_plan(cfg, k, strategy=strategy)


def run_lm_sequential(cfg, plan, params, batch_fn: Callable[[int], dict],
                      spec: TrainSpec, key, *, shard_x=None,
                      grad_pspecs_fn=None):
    """Stage-sequential PNN over a PartitionPlan (legacy pnn_train_lm).
    ``plan`` may also be an int or ``"auto[:K]"`` — see ``resolve_plan``."""
    plan = resolve_plan(cfg, plan)
    backend = LMBackend(cfg, plan, batch_fn, spec, shard_x=shard_x,
                        grad_pspecs_fn=grad_pspecs_fn)
    recovery = bool(spec.recovery and spec.recovery.steps)
    return Trainer(backend, spec).run(
        lm_sequential_phases(plan.n_stages, recovery=recovery),
        params=params, key=key)


def run_lm_parallel(cfg, plan, params, batch_fn: Callable[[int], dict],
                    spec: TrainSpec, key, *, shard_x=None,
                    grad_pspecs_fn=None, dist=None, dist_devices=None,
                    ckpt_dir=None, ckpt_every: int = 0):
    """Fig.-5 all-parallel mode at transformer scale.

    ``plan`` may be a PartitionPlan, an int, or ``"auto[:K]"`` (searched
    cut) — see ``resolve_plan``.
    dist / dist_devices / ckpt_*: ``repro.dist`` routing — place each stage
    on its own device and checkpoint each stage independently."""
    plan = resolve_plan(cfg, plan)
    backend = LMBackend(cfg, plan, batch_fn, spec, shard_x=shard_x,
                        grad_pspecs_fn=grad_pspecs_fn)
    phase = ParallelSilPhase(plan=dist, devices=dist_devices,
                             ckpt_dir=ckpt_dir, ckpt_every=ckpt_every)
    return Trainer(backend, spec).run([phase], params=params,
                                      key=key)
