"""BoundaryCache: storage for materialized partition-boundary activations.

The paper's Fig.-3 schedule communicates between partitions exactly once: the
trained prefix runs forward over the dataset and the boundary activations are
stored for the suffix to train on.  The legacy implementation accumulated a
python list of per-batch arrays and ``np.concatenate``-d them (a transient
2x-memory spike and a full copy).  This cache instead reserves the
destination buffer once and writes device-sized chunks into it as they are
pulled from the accelerator; when the buffer would exceed
``spill_threshold_bytes`` (or a ``spill_dir`` is forced) it is backed by an
on-disk ``np.memmap`` so production-sized materializations don't need to fit
in host RAM.

The buffer dtype is the caller's choice (``reserve(..., dtype)``);
``BoundaryMaterializePhase`` passes the backend's ``boundary_dtype()`` — the
precision policy's compute dtype — so a bf16 policy halves both the RAM
buffer and the memmap spill (ml_dtypes registers bfloat16 with numpy, so
memmaps of it work transparently).
"""
from __future__ import annotations

import os
import tempfile
from typing import Optional, Tuple

import numpy as np

_DEFAULT_SPILL_THRESHOLD = 8 << 30  # 8 GiB


class BoundaryCache:
    """Chunk-filled (N, *feat) activation store with optional disk spill."""

    def __init__(self, spill_dir: Optional[str] = None,
                 spill_threshold_bytes: int = _DEFAULT_SPILL_THRESHOLD):
        self.spill_dir = spill_dir
        self.spill_threshold_bytes = spill_threshold_bytes
        self._buf: Optional[np.ndarray] = None
        self._path: Optional[str] = None
        self._n_filled = 0

    # -- lifecycle ---------------------------------------------------------

    def reserve(self, n_rows: int, feat_shape: Tuple[int, ...], dtype) -> None:
        """Allocate the destination once (RAM or memmap)."""
        if self._buf is not None:
            raise RuntimeError("BoundaryCache already reserved")
        shape = (n_rows,) + tuple(feat_shape)
        nbytes = int(np.prod(shape)) * np.dtype(dtype).itemsize
        if self.spill_dir is not None or nbytes > self.spill_threshold_bytes:
            d = self.spill_dir or tempfile.gettempdir()
            os.makedirs(d, exist_ok=True)
            fd, self._path = tempfile.mkstemp(suffix=".boundary.npy", dir=d)
            os.close(fd)
            self._buf = np.memmap(self._path, dtype=dtype, mode="w+",
                                  shape=shape)
        else:
            self._buf = np.empty(shape, dtype=dtype)
        self._n_filled = 0

    def append(self, chunk) -> None:
        """Write one device-sized chunk (host copy happens here, once)."""
        chunk = np.asarray(chunk)
        if self._buf is None:
            raise RuntimeError("reserve() before append()")
        n = len(chunk)
        if self._n_filled + n > len(self._buf):
            raise ValueError(
                f"cache overflow: reserved {len(self._buf)} rows, "
                f"got {self._n_filled + n}")
        self._buf[self._n_filled:self._n_filled + n] = chunk
        self._n_filled += n

    # -- access ------------------------------------------------------------

    @property
    def n_rows(self) -> int:
        return self._n_filled

    @property
    def spilled(self) -> bool:
        return self._path is not None

    @property
    def nbytes(self) -> int:
        return 0 if self._buf is None else self._buf.nbytes

    def array(self) -> np.ndarray:
        """The filled prefix of the reserved buffer (zero-copy view)."""
        if self._buf is None:
            raise RuntimeError("cache is empty")
        return self._buf[: self._n_filled]

    def close(self) -> None:
        self._buf = None
        if self._path is not None:
            try:
                os.unlink(self._path)
            except OSError:
                pass
            self._path = None
