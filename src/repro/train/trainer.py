"""The phase-sequence Trainer.

``Trainer(backend, spec).run(phases, params=...)`` executes a list of
``repro.train.phases`` objects over shared mutable ``TrainState`` and returns
the joined parameters plus a unified ``History``.  Every legacy trainer is a
short phase list (see ``repro.train.recipes``):

    Fig. 3     [SilStagePhase(0), BoundaryMaterializePhase(1),
                FrozenPrefixPhase(1), RecoveryPhase(0)]
    baseline   [BaselinePhase()]
    Fig. 5     [ParallelSilPhase()]

The loop drivers here implement the perf contract: the MLP backend's epochs
run as one jitted ``lax.scan`` per epoch (device-resident losses, donated
carry), and the LM backend's step loop never blocks on a loss — device
scalars are collected and fetched in a single transfer when the phase ends.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.obs.metrics import LOSS_BUCKETS
from repro.obs.registry import MetricsRegistry
from repro.obs.trace import TID_LOOP, Tracer
from repro.optim import read_skipped
from repro.train.backends import scanned_epoch_fn
from repro.train.history import History


class SkippedStepBudgetExceeded(RuntimeError):
    """More optimizer steps were NaN/inf-skipped than
    ``TrainSpec.max_skipped_steps`` allows — the run is diverging, not
    hiccuping, so it aborts loudly instead of burning compute on a
    params-frozen loop."""


@dataclass
class TrainState:
    stage_params: List[Any]
    sils: List[Any] = field(default_factory=list)
    history: History = field(default_factory=History)
    boundary: Dict[str, Any] = field(default_factory=dict)
    cum_macs: int = 0
    step_idx: int = 0          # global LM optimizer-step counter (batch_fn arg)
    skipped_steps: int = 0     # NaN/inf-guarded steps skipped (all stages)


class Trainer:
    """Runs any phase sequence over an MLP or transformer backend."""

    def __init__(self, backend, spec, *,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        """metrics/tracer (repro.obs, optional): a ``MetricsRegistry`` for
        the trainer's series (defaults to a private one) and a ``Tracer``
        for phase spans.  The loss histogram is **device-resident** — loop
        drivers observe the device scalars the step already returns (a few
        lazily-dispatched ops, no sync) and it drains only at the flush
        boundaries the loop already has (``flush_losses`` / end of
        ``run``)."""
        self.backend = backend
        self.spec = spec
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer()
        self._loss_hist = self.metrics.device_histogram(
            "train_loss", LOSS_BUCKETS,
            help="per-step training loss (device-accumulated)")
        self._skipped = self.metrics.counter(
            "train_skipped_steps_total",
            help="NaN/inf-guarded optimizer steps skipped, by phase[stage]")

    def run(self, phases: Sequence, *, params, sils: Optional[list] = None,
            key=None):
        """Execute `phases` starting from full `params`.

        `sils`: per-cut SIL tables; derived from `key` via the backend's
        legacy-compatible schedule when omitted and any phase needs them.
        Returns (joined_params, History).
        """
        needs_sil = any(getattr(p, "needs_sil", False) for p in phases)
        if sils is None and needs_sil:
            if key is None:
                raise ValueError("phases need SIL tables: pass sils= or key=")
            sils = self.backend.make_sils(key, self.spec.kappa)
        state = TrainState(stage_params=self.backend.split(params),
                           sils=sils or [])
        if getattr(self.backend, "dropped_per_epoch", 0):
            # tail-drop is silent no more: surface it in every history
            # AND in the metrics schema (satellite of history.meta)
            state.history.meta["dropped_per_epoch"] = \
                self.backend.dropped_per_epoch
            self.metrics.gauge(
                "train_dropped_per_epoch",
                help="samples tail-dropped per epoch by batching").set(
                    self.backend.dropped_per_epoch)
        for phase in phases:
            with self.tracer.span(type(phase).__name__, cat="phase",
                                  tid=TID_LOOP):
                phase.run(self, state)
        for cache in state.boundary.values():
            if hasattr(cache, "close"):
                cache.close()
        self.metrics.drain()     # end-of-run flush boundary (idempotent)
        return self.backend.join(state.stage_params), state.history

    # ------------------------------------------------------------------
    # loop drivers (used by the phases)
    # ------------------------------------------------------------------

    def drive_epochs(self, state: TrainState, *, step, train_params,
                     opt_state, epochs: int, phase_name: str, stage: int,
                     macs_per_sample: int, seed_base: int, log_mode: str,
                     eval_fn=None, batch_arrays=None,
                     shuffle: Optional[bool] = None):
        """MLP driver: one jitted scan per epoch over stacked batches.

        batch_arrays(ep) -> tuple of (nb, bs, ...) arrays; defaults to the
        backend dataset.  eval_fn(train_params) -> joined-network accuracy
        (the paper's y-axis); defaults to substituting the in-flight stage
        into the current stage list.  log_mode: 'cadence' | 'cadence+last'
        | 'every' (the three cadences the legacy trainers used)."""
        be = self.backend
        shuffle = be.spec.shuffle if shuffle is None else shuffle
        if batch_arrays is None:
            def batch_arrays(ep):
                return be.epoch_arrays(seed_base + ep, shuffle)
        if eval_fn is None:
            def eval_fn(tp):
                sp = list(state.stage_params)
                sp[stage] = tp
                return be.eval_joined(sp)
        epoch_fn = scanned_epoch_fn(step)
        eval_every = be.spec.eval_every
        for ep in range(epochs):
            batches = batch_arrays(ep)
            train_params, opt_state, losses = epoch_fn(train_params,
                                                       opt_state, batches)
            # device-side: bucket the epoch's per-batch losses without a sync
            self._loss_hist.observe_device(losses)
            n_samples = batches[0].shape[0] * batches[0].shape[1]
            state.cum_macs += macs_per_sample * n_samples
            log = (log_mode == "every"
                   or (ep + 1) % eval_every == 0
                   or (log_mode == "cadence+last" and ep == epochs - 1))
            if log:
                state.history.log(phase=phase_name, stage=stage,
                                  step=state.step_idx, macs=state.cum_macs,
                                  acc=eval_fn(train_params))
        self.note_skipped(state, opt_state, phase_name, stage)
        return train_params, opt_state

    def drive_steps(self, state: TrainState, *, step, inputs_fn,
                    n_steps: int, phase_name: str, stage: int,
                    train_params, opt_state, advance_global: bool = True):
        """LM driver: python step loop, losses collected as device scalars
        and fetched in ONE transfer at the end (async dispatch preserved)."""
        pending, steps_logged = [], []
        for _ in range(n_steps):
            args = inputs_fn(state.step_idx)
            train_params, opt_state, loss = step(train_params, opt_state,
                                                 *args)
            self._loss_hist.observe_device(loss)
            pending.append(loss)
            steps_logged.append(state.step_idx)
            if advance_global:
                state.step_idx += 1
        self.flush_losses(state, pending, steps_logged, phase_name, stage)
        self.note_skipped(state, opt_state, phase_name, stage)
        return train_params, opt_state

    def note_skipped(self, state: TrainState, opt_state, phase_name,
                     stage) -> None:
        """End-of-phase skipped-step telemetry (repro.resilience).

        The NaN/inf guard counts skips in a device-resident int32 inside the
        jitted step; this is the single sanctioned host read of it, at phase
        granularity — the hot loop never syncs.  Raises
        ``SkippedStepBudgetExceeded`` past ``spec.max_skipped_steps``."""
        counter = read_skipped(opt_state)
        if counter is None:
            return
        skipped = int(jax.device_get(counter))  # repro: allow-host-sync
        if not skipped:
            return
        per_phase = state.history.meta.setdefault("skipped_steps", {})
        key = f"{phase_name}[{stage}]"
        # counters are cumulative per opt_state; record the high-water mark
        # so replayed/repeated reads of the same state don't double-count —
        # the metrics counter advances by the same high-water delta
        prev = per_phase.get(key, 0)
        if skipped > prev:
            self._skipped.inc(skipped - prev, phase=key)
        per_phase[key] = max(prev, skipped)
        state.skipped_steps = sum(per_phase.values())
        budget = getattr(self.spec, "max_skipped_steps", None)
        if budget is not None and state.skipped_steps > budget:
            raise SkippedStepBudgetExceeded(
                f"{state.skipped_steps} non-finite optimizer steps skipped "
                f"(> budget {budget}) by phase {phase_name!r} stage {stage}: "
                "the run is diverging — lower the lr, raise the loss scale, "
                "or raise TrainSpec.max_skipped_steps")

    def flush_losses(self, state: TrainState, pending: list,
                     steps_logged: list, phase_name, stage) -> None:
        """One device->host transfer (per device) for a phase's loss curve.

        The pending scalars may live on DIFFERENT devices (repro.dist
        places each stage's step on its own device) and committed buffers
        refuse to stack across devices — so scalars are stacked per
        device-group and fetched in one transfer each.  The common
        single-device case keeps the exact legacy one-stack-one-transfer
        path."""
        if not pending:
            return
        groups: dict = {}
        for idx, leaf in enumerate(pending):
            dev = tuple(sorted(map(str, leaf.devices()))) \
                if isinstance(leaf, jax.Array) else None
            groups.setdefault(dev, []).append(idx)
        if len(groups) == 1:
            values = jax.device_get(jnp.stack(pending))  # repro: allow-host-sync
        else:
            values = [None] * len(pending)
            for idxs in groups.values():
                got = jax.device_get(  # repro: allow-host-sync
                    jnp.stack([pending[i] for i in idxs]))
                for j, i in enumerate(idxs):
                    values[i] = got[j]
        stages = stage if isinstance(stage, list) else [stage] * len(pending)
        names = phase_name if isinstance(phase_name, list) \
            else [phase_name] * len(pending)
        for name, st, i, v in zip(names, stages, steps_logged, values):
            state.history.log(phase=name, stage=st, step=i, loss=float(v))
        # this is already a sanctioned sync point — drain the device-resident
        # metrics accumulated since the last flush (idempotent)
        self.metrics.drain()
