"""Stage-aware training configuration for the `repro.train` phase API.

``TrainSpec`` is the single config that replaces the three legacy dataclasses
(`PaperHP` for the MLP reproduction, `PNNLMConfig`/`PNNStageHP` for the
transformer generalization): one spec carries per-stage optimizer / learning
rate / duration plus the SIL and batching knobs shared by every phase.

Durations are expressed in whichever unit the backend natively consumes —
**epochs** for the dataset-backed MLP backend, **steps** for the stream-backed
LM backend; a ``StageSpec`` may set either (or both, when the same spec is
reused across backends).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class StageSpec:
    """Hyperparameters of one partition stage (paper §2.1: per-partition
    hyperparameters are a core advantage of the scheme)."""
    epochs: int = 0            # MLP backend duration
    steps: int = 0             # LM backend duration
    lr: float = 1e-2
    optimizer: str = "sgdm"
    momentum: float = 0.9      # sgdm only
    # gradient-accumulation microbatches per optimizer step: the batch is
    # split `accum` ways inside the jitted step and gradients accumulate in
    # the policy's accum dtype (fp32) — large effective batches under the
    # reduced-precision activation memory budget
    accum: int = 1
    # per-stage precision override — a repro.precision preset name
    # ("fp32" | "bf16" | "fp16") or a PrecisionPolicy — for the OPTIMIZER
    # side only: loss scaling / master weights via make_optimizer_for (e.g.
    # fp16-scale one fragile stage).  The forward compute dtype is a
    # backend-wide choice taken from TrainSpec.precision (one backend traces
    # one model dtype); None inherits that spec-wide policy
    precision: Optional[object] = None
    # NaN/inf step guard override for this stage (repro.resilience): None
    # inherits TrainSpec.nan_guard.  Irrelevant for stages whose precision
    # policy already wraps the optimizer in loss scaling (fp16) — that
    # wrapper skips-and-counts non-finite steps on its own
    nan_guard: Optional[bool] = None


@dataclass(frozen=True)
class TrainSpec:
    """One stage-aware config for every PNN training schedule.

    ``stages[k]`` configures partition k.  ``recovery_*`` configures the §5
    recovery phase (stage 0 fine-tuned end-to-end, the rest frozen).
    ``baseline`` (a StageSpec) configures conventional end-to-end training.
    """
    n_stages: int = 2
    kappa: float = 10.0
    stages: Tuple[StageSpec, ...] = ()
    baseline: Optional[StageSpec] = None
    recovery: Optional[StageSpec] = None
    # data / batching (MLP backend; the LM backend receives batches from a
    # caller-supplied batch_fn and ignores these)
    batch_size: int = 1410
    shuffle: bool = False
    eval_every: int = 1
    # spec-wide precision policy (preset name or PrecisionPolicy); None keeps
    # the legacy behavior: MLP backend fp32, LM backend the config's dtype
    precision: Optional[object] = None
    # ---- resilience (repro.resilience) -----------------------------------
    # wrap every stage optimizer in optim.step_guard: a step whose grads
    # contain inf/nan is skipped in-device (params + optimizer state kept,
    # counter bumped) instead of silently poisoning the run.  fp16 stages
    # keep their mixed_precision skip — the guard never stacks on top of it
    nan_guard: bool = False
    # abort the run (SkippedStepBudgetExceeded) once the total number of
    # guard-skipped steps across a phase exceeds this; None = never abort
    max_skipped_steps: Optional[int] = None

    def stage(self, k: int) -> StageSpec:
        if self.stages and k < len(self.stages):
            return self.stages[k]
        return StageSpec()

    def with_stages(self, *stages: StageSpec) -> "TrainSpec":
        return replace(self, stages=tuple(stages), n_stages=len(stages))


# --------------------------------------------------------------------------
# conversions from the legacy configs (kept so callers can migrate piecemeal)
# --------------------------------------------------------------------------

def spec_from_paper_hp(hp) -> TrainSpec:
    """`repro.core.pnn.PaperHP` -> TrainSpec (MLP backend, 2 stages)."""
    lr_right = hp.lr_right if hp.lr_right is not None else hp.lr
    rec_lr = hp.lr_recovery if hp.lr_recovery is not None else lr_right / 10.0
    return TrainSpec(
        n_stages=2,
        kappa=hp.kappa,
        stages=(
            StageSpec(epochs=hp.n_left, lr=hp.lr, optimizer="sgdm",
                      momentum=hp.momentum),
            StageSpec(epochs=hp.n_right, lr=lr_right, optimizer="sgdm",
                      momentum=hp.momentum),
        ),
        baseline=StageSpec(epochs=hp.n_baseline, lr=hp.lr, optimizer="sgdm",
                           momentum=hp.momentum),
        recovery=StageSpec(epochs=hp.n_recovery, lr=rec_lr, optimizer="sgdm",
                           momentum=hp.momentum),
        batch_size=hp.batch_size,
        shuffle=hp.shuffle,
    )


def spec_from_lm_config(pnn_cfg, n_stages: Optional[int] = None) -> TrainSpec:
    """`repro.core.pnn.PNNLMConfig` -> TrainSpec (LM backend)."""
    n = n_stages or pnn_cfg.n_stages
    stage_hps = pnn_cfg.stages or [None] * n
    stages = []
    for hp in stage_hps:
        if hp is None:
            stages.append(StageSpec(steps=50, lr=1e-3, optimizer="adamw"))
        else:
            stages.append(StageSpec(steps=hp.steps, lr=hp.lr,
                                    optimizer=hp.optimizer))
    recovery = StageSpec(steps=pnn_cfg.recovery_steps, lr=pnn_cfg.recovery_lr,
                         optimizer="adamw") if pnn_cfg.recovery_steps else None
    return TrainSpec(n_stages=n, kappa=pnn_cfg.kappa, stages=tuple(stages),
                     recovery=recovery)
