"""`repro.train` — the composable phase API for PNN training.

One ``Trainer`` runs any sequence of phases over either model backend; the
paper's schedules are the short phase lists in ``repro.train.recipes``.

    from repro.train import (TrainSpec, StageSpec, Trainer, MLPBackend,
                             LMBackend, recipes)

    spec = TrainSpec(stages=(StageSpec(epochs=5, lr=0.01),
                             StageSpec(epochs=160, lr=0.003)), kappa=10.0)
    params, hist = recipes.run_mlp_fig3(cfg, data, spec, key)
"""
from repro.train import recipes
from repro.train.backends import LMBackend, MLPBackend
from repro.train.boundary import BoundaryCache
from repro.train.history import History
from repro.train.phases import (BaselinePhase, BoundaryMaterializePhase,
                                FrozenPrefixPhase, ParallelSilPhase,
                                RecoveryPhase, SilStagePhase)
from repro.train.spec import (StageSpec, TrainSpec, spec_from_lm_config,
                              spec_from_paper_hp)
from repro.train.trainer import Trainer, TrainState

__all__ = [
    "recipes", "LMBackend", "MLPBackend", "BoundaryCache", "History",
    "BaselinePhase", "BoundaryMaterializePhase", "FrozenPrefixPhase",
    "ParallelSilPhase", "RecoveryPhase", "SilStagePhase",
    "StageSpec", "TrainSpec", "spec_from_lm_config", "spec_from_paper_hp",
    "Trainer", "TrainState",
]
