"""Unified training history for the phase API.

Every phase appends ``Record``s; the two legacy dict-of-lists formats (the
MLP trainers' ``{"macs", "acc", "phase"}`` and the LM trainers'
``{"stage", "step", "loss"}``) are derived views, kept so pre-redesign
consumers and tests keep working.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional


@dataclass
class Record:
    phase: str                 # "left" / "right" / "baseline" / "recovery" / ...
    stage: int                 # partition index; -1 for recovery / whole-net
    step: int                  # global optimizer-step index at log time
    macs: Optional[int] = None     # cumulative per-sample MACs (MLP backend)
    loss: Optional[float] = None
    acc: Optional[float] = None


class History:
    def __init__(self):
        self.records: List[Record] = []
        self.meta: Dict[str, Any] = {}

    def log(self, **kw) -> None:
        self.records.append(Record(**kw))

    def column(self, name: str, *, phase: Optional[str] = None,
               stage: Optional[int] = None) -> List[Any]:
        out = []
        for r in self.records:
            if phase is not None and r.phase != phase:
                continue
            if stage is not None and r.stage != stage:
                continue
            v = getattr(r, name)
            if v is not None:
                out.append(v)
        return out

    # -- legacy views ------------------------------------------------------

    def to_mlp_legacy(self) -> Dict[str, list]:
        """{"macs", "acc", "phase"} rows = eval points (acc is not None)."""
        hist = {"macs": [], "acc": [], "phase": []}
        for r in self.records:
            if r.acc is None:
                continue
            hist["macs"].append(r.macs)
            hist["acc"].append(r.acc)
            hist["phase"].append(r.phase)
        hist.update({k: v for k, v in self.meta.items()})
        return hist

    def to_lm_legacy(self) -> Dict[str, list]:
        """{"stage", "step", "loss"} rows = per-step losses."""
        hist = {"stage": [], "step": [], "loss": []}
        for r in self.records:
            if r.loss is None:
                continue
            hist["stage"].append(r.stage)
            hist["step"].append(r.step)
            hist["loss"].append(r.loss)
        hist.update({k: v for k, v in self.meta.items()})
        return hist
