"""Composable training phases (the paper's schedule, decomposed).

Each phase is a small dataclass with ``run(trainer, state)``; a training
procedure is a *list* of phases executed in order over shared ``TrainState``:

* ``BaselinePhase``            — conventional end-to-end training.
* ``SilStagePhase``            — train one stage against its SIL targets
                                 (paper Fig. 3 "left" phase; interior LM
                                 stages consume the live frozen prefix).
* ``BoundaryMaterializePhase`` — run the frozen prefix over the data once and
                                 store the boundary (the paper's only
                                 communication), into a ``BoundaryCache``.
* ``FrozenPrefixPhase``        — train a stage on frozen-prefix inputs
                                 (stored or live) with its natural loss (CE
                                 for the last stage; Fig. 3 "right" phase).
* ``RecoveryPhase``            — §5: fine-tune one stage end-to-end with the
                                 others frozen.
* ``ParallelSilPhase``         — Fig. 5: every stage trains simultaneously on
                                 synthetic inputs/targets, zero dependencies.

Per-phase ``lr`` / ``optimizer`` / duration default to the ``TrainSpec``'s
per-stage entries; seeds (``seed_base``) reproduce the legacy trainers'
epoch seeding so schedules are bit-for-bit comparable with the pre-redesign
functions.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.backends import make_optimizer_for, scanned_epoch_fn
from repro.train.boundary import BoundaryCache
from repro.train.spec import StageSpec


def _resolve_placement(plan, devices, trainer, state):
    """Plan-or-strategy-name -> validated ``repro.dist.PlacementPlan``.

    The ``"memory"`` strategy's byte estimates come from the LIVE per-stage
    param trees plus each stage's configured optimizer (deferred — only
    computed when that strategy is chosen)."""
    from repro.dist import placement as P
    be = trainer.backend

    def stage_bytes():
        return [P.estimate_stage_bytes(state.stage_params[k],
                                       trainer.spec.stage(k).optimizer)
                for k in range(be.n_stages)]
    return P.resolve(plan, be.n_stages, devices=devices,
                     stage_bytes=stage_bytes)


def _rehost(tree):
    """Pull a (possibly device-committed) tree back to uncommitted default-
    device arrays so later phases can freely mix it with other stages."""
    return jax.tree_util.tree_map(
        jnp.asarray, jax.device_get(tree))  # repro: allow-host-sync


@dataclass
class PhaseBase:
    # overrides; default to the TrainSpec's per-stage entries
    epochs: Optional[int] = None
    steps: Optional[int] = None
    lr: Optional[float] = None
    optimizer: Optional[str] = None
    momentum: Optional[float] = None
    accum: Optional[int] = None
    precision: Optional[object] = None
    nan_guard: Optional[bool] = None
    seed_base: int = 0
    needs_sil = False

    def resolve(self, base: StageSpec) -> StageSpec:
        return StageSpec(
            epochs=self.epochs if self.epochs is not None else base.epochs,
            steps=self.steps if self.steps is not None else base.steps,
            lr=self.lr if self.lr is not None else base.lr,
            optimizer=self.optimizer or base.optimizer,
            momentum=self.momentum if self.momentum is not None
            else base.momentum,
            accum=self.accum if self.accum is not None else base.accum,
            precision=self.precision if self.precision is not None
            else base.precision,
            nan_guard=self.nan_guard if self.nan_guard is not None
            else base.nan_guard)


# ==========================================================================

@dataclass
class BaselinePhase(PhaseBase):
    """Conventional training of the unpartitioned network."""
    name: str = "baseline"

    def run(self, trainer, state) -> None:
        be = trainer.backend
        hp = self.resolve(trainer.spec.baseline or trainer.spec.stage(0))
        opt = make_optimizer_for(hp, trainer.spec)
        if be.kind == "mlp":
            params = be.join(state.stage_params)
            opt_state = opt.init(params)
            params, _ = trainer.drive_epochs(
                state, step=be.build_baseline_step(opt, accum=hp.accum),
                train_params=params,
                opt_state=opt_state, epochs=hp.epochs, phase_name=self.name,
                stage=-1, macs_per_sample=be.full_macs(),
                seed_base=self.seed_base, log_mode="cadence+last",
                eval_fn=be.eval_full)
            state.stage_params = be.split(params)
        else:
            # true unpartitioned training: the full joined tree through
            # M.forward (tied embeddings receive unembedding gradients)
            params = be.join(state.stage_params)
            step = be.build_baseline_step(opt, accum=hp.accum)
            opt_state = opt.init(params)

            def inputs(i):
                return (be.batch_fn(i),)
            params, _ = trainer.drive_steps(
                state, step=step, inputs_fn=inputs, n_steps=hp.steps,
                phase_name=self.name, stage=-1,
                train_params=params, opt_state=opt_state)
            state.stage_params = be.split(params)


# ==========================================================================

@dataclass
class SilStagePhase(PhaseBase):
    """Train stage `stage` against its SIL table (paper's left phase).

    MLP backend: stage 0 only (real inputs).  LM backend: any interior
    stage; stages > 0 consume the live frozen prefix per step."""
    stage: int = 0
    name: str = "left"
    needs_sil = True

    def run(self, trainer, state) -> None:
        be = trainer.backend
        k = self.stage
        if k >= be.n_stages - 1:
            raise ValueError("SilStagePhase is for interior stages; the last "
                             "stage trains with CE (FrozenPrefixPhase)")
        hp = self.resolve(trainer.spec.stage(k))
        opt = make_optimizer_for(hp, trainer.spec)
        sil = state.sils[k]
        if be.kind == "mlp":
            if k != 0:
                raise ValueError("MLP SilStagePhase supports stage 0 only "
                                 "(materialize the boundary for later stages)")
            opt_state = opt.init(state.stage_params[k])
            state.stage_params[k], _ = trainer.drive_epochs(
                state, step=be.build_sil_step(k, opt, sil, accum=hp.accum),
                train_params=state.stage_params[k], opt_state=opt_state,
                epochs=hp.epochs, phase_name=self.name, stage=k,
                macs_per_sample=be.stage_macs(k), seed_base=self.seed_base,
                log_mode="cadence")
        else:
            step = be.build_stage_step(k, opt, sil, state.stage_params[k],
                                       accum=hp.accum)
            opt_state = opt.init(be.trainable(state.stage_params[k]))
            prefix = be.prefix_forward(k) if k else None
            frozen = tuple(state.stage_params[:k])

            def inputs(i):
                batch = be.batch_fn(i)
                xin = batch if k == 0 else prefix(frozen, batch)
                return (xin, batch["labels"], batch.get("mask"))
            state.stage_params[k], _ = trainer.drive_steps(
                state, step=step, inputs_fn=inputs, n_steps=hp.steps,
                phase_name=self.name, stage=k,
                train_params=state.stage_params[k], opt_state=opt_state)


# ==========================================================================

@dataclass
class BoundaryMaterializePhase(PhaseBase):
    """Store the frozen prefix's boundary activations (stages < `upto`).

    This is the paper's single inter-partition communication.  Activations
    are pulled from the device in chunks straight into a reserved
    ``BoundaryCache`` buffer (optionally memmap-spilled to `spill_dir`).
    LM backend: captures `n_batches` batches from the stream (decoder-only
    models).

    With a ``plan`` (a ``repro.dist`` PlacementPlan or strategy name) the
    frozen-prefix forward runs as the PRODUCER on the device that owns the
    last prefix stage — paired with ``FrozenPrefixPhase(plan=...)`` the
    paper's single communication becomes an actual inter-device hop."""
    upto: int = 1
    spill_dir: Optional[str] = None
    spill_threshold_bytes: Optional[int] = None
    n_batches: Optional[int] = None    # LM backend only
    plan: Optional[object] = None
    devices: Optional[Sequence] = None
    name: str = "materialize"

    def _cache(self) -> BoundaryCache:
        kw = {}
        if self.spill_threshold_bytes is not None:
            kw["spill_threshold_bytes"] = self.spill_threshold_bytes
        return BoundaryCache(spill_dir=self.spill_dir, **kw)

    def run(self, trainer, state) -> None:
        be = trainer.backend
        fwd = be.prefix_forward(self.upto)
        frozen = tuple(state.stage_params[: self.upto])
        if self.plan is not None:
            # producer placement: the prefix forward runs on the device
            # owning the last frozen stage (batches follow the committed
            # params; the cache append pulls to host as before)
            placement = _resolve_placement(self.plan, self.devices,
                                           trainer, state)
            frozen = jax.device_put(frozen,
                                    placement.device_for(self.upto - 1))
        old = state.boundary.get("h")
        if old is not None and hasattr(old, "close"):
            old.close()   # re-materialization must not leak a spill file
        cache = self._cache()
        if be.kind == "mlp":
            bx, by = be.epoch_arrays(seed=0, shuffle=False)
            nb, bs = bx.shape[0], bx.shape[1]
            cache.reserve(nb * bs, (be.boundary_width(self.upto - 1),),
                          be.boundary_dtype())
            for i in range(nb):
                cache.append(fwd(frozen, bx[i]))
            labels = np.asarray(
                jax.device_get(by)).reshape(-1)  # repro: allow-host-sync
            state.boundary = {"h": cache, "labels": labels}
        else:
            if be.cfg.enc_dec:
                raise NotImplementedError(
                    "boundary materialization for enc-dec payloads is not "
                    "supported; use FrozenPrefixPhase(source='live')")
            if not self.n_batches:
                raise ValueError("LM materialization needs n_batches")
            hs, labels, masks = None, [], []
            for j in range(self.n_batches):
                batch = be.batch_fn(state.step_idx + j)
                h = fwd(frozen, batch)
                if hs is None:
                    b, s, d = h.shape
                    cache.reserve(self.n_batches * b, (s, d),
                                  be.boundary_dtype())
                    hs = True
                cache.append(h)
                labels.append(np.asarray(batch["labels"]))
                if batch.get("mask") is not None:
                    masks.append(np.asarray(batch["mask"]))
            state.boundary = {"h": cache,
                              "labels": np.concatenate(labels),
                              "mask": np.concatenate(masks) if masks
                              else None,
                              "batch_size": int(labels[0].shape[0])}


# ==========================================================================

@dataclass
class FrozenPrefixPhase(PhaseBase):
    """Train stage `stage` on frozen-prefix inputs with its natural loss
    (CE if it is the last stage, SIL-MSE otherwise).

    source='cache': inputs come from the materialized BoundaryCache (the
    paper's Fig.-3 right phase — zero prefix compute during training).
    source='live': the frozen prefix runs forward every step (the
    transformer-sequential default, where data is a stream).

    With a ``plan`` (``repro.dist`` PlacementPlan or strategy name) the
    trained stage lives on its assigned device as the CONSUMER; under
    source='live' the frozen prefix runs as the PRODUCER on the device
    owning stage k-1 and each boundary activation hops producer->consumer
    (the paper's sole communication, as a real transfer)."""
    stage: int = 1
    source: str = "cache"
    plan: Optional[object] = None
    devices: Optional[Sequence] = None
    name: str = "right"
    seed_base: int = 100
    # interior stages regress to their SIL table; the last stage does not,
    # but SIL derivation is cheap, so be conservative (pass sils=[] to a
    # Trainer.run that genuinely needs none)
    needs_sil = True

    def run(self, trainer, state) -> None:
        be = trainer.backend
        k = self.stage
        last = k == be.n_stages - 1
        if not last and not state.sils:
            raise ValueError("interior FrozenPrefixPhase needs SIL tables: "
                             "pass sils= or key= to Trainer.run")
        hp = self.resolve(trainer.spec.stage(k))
        opt = make_optimizer_for(hp, trainer.spec)
        if hasattr(be, "before_stage_train"):
            be.before_stage_train(state.stage_params, k)
        consumer = producer = None
        if self.plan is not None:
            placement = _resolve_placement(self.plan, self.devices,
                                           trainer, state)
            consumer = placement.device_for(k)
            producer = placement.device_for(k - 1) if k > 0 else consumer
        train_params = state.stage_params[k]
        if consumer is not None:
            train_params = jax.device_put(train_params, consumer)
        if be.kind == "mlp":
            if self.source != "cache" or "h" not in state.boundary:
                raise ValueError("MLP FrozenPrefixPhase needs a preceding "
                                 "BoundaryMaterializePhase")
            step = be.build_ce_step(k, opt, accum=hp.accum) if last \
                else be.build_sil_step(k, opt, state.sils[k],
                                       accum=hp.accum)
            h = jnp.asarray(state.boundary["h"].array())
            y = jnp.asarray(state.boundary["labels"])

            def batch_arrays(ep):
                return be.array_epoch_arrays(h, y, self.seed_base + ep,
                                             be.spec.shuffle)
            opt_state = opt.init(train_params)
            train_params, _ = trainer.drive_epochs(
                state, step=step, train_params=train_params,
                opt_state=opt_state, epochs=hp.epochs, phase_name=self.name,
                stage=k, macs_per_sample=be.stage_macs(k),
                seed_base=self.seed_base, log_mode="cadence+last",
                batch_arrays=batch_arrays)
        else:
            sil = None if last else state.sils[k]
            step = be.build_stage_step(k, opt, sil, train_params,
                                       accum=hp.accum)
            opt_state = opt.init(be.trainable(train_params))
            if self.source == "cache":
                if "h" not in state.boundary:
                    raise ValueError("no materialized boundary; add a "
                                     "BoundaryMaterializePhase first")
                h = state.boundary["h"].array()
                labels = state.boundary["labels"]
                mask = state.boundary.get("mask")
                b = state.boundary["batch_size"]
                n_batches = len(h) // b

                def inputs(i):
                    j = (i % n_batches) * b
                    m = None if mask is None else jnp.asarray(mask[j:j + b])
                    return (jnp.asarray(h[j:j + b]),
                            jnp.asarray(labels[j:j + b]), m)
            else:
                prefix = be.prefix_forward(k)
                frozen = tuple(state.stage_params[:k])
                if producer is not None:
                    frozen = jax.device_put(frozen, producer)

                def inputs(i):
                    batch = be.batch_fn(i)
                    hb = prefix(frozen, batch)
                    if consumer is not None:
                        # the paper's single inter-partition communication,
                        # as an actual producer->consumer device transfer
                        hb = jax.device_put(hb, consumer)
                    return (hb, batch["labels"], batch.get("mask"))
            train_params, _ = trainer.drive_steps(
                state, step=step, inputs_fn=inputs, n_steps=hp.steps,
                phase_name=self.name, stage=k,
                train_params=train_params, opt_state=opt_state)
        state.stage_params[k] = _rehost(train_params) \
            if consumer is not None else train_params


# ==========================================================================

@dataclass
class RecoveryPhase(PhaseBase):
    """§5 recovery: fine-tune stage `stage` end-to-end, the rest frozen."""
    stage: int = 0
    name: str = "recovery"
    seed_base: int = 200

    def run(self, trainer, state) -> None:
        be = trainer.backend
        j = self.stage
        base = trainer.spec.recovery
        if base is None and self.epochs is None and self.steps is None:
            return   # recovery disabled in the spec and not forced here
        hp = self.resolve(base or trainer.spec.stage(j))
        n = hp.epochs if be.kind == "mlp" else hp.steps
        if not n:
            return
        opt = make_optimizer_for(hp, trainer.spec)
        frozen = list(state.stage_params)
        if be.kind == "mlp":
            step = be.build_recovery_step(j, frozen, opt, accum=hp.accum)
            opt_state = opt.init(state.stage_params[j])
            state.stage_params[j], _ = trainer.drive_epochs(
                state, step=step, train_params=state.stage_params[j],
                opt_state=opt_state, epochs=n, phase_name=self.name,
                stage=j, macs_per_sample=be.full_macs(),
                seed_base=self.seed_base, log_mode="every")
        else:
            step = be.build_recovery_step(j, frozen, opt, accum=hp.accum)
            opt_state = opt.init(be.trainable(state.stage_params[j]))

            def inputs(i):
                return (be.batch_fn(i),)
            state.stage_params[j], _ = trainer.drive_steps(
                state, step=step, inputs_fn=inputs, n_steps=n,
                phase_name=self.name, stage=-1,   # legacy: recovery logs -1
                train_params=state.stage_params[j], opt_state=opt_state)


# ==========================================================================

@dataclass
class ParallelSilPhase(PhaseBase):
    """Fig. 5: ALL stages train simultaneously with zero dependencies.

    Interior stage k consumes SIL_{k-1}[:, y] and regresses to SIL_k[:, y];
    stage 0 consumes real inputs; the last stage trains with CE.  The paper
    deems the mode impractical for accuracy; it is the zero-communication
    extreme of the schedule space.

    ``plan`` (a ``repro.dist`` PlacementPlan, a strategy name
    'round_robin'/'memory', or an explicit assignment list) routes the phase
    through ``repro.dist.StageExecutor``: every stage's params/optimizer
    state pin to its assigned device and all stage steps dispatch per tick
    with no host sync — the paper's Fig.-5 simultaneity actually executed.
    ``ckpt_dir``/``ckpt_every`` enable per-stage checkpointing (one manifest
    and tick counter per stage; see ``repro.dist.lifecycle``)."""
    name: str = "parallel"
    needs_sil = True
    shuffle: bool = True           # legacy MLP fig-5 shuffles
    plan: Optional[object] = None
    devices: Optional[Sequence] = None
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    ckpt_keep_last: Optional[int] = None

    def run(self, trainer, state) -> None:
        be = trainer.backend
        if self.plan is not None:
            self._run_dist(trainer, state)
        elif be.kind == "mlp":
            self._run_mlp(trainer, state)
        else:
            self._run_lm(trainer, state)

    def _run_dist(self, trainer, state) -> None:
        from repro.dist.executor import StageExecutor
        be = trainer.backend
        if be.kind != "mlp" and (be.shard_x is not None
                                 or be.grad_pspecs_fn is not None):
            # the executor builds steps without the mesh-sharding hooks;
            # dropping a caller's with_sharding_constraint pass silently
            # would be a correctness trap on real meshes
            raise ValueError(
                "dist placement is incompatible with the Policy sharding "
                "hooks (shard_x/grad_pspecs_fn): stages pin whole trees to "
                "single devices. Drop the hooks or run without plan=.")
        placement = _resolve_placement(self.plan, self.devices,
                                       trainer, state)
        hps = [self.resolve(trainer.spec.stage(k))
               for k in range(be.n_stages)]
        opts = [make_optimizer_for(hp, trainer.spec) for hp in hps]
        ex = StageExecutor(be, placement, state.stage_params, state.sils,
                           opts, hps, seed_base=self.seed_base,
                           shuffle=self.shuffle, ckpt_dir=self.ckpt_dir,
                           ckpt_every=self.ckpt_every,
                           ckpt_keep_last=self.ckpt_keep_last,
                           metrics=trainer.metrics, tracer=trainer.tracer)
        if be.kind == "mlp":
            n_ticks = max(hp.epochs for hp in hps)
        else:
            n_ticks = max(hp.steps for hp in hps)
        ex.run(n_ticks)
        if self.ckpt_dir:
            ex.checkpoint()    # final per-stage manifests at their ticks
        ex.finalize(trainer, state, phase_name=self.name)

    def _run_mlp(self, trainer, state) -> None:
        be = trainer.backend
        hps = [self.resolve(trainer.spec.stage(k))
               for k in range(be.n_stages)]
        opts = [make_optimizer_for(hp, trainer.spec) for hp in hps]
        opt_states = [opts[k].init(state.stage_params[k])
                      for k in range(be.n_stages)]
        epoch_fns = [scanned_epoch_fn(
            be.build_parallel_step(k, opts[k], state.sils, accum=hps[k].accum))
            for k in range(be.n_stages)]
        # epoch loop outside the stage loop: the (shuffled) epoch gather is
        # done once per epoch, shared by every independent stage
        for ep in range(max(hp.epochs for hp in hps)):
            batches = be.epoch_arrays(self.seed_base + ep, self.shuffle)
            n_samples = batches[0].shape[0] * batches[0].shape[1]
            for k in range(be.n_stages):
                if ep >= hps[k].epochs:
                    continue
                state.stage_params[k], opt_states[k], _ = epoch_fns[k](
                    state.stage_params[k], opt_states[k], batches)
                state.cum_macs += be.stage_macs(k) * n_samples
        state.history.log(phase=self.name, stage=-1, step=state.step_idx,
                          macs=state.cum_macs,
                          acc=be.eval_joined(state.stage_params))

    def _run_lm(self, trainer, state) -> None:
        be = trainer.backend
        hps = [self.resolve(trainer.spec.stage(k))
               for k in range(be.n_stages)]
        opts = [make_optimizer_for(hp, trainer.spec) for hp in hps]
        opt_states = [opts[k].init(be.trainable(state.stage_params[k]))
                      for k in range(be.n_stages)]
        steps = [be.build_stage_step(
            k, opts[k],
            None if k == be.n_stages - 1 else state.sils[k],
            state.stage_params[k], accum=hps[k].accum)
            for k in range(be.n_stages)]
        pending, logged_steps, logged_stages = [], [], []
        n_steps = max(hp.steps for hp in hps)
        for i in range(n_steps):
            batch = be.batch_fn(i)
            labels = batch["labels"]
            for k in range(be.n_stages):
                if i >= hps[k].steps:
                    continue
                xin = batch if k == 0 else be.synthetic_input(k, state.sils,
                                                              labels)
                state.stage_params[k], opt_states[k], loss = steps[k](
                    state.stage_params[k], opt_states[k], xin, labels)
                pending.append(loss)
                logged_steps.append(i)
                logged_stages.append(k)
            state.step_idx += 1
        trainer.flush_losses(state, pending, logged_steps, self.name,
                             logged_stages)
