"""Training backends for the phase API.

A backend binds the phase engine to a model family and its data access
pattern:

* ``MLPBackend`` — the paper's fully-connected EMNIST experiment.  Dataset is
  array-resident, so the inner loop is a single jitted ``jax.lax.scan`` over
  the epoch's stacked batches: metrics stay device-resident and the host sees
  one transfer per epoch instead of one blocking ``float(loss)`` per step.
* ``LMBackend`` — the transformer generalization over a
  ``partition.PartitionPlan``.  Batches come from a host ``batch_fn`` stream,
  so steps run in a python loop, but losses are kept as device scalars and
  fetched in one transfer at phase end, which keeps dispatch asynchronous.

Both backends donate params + optimizer state into their jitted steps on
accelerators (donation is a no-op on CPU, where JAX would only warn), and
defensively copy shared leaves when slicing stages so donation can never
invalidate a caller-held param tree.
"""
from __future__ import annotations

import functools
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import precision as precision_lib
from repro import runtime
from repro.core import losses, partition, sil as sil_lib
from repro.models import mlp as MLP
from repro.models import model as M
from repro.optim import make_optimizer, mixed_precision, step_guard

from repro.train.spec import StageSpec, TrainSpec


def donate_argnums(*nums) -> Tuple[int, ...]:
    """Buffer donation is unimplemented on CPU (JAX emits a warning and
    ignores it); only request it where it exists.  ``repro.runtime`` owns
    the decision (REPRO_ASSUME_DONATION=1 forces the request on for
    trace-only introspection such as ``repro.analysis``)."""
    return runtime.donate_argnums(*nums)


def _copy_tree(tree):
    return jax.tree_util.tree_map(jnp.copy, tree)


def resolve_policy(hp=None, spec=None):
    """The explicitly-requested PrecisionPolicy for a stage (StageSpec
    override first, then the TrainSpec-wide default), or None — None keeps
    the legacy numerics exactly (MLP backend fp32; LM backend whatever the
    ModelConfig's dtype says)."""
    p = getattr(hp, "precision", None) if hp is not None else None
    if p is None and spec is not None:
        p = getattr(spec, "precision", None)
    return None if p is None else precision_lib.get_policy(p)


def make_optimizer_for(hp: StageSpec, spec: Optional[TrainSpec] = None):
    kw = {"momentum": hp.momentum} if hp.optimizer == "sgdm" else {}
    opt = make_optimizer(hp.optimizer, hp.lr, **kw)
    pol = resolve_policy(hp, spec)
    if pol is not None and pol.wraps_optimizer:
        opt = mixed_precision(opt, loss_scale=pol.loss_scale,
                              dynamic=pol.dynamic_scale,
                              growth_interval=pol.scale_growth_interval)
    else:
        # NaN/inf step guard (repro.resilience) for the unscaled precisions
        # only: mixed_precision already skips-and-counts non-finite steps,
        # and a guard stacked OUTSIDE it would veto scaled gradients before
        # the dynamic loss scale could cure them by halving
        guard = hp.nan_guard
        if guard is None:
            guard = bool(getattr(spec, "nan_guard", False))
        if guard:
            opt = step_guard(opt)
    return opt


def value_and_accum_grads(loss_fn, params, args, accum: int,
                          accum_dtype=jnp.float32):
    """(mean loss, grads) of ``loss_fn(params, *args)`` with the batch split
    into ``accum`` microbatches inside the (caller-jitted) step; gradients
    accumulate in ``accum_dtype`` (fp32) regardless of the compute dtype.
    ``accum=1`` is the exact legacy single-shot path."""
    grad_fn = jax.value_and_grad(loss_fn)
    if accum <= 1:
        return grad_fn(params, *args)

    def fold(a):
        if a.shape[0] % accum:
            raise ValueError(f"batch dim {a.shape[0]} not divisible by "
                             f"accum={accum}")
        return a.reshape((accum, a.shape[0] // accum) + a.shape[1:])

    mbs = jax.tree_util.tree_map(fold, args)

    def body(acc, mb):
        loss, g = grad_fn(params, *mb)
        acc = jax.tree_util.tree_map(
            lambda a, gi: a + gi.astype(a.dtype), acc, g)
        return acc, loss

    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, accum_dtype), params)
    gsum, mb_losses = jax.lax.scan(body, zeros, mbs)
    grads = jax.tree_util.tree_map(lambda g: g / accum, gsum)
    return mb_losses.mean(), grads


def scanned_epoch_fn(step):
    """One jitted epoch: scan `step` over stacked batches, returning the
    per-step losses as a device array (no per-step host sync)."""

    def epoch(params, opt_state, batches):
        def body(carry, batch):
            p, s = carry
            p, s, loss = step(p, s, *batch)
            return (p, s), loss
        (p, s), ls = jax.lax.scan(body, (params, opt_state), batches)
        return p, s, ls

    return jax.jit(epoch, donate_argnums=donate_argnums(0, 1))


# ==========================================================================
# MLP backend (paper §3-§5)
# ==========================================================================

def balanced_bounds(cfg: MLP.MLPConfig, n_stages: int, *,
                    costs=None) -> Tuple[Tuple[int, int], ...]:
    """Balanced contiguous layer split (the legacy fig-5 scheme).

    ``costs`` routes through the ``repro.plan`` bottleneck searcher instead:
    pass a ``plan.ModelCosts`` table (head/tail-overhead-aware), a per-layer
    scalar cost sequence, or ``"auto"`` to build the MLP cost table from the
    config (paper batch size, sgdm slots)."""
    if costs is not None:
        from repro import plan as plan_lib
        if isinstance(costs, str):
            if costs != "auto":
                raise ValueError(f"bad costs={costs!r}; expected 'auto', a "
                                 "ModelCosts table, or a scalar sequence")
            return plan_lib.auto_mlp_bounds(cfg, n_stages)
        if isinstance(costs, plan_lib.ModelCosts):
            return plan_lib.solve(costs, n_stages)
        from repro.plan.search import searched_bounds_for_sequence
        return searched_bounds_for_sequence(costs, n_stages)
    base, rem = divmod(cfg.n_layers, n_stages)
    bounds, s = [], 0
    for k in range(n_stages):
        e = s + base + (1 if k < rem else 0)
        bounds.append((s, e))
        s = e
    return tuple(bounds)


def mlp_default_bounds(cfg: MLP.MLPConfig, n_stages: int
                       ) -> Tuple[Tuple[int, int], ...]:
    """2 stages -> the paper's cut; otherwise balanced contiguous split."""
    if n_stages == 2:
        return ((0, cfg.cut), (cfg.cut, cfg.n_layers))
    return balanced_bounds(cfg, n_stages)


class MLPBackend:
    kind = "mlp"

    def __init__(self, cfg: MLP.MLPConfig, data, spec: TrainSpec,
                 bounds: Optional[Sequence[Tuple[int, int]]] = None):
        self.cfg = cfg
        self.spec = spec
        # spec-wide policy; None = legacy fp32-everything (bit-exact).
        # Per-stage StageSpec.precision overrides only affect the optimizer
        # wrapper (built in phases via make_optimizer_for) — the forward
        # compute dtype is a backend-wide choice
        self.policy = resolve_policy(None, spec)
        tx, ty, vx, vy = data
        self._tx = jnp.asarray(tx)
        self._ty = jnp.asarray(ty)
        self._vx, self._vy = vx, vy
        self.bounds = tuple(bounds) if bounds is not None \
            else mlp_default_bounds(cfg, spec.n_stages)
        self.n_stages = len(self.bounds)
        bs = spec.batch_size
        self.n_train = len(tx)
        self.batches_per_epoch = self.n_train // bs
        self.samples_per_epoch = self.batches_per_epoch * bs
        self.dropped_per_epoch = self.n_train - self.samples_per_epoch
        self._plain_epoch = None   # cached unshuffled epoch arrays

    # -- params ------------------------------------------------------------

    def split(self, params) -> List[list]:
        return [_copy_tree(params[b0:b1]) for b0, b1 in self.bounds]

    def join(self, stage_params) -> list:
        return sum(stage_params, [])

    @staticmethod
    def trainable(stage_params):
        return stage_params       # no frozen leaves in the MLP stages

    def boundary_width(self, k: int) -> int:
        return self.cfg.sizes[self.bounds[k][1]]

    def make_sils(self, key, kappa: float) -> list:
        """Legacy-compatible fig-5 scheme: split(key, n_stages + 2), sils
        keyed from keys[1 + k].  (The fig-3 recipe derives its single SIL
        differently for seed compatibility — see recipes.run_mlp_fig3.)"""
        keys = jax.random.split(key, self.n_stages + 2)
        return [sil_lib.make_sil(keys[1 + k], self.boundary_width(k),
                                 self.cfg.n_classes, kappa)
                for k in range(self.n_stages - 1)]

    # -- macs --------------------------------------------------------------

    def stage_macs(self, k: int) -> int:
        b0, b1 = self.bounds[k]
        return MLP.macs(self.cfg, b0, b1)

    def full_macs(self) -> int:
        return MLP.macs(self.cfg)

    # -- data --------------------------------------------------------------

    def epoch_arrays(self, seed: int, shuffle: bool):
        """Stacked (nb, bs, ...) device arrays for one epoch, in the exact
        order the legacy `_batches` generator produced."""
        bs = self.spec.batch_size
        nb = self.batches_per_epoch
        n = self.samples_per_epoch
        if not shuffle:
            if self._plain_epoch is None:
                self._plain_epoch = (
                    self._tx[:n].reshape(nb, bs, -1),
                    self._ty[:n].reshape(nb, bs))
            return self._plain_epoch
        order = np.arange(self.n_train)
        np.random.RandomState(seed).shuffle(order)
        idx = jnp.asarray(order[:n])
        return (jnp.take(self._tx, idx, axis=0).reshape(nb, bs, -1),
                jnp.take(self._ty, idx, axis=0).reshape(nb, bs))

    def array_epoch_arrays(self, x, y, seed: int, shuffle: bool):
        """Same batching over caller-supplied arrays (e.g. the materialized
        boundary from a BoundaryCache)."""
        bs = self.spec.batch_size
        n = (len(x) // bs) * bs
        nb = n // bs
        x = jnp.asarray(x) if not isinstance(x, jax.Array) else x
        y = jnp.asarray(y) if not isinstance(y, jax.Array) else y
        if shuffle:
            order = np.arange(len(x))
            np.random.RandomState(seed).shuffle(order)
            idx = jnp.asarray(order[:n])
            return (jnp.take(x, idx, axis=0).reshape(nb, bs, -1),
                    jnp.take(y, idx, axis=0).reshape(nb, bs))
        return x[:n].reshape(nb, bs, -1), y[:n].reshape(nb, bs)

    # -- step builders -----------------------------------------------------

    def _compute_dtype(self):
        return None if self.policy is None else self.policy.compute_jnp

    def _range_forward(self, p, x, b0, b1):
        return MLP.forward_range(self.cfg, p, x, b0, b1,
                                 compute_dtype=self._compute_dtype())

    def _cast_in(self, x):
        """Inputs enter the network in the compute dtype (no-op legacy)."""
        return x if self.policy is None else self.policy.cast_compute(x)

    def _finish_step(self, opt, loss_fn, p, st, args, accum: int):
        """Shared tail of every MLP step: (scaled) grads — accumulated over
        `accum` microbatches in fp32 — into the optimizer; the returned loss
        is unscaled.  accum=1 / no policy is the exact legacy path."""
        scale = precision_lib.read_loss_scale(st)

        def scaled(p_, *a):
            return loss_fn(p_, *a) * scale
        loss, grads = value_and_accum_grads(scaled, p, args, accum)
        p2, st2 = opt.update(grads, st, p)
        return p2, st2, loss / scale

    def build_sil_step(self, k: int, opt, sil, accum: int = 1):
        b0, b1 = self.bounds[k]

        def step(p, st, x, y):
            def loss_fn(p_, xb, yb):
                h = self._range_forward(p_, xb, b0, b1)
                return losses.sil_stage_loss(h, sil, yb)
            return self._finish_step(opt, loss_fn, p, st,
                                     (self._cast_in(x), y), accum)
        return step

    def build_ce_step(self, k: int, opt, accum: int = 1):
        """CE through stage k alone (its input is the stage boundary)."""
        b0, b1 = self.bounds[k]

        def step(p, st, h, y):
            def loss_fn(p_, hb, yb):
                logits = self._range_forward(p_, hb, b0, b1)
                return losses.cross_entropy(logits, yb)
            return self._finish_step(opt, loss_fn, p, st,
                                     (self._cast_in(h), y), accum)
        return step

    def build_baseline_step(self, opt, accum: int = 1):
        cfg = self.cfg

        def step(p, st, x, y):
            def loss_fn(p_, xb, yb):
                logits = self._range_forward(p_, xb, 0, cfg.n_layers)
                return losses.cross_entropy(logits, yb)
            return self._finish_step(opt, loss_fn, p, st,
                                     (self._cast_in(x), y), accum)
        return step

    def build_recovery_step(self, j: int, frozen: list, opt, accum: int = 1):
        """End-to-end CE training of stage j with every other stage frozen
        (paper §5 for j=0)."""
        bounds = self.bounds

        def step(pj, st, x, y):
            def loss_fn(pj_, xb, yb):
                h = xb
                for k, (b0, b1) in enumerate(bounds):
                    p = pj_ if k == j else jax.lax.stop_gradient(frozen[k])
                    h = self._range_forward(p, h, b0, b1)
                return losses.cross_entropy(h, yb)
            return self._finish_step(opt, loss_fn, pj, st,
                                     (self._cast_in(x), y), accum)
        return step

    def build_parallel_step(self, k: int, opt, sils, accum: int = 1):
        """Fig.-5 stage step: interior stages consume SIL_{k-1}[:, y] and
        regress to SIL_k[:, y]; the last trains with CE; stage 0 consumes
        the real batch.  The synthetic input is looked up inside the jitted
        step from the labels (identical math to the legacy host lookup)."""
        b0, b1 = self.bounds[k]
        last = k == self.n_stages - 1

        def step(p, st, x, y):
            def loss_fn(p_, xb, yb):
                xin = xb if k == 0 else sil_lib.sil_lookup(sils[k - 1], yb)
                h = self._range_forward(p_, self._cast_in(xin), b0, b1)
                if last:
                    return losses.cross_entropy(h, yb)
                return losses.sil_stage_loss(h, sils[k], yb)
            return self._finish_step(opt, loss_fn, p, st, (x, y), accum)
        return step

    # -- prefix / eval -----------------------------------------------------

    def boundary_dtype(self):
        """Storage dtype for materialized boundary activations — the policy's
        compute dtype (halving the memmap spill under bf16)."""
        return np.dtype(jnp.float32) if self.policy is None \
            else np.dtype(self.policy.compute_jnp)

    def prefix_forward(self, k: int):
        bounds = self.bounds

        @jax.jit
        def fwd(prefix: tuple, x):
            x = self._cast_in(x)
            for j in range(k):
                b0, b1 = bounds[j]
                x = self._range_forward(prefix[j], x, b0, b1)
            return x
        return fwd

    def eval_joined(self, stage_params) -> float:
        return self.eval_full(self.join(stage_params))

    def eval_full(self, params) -> float:
        return mlp_test_accuracy(self.cfg, params, self._vx, self._vy)


@functools.partial(jax.jit, static_argnums=(0,))
def _mlp_eval(cfg: MLP.MLPConfig, params, x, y):
    logits = MLP.forward_range(cfg, params, x, 0, cfg.n_layers)
    return losses.accuracy(logits, y)


def mlp_test_accuracy(cfg, params, tx, ty, bs=4096) -> float:
    accs = []
    for i in range(0, len(tx), bs):
        accs.append(float(_mlp_eval(cfg, params, tx[i:i + bs], ty[i:i + bs]))
                    * len(tx[i:i + bs]))
    return sum(accs) / len(tx)


# ==========================================================================
# Transformer (PartitionPlan) backend
# ==========================================================================

class LMBackend:
    kind = "lm"

    def __init__(self, cfg, plan: partition.PartitionPlan,
                 batch_fn: Callable[[int], dict], spec: TrainSpec, *,
                 shard_x=None, grad_pspecs_fn=None):
        """shard_x / grad_pspecs_fn: the production sharding hooks —
        `launch/train.py` passes the Policy's sequence-shard constraint and
        `policy.params_shardings` (NamedShardings, usable outside a mesh
        context) so PNN stage steps run through the same plumbing as
        baseline training."""
        # an explicit spec.precision re-dtypes the whole stage forward
        # (activations, caches, boundary spills run in compute dtype);
        # params keep cfg.param_dtype — see repro.precision
        self.policy = resolve_policy(None, spec)
        if self.policy is not None:
            cfg = self.policy.apply_to_model(cfg)
        self.cfg = cfg
        self.plan = plan
        self.batch_fn = batch_fn
        self.spec = spec
        self.n_stages = plan.n_stages
        self.shard_x = shard_x
        self.grad_pspecs_fn = grad_pspecs_fn

    # -- params ------------------------------------------------------------

    def split(self, params) -> List[dict]:
        # copy so donated stage buffers can never alias the caller's tree
        return [_copy_tree(partition.slice_stage_params(
            self.cfg, self.plan, params, k)) for k in range(self.n_stages)]

    def join(self, stage_params) -> dict:
        return partition.join_stage_params(self.cfg, self.plan, stage_params)

    def make_sils(self, key, kappa: float) -> list:
        # exact legacy key schedule: split(key, n_stages), sils from keys[:n-1]
        keys = jax.random.split(key, self.n_stages)
        return [sil_lib.make_sil(keys[k], self.cfg.d_model,
                                 self.cfg.vocab_size, kappa)
                for k in range(self.n_stages - 1)]

    def before_stage_train(self, stage_params: list, k: int) -> None:
        """Refresh the last stage's frozen tied-unembedding copy from stage
        0's (possibly already trained) embedding before training it."""
        if k == self.n_stages - 1:
            partition.refresh_tied_unembed(self.cfg, self.plan, stage_params)

    @staticmethod
    def trainable(stage_params: dict) -> dict:
        """The stage's differentiated/optimized subtree.  The frozen
        ``tied_unembed`` snapshot is excluded so no gradient or optimizer
        state is ever allocated for it (the paper's per-stage memory claim)."""
        return {k: v for k, v in stage_params.items() if k != "tied_unembed"}

    # -- step builders -----------------------------------------------------

    def _trim_vision(self, x):
        if self.cfg.frontend == "vision":
            return x[:, self.cfg.vision_tokens:]
        return x

    def _jit_step(self, step):
        return jax.jit(step, donate_argnums=donate_argnums(0, 1))

    def _grad_pspecs(self, stage_params):
        if self.grad_pspecs_fn is None:
            return None
        return self.grad_pspecs_fn(stage_params)

    @staticmethod
    def _split_frozen(sp: dict):
        frozen = {k: v for k, v in sp.items() if k == "tied_unembed"}
        train = {k: v for k, v in sp.items() if k != "tied_unembed"}
        return train, frozen

    def _cast_in(self, xin):
        """Boundary inputs enter the stage in the compute dtype (handles
        stale dtypes from caches materialized under another policy)."""
        if self.policy is None:
            return xin
        return self.policy.cast_compute(xin)

    def build_stage_step(self, k: int, opt, sil, stage_params_struct=None,
                         accum: int = 1):
        """Train step for stage k: SIL-MSE on the boundary for interior
        stages, CE (+ MoE aux) through the real unembedding for the last.
        The frozen tied_unembed snapshot (if any) is carried outside the
        differentiated tree — zero grad/optimizer-state cost.  Gradients of
        ``accum`` microbatches accumulate in fp32 inside the jitted step;
        the loss is scaled by the live loss scale (1.0 unless the optimizer
        is a mixed_precision fp16 wrapper)."""
        cfg, plan = self.cfg, self.plan
        last = k == self.n_stages - 1
        pspecs = self._grad_pspecs(self.trainable(stage_params_struct)) \
            if stage_params_struct is not None else None

        def step(sp, st, xin, labels, mask=None):
            train, frozen = self._split_frozen(sp)
            scale = precision_lib.read_loss_scale(st)

            def loss_fn(p, xin, labels, mask):
                out, aux = partition.stage_forward(cfg, plan, k,
                                                   {**p, **frozen}, xin,
                                                   shard_x=self.shard_x)
                if last:
                    loss, _ = losses.train_objective(
                        cfg, self._trim_vision(out), labels, aux, mask)
                    return loss * scale
                bound = out[0] if cfg.enc_dec else out
                bound = self._trim_vision(bound)
                loss = losses.sil_stage_loss(bound, sil, labels)
                if cfg.moe is not None:
                    loss = loss + cfg.moe.load_balance_loss * aux["lb_loss"] \
                        + cfg.moe.router_z_loss * aux["z_loss"]
                return loss * scale
            loss, grads = value_and_accum_grads(
                loss_fn, train, (self._cast_in(xin), labels, mask), accum)
            if pspecs is not None:
                grads = jax.tree_util.tree_map(
                    lambda g, s: jax.lax.with_sharding_constraint(g, s),
                    grads, pspecs)
            new_train, st2 = opt.update(grads, st, train)
            return {**new_train, **frozen}, st2, loss / scale

        return self._jit_step(step)

    def build_parallel_stage_step(self, k: int, opt, sil_in, sil_target,
                                  stage_params_struct=None, accum: int = 1):
        """Fig.-5 step for stage k>0 with the synthetic-input lookup FUSED
        into the jitted program: callers pass only (sp, st, labels) and
        SIL_{k-1}[:, y] is derived on-device from ``sil_in``.  The
        ``repro.dist`` executor uses this so one tick dispatches one call
        per stage with zero host-side array construction between stages —
        ``sil_in`` is expected to be pre-pinned to the stage's device.

        ``sil_target`` is SIL_k (None for the last stage, which trains CE);
        math is identical to ``synthetic_input`` + ``build_stage_step``."""
        if k == 0:
            raise ValueError("stage 0 consumes the real batch; use "
                             "build_stage_step")
        inner = self.build_stage_step(k, opt, sil_target,
                                      stage_params_struct, accum=accum)
        act = self.cfg.activation_dtype()
        enc_dec = self.cfg.enc_dec

        def step(sp, st, labels):
            syn = sil_lib.sil_lookup(sil_in, labels).astype(act)
            xin = (syn, None) if enc_dec else syn
            return inner(sp, st, xin, labels)

        return self._jit_step(step)

    def build_recovery_step(self, j: int, frozen_stages: list, opt,
                            accum: int = 1):
        """End-to-end CE training of stage j, all other stages frozen."""
        cfg, plan = self.cfg, self.plan

        def step(pj, st, batch):
            train, snap = self._split_frozen(pj)
            scale = precision_lib.read_loss_scale(st)

            def loss_fn(pj_, batch):
                x = batch
                aux = {}
                for k in range(self.n_stages):
                    p = {**pj_, **snap} if k == j \
                        else jax.lax.stop_gradient(frozen_stages[k])
                    x, aux = partition.stage_forward(cfg, plan, k, p, x,
                                                     shard_x=self.shard_x)
                loss, _ = losses.train_objective(
                    cfg, self._trim_vision(x), batch["labels"], aux,
                    batch.get("mask"))
                return loss * scale
            loss, grads = value_and_accum_grads(loss_fn, train, (batch,),
                                                accum)
            new_train, st2 = opt.update(grads, st, train)
            return {**new_train, **snap}, st2, loss / scale

        return self._jit_step(step)

    def build_baseline_step(self, opt, accum: int = 1):
        """Conventional end-to-end training of the UNPARTITIONED network
        (full joined param tree through M.forward — tied embeddings train
        with gradient flowing through the unembedding, exactly as outside
        the phase API)."""
        cfg = self.cfg

        def step(params, st, batch):
            scale = precision_lib.read_loss_scale(st)

            def loss_fn(p, batch):
                logits, aux = M.forward(cfg, p, batch, shard_x=self.shard_x)
                loss, _ = losses.train_objective(
                    cfg, self._trim_vision(logits), batch["labels"], aux,
                    batch.get("mask"))
                return loss * scale
            loss, grads = value_and_accum_grads(loss_fn, params, (batch,),
                                                accum)
            p2, st2 = opt.update(grads, st, params)
            return p2, st2, loss / scale

        return self._jit_step(step)

    def boundary_dtype(self):
        """Storage dtype for materialized boundaries (= activation dtype)."""
        return np.dtype(self.cfg.activation_dtype())

    def prefix_forward(self, k: int):
        """Jitted frozen forward of stages < k — the paper's sole
        inter-partition communication."""
        cfg, plan = self.cfg, self.plan

        @jax.jit
        def fwd(prefix_params: tuple, batch):
            x = batch
            for j in range(k):
                x, _ = partition.stage_forward(cfg, plan, j, prefix_params[j],
                                               x, remat=False,
                                               shard_x=self.shard_x)
            return x
        return fwd

    def synthetic_input(self, k: int, sils, labels):
        """Fig.-5 synthetic input for stage k>0: SIL_{k-1}[:, y]."""
        syn = sil_lib.sil_lookup(sils[k - 1], labels).astype(
            self.cfg.activation_dtype())
        return (syn, None) if self.cfg.enc_dec else syn
