"""repro.obs — unified observability: metrics, structured events, spans.

Three pillars (full guide: docs/OBSERVABILITY.md):

* **Metrics** (``obs.metrics`` / ``obs.registry``) — typed
  ``Counter``/``Gauge``/``Histogram`` series in a ``MetricsRegistry`` with
  one schema-versioned export (``repro.obs/1``).  Hot-path variants
  (``DeviceCounter``/``DeviceHistogram``) accumulate in device-resident
  int32 arrays and drain only at existing flush boundaries.
* **Events** (``obs.events``) — a bounded ring of schema-versioned records
  (scheduler admits/retires/rejects, supervisor health transitions,
  checkpoint saves/restores, fault sightings/recoveries).
* **Spans** (``obs.trace``) — host-walltime timelines exportable as Chrome
  trace-event JSON for Perfetto (trainer phases, per-stage executor ticks,
  request lifecycles).

Consumption: ``launch/loadgen.py`` (open-loop Poisson load against the
serve engine, SLOs into ``results/BENCH_9.json``) and
``launch/metrics.py`` (dump / summary / schema check).
"""
from repro.obs.events import (EVENT_KINDS, Event, EventLog, default_log,
                              set_default_log)
from repro.obs.metrics import (DEPTH_BUCKETS, LOSS_BUCKETS, TTFT_MS_BUCKETS,
                               Counter, DeviceCounter, DeviceHistogram,
                               Gauge, Histogram)
from repro.obs.registry import (SCHEMA, MetricsRegistry, default_registry,
                                set_default_registry)
from repro.obs.trace import (TID_LOOP, TID_REQ0, TID_STAGE0, Span, Tracer)

__all__ = [
    "SCHEMA", "EVENT_KINDS", "TTFT_MS_BUCKETS", "LOSS_BUCKETS",
    "DEPTH_BUCKETS", "TID_LOOP", "TID_STAGE0", "TID_REQ0",
    "Counter", "Gauge", "Histogram", "DeviceCounter", "DeviceHistogram",
    "MetricsRegistry", "default_registry", "set_default_registry",
    "Event", "EventLog", "default_log", "set_default_log",
    "Span", "Tracer",
]
