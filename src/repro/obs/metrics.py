"""Typed metrics: ``Counter`` / ``Gauge`` / ``Histogram`` plus their
device-resident variants.

Host metrics are plain labeled series — cheap dict updates on the control
plane, never inside a jitted region.  The device variants
(``DeviceCounter`` / ``DeviceHistogram``) hold **device-resident int32
state** updated by lazily-dispatched device ops (the same zero-host-sync
idiom as ``optim.step_guard``'s skip counter): ``add`` / ``observe_device``
enqueue a few XLA ops and return immediately, and the single sanctioned
device->host read happens at ``drain()`` — which callers invoke only at
the flush boundaries the system already has (``Trainer.flush_losses``,
``StageExecutor.finalize``, the engine's end-of-``generate``).

Draining is idempotent by construction: ``drain`` folds the device
accumulator into the host value and resets it to zero, so reading twice
never double-counts.  Replay protection (a resumed stage re-running a
tick) is the *caller's* job — observe under the same high-water guard
that already suppresses replayed loss logging (see ``dist.executor``).

Histograms are **fixed-bucket**: ``edges`` define ``len(edges) + 1``
buckets — bucket 0 is ``(-inf, edges[0]]``, bucket i is
``(edges[i-1], edges[i]]``, and the last bucket is ``(edges[-1], inf)``.
``percentile(q)`` interpolates linearly inside the covering bucket, so its
error is bounded by that bucket's width (pinned against numpy percentiles
in tests/test_obs.py).
"""
from __future__ import annotations

import bisect
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, str], ...]

# default bucket ladders (ms for latency, nats for losses, entities for
# depth) — log-spaced so p99 of a heavy tail still lands in a narrow bucket
TTFT_MS_BUCKETS = (1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
                   1000.0, 2500.0, 5000.0, 10000.0, 30000.0, 120000.0)
LOSS_BUCKETS = (0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 64.0,
                256.0, 4096.0)
DEPTH_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 512.0)


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _device_key(v) -> Any:
    """Accumulator key for a device value: committed buffers on different
    devices (repro.dist pins one stage per device) must never meet in one
    op, so each device set accumulates separately and ``drain`` folds
    host-side."""
    try:
        return tuple(sorted(map(str, v.devices())))
    except AttributeError:
        return None


class Metric:
    """Base: one named metric holding labeled series."""

    kind = "metric"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help

    def drain(self) -> None:
        """Fold any device-resident state into the host value (no-op for
        host-only metrics).  Idempotent."""

    def rows(self) -> Iterable[Dict[str, Any]]:
        raise NotImplementedError


class Counter(Metric):
    """Monotone counter with optional labels: ``c.inc(3, stage=0)``."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._series: Dict[LabelKey, int] = {}

    def inc(self, n: int = 1, **labels) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        k = _label_key(labels)
        self._series[k] = self._series.get(k, 0) + int(n)

    def value(self, **labels) -> int:
        return self._series.get(_label_key(labels), 0)

    def total(self) -> int:
        return sum(self._series.values())

    def rows(self):
        for k, v in sorted(self._series.items()):
            yield {"name": self.name, "kind": self.kind,
                   "labels": dict(k), "value": v}


class Gauge(Metric):
    """Last-value gauge with optional labels; ``set_max`` keeps peaks."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._series: Dict[LabelKey, float] = {}

    def set(self, v: float, **labels) -> None:
        self._series[_label_key(labels)] = float(v)

    def set_max(self, v: float, **labels) -> None:
        k = _label_key(labels)
        self._series[k] = max(self._series.get(k, float("-inf")), float(v))

    def value(self, **labels) -> Optional[float]:
        return self._series.get(_label_key(labels))

    def rows(self):
        for k, v in sorted(self._series.items()):
            yield {"name": self.name, "kind": self.kind,
                   "labels": dict(k), "value": v}


class Histogram(Metric):
    """Fixed-bucket histogram (single series)."""

    kind = "histogram"

    def __init__(self, name: str, buckets: Sequence[float],
                 help: str = ""):
        super().__init__(name, help)
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError(f"histogram {name}: buckets must be a "
                             "non-empty ascending sequence")
        self.edges: Tuple[float, ...] = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.edges) + 1)
        self.total = 0
        self.sum = 0.0
        self.max: Optional[float] = None
        self.min: Optional[float] = None

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.total += 1
        self.sum += v
        self.max = v if self.max is None else max(self.max, v)
        self.min = v if self.min is None else min(self.min, v)

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.total if self.total else None

    def percentile(self, q: float) -> Optional[float]:
        """Bucket-interpolated percentile (None when empty).

        Error bound: the width of the covering bucket.  The open-ended
        buckets substitute the tracked extrema for their missing edge: the
        underflow bucket interpolates from ``min`` up to
        ``min(edges[0], max)`` (every observation may sit far below
        ``edges[0]`` — sub-ms TTFTs under a 1 ms first edge — so reporting
        ``edges[0]`` could exceed the true maximum), and the overflow
        bucket reports ``max``.  The estimate is always within
        ``[min, max]``."""
        if not self.total:
            return None
        target = (q / 100.0) * self.total
        cum = 0.0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if cum + c >= target:
                if i == len(self.edges):
                    return self.max
                if i == 0:
                    lo = self.edges[0] if self.min is None else self.min
                    hi = self.edges[0] if self.max is None \
                        else min(self.edges[0], self.max)
                else:
                    lo, hi = self.edges[i - 1], self.edges[i]
                est = lo + (hi - lo) * (target - cum) / c
                # interpolation can overshoot the tracked extrema inside
                # the covering bucket; they are tighter bounds
                if self.max is not None:
                    est = min(est, self.max)
                if self.min is not None:
                    est = max(est, self.min)
                return est
            cum += c
        return self.max

    def summary(self) -> Dict[str, Any]:
        return {"count": self.total, "sum": self.sum, "mean": self.mean,
                "max": self.max, "min": self.min,
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99)}

    def rows(self):
        yield {"name": self.name, "kind": self.kind, "labels": {},
               "edges": list(self.edges), "counts": list(self.counts),
               **self.summary()}


class DeviceCounter(Counter):
    """Counter whose hot-path half is a device-resident int32 scalar.

    ``add(n)`` accepts a device scalar (or python int) and enqueues one
    device add — no host sync; ``drain()`` performs the single sanctioned
    device->host transfer and folds into the host series."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._dev: Dict[Any, Any] = {}   # device-key -> int32 scalar

    def add(self, n) -> None:
        import jax.numpy as jnp
        delta = jnp.asarray(n, jnp.int32)
        k = _device_key(delta)
        prev = self._dev.get(k)
        self._dev[k] = delta if prev is None else prev + delta

    def drain(self) -> None:
        if not self._dev:
            return
        import jax
        accs, self._dev = self._dev, {}
        got = sum(int(jax.device_get(a))  # repro: allow-host-sync
                  for a in accs.values())
        if got:
            self.inc(got)


class DeviceHistogram(Histogram):
    """Histogram whose bucket counts / sum / max live on device as int32 /
    f32 arrays, updated by ``observe_device`` with a searchsorted +
    scatter-add — a handful of lazily-dispatched ops per observation, zero
    host syncs until ``drain()``."""

    def __init__(self, name: str, buckets: Sequence[float],
                 help: str = ""):
        super().__init__(name, buckets, help)
        # device-key -> (counts[int32, n+1], sum[f32], max[f32]); one
        # accumulator per device set (see ``_device_key``)
        self._dev: Dict[Any, Any] = {}

    def observe_device(self, values) -> None:
        import jax.numpy as jnp
        v = jnp.asarray(values, jnp.float32).reshape(-1)
        if v.size == 0:
            return
        edges = jnp.asarray(self.edges, jnp.float32)
        k = _device_key(v)
        acc = self._dev.get(k)
        if acc is None:
            acc = (jnp.zeros((len(self.edges) + 1,), jnp.int32),
                   jnp.zeros((), jnp.float32),
                   jnp.full((), -jnp.inf, jnp.float32),
                   jnp.full((), jnp.inf, jnp.float32))
        counts, total, vmax, vmin = acc
        idx = jnp.searchsorted(edges, v, side="left")
        self._dev[k] = (counts.at[idx].add(1), total + jnp.sum(v),
                        jnp.maximum(vmax, jnp.max(v)),
                        jnp.minimum(vmin, jnp.min(v)))

    def drain(self) -> None:
        if not self._dev:
            return
        import jax
        accs, self._dev = self._dev, {}
        for acc in accs.values():
            counts, total, vmax, vmin = jax.device_get(acc)  # repro: allow-host-sync
            n = int(counts.sum())
            if not n:
                continue
            for i, c in enumerate(counts):
                self.counts[i] += int(c)
            self.total += n
            self.sum += float(total)
            m = float(vmax)
            if m != float("-inf"):     # ±inf = the accumulators' identities
                self.max = m if self.max is None else max(self.max, m)
            lo = float(vmin)
            if lo != float("inf"):
                self.min = lo if self.min is None else min(self.min, lo)
