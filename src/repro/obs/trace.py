"""Span timelines exportable as Chrome trace-event JSON (Perfetto/about:
tracing loadable).

A ``Tracer`` collects completed ``Span``s — host-walltime intervals on
integer tracks (``tid``s).  Three ways in:

* ``with tracer.span("tick 3", cat="stage", tid=1, stage=0):`` — timed
  around a block (the executor wraps each stage's tick *dispatch*; on an
  accelerator that is dispatch latency, not device compute — the span
  marks when work was issued and in what order).
* ``tracer.add_span(name, ts, dur, ...)`` — retroactive, for lifecycle
  spans whose start was recorded earlier (the engine's queued/active
  request spans).
* ``tracer.instant(name, ...)`` — zero-duration markers (retirements).

Track convention (one Perfetto row each): tid 0 = the driving loop
(trainer phases / engine admit+decode), tid 1+k = stage k of a
``StageExecutor``, tid 1000+i = request i's lifecycle.

``clock`` is injectable (``resilience.FakeClock`` pattern) so span
nesting/ordering is deterministic under test.  The span list is bounded:
past ``capacity`` new spans are counted in ``dropped`` and discarded —
a tracer must never become the memory leak it is meant to find.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

# track-id convention (see module docstring)
TID_LOOP = 0
TID_STAGE0 = 1          # stage k -> TID_STAGE0 + k
TID_REQ0 = 1000         # request i -> TID_REQ0 + i


@dataclass(frozen=True)
class Span:
    name: str
    ts: float              # start, seconds on the tracer's clock
    dur: float             # seconds
    cat: str = ""
    tid: int = TID_LOOP
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def end(self) -> float:
        return self.ts + self.dur


class Tracer:
    def __init__(self, clock=None, capacity: int = 100_000,
                 pid: int = 0):
        self._clock = clock or time.monotonic
        self.capacity = capacity
        self.pid = pid
        self.spans: List[Span] = []
        self.dropped = 0

    def now(self) -> float:
        return float(self._clock())

    def add_span(self, name: str, ts: float, dur: float, *, cat: str = "",
                 tid: int = TID_LOOP, **args) -> None:
        if len(self.spans) >= self.capacity:
            self.dropped += 1
            return
        self.spans.append(Span(name=name, ts=float(ts),
                               dur=max(0.0, float(dur)), cat=cat, tid=tid,
                               args=args))

    @contextmanager
    def span(self, name: str, *, cat: str = "", tid: int = TID_LOOP,
             **args):
        t0 = self.now()
        try:
            yield
        finally:
            self.add_span(name, t0, self.now() - t0, cat=cat, tid=tid,
                          **args)

    def instant(self, name: str, *, ts: Optional[float] = None,
                cat: str = "", tid: int = TID_LOOP, **args) -> None:
        self.add_span(name, self.now() if ts is None else ts, 0.0, cat=cat,
                      tid=tid, **args)

    # -- consumption --------------------------------------------------------

    def by_tid(self) -> Dict[int, List[Span]]:
        out: Dict[int, List[Span]] = {}
        for s in self.spans:
            out.setdefault(s.tid, []).append(s)
        for spans in out.values():
            spans.sort(key=lambda s: (s.ts, -s.dur))
        return out

    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON (ts/dur in microseconds, "X" complete
        events; instants are "i").  Load in Perfetto or chrome://tracing."""
        events = []
        for s in self.spans:
            ev: Dict[str, Any] = {
                "name": s.name, "cat": s.cat or "repro", "pid": self.pid,
                "tid": s.tid, "ts": s.ts * 1e6, "args": dict(s.args),
            }
            if s.dur > 0.0:
                ev["ph"] = "X"
                ev["dur"] = s.dur * 1e6
            else:
                ev["ph"] = "i"
                ev["s"] = "t"      # thread-scoped instant
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"dropped_spans": self.dropped}}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)
