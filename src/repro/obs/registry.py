"""The metrics registry: one namespace of typed metrics, one export format.

Every instrumented component (``Trainer``, ``StageExecutor``,
``SupervisedExecutor``, ``Engine``) takes ``metrics=`` and defaults to a
**private** registry so legacy per-object telemetry semantics (e.g.
``Engine.stats`` cumulative per engine) stay byte-identical; pass one
shared registry to aggregate across components (``launch/loadgen.py``
does).  ``default_registry()`` is the process-wide instance used by
module-level emitters with no object to hang state on
(``checkpoint.checkpoint``).

``export()`` is the single schema-versioned wire format
(``repro.obs/1``) both training and serving telemetry flow through —
``launch/metrics.py`` dumps/validates it, ``launch/loadgen.py`` embeds it
in ``results/BENCH_9.json``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

from repro.obs.metrics import (Counter, DeviceCounter, DeviceHistogram,
                               Gauge, Histogram, Metric)

SCHEMA = "repro.obs/1"


class MetricsRegistry:
    """Name -> metric, get-or-create with kind checking."""

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}

    def _get_or_create(self, cls, name: str, *args, **kw) -> Any:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, *args, **kw)
        elif type(m) is not cls:
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, buckets: Sequence[float],
                  help: str = "") -> Histogram:
        return self._get_or_create(Histogram, name, buckets, help)

    def device_counter(self, name: str, help: str = "") -> DeviceCounter:
        return self._get_or_create(DeviceCounter, name, help)

    def device_histogram(self, name: str, buckets: Sequence[float],
                         help: str = "") -> DeviceHistogram:
        return self._get_or_create(DeviceHistogram, name, buckets, help)

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def drain(self) -> None:
        """Fold every device-resident accumulator into its host value —
        the flush-boundary call.  Idempotent."""
        for m in self._metrics.values():
            m.drain()

    def export(self, drain: bool = True) -> Dict[str, Any]:
        """Schema-versioned snapshot of every series."""
        if drain:
            self.drain()
        rows: List[Dict[str, Any]] = []
        for name in sorted(self._metrics):
            rows.extend(self._metrics[name].rows())
        return {"schema": SCHEMA, "metrics": rows}


_DEFAULT: Optional[MetricsRegistry] = None


def default_registry() -> MetricsRegistry:
    """The process-wide registry (created on first use)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = MetricsRegistry()
    return _DEFAULT


def set_default_registry(reg: Optional[MetricsRegistry]) -> None:
    """Swap the process-wide registry (tests inject a fresh one)."""
    global _DEFAULT
    _DEFAULT = reg
