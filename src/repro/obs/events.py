"""Structured event log: a bounded ring buffer of schema-versioned records.

Everything that used to be an ad-hoc tuple list — scheduler
admit/retire/reject audits, supervisor health transitions and fault
sightings, checkpoint save/restore — lands here as one record shape:

    {"schema_v": 1, "seq": 17, "t": 0.031, "kind": "admit",
     "fields": {"slot": 2, "req": 5}}

``seq`` is monotone across the log's lifetime (records evicted by the ring
bound keep their numbers, so ``dropped`` is always ``seq_end - len``).
``clock`` is injectable (``resilience.FakeClock`` pattern) so event
timestamps are deterministic in tests.  The legacy tuple lists
(``Scheduler.events``, ``SupervisedExecutor.events``) are kept untouched —
the event log is an additional, unified consumer-facing stream.

``default_log()`` is the process-wide instance module-level emitters use
(``checkpoint.checkpoint``); components take ``event_log=`` to inject an
isolated one.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

SCHEMA_V = 1

# the record vocabulary (schema_v 1); emitters must pick from this list so
# consumers can switch on ``kind`` without scraping free text
EVENT_KINDS = (
    "admit", "retire", "reject",                       # scheduler audits
    "health", "fault", "recover", "give_up",           # supervisor
    "checkpoint_save", "checkpoint_restore",           # checkpoint
    "generate_begin", "generate_end",                  # engine lifecycle
)


@dataclass(frozen=True)
class Event:
    """One structured record."""
    seq: int
    t: float
    kind: str
    fields: Dict[str, Any] = field(default_factory=dict)

    def row(self) -> Dict[str, Any]:
        return {"schema_v": SCHEMA_V, "seq": self.seq, "t": self.t,
                "kind": self.kind, "fields": dict(self.fields)}


class EventLog:
    """Bounded ring buffer of ``Event``s."""

    def __init__(self, capacity: int = 4096, clock=None):
        if capacity <= 0:
            raise ValueError("EventLog capacity must be positive")
        self.capacity = capacity
        self._clock = clock or time.monotonic
        self._buf: deque = deque(maxlen=capacity)
        self._seq = 0

    def emit(self, kind: str, **fields) -> Event:
        if kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {kind!r} "
                             f"(schema_v {SCHEMA_V} kinds: {EVENT_KINDS})")
        ev = Event(seq=self._seq, t=float(self._clock()), kind=kind,
                   fields=fields)
        self._seq += 1
        self._buf.append(ev)
        return ev

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def dropped(self) -> int:
        """Records evicted by the ring bound."""
        return self._seq - len(self._buf)

    def records(self, kind: Optional[str] = None) -> List[Event]:
        if kind is None:
            return list(self._buf)
        return [e for e in self._buf if e.kind == kind]

    def rows(self) -> List[Dict[str, Any]]:
        return [e.row() for e in self._buf]

    def clear(self) -> None:
        self._buf.clear()


_DEFAULT: Optional[EventLog] = None


def default_log() -> EventLog:
    """The process-wide event log (created on first use)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = EventLog()
    return _DEFAULT


def set_default_log(log: Optional[EventLog]) -> None:
    """Swap the process-wide log (tests inject a fresh one)."""
    global _DEFAULT
    _DEFAULT = log
