"""Step builders: train / prefill / decode, with sharded in/out specs.

``build_train_step`` supports gradient-accumulation microbatching (lax.scan)
and optional sequence-parallel residual sharding (``seq_shard=True`` places a
with_sharding_constraint on the residual stream at every layer-group boundary
so saved activations are sharded over the model axis — a beyond-paper
optimization lever, see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.core import losses
from repro.launch.sharding import Policy
from repro.models import model as M


def pick_optimizer_name(cfg: ModelConfig) -> str:
    """Memory-aware default: Adafactor for >=50B-param models."""
    return "adafactor" if cfg.param_counts()["total"] > 50e9 else "adamw"


def pick_accum(cfg: ModelConfig, shape: InputShape, policy: Policy) -> int:
    """Microbatch count: keep per-chip live activations bounded while never
    dropping below 1 sample per data shard."""
    total = cfg.param_counts()["total"]
    # thresholds sized so per-chip saved activations fit HBM at d_model
    # scale (llava-34b @ accum 4 peaked at 20.8 GiB -> 16; §Perf fit fixes)
    want = 16 if total > 20e9 else (8 if total > 5e9 else 1)
    dp_total = 1
    for ax in policy.batch_entry(shape.global_batch):
        dp_total *= policy.mesh.shape[ax]
    return max(1, min(want, shape.global_batch // max(dp_total, 1)))


def _shard_x_fn(cfg, policy: Policy, batch_size: int, seq_len: int):
    """Sequence-parallel constraint for the residual stream, if legal."""
    ent = policy.batch_entry(batch_size)
    bent = ent if len(ent) > 1 else (ent[0] if ent else None)
    if seq_len % policy.tp_size:
        return None
    sharding = NamedSharding(policy.mesh, P(bent, "model", None))

    def f(x):
        return jax.lax.with_sharding_constraint(x, sharding)
    return f


def _split_vlm_logits(cfg, logits):
    if cfg.frontend == "vision":
        return logits[:, cfg.vision_tokens:]
    return logits


def build_train_step(cfg: ModelConfig, opt, *, accum: int = 1,
                     seq_shard_fn=None, accum_dtype=jnp.float32,
                     grad_pspecs=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).

    grad_pspecs: optional PartitionSpec tree matching params; gradients are
    constrained to it immediately after value_and_grad so XLA emits
    reduce-scatters to the FSDP shard instead of full all-reduces
    (EXPERIMENTS.md §Perf iteration 4: 16x less gradient traffic).

    The loss is scaled by the live loss scale carried in the optimizer state
    (1.0 for plain optimizers — exact no-op; the fp16 mixed_precision
    wrapper unscales gradients and skips overflowed steps)."""
    from repro.precision import read_loss_scale

    def loss_for(params, mb, scale):
        logits, aux = M.forward(cfg, params, mb, remat=True,
                                shard_x=seq_shard_fn)
        logits = _split_vlm_logits(cfg, logits)
        loss, metrics = losses.train_objective(cfg, logits, mb["labels"], aux)
        return loss * scale, metrics

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)

    def constrain_grads(grads):
        if grad_pspecs is None:
            return grads
        return jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, grad_pspecs)

    def train_step(params, opt_state, batch):
        scale = read_loss_scale(opt_state)
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch, scale)
            loss = loss / scale
            grads = constrain_grads(grads)
        else:
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb, scale)
                g = constrain_grads(g)
                acc = jax.tree_util.tree_map(
                    lambda a, gi: a + gi.astype(a.dtype), acc, g)
                return acc, (l, m)

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            gsum, (ls, ms) = jax.lax.scan(body, zeros, mbs)
            grads = jax.tree_util.tree_map(lambda g: (g / accum), gsum)
            loss = ls.mean() / scale
            metrics = jax.tree_util.tree_map(lambda m: m.mean(), ms)
        new_params, new_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["grad_norm"] = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads))) / scale
        return new_params, new_state, metrics

    return train_step


def build_pnn_stage_step(cfg: ModelConfig, plan, k: int, opt, *,
                         seq_shard_fn=None, grad_pspecs=None):
    """PNN stage-k train step (paper's scheme at scale).

    Interior stages take the boundary activation `xin` (B,S,d) and the SIL
    table as explicit (sharded) arguments; the last stage takes `xin` and
    trains with CE.  Stage 0 takes the raw batch dict.
    """
    from repro.core import losses as closses, partition

    last = k == plan.n_stages - 1

    def stage_step(stage_params, opt_state, xin, labels, sil):
        def loss_fn(p):
            out, aux = partition.stage_forward(cfg, plan, k, p, xin,
                                               shard_x=seq_shard_fn)
            if last:
                loss, _ = closses.train_objective(
                    cfg, _split_vlm_logits(cfg, out), labels, aux)
                return loss
            bound = out[0] if cfg.enc_dec else out
            bound = _split_vlm_logits(cfg, bound)
            loss = closses.sil_stage_loss(bound, sil, labels)
            if cfg.moe is not None:
                loss = loss + cfg.moe.load_balance_loss * aux["lb_loss"] \
                    + cfg.moe.router_z_loss * aux["z_loss"]
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(stage_params)
        if grad_pspecs is not None:
            grads = jax.tree_util.tree_map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, grad_pspecs)
        new_params, new_state = opt.update(grads, opt_state, stage_params)
        return new_params, new_state, loss

    return stage_step


def build_prefill_step(cfg: ModelConfig, cache_len: int):
    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch, cache_len)
    return prefill_step


def build_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, token, pos):
        return M.decode_step(cfg, params, cache, token, pos)
    return serve_step
