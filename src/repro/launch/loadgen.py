"""Open-loop Poisson load generator against the real serving engine.

Unlike ``launch/serve.py`` (closed loop: every request present at t=0),
this drives ``Engine.generate(..., arrivals=)`` with exponential
inter-arrival times — the open-loop model where the offered load does NOT
slow down when the server falls behind, so queueing delay shows up in
TTFT instead of being hidden by the harness.

The run is two passes over ONE engine:

1. **Warmup** (closed loop, throwaway registry): one batch per distinct
   (prompt-length, group-size) shape, so the measured pass hits compiled
   prefill programs and TTFT measures serving latency, not XLA.
2. **Measured** (open loop, fresh registry via ``Engine.bind_metrics``):
   the Poisson trace, timed end to end.

The workload mixes prompt/output lengths (quantized to a small ladder —
the engine compiles one prefill per distinct prompt length) and includes
deliberately oversized requests (span > ``max_cache_tokens``) so the
cache-pressure shed path deterministically fires and the shed-rate row in
the report is never vacuously zero.

Output: a schema-versioned report (``repro.obs/1``) with the workload
spec, SLO summary (p50/p99 TTFT — both at the admission sync and on the
first *streamed* token, tokens/s, queue depth, cache occupancy, shed
rate), the full metric export, and event-log totals — written to
``results/BENCH_9.json`` and validated by ``launch/metrics.py --check``.

``--compare`` (the "paged" preset's natural mode) runs the SAME workload
through the block-paged pool and through a slot-contiguous baseline sized
to the same ``max_cache_tokens`` device budget, writes the paged report
(with the baseline SLO and a verdict embedded) to
``results/BENCH_10.json``, and exits nonzero unless paging sustains
strictly more concurrent sessions at no p99-TTFT regression.

Usage:
  PYTHONPATH=src python -m repro.launch.loadgen --preset tiny \
      [--out results/BENCH_9.json] [--trace results/trace.json] \
      [--n 24] [--rate 10] [--seed 0]
  PYTHONPATH=src python -m repro.launch.loadgen --preset paged --compare
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.configs import get
from repro.models import model as M
from repro.obs.events import EventLog
from repro.obs.metrics import TTFT_MS_BUCKETS, Histogram
from repro.obs.registry import SCHEMA, MetricsRegistry
from repro.obs.trace import Tracer
from repro.serve import Engine, GenerationConfig, Request

# Workload presets.  Prompt lengths come from a tiny ladder (the engine
# compiles one prefill program per distinct length); ``oversized`` counts
# requests rewritten to exceed the cache budget (deterministic sheds);
# ``shared_prefix`` tokens lead every prompt (a common system prompt, the
# shared-prefix-reuse case) and ``block_size`` applies in paged mode.
PRESETS: Dict[str, Dict[str, Any]] = {
    "tiny": dict(arch="qwen2-1.5b", n_requests=10, rate_rps=20.0,
                 prompt_lens=(4, 8), new_tokens=(4, 8), slots=2,
                 decode_block=8, max_cache_tokens=64,
                 max_queue_wait_ms=60_000.0, oversized=1),
    "full": dict(arch="qwen2-1.5b", n_requests=48, rate_rps=12.0,
                 prompt_lens=(8, 16), new_tokens=(8, 16), slots=4,
                 decode_block=16, max_cache_tokens=192,
                 max_queue_wait_ms=60_000.0, oversized=2),
    # the BENCH_10 comparison workload: mixed spans + a common system
    # prompt under ONE 64-token K/V budget.  The contiguous baseline fits
    # 64 // 32 = 2 full rows; paging fits whatever the footprints allow.
    "paged": dict(arch="qwen2-1.5b", n_requests=16, rate_rps=300.0,
                  prompt_lens=(8, 16), new_tokens=(4, 8), slots=6,
                  decode_block=4, max_cache_tokens=64,
                  max_queue_wait_ms=60_000.0, oversized=1,
                  block_size=8, shared_prefix=8),
}


def build_workload(cfg, p: Dict[str, Any], seed: int,
                   n: Optional[int] = None, rate: Optional[float] = None):
    """(requests, arrivals) — a reproducible Poisson trace over mixed
    prompt/output lengths, with the last ``oversized`` requests rewritten
    to blow the cache budget."""
    n = int(n or p["n_requests"])
    rate = float(rate or p["rate_rps"])
    sp = int(p.get("shared_prefix", 0))
    rng = np.random.default_rng(seed)
    lens = rng.choice(p["prompt_lens"], size=n).astype(int)
    news = rng.choice(p["new_tokens"], size=n).astype(int)
    for j in range(min(p["oversized"], n)):
        lens[n - 1 - j] = p["max_cache_tokens"] + 8
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    prefix = (rng.integers(0, cfg.vocab_size, size=sp).astype(np.int32)
              if sp else None)
    reqs = []
    for i, (ln, nn) in enumerate(zip(lens, news)):
        toks = rng.integers(0, cfg.vocab_size, size=int(ln)).astype(np.int32)
        if prefix is not None:                 # common system prompt
            toks[:sp] = prefix[:int(ln)]
        reqs.append(Request(tokens=toks,
                            gen=GenerationConfig(max_new_tokens=int(nn)),
                            id=f"load-{i}"))
    return reqs, [float(a) for a in arrivals], n, rate


def _warmup(engine, cfg, p: Dict[str, Any], slots: int) -> None:
    """Compile the programs the measured pass will hit: one closed-loop
    batch per (prompt length, admitted-group size) — block-grained
    admission can admit ANY group size up to ``slots`` as blocks free up —
    plus a single-request sweep over the power-of-two fused chunk lengths
    (``Engine._chunk_len``), so mid-run TTFT measures serving latency, not
    XLA."""
    rng = np.random.default_rng(1)
    nn = int(min(p["new_tokens"]))
    ln0 = int(min(p["prompt_lens"]))
    # identical prompts per length: under block-grained admission a batch
    # of distinct prompts can exhaust the fresh-block budget and get split
    # into smaller groups, silently skipping the very shapes this loop
    # exists to compile — shared prefixes keep each batch admitted whole
    prompts = {int(ln): rng.integers(0, cfg.vocab_size, size=int(ln)
                                     ).astype(np.int32)
               for ln in p["prompt_lens"]}

    def req(ln, nn, tag, i):
        return Request(tokens=prompts[int(ln)],
                       gen=GenerationConfig(max_new_tokens=int(nn)),
                       id=f"warm-{tag}-{i}")

    for ln in p["prompt_lens"]:
        for size in range(1, slots + 1):
            engine.generate([req(ln, nn, f"{ln}-{size}", i)
                             for i in range(size)])
    chunk = 1
    while chunk <= p["decode_block"]:
        # the first token comes out of the admit step, so ``chunk + 1`` new
        # tokens leave exactly ``chunk`` for one fused decode chunk
        engine.generate([req(ln0, chunk + 1, f"chunk-{chunk}", 0)])
        chunk *= 2


def run_loadgen(preset: str = "tiny", *, seed: int = 0,
                n: Optional[int] = None, rate: Optional[float] = None,
                trace_path: Optional[str] = None, paged: bool = False,
                slots: Optional[int] = None) -> Dict[str, Any]:
    """One full loadgen run; returns the schema-versioned report dict.

    ``paged=True`` serves through the block-paged pool (block size from the
    preset); ``slots`` overrides the preset's scheduler slots — the compare
    mode uses it to size the contiguous baseline to the same token budget.
    The measured pass is driven through ``Engine.stream`` so TTFT is also
    measured on the first *streamed* token (``slo.ttft_stream_ms``), not
    just at the admission sync (``slo.ttft_ms``)."""
    p = PRESETS[preset]
    n_slots = int(slots or p["slots"])
    cfg = get(p["arch"], smoke=True).replace(dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    events = EventLog(capacity=8192)
    tracer = Tracer()
    engine = Engine(cfg, params, max_slots=n_slots,
                    decode_block=p["decode_block"],
                    max_cache_tokens=p["max_cache_tokens"],
                    max_queue_wait_ms=p["max_queue_wait_ms"],
                    tracer=tracer, event_log=events,
                    paged=paged, block_size=int(p.get("block_size", 16)))
    _warmup(engine, cfg, p, n_slots)
    events.clear()                     # report covers the measured pass only
    measured = MetricsRegistry()
    engine.bind_metrics(measured)

    reqs, arrivals, n, rate = build_workload(cfg, p, seed, n=n, rate=rate)
    stream_ttft = Histogram("serve_ttft_stream_ms", TTFT_MS_BUCKETS)
    outs_by_idx: Dict[int, Any] = {}
    first_seen = set()
    t0 = time.perf_counter()
    for ev in engine.stream(reqs, arrivals=arrivals):
        if ev.kind == "delta" and ev.req_idx not in first_seen:
            first_seen.add(ev.req_idx)
            stream_ttft.observe(
                (time.perf_counter() - t0 - arrivals[ev.req_idx]) * 1e3)
        elif ev.kind == "done":
            outs_by_idx[ev.req_idx] = ev.completion
    wall = time.perf_counter() - t0
    outs = [outs_by_idx[i] for i in range(len(reqs))]

    if trace_path:
        os.makedirs(os.path.dirname(trace_path) or ".", exist_ok=True)
        tracer.write_chrome_trace(trace_path)

    export = measured.export()
    stats = engine.stats
    n_tokens = measured.get("serve_tokens_total").total()
    by_kind: Dict[str, int] = {}
    for ev in events.records():
        by_kind[ev.kind] = by_kind.get(ev.kind, 0) + 1
    report = {
        "schema": SCHEMA,
        "kind": "loadgen",
        "preset": preset,
        "workload": {
            "arch": p["arch"], "n_requests": n, "rate_rps": rate,
            "seed": seed, "prompt_lens": list(p["prompt_lens"]),
            "new_tokens": list(p["new_tokens"]), "slots": n_slots,
            "decode_block": p["decode_block"],
            "max_cache_tokens": p["max_cache_tokens"],
            "max_queue_wait_ms": p["max_queue_wait_ms"],
            "oversized": p["oversized"],
            "paged": paged,
            "block_size": int(p.get("block_size", 16)) if paged else None,
            "shared_prefix": int(p.get("shared_prefix", 0)),
        },
        "slo": {
            "ttft_ms": measured.get("serve_ttft_ms").summary(),
            "ttft_stream_ms": stream_ttft.summary(),
            "tokens_per_s": n_tokens / wall if wall > 0 else 0.0,
            "n_tokens": n_tokens,
            "wall_s": wall,
            "queue_depth": measured.get("serve_queue_depth").summary(),
            "slots_busy": measured.get("serve_slots_busy").summary(),
            "peak_slots_busy":
                measured.get("serve_peak_slots_busy").value(),
            "cache_tokens": measured.get("serve_cache_tokens").value(),
            "shed": {
                "rate": sum(stats.values()) / n,
                **stats,
            },
            "completed": sum(1 for c in outs
                             if c.finish_reason in ("eos", "length")),
        },
        "metrics": export["metrics"],
        "events": {"n": len(events), "dropped": events.dropped,
                   "by_kind": by_kind},
    }
    return report


def run_compare(preset: str = "paged", *, seed: int = 0,
                n: Optional[int] = None, rate: Optional[float] = None,
                trace_path: Optional[str] = None,
                bench9_path: str = "results/BENCH_9.json") -> Dict[str, Any]:
    """Paged vs slot-contiguous on the SAME workload and device budget.

    The contiguous baseline gets ``max_cache_tokens // row`` slots, where
    ``row`` is the per-slot cache length the engine would allocate for the
    longest in-budget span — i.e. both pools hold the same number of K/V
    tokens, the only difference is the allocation granularity.  The paged
    run must sustain *strictly more* concurrent sessions and keep p99
    TTFT within ``max(1.25x, +25ms)`` of the baseline (and at or below the
    committed BENCH_9 p99 when that file is present); ``comparison.ok``
    records the verdict and ``main --compare`` turns it into the exit code.
    The returned dict is the paged report (still a valid ``repro.obs/1``
    loadgen report for ``launch.metrics --check``) with ``baseline`` and
    ``comparison`` sections embedded."""
    p = PRESETS[preset]
    span = max(p["prompt_lens"]) + max(p["new_tokens"])
    row = -(-span // 32) * 32          # engine rounds cache rows up to 32
    ctg_slots = max(1, p["max_cache_tokens"] // row)
    paged_rep = run_loadgen(preset, seed=seed, n=n, rate=rate,
                            trace_path=trace_path, paged=True)
    ctg_rep = run_loadgen(preset, seed=seed, n=n, rate=rate,
                          paged=False, slots=ctg_slots)

    p_slo, c_slo = paged_rep["slo"], ctg_rep["slo"]
    p_peak, c_peak = p_slo["peak_slots_busy"], c_slo["peak_slots_busy"]
    p_p99 = p_slo["ttft_ms"]["p99"]
    c_p99 = c_slo["ttft_ms"]["p99"]
    comparison: Dict[str, Any] = {
        "baseline_slots": ctg_slots,
        "paged_peak_slots_busy": p_peak,
        "contiguous_peak_slots_busy": c_peak,
        "concurrency_ok": bool(p_peak > c_peak),
        "paged_p99_ttft_ms": p_p99,
        "contiguous_p99_ttft_ms": c_p99,
        "ttft_ok": bool(p_p99 <= max(c_p99 * 1.25, c_p99 + 25.0)),
        "paged_completed": p_slo["completed"],
        "contiguous_completed": c_slo["completed"],
    }
    if os.path.exists(bench9_path):
        with open(bench9_path) as f:
            b9 = json.load(f)["slo"]["ttft_ms"]["p99"]
        comparison["bench9_p99_ttft_ms"] = b9
        comparison["ttft_ok_vs_bench9"] = bool(p_p99 <= b9)
    comparison["ok"] = all(v for k, v in comparison.items()
                           if k.endswith("_ok") or "_ok_" in k)
    paged_rep["baseline"] = {"workload": ctg_rep["workload"],
                             "slo": c_slo}
    paged_rep["comparison"] = comparison
    return paged_rep


def summarize(report: Dict[str, Any]) -> str:
    s = report["slo"]
    ttft = s["ttft_ms"]
    shed = s["shed"]
    mode = "paged" if report["workload"].get("paged") else "contiguous"
    line = (f"loadgen[{report['preset']}/{mode}]"
            f" n={report['workload']['n_requests']}"
            f" rate={report['workload']['rate_rps']:.1f}rps | "
            f"ttft p50={ttft['p50']:.1f}ms p99={ttft['p99']:.1f}ms | "
            f"{s['tokens_per_s']:.1f} tok/s | "
            f"queue p99={s['queue_depth']['p99']} | "
            f"shed {shed['rate']:.2f} "
            f"(cache={shed['rejected_cache']} queue={shed['rejected_queue']}"
            f" deadline={shed['rejected_deadline']}) | "
            f"completed {s['completed']}")
    st = s.get("ttft_stream_ms")
    if st and st.get("count"):
        line += f" | stream-ttft p99={st['p99']:.1f}ms"
    cmp_ = report.get("comparison")
    if cmp_:
        line += (f"\ncompare: paged peak={cmp_['paged_peak_slots_busy']}"
                 f" vs contiguous peak={cmp_['contiguous_peak_slots_busy']}"
                 f" ({cmp_['baseline_slots']} slots) | "
                 f"p99 ttft {cmp_['paged_p99_ttft_ms']:.1f}ms vs "
                 f"{cmp_['contiguous_p99_ttft_ms']:.1f}ms | "
                 f"{'OK' if cmp_['ok'] else 'FAIL'}")
    return line


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--out", default=None,
                    help="report path (default results/BENCH_9.json, or "
                         "results/BENCH_10.json with --compare)")
    ap.add_argument("--trace", default=None,
                    help="also write the Chrome trace JSON here")
    ap.add_argument("--n", type=int, default=None,
                    help="override the preset's request count")
    ap.add_argument("--rate", type=float, default=None,
                    help="override the preset's offered rate (req/s)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="serve through the block-paged cache pool")
    ap.add_argument("--compare", action="store_true",
                    help="run paged AND a budget-matched contiguous "
                         "baseline on the same workload; exit nonzero "
                         "unless paging wins (BENCH_10 mode)")
    args = ap.parse_args(argv)

    if args.compare:
        report = run_compare(args.preset, seed=args.seed, n=args.n,
                             rate=args.rate, trace_path=args.trace)
    else:
        report = run_loadgen(args.preset, seed=args.seed, n=args.n,
                             rate=args.rate, trace_path=args.trace,
                             paged=args.paged)
    out = args.out or ("results/BENCH_10.json" if args.compare
                       else "results/BENCH_9.json")
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    print(summarize(report))
    print(f"wrote {out}" + (f" and {args.trace}" if args.trace else ""))
    return 0 if report.get("comparison", {}).get("ok", True) else 1


if __name__ == "__main__":
    raise SystemExit(main())
