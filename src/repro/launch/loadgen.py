"""Open-loop Poisson load generator against the real serving engine.

Unlike ``launch/serve.py`` (closed loop: every request present at t=0),
this drives ``Engine.generate(..., arrivals=)`` with exponential
inter-arrival times — the open-loop model where the offered load does NOT
slow down when the server falls behind, so queueing delay shows up in
TTFT instead of being hidden by the harness.

The run is two passes over ONE engine:

1. **Warmup** (closed loop, throwaway registry): one batch per distinct
   (prompt-length, group-size) shape, so the measured pass hits compiled
   prefill programs and TTFT measures serving latency, not XLA.
2. **Measured** (open loop, fresh registry via ``Engine.bind_metrics``):
   the Poisson trace, timed end to end.

The workload mixes prompt/output lengths (quantized to a small ladder —
the engine compiles one prefill per distinct prompt length) and includes
deliberately oversized requests (span > ``max_cache_tokens``) so the
cache-pressure shed path deterministically fires and the shed-rate row in
the report is never vacuously zero.

Output: a schema-versioned report (``repro.obs/1``) with the workload
spec, SLO summary (p50/p99 TTFT, tokens/s, queue depth, cache occupancy,
shed rate), the full metric export, and event-log totals — written to
``results/BENCH_9.json`` and validated by ``launch/metrics.py --check``.

Usage:
  PYTHONPATH=src python -m repro.launch.loadgen --preset tiny \
      [--out results/BENCH_9.json] [--trace results/trace.json] \
      [--n 24] [--rate 10] [--seed 0]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.configs import get
from repro.models import model as M
from repro.obs.events import EventLog
from repro.obs.registry import SCHEMA, MetricsRegistry
from repro.obs.trace import Tracer
from repro.serve import Engine, GenerationConfig, Request

# Workload presets.  Prompt lengths come from a tiny ladder (the engine
# compiles one prefill program per distinct length); ``oversized`` counts
# requests rewritten to exceed the cache budget (deterministic sheds).
PRESETS: Dict[str, Dict[str, Any]] = {
    "tiny": dict(arch="qwen2-1.5b", n_requests=10, rate_rps=20.0,
                 prompt_lens=(4, 8), new_tokens=(4, 8), slots=2,
                 decode_block=8, max_cache_tokens=64,
                 max_queue_wait_ms=60_000.0, oversized=1),
    "full": dict(arch="qwen2-1.5b", n_requests=48, rate_rps=12.0,
                 prompt_lens=(8, 16), new_tokens=(8, 16), slots=4,
                 decode_block=16, max_cache_tokens=192,
                 max_queue_wait_ms=60_000.0, oversized=2),
}


def build_workload(cfg, p: Dict[str, Any], seed: int,
                   n: Optional[int] = None, rate: Optional[float] = None):
    """(requests, arrivals) — a reproducible Poisson trace over mixed
    prompt/output lengths, with the last ``oversized`` requests rewritten
    to blow the cache budget."""
    n = int(n or p["n_requests"])
    rate = float(rate or p["rate_rps"])
    rng = np.random.default_rng(seed)
    lens = rng.choice(p["prompt_lens"], size=n).astype(int)
    news = rng.choice(p["new_tokens"], size=n).astype(int)
    for j in range(min(p["oversized"], n)):
        lens[n - 1 - j] = p["max_cache_tokens"] + 8
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    reqs = [Request(tokens=rng.integers(0, cfg.vocab_size,
                                        size=int(ln)).astype(np.int32),
                    gen=GenerationConfig(max_new_tokens=int(nn)),
                    id=f"load-{i}")
            for i, (ln, nn) in enumerate(zip(lens, news))]
    return reqs, [float(a) for a in arrivals], n, rate


def _warmup(engine, cfg, p: Dict[str, Any]) -> None:
    """Compile the prefill programs the measured pass will hit: one
    closed-loop batch per (prompt length, group size) shape."""
    rng = np.random.default_rng(1)
    nn = int(min(p["new_tokens"]))
    for ln in p["prompt_lens"]:
        for size in {1, p["slots"]}:
            reqs = [Request(tokens=rng.integers(0, cfg.vocab_size,
                                                size=int(ln)
                                                ).astype(np.int32),
                            gen=GenerationConfig(max_new_tokens=nn),
                            id=f"warm-{ln}-{size}-{i}")
                    for i in range(size)]
            engine.generate(reqs)


def run_loadgen(preset: str = "tiny", *, seed: int = 0,
                n: Optional[int] = None, rate: Optional[float] = None,
                trace_path: Optional[str] = None) -> Dict[str, Any]:
    """One full loadgen run; returns the schema-versioned report dict."""
    p = PRESETS[preset]
    cfg = get(p["arch"], smoke=True).replace(dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    events = EventLog(capacity=8192)
    tracer = Tracer()
    engine = Engine(cfg, params, max_slots=p["slots"],
                    decode_block=p["decode_block"],
                    max_cache_tokens=p["max_cache_tokens"],
                    max_queue_wait_ms=p["max_queue_wait_ms"],
                    tracer=tracer, event_log=events)
    _warmup(engine, cfg, p)
    events.clear()                     # report covers the measured pass only
    measured = MetricsRegistry()
    engine.bind_metrics(measured)

    reqs, arrivals, n, rate = build_workload(cfg, p, seed, n=n, rate=rate)
    t0 = time.perf_counter()
    outs = engine.generate(reqs, arrivals=arrivals)
    wall = time.perf_counter() - t0

    if trace_path:
        os.makedirs(os.path.dirname(trace_path) or ".", exist_ok=True)
        tracer.write_chrome_trace(trace_path)

    export = measured.export()
    stats = engine.stats
    n_tokens = measured.get("serve_tokens_total").total()
    by_kind: Dict[str, int] = {}
    for ev in events.records():
        by_kind[ev.kind] = by_kind.get(ev.kind, 0) + 1
    report = {
        "schema": SCHEMA,
        "kind": "loadgen",
        "preset": preset,
        "workload": {
            "arch": p["arch"], "n_requests": n, "rate_rps": rate,
            "seed": seed, "prompt_lens": list(p["prompt_lens"]),
            "new_tokens": list(p["new_tokens"]), "slots": p["slots"],
            "decode_block": p["decode_block"],
            "max_cache_tokens": p["max_cache_tokens"],
            "max_queue_wait_ms": p["max_queue_wait_ms"],
            "oversized": p["oversized"],
        },
        "slo": {
            "ttft_ms": measured.get("serve_ttft_ms").summary(),
            "tokens_per_s": n_tokens / wall if wall > 0 else 0.0,
            "n_tokens": n_tokens,
            "wall_s": wall,
            "queue_depth": measured.get("serve_queue_depth").summary(),
            "slots_busy": measured.get("serve_slots_busy").summary(),
            "peak_slots_busy":
                measured.get("serve_peak_slots_busy").value(),
            "cache_tokens": measured.get("serve_cache_tokens").value(),
            "shed": {
                "rate": sum(stats.values()) / n,
                **stats,
            },
            "completed": sum(1 for c in outs
                             if c.finish_reason in ("eos", "length")),
        },
        "metrics": export["metrics"],
        "events": {"n": len(events), "dropped": events.dropped,
                   "by_kind": by_kind},
    }
    return report


def summarize(report: Dict[str, Any]) -> str:
    s = report["slo"]
    ttft = s["ttft_ms"]
    shed = s["shed"]
    return (f"loadgen[{report['preset']}] n={report['workload']['n_requests']}"
            f" rate={report['workload']['rate_rps']:.1f}rps | "
            f"ttft p50={ttft['p50']:.1f}ms p99={ttft['p99']:.1f}ms | "
            f"{s['tokens_per_s']:.1f} tok/s | "
            f"queue p99={s['queue_depth']['p99']} | "
            f"shed {shed['rate']:.2f} "
            f"(cache={shed['rejected_cache']} queue={shed['rejected_queue']}"
            f" deadline={shed['rejected_deadline']}) | "
            f"completed {s['completed']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--out", default="results/BENCH_9.json")
    ap.add_argument("--trace", default=None,
                    help="also write the Chrome trace JSON here")
    ap.add_argument("--n", type=int, default=None,
                    help="override the preset's request count")
    ap.add_argument("--rate", type=float, default=None,
                    help="override the preset's offered rate (req/s)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    report = run_loadgen(args.preset, seed=args.seed, n=args.n,
                         rate=args.rate, trace_path=args.trace)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(summarize(report))
    print(f"wrote {args.out}" + (f" and {args.trace}" if args.trace else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
