"""Conformance sweep CLI: run the `repro.verify` oracle registry and emit a
machine-readable report into ``results/``.

Every registered equivalence contract (kernel == reference, concurrent ==
sequential, batched == sequential decode, bf16 ~= fp32, resume ==
uninterrupted, staged == joined, paper parity) runs under one (preset,
arch) context; arch-aware oracles sweep any ``repro.configs`` entry.

Usage:
  PYTHONPATH=src python -m repro.launch.verify --preset tiny \
      [--arch qwen2-1.5b] [--only serve] [--tags kernel,serve] [--list] \
      [--json results/CONFORMANCE_5.json]

Exit status is non-zero when any oracle fails — CI gates on it.
"""
from __future__ import annotations

import argparse
import sys

from repro.configs import ARCH_NAMES
from repro.verify import Context, all_oracles, run_oracle, write_report
from repro.verify.oracle import PRESETS


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="sweep the repro.verify conformance oracles")
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_NAMES,
                    help="repro.configs entry for arch-aware oracles "
                         "(serve / LM-train contracts)")
    ap.add_argument("--only", default=None,
                    help="substring filter on oracle names")
    ap.add_argument("--tags", default=None,
                    help="comma-separated tag filter (kernel, train, "
                         "serve, dist, precision, checkpoint, paper)")
    ap.add_argument("--list", action="store_true",
                    help="list matching oracles and exit")
    ap.add_argument("--json", default="results/CONFORMANCE_5.json",
                    help="conformance report path ('' disables)")
    args = ap.parse_args(argv)

    oracles = all_oracles(tags=args.tags.split(",") if args.tags else None)
    if args.only:
        oracles = [o for o in oracles if args.only in o.name]
    if not oracles:
        print("no oracles match the filter", file=sys.stderr)
        return 2
    if args.list:
        for o in oracles:
            arch = " [arch-aware]" if o.arch_aware else ""
            print(f"{o.name:38s} tags={','.join(o.tags)}{arch}")
            print(f"  {o.contract}")
        return 0

    ctx = Context(preset=args.preset, arch=args.arch)
    print(f"# repro.verify sweep: preset={args.preset} arch={args.arch} "
          f"({len(oracles)} oracles)")
    results = []
    for o in oracles:
        res = run_oracle(o, Context(preset=ctx.preset, arch=ctx.arch))
        results.append(res)
        status = "PASS" if res.ok else "FAIL"
        line = f"[{status}] {o.name:38s} {res.seconds:7.1f}s"
        if res.verdict is not None and res.verdict.metrics:
            interesting = {k: v for k, v in res.verdict.metrics.items()
                           if k in ("max_abs_err", "gap", "n_tokens",
                                    "n_leaves", "n_sequences")}
            if interesting:
                line += "  " + " ".join(
                    f"{k}={v:.3g}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in interesting.items())
        print(line)
        if not res.ok:
            print("  " + (res.error or res.verdict.detail).strip()
                  .replace("\n", "\n  "))

    n_failed = sum(not r.ok for r in results)
    print(f"# {len(results) - n_failed}/{len(results)} oracles passed")
    if args.json:
        write_report(args.json, results, preset=args.preset, arch=args.arch)
        print(f"# wrote {args.json}")
    return 1 if n_failed else 0


if __name__ == "__main__":
    sys.exit(main())
