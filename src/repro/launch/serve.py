"""Production serving launcher: batched prefill + decode loop.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch 4 --prompt-len 64 --new-tokens 32 [--window 256]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get
from repro.data.lm import synthetic_token_stream
from repro.launch.steps import build_decode_step, build_prefill_step
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get(args.arch, smoke=args.smoke)
    if args.window:
        cfg = cfg.replace(sliding_window=args.window)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    stream = synthetic_token_stream(args.batch * args.prompt_len + 1,
                                    cfg.vocab_size, seed=0)
    batch = {"tokens": jnp.asarray(
        stream[: args.batch * args.prompt_len].reshape(args.batch, -1))}
    if cfg.enc_dec:
        batch["frames"] = jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model))
    if cfg.frontend == "vision":
        batch["image_embeds"] = jnp.zeros(
            (args.batch, cfg.vision_tokens, cfg.d_model))
    lc = args.prompt_len + args.new_tokens \
        + (cfg.vision_tokens if cfg.frontend == "vision" else 0)
    prefill = jax.jit(build_prefill_step(cfg, cache_len=lc))
    decode = jax.jit(build_decode_step(cfg))

    logits, cache, pos = prefill(params, batch)
    key = jax.random.PRNGKey(0)

    def sample(lg, k):
        lg = lg[:, : cfg.vocab_size]
        if args.temperature <= 0:
            return jnp.argmax(lg, -1).astype(jnp.int32)
        return jax.random.categorical(k, lg / args.temperature, -1) \
            .astype(jnp.int32)

    tok = sample(logits, key)
    t0 = time.perf_counter()
    outs = [tok]
    for i in range(args.new_tokens - 1):
        key, sk = jax.random.split(key)
        logits, cache = decode(params, cache, tok, pos + i)
        tok = sample(logits, sk)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    n = args.batch * (args.new_tokens - 1)
    print(f"decoded {n} tokens in {dt*1e3:.0f}ms -> {n/dt:.0f} tok/s "
          f"(batch={args.batch}, window={cfg.sliding_window or 'full'})")
    print("sample:", jnp.stack(outs, 1)[0, :16].tolist())


if __name__ == "__main__":
    main()
