"""Production serving launcher: thin CLI over ``repro.serve.Engine``.

All batching, cache, sampling, and decode-loop logic lives in
``repro.serve``; this file only parses arguments, builds synthetic
requests, and prints throughput.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch 4 --prompt-len 64 --new-tokens 32 [--window 256] \
      [--slots 4] [--stages 2] [--temperature 0.8 --top-k 40 --top-p 0.95]
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCH_NAMES, get
from repro.core import partition
from repro.data.lm import synthetic_token_stream
from repro.models import model as M
from repro.serve import Engine, GenerationConfig, Request


def build_engine(cfg, args):
    """Engine in joined or PartitionPlan-staged mode (--stages > 1)."""
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    precision = getattr(args, "precision", None)
    if args.stages > 1:
        plan = partition.make_plan(cfg, args.stages)
        stage_params = [partition.slice_stage_params(cfg, plan, params, k)
                        for k in range(plan.n_stages)]
        return Engine(cfg, plan=plan, stage_params=stage_params,
                      max_slots=args.slots, decode_block=args.decode_block,
                      precision=precision)
    return Engine(cfg, params, max_slots=args.slots,
                  decode_block=args.decode_block, precision=precision)


def synthetic_requests(cfg, args) -> list:
    stream = synthetic_token_stream(args.batch * args.prompt_len + 1,
                                    cfg.vocab_size, seed=0)
    prompts = stream[: args.batch * args.prompt_len].reshape(args.batch, -1)
    gen = GenerationConfig(max_new_tokens=args.new_tokens,
                           temperature=args.temperature, top_k=args.top_k,
                           top_p=args.top_p)
    return [Request(tokens=prompts[i], gen=gen, id=f"req-{i}")
            for i in range(args.batch)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--slots", type=int, default=0,
                    help="concurrent cache slots (0 = one per request)")
    ap.add_argument("--decode-block", type=int, default=16,
                    help="fused decode steps between scheduler events")
    ap.add_argument("--stages", type=int, default=1,
                    help=">1 serves the PartitionPlan stages unjoined")
    ap.add_argument("--precision", default=None,
                    choices=["fp32", "bf16", "fp16"],
                    help="serving precision policy: activations + the slot "
                         "cache pool in the compute dtype, fp32 sampling "
                         "logits (default: the arch config's dtype)")
    args = ap.parse_args()
    args.slots = args.slots or args.batch

    cfg = get(args.arch, smoke=args.smoke)
    if args.window:
        cfg = cfg.replace(sliding_window=args.window)
    engine = build_engine(cfg, args)
    requests = synthetic_requests(cfg, args)

    t0 = time.perf_counter()
    outs = engine.generate(requests)
    dt = time.perf_counter() - t0
    n = sum(c.n_generated for c in outs)
    pool = engine._pool
    cache_note = "" if pool is None else \
        f", cache={pool.nbytes/2**20:.1f}MiB@{engine.cfg.dtype}"
    print(f"decoded {n} tokens in {dt*1e3:.0f}ms -> {n/dt:.0f} tok/s "
          f"(requests={args.batch}, slots={args.slots}, "
          f"stages={args.stages}, window={cfg.sliding_window or 'full'}"
          f"{cache_note})")
    print("sample:", list(outs[0].tokens[:16]))


if __name__ == "__main__":
    main()
