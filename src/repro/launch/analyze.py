"""CLI: run the static analyzer over hot-path entry points + kernel plans.

    python -m repro.launch.analyze --arch paper_mlp --arch qwen2_1_5b

Traces the registered entry points to jaxprs (never compiles or executes a
step), checks them against the trace rules, validates every Pallas
KernelPlan, runs the AST source lint, and writes the schema-versioned
report to results/ANALYSIS_6.json.  Exit 1 iff any fail-severity finding
(or a crashed rule) — warn/info never gate.

NOTE: do not import repro.launch.dryrun here — its module top installs a
512-host-device XLA_FLAGS world that would poison this process.
"""
from __future__ import annotations

import argparse
import sys

# importing the rule modules populates the registry
import repro.analysis.rules_pallas   # noqa: F401
import repro.analysis.rules_trace    # noqa: F401
import repro.analysis.source         # noqa: F401
from repro.analysis import AnalysisContext, all_rules, get_rule, run_rule
from repro.analysis.report import build_report, write_report

DEFAULT_ARCHS = ("paper_mlp", "qwen2-1.5b")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.analyze",
        description="Static hot-path lint + Pallas kernel checker.")
    ap.add_argument("--arch", action="append", default=None,
                    help="config name to analyze (repeatable; default: "
                         f"{', '.join(DEFAULT_ARCHS)})")
    ap.add_argument("--rules", action="append", default=None,
                    help="run only these rules (repeatable)")
    ap.add_argument("--precision", default="bf16",
                    help="policy preset the hot paths are checked under")
    ap.add_argument("--json", default="results/ANALYSIS_6.json",
                    help="report path ('' disables)")
    ap.add_argument("--list", action="store_true",
                    help="list registered rules and exit")
    args = ap.parse_args(argv)

    rules = all_rules()
    if args.list:
        for r in rules:
            print(f"{r.name:28s} [{','.join(r.tags)}] {r.doc}")
        return 0
    if args.rules:
        rules = [get_rule(n) for n in args.rules]

    archs = list(args.arch or DEFAULT_ARCHS)
    results_by_arch = {}
    gate = False
    for arch in archs:
        ctx = AnalysisContext(arch=arch, precision=args.precision)
        results = [run_rule(r, ctx) for r in rules]
        results_by_arch[arch] = results
        for res in results:
            mark = "PASS" if res.ok else "FAIL"
            if res.ok and res.n_warn:
                mark = "WARN"
            print(f"[{mark}] {arch:14s} {res.name:26s} "
                  f"({res.seconds:.2f}s, {res.n_fail} fail / "
                  f"{res.n_warn} warn)")
            for f in res.findings:
                if f.severity != "info":
                    print(f"    {f.severity.upper()}: {f.target}: "
                          f"{f.message}")
            if res.error:
                gate = True
                print("    RULE ERROR:\n      "
                      + res.error.strip().replace("\n", "\n      "))
            gate = gate or not res.ok

    report = build_report(results_by_arch)
    if args.json:
        write_report(report, args.json)
        print(f"report: {args.json} (schema {report['schema']})")
    n = report["n_fail_findings"]
    print(f"analysis: {'FAIL' if gate else 'OK'} "
          f"({n} fail finding(s), {report['n_warn_findings']} warn)")
    return 1 if gate else 0


if __name__ == "__main__":
    sys.exit(main())
