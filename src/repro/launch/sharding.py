"""FSDP+TP sharding policy with divisibility fallback.

Every rule checks divisibility against the actual mesh axis size and falls
back to replication on that axis when a dimension doesn't divide (e.g.
qwen2's 12 Q heads on a 16-way model axis).  The decisions are queryable
(``explain()``) and recorded by the dry-run.

Weight layout conventions (see models/layers.py):
  attention  wq (d, H*hd)   / wk, wv (d, KV*hd) / wo (H*hd, d)
  mlp        wg,wu (d, ff)  / wd (ff, d)
  moe        experts (E, d, ff) etc., router (d, E)
  stacked over groups: leading G dim (never sharded).

Sharding a fused (H*hd) dim over the model axis is only legal when H divides
the axis size (so shards hold whole heads and the (B,S,H,hd) reshape stays
representable); same for KV heads.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


class Policy:
    """Sharding policy for one (cfg, mesh) pair.

    pipeline=True (multi-pod meshes): the conventional model-parallel
    baseline the paper argues against — the layer-group stack is sharded
    over the "pod" axis (stage-per-pod), so every microbatch's residual
    crosses pods forward AND backward (GSPMD inserts the transfers).  PNN
    eliminates exactly this traffic; the dry-run quantifies both.
    """

    def __init__(self, cfg: ModelConfig, mesh, *, fsdp: bool = True,
                 pipeline: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.fsdp = fsdp
        self.tp = "model"
        self.tp_size = mesh.shape["model"]
        self.fsdp_ax = "data" if fsdp else None
        self.fsdp_size = mesh.shape["data"] if fsdp else 1
        self.pipeline = pipeline and "pod" in mesh.axis_names
        if self.pipeline:
            self.dp = ("data",)   # pod axis carries stages, not batch
        else:
            self.dp = ("pod", "data") if "pod" in mesh.axis_names \
                else ("data",)
        self.decisions: Dict[str, str] = {}

    def _stage_axis(self, n_stacked: int) -> Optional[str]:
        """Pipeline stage axis for the stacked layer-group dim."""
        if not self.pipeline:
            return None
        pod = self.mesh.shape["pod"]
        ok = n_stacked % pod == 0
        self.decisions.setdefault(
            "pipeline_groups",
            f"pod({n_stacked})" if ok else f"replicated({n_stacked})")
        return "pod" if ok else None

    # -- helpers -----------------------------------------------------------
    def _tp(self, dim: int, why: str) -> Optional[str]:
        ok = dim % self.tp_size == 0
        self.decisions.setdefault(
            why, f"model({dim})" if ok else f"replicated({dim})")
        return self.tp if ok else None

    def _fs(self, dim: int) -> Optional[str]:
        if not self.fsdp:
            return None
        return self.fsdp_ax if dim % self.fsdp_size == 0 else None

    # -- parameter specs ----------------------------------------------------
    def param_spec(self, path: str, shape) -> P:
        cfg = self.cfg
        h, kv = cfg.n_heads, cfg.n_kv_heads
        stacked = path.startswith("groups") or path.startswith("encoder")
        lead: Tuple = ()
        if stacked:
            from repro.models import model as _M
            lead = (self._stage_axis(_M.n_groups(cfg))
                    if path.startswith("groups") else None,)
        is_bias = path.endswith("/b")

        def spec(*axes):
            return P(*(lead + axes))

        if "attn/wq" in path or "cross/wq" in path:
            ax = self._tp(h, "attn_q_heads")
            if is_bias:
                return spec(ax)
            return spec(self._fs(shape[-2]), ax)
        if any(s in path for s in ("attn/wk", "attn/wv", "cross/wk", "cross/wv")):
            ax = self._tp(kv, "attn_kv_heads")
            if is_bias:
                return spec(ax)
            return spec(self._fs(shape[-2]), ax)
        if "attn/wo" in path or "cross/wo" in path:
            if is_bias:
                return spec(None)
            return spec(self._tp(h, "attn_q_heads"), self._fs(shape[-1]))
        if any(s in path for s in ("mlp/wg", "mlp/wu", "mlp/w1")):
            ax = self._tp(cfg.d_ff, "mlp_ff")
            if is_bias:
                return spec(ax)
            return spec(self._fs(shape[-2]), ax)
        if "mlp/wd" in path or "mlp/w2" in path:
            if is_bias:
                return spec(None)
            return spec(self._tp(cfg.d_ff, "mlp_ff"), self._fs(shape[-1]))
        if "moe/router" in path:
            return spec(self._fs(shape[-2]), None)
        if "moe/" in path:  # expert stacks (E, d, ff) or (E, ff, d)
            e = cfg.moe.num_experts
            if e % self.tp_size == 0:
                self.decisions.setdefault("moe_experts",
                                          f"model({e})=expert-parallel")
                return spec(self.tp, self._fs(shape[-2]), None)
            if path.split("/")[-1] in ("wd", "w2"):   # (E, ff, d)
                return spec(None, self._tp(cfg.d_ff, "moe_ff"),
                            self._fs(shape[-1]))
            return spec(None, self._fs(shape[-2]),
                        self._tp(cfg.d_ff, "moe_ff"))
        if "mamba/" in path:
            return self._mamba_spec(path, shape, spec, is_bias)
        if "mlstm/" in path or "slstm/" in path:
            return self._xlstm_spec(path, shape, spec, is_bias)
        if path in ("tok_embed", "tied_unembed"):
            # tied_unembed: the last PNN stage's frozen embedding snapshot
            return P(self._tp(cfg.vocab_padded, "vocab"),
                     self._fs(cfg.d_model))
        if path == "unembed":
            return P(self._fs(cfg.d_model),
                     self._tp(cfg.vocab_padded, "vocab"))
        if path.startswith("img_proj") and len(shape) == 2:
            return P(self._fs(shape[-2]), None)
        # dec_pos, norms, scalars, 1D leftovers: replicate
        return P(*(None,) * len(shape))

    def _mamba_spec(self, path, shape, spec, is_bias):
        tp = lambda d: self._tp(d, "mamba_inner")  # noqa: E731
        if "in_proj" in path:
            if is_bias:
                return spec(tp(shape[-1]))
            return spec(self._fs(shape[-2]), tp(shape[-1]))
        if "conv_w" in path:
            return spec(None, tp(shape[-1]))
        if "conv_b" in path or path.endswith("/D"):
            return spec(tp(shape[-1]))
        if "x_proj" in path:
            if is_bias:
                return spec(None)
            return spec(tp(shape[-2]), None)
        if "dt_proj" in path:
            if is_bias:
                return spec(tp(shape[-1]))
            return spec(None, tp(shape[-1]))
        if "A_log" in path:
            return spec(tp(shape[-2]), None)
        if "out_proj" in path:
            if is_bias:
                return spec(None)
            return spec(tp(shape[-2]), self._fs(shape[-1]))
        return P(*(None,) * len(shape))

    def _xlstm_spec(self, path, shape, spec, is_bias):
        tp = lambda d: self._tp(d, "mlstm_up")  # noqa: E731
        if "mlstm/up" in path:
            if is_bias:
                return spec(tp(shape[-1]))
            return spec(self._fs(shape[-2]), tp(shape[-1]))
        if any(s in path for s in ("mlstm/wq", "mlstm/wk", "mlstm/wv")):
            if is_bias:
                return spec(None)
            return spec(tp(shape[-2]), None)
        if "mlstm/down" in path:
            if is_bias:
                return spec(None)
            return spec(tp(shape[-2]), self._fs(shape[-1]))
        # gate projections, slstm weights: data-shard the first matmul dim
        if not is_bias and len(shape) >= 2 and "slstm/r" not in path:
            return P(*([None] * (len(shape) - 2)
                       + [self._fs(shape[-2]), None]))
        return P(*(None,) * len(shape))

    # -- whole-tree specs ---------------------------------------------------
    def params_pspecs(self, params) -> Any:
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        specs = []
        for path, leaf in flat:
            pstr = "/".join(_key(p) for p in path)
            sp = self.param_spec(pstr, leaf.shape)
            assert len(sp) <= len(leaf.shape), (pstr, leaf.shape, sp)
            specs.append(sp)
        return jax.tree_util.tree_unflatten(treedef, specs)

    def params_shardings(self, params):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), self.params_pspecs(params))

    def opt_state_pspecs(self, opt_name: str, params):
        pspecs = self.params_pspecs(params)
        scalar = P()
        if opt_name == "sgdm":
            return {"mu": pspecs, "count": scalar}
        if opt_name == "adamw":
            return {"m": pspecs, "v": pspecs, "count": scalar}
        if opt_name == "adafactor":
            def fspec(p, s):
                sp = _pad_spec(s, p.ndim)
                if p.ndim >= 2 and p.shape[-1] >= 32 and p.shape[-2] >= 32:
                    return {"vr": P(*sp[:-1]), "vc": P(*(sp[:-2] + sp[-1:]))}
                return {"v": P(*sp)}
            v = jax.tree_util.tree_map(fspec, params, pspecs)
            return {"v": v, "count": scalar}
        raise ValueError(opt_name)

    def opt_state_shardings(self, opt_name, params):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s),
            self.opt_state_pspecs(opt_name, params),
            is_leaf=lambda x: isinstance(x, P))

    # -- activations / batch / cache ----------------------------------------
    def batch_entry(self, batch_size: int):
        """Mesh axes to shard the batch dim over (tuple, possibly empty)."""
        axes = []
        rem = batch_size
        for ax in self.dp:
            sz = self.mesh.shape[ax]
            if rem % sz == 0:
                axes.append(ax)
                rem //= sz
        return tuple(axes)

    def batch_pspec(self, array_shape, batch_size=None) -> P:
        b = batch_size if batch_size is not None else array_shape[0]
        ent = self.batch_entry(b)
        first = ent if len(ent) > 1 else (ent[0] if ent else None)
        return P(*((first,) + (None,) * (len(array_shape) - 1)))

    def batch_shardings(self, batch_specs: Dict[str, Any]):
        return {k: NamedSharding(self.mesh, self.batch_pspec(v.shape))
                for k, v in batch_specs.items()}

    def cache_pspecs(self, cache, batch_size: int):
        ent = self.batch_entry(batch_size)
        bent = ent if len(ent) > 1 else (ent[0] if ent else None)
        batch_sharded = bool(ent)
        cfg = self.cfg

        def leaf(path, x):
            pstr = "/".join(_key(p) for p in path)
            last = pstr.split("/")[-1]
            rest = [None] * (x.ndim - 2)
            if last in ("k", "v", "cross_k", "cross_v"):
                # (G, B, L, KV, hd): prefer KV-head sharding; fall back to
                # head_dim sharding (always combinable with batch sharding —
                # decode attention contracts hd, giving a small psum, vs. a
                # replicated multi-GiB cache; EXPERIMENTS.md §Perf fit fixes)
                if cfg.n_kv_heads % self.tp_size == 0:
                    rest = [None, self.tp, None]
                elif cfg.hd % self.tp_size == 0:
                    rest = [None, None, self.tp]
            elif last == "ssm":           # (G, B, Di, N)
                rest = [self.tp if x.shape[-2] % self.tp_size == 0 else None,
                        None]
            elif last == "conv":          # (G, B, K-1, Di)
                rest = [None,
                        self.tp if x.shape[-1] % self.tp_size == 0 else None]
            return P(*([None, bent] + rest))

        flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
        return jax.tree_util.tree_unflatten(
            treedef, [leaf(p, x) for p, x in flat])

    def cache_shardings(self, cache, batch_size: int):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s),
            self.cache_pspecs(cache, batch_size),
            is_leaf=lambda x: isinstance(x, P))

    def explain(self) -> Dict[str, str]:
        return dict(self.decisions)


def _key(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _pad_spec(s: P, ndim: int):
    t = tuple(s)
    return t + (None,) * (ndim - len(t))
