"""Metrics report CLI: dump / summarize / validate ``repro.obs/1`` reports.

Consumes the schema-versioned JSON that ``launch/loadgen.py`` writes
(``results/BENCH_9.json``) — or any file embedding a
``MetricsRegistry.export()`` under a ``metrics`` key.

``--check`` is the CI gate: exit 1 on any schema violation or on empty
percentile rows (a histogram that claims observations but reports no
p50/p99 means the drain path is broken — exactly the regression this
guard exists to catch).

Usage:
  PYTHONPATH=src python -m repro.launch.metrics results/BENCH_9.json
  PYTHONPATH=src python -m repro.launch.metrics --dump  results/BENCH_9.json
  PYTHONPATH=src python -m repro.launch.metrics --check results/BENCH_9.json
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List

from repro.obs.registry import SCHEMA

_HIST_KEYS = ("count", "p50", "p90", "p99")


def validate_report(report: Dict[str, Any]) -> List[str]:
    """All schema violations in ``report`` (empty list = valid)."""
    bad: List[str] = []
    if report.get("schema") != SCHEMA:
        bad.append(f"schema: expected {SCHEMA!r}, "
                   f"got {report.get('schema')!r}")
    rows = report.get("metrics")
    if not isinstance(rows, list) or not rows:
        bad.append("metrics: missing or empty row list")
        rows = []
    for i, row in enumerate(rows):
        where = f"metrics[{i}]"
        if not isinstance(row, dict) or "name" not in row \
                or "kind" not in row:
            bad.append(f"{where}: rows need name+kind, got {row!r}")
            continue
        where = f"metrics[{i}] ({row['name']})"
        if row["kind"] == "histogram":
            missing = [k for k in _HIST_KEYS if k not in row]
            if missing:
                bad.append(f"{where}: histogram row lacks {missing}")
            elif row["count"] and any(row[q] is None
                                      for q in ("p50", "p90", "p99")):
                bad.append(f"{where}: {row['count']} observations but "
                           "empty percentile row (drain broken?)")
        elif "value" not in row:
            bad.append(f"{where}: {row['kind']} row lacks value")
    slo = report.get("slo")
    if slo is not None:          # loadgen reports carry an SLO block
        ttft = slo.get("ttft_ms") or {}
        if not ttft.get("count"):
            bad.append("slo.ttft_ms: no observations — the measured pass "
                       "admitted nothing")
        elif ttft.get("p50") is None or ttft.get("p99") is None:
            bad.append("slo.ttft_ms: empty percentile row")
        if not isinstance(slo.get("tokens_per_s"), (int, float)) \
                or slo["tokens_per_s"] <= 0:
            bad.append("slo.tokens_per_s: missing or non-positive")
        shed = slo.get("shed") or {}
        for k in ("rate", "rejected_cache", "rejected_queue",
                  "rejected_deadline"):
            if k not in shed:
                bad.append(f"slo.shed.{k}: missing")
    return bad


def dump(report: Dict[str, Any]) -> str:
    lines = []
    for row in report.get("metrics", []):
        labels = ",".join(f"{k}={v}" for k, v in
                          sorted((row.get("labels") or {}).items()))
        name = row["name"] + (f"{{{labels}}}" if labels else "")
        if row["kind"] == "histogram":
            lines.append(f"{name}  count={row['count']} mean={row['mean']}"
                         f" p50={row['p50']} p90={row['p90']}"
                         f" p99={row['p99']} max={row['max']}")
        else:
            lines.append(f"{name}  {row['value']}")
    return "\n".join(lines)


def summary(report: Dict[str, Any]) -> str:
    if report.get("slo") is not None:
        from repro.launch.loadgen import summarize
        return summarize(report)
    rows = report.get("metrics", [])
    kinds: Dict[str, int] = {}
    for row in rows:
        kinds[row.get("kind", "?")] = kinds.get(row.get("kind", "?"), 0) + 1
    return f"{len(rows)} series: " + \
        ", ".join(f"{v} {k}" for k, v in sorted(kinds.items()))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("path", help="repro.obs/1 JSON report")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--dump", action="store_true",
                      help="print every metric row")
    mode.add_argument("--check", action="store_true",
                      help="validate; exit 1 on schema violations or "
                           "empty percentile rows")
    args = ap.parse_args(argv)

    with open(args.path) as f:
        report = json.load(f)
    if args.check:
        bad = validate_report(report)
        if bad:
            for b in bad:
                print(f"FAIL {args.path}: {b}")
            return 1
        print(f"OK {args.path}: schema {report['schema']}, "
              f"{len(report['metrics'])} metric rows")
        return 0
    print(dump(report) if args.dump else summary(report))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
