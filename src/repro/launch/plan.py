"""Emit the auto-partitioner's search report: ``results/PLAN_7.json``.

For every assigned arch (plus the paper's own MLP) this solves the balanced
K-way cut under the ``repro.plan`` cost model and records the chosen
bounds, the uniform split for comparison, predicted per-stage bytes/FLOPs,
imbalance ratios, and the rejected search frontier.

Pure planning: no lowering, no mesh, no device fan-out — this module must
NEVER import ``launch.dryrun`` (which forces a 512-device host platform at
import time).

Usage:
  PYTHONPATH=src python -m repro.launch.plan --stages 4
  PYTHONPATH=src python -m repro.launch.plan --arch qwen2-1.5b --stages 4 \
      --assert-nonuniform          # CI gate on the searched cut
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.configs import ARCH_NAMES, get

SCHEMA = 1


def arch_report(arch: str, n_stages: int, *, objective: str = "bytes"
                ) -> dict:
    """One arch's PLAN_7 record; K is clamped to the unit count (an arch
    with fewer groups than requested stages still gets a valid plan)."""
    from repro import plan as plan_lib
    cfg = get(arch)
    if arch == "paper_mlp":
        table = plan_lib.mlp_costs(cfg)
        optimizer = "sgdm"           # the paper's own training setup
    else:
        from repro.launch.steps import pick_optimizer_name
        optimizer = pick_optimizer_name(cfg)
        table = plan_lib.lm_costs(cfg, optimizer=optimizer)
    k = min(n_stages, table.n_units)
    rep = plan_lib.plan_report(cfg, k, optimizer=optimizer,
                               objective=objective)
    rep["arch"] = arch               # CLI name (cfg.name may differ)
    if k != n_stages:
        rep["n_stages_requested"] = n_stages
    return rep


def check_nonuniform(rep: dict) -> list:
    """CI assertions on one arch's record: the searched cut must be a
    valid partition, never worse than uniform, and actually non-uniform
    (the searcher found structure to exploit)."""
    errs = []
    bounds = [tuple(b) for b in rep["auto"]["bounds"]]
    n, k = rep["n_units"], rep["n_stages"]
    if len(bounds) != k:
        errs.append(f"{len(bounds)} stages != requested {k}")
    lo = 0
    for b_lo, b_hi in bounds:
        if b_lo != lo or b_hi <= b_lo:
            errs.append(f"bounds {bounds} are not a contiguous partition")
            break
        lo = b_hi
    else:
        if lo != n:
            errs.append(f"bounds {bounds} do not cover {n} units")
    if not rep["auto_le_uniform"]:
        errs.append("searched bottleneck exceeds the uniform split's")
    if rep["auto"]["cuts"] == rep["uniform"]["cuts"] and k > 1:
        errs.append("searched cut degenerated to the uniform split")
    return [f"{rep['arch']}: {e}" for e in errs]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    choices=ARCH_NAMES + ["all", "paper_mlp"])
    ap.add_argument("--stages", default="4",
                    help="stage count K (plain N or 'auto:K' — this CLI "
                         "always searches)")
    ap.add_argument("--objective", default="bytes",
                    choices=["bytes", "flops"])
    ap.add_argument("--out", default="results/PLAN_7.json")
    ap.add_argument("--assert-nonuniform", action="store_true",
                    help="exit 1 unless every reported arch's searched cut "
                         "is valid, non-uniform, and <= uniform bottleneck")
    args = ap.parse_args(argv)

    from repro.plan import parse_stages
    _, n_stages = parse_stages(args.stages)
    archs = (ARCH_NAMES + ["paper_mlp"]) if args.arch == "all" \
        else [args.arch]

    report = {"schema": SCHEMA, "tool": "repro.launch.plan",
              "objective": args.objective, "n_stages": n_stages,
              "archs": {}}
    failures = []
    for arch in archs:
        rep = arch_report(arch, n_stages, objective=args.objective)
        report["archs"][arch] = rep
        auto, uni = rep["auto"], rep["uniform"]
        print(f"{arch}: K={rep['n_stages']} units={rep['n_units']} "
              f"cuts {auto['cuts']} (uniform {uni['cuts']}) "
              f"imbalance {auto['imbalance']:.4f} "
              f"(uniform {uni['imbalance']:.4f}) "
              f"auto<=uniform={rep['auto_le_uniform']}")
        if args.assert_nonuniform:
            failures += check_nonuniform(rep)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out} ({len(report['archs'])} archs)")

    for msg in failures:
        print(f"ASSERT FAILED {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
