"""Production meshes (TPU v5e target).

A function, not a module-level constant, so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips ("data", "model").
Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model").

Under PNN (the paper's scheme) the "pod" axis carries *stages*, not replicas:
each pod trains one model partition with zero inter-pod collectives during
training (DESIGN.md §2.2); under the conventional baseline the pod axis is an
outer data-parallel axis.
"""
from __future__ import annotations

import os

import jax


def force_host_device_count(n: int) -> None:
    """Ask XLA's CPU backend for ``n`` host devices — the CI/dev-box
    stand-in for a multi-accelerator host that ``repro.dist`` places stages
    across.  Must run BEFORE the first JAX backend touch (any
    ``jax.devices()`` / array op); a no-op when the flag is already set so
    an outer ``XLA_FLAGS`` export wins."""
    cur = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in cur:
        os.environ["XLA_FLAGS"] = \
            (cur + f" --xla_force_host_platform_device_count={n}").strip()


def stage_devices(n: int) -> tuple:
    """The first ``n`` devices, for a stage placement plan."""
    devs = jax.devices()
    if n > len(devs):
        raise RuntimeError(
            f"need {n} devices, have {len(devs)}; on CPU export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            "python starts (or pass --devices to repro.launch.train, which "
            "sets it pre-init)")
    return tuple(devs[:n])


def make_production_mesh(*, multi_pod: bool = False, shape=None):
    """shape: optional (data, model) override, e.g. (32, 8) for an
    expert-parallel variant (model axis dividing the expert count)."""
    if shape is None:
        shape = (16, 16)
    assert shape[0] * shape[1] == 256, "one pod = 256 chips"
    full = ((2,) + tuple(shape)) if multi_pod else tuple(shape)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(full, axes)


def dp_axes(mesh) -> tuple:
    """Data-parallel axis names for this mesh (batch sharding)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axis(mesh) -> str:
    return "data"


def tp_axis(mesh) -> str:
    return "model"


# TPU v5e hardware constants (per chip) for the roofline model
PEAK_FLOPS_BF16 = 197e12      # FLOP/s (MXU native)
PEAK_FLOPS_FP32 = 98.5e12     # FLOP/s (fp32 via multi-pass MXU, ~half rate)
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
