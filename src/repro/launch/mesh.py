"""Production meshes (TPU v5e target).

A function, not a module-level constant, so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips ("data", "model").
Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model").

Under PNN (the paper's scheme) the "pod" axis carries *stages*, not replicas:
each pod trains one model partition with zero inter-pod collectives during
training (DESIGN.md §2.2); under the conventional baseline the pod axis is an
outer data-parallel axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, shape=None):
    """shape: optional (data, model) override, e.g. (32, 8) for an
    expert-parallel variant (model axis dividing the expert count)."""
    if shape is None:
        shape = (16, 16)
    assert shape[0] * shape[1] == 256, "one pod = 256 chips"
    full = ((2,) + tuple(shape)) if multi_pod else tuple(shape)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(full, axes)


def dp_axes(mesh) -> tuple:
    """Data-parallel axis names for this mesh (batch sharding)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axis(mesh) -> str:
    return "data"


def tp_axis(mesh) -> str:
    return "model"


# TPU v5e hardware constants (per chip) for the roofline model
PEAK_FLOPS_BF16 = 197e12      # FLOP/s (MXU native)
PEAK_FLOPS_FP32 = 98.5e12     # FLOP/s (fp32 via multi-pass MXU, ~half rate)
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
