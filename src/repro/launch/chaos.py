"""Chaos sweep CLI: drive the fault matrix through the supervised executor
and report every cell into ``results/RESILIENCE_8.json``.

Each cell injects one fault family (or a seeded mixed schedule) into a
2-stage EMNIST-like run under ``resilience.SupervisedExecutor`` and checks
the recovery guarantee that applies:

* crash / transient / ckpt_corruption / straggler / mixed — the recovered
  run must be **bitwise equal** to the fault-free reference (the paper's
  zero-communication property makes per-stage replay exact).
* nan — the step guard must skip exactly the poisoned steps and leave the
  final params finite (a skipped step is *absent*, not approximated, so
  there is no fault-free twin to compare against).

Time is a ``FakeClock`` everywhere: backoff and straggler delays advance a
counter, so the whole matrix is deterministic and fast enough for CI.

Usage:
  PYTHONPATH=src python -m repro.launch.chaos --preset tiny \
      [--seed 0] [--json results/RESILIENCE_8.json]

Exit status is non-zero when any cell has an unrecovered fault or a failed
equivalence — CI gates on it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

SCHEMA = "repro.resilience/1"

TINY = {"n_ticks": 3, "n_train": 256, "batch_size": 64, "mixed_seeds": (0,)}
FULL = {"n_ticks": 6, "n_train": 1024, "batch_size": 128,
        "mixed_seeds": (0, 1, 2)}
PRESETS = {"tiny": TINY, "full": FULL}


def _world(preset: dict, *, nan_guard: bool = False):
    """(backend, stage_params, sils, hps, spec) for the 2-stage cell setup —
    identical across cells so the fault is the only variable."""
    from dataclasses import replace

    from repro.models import mlp as MLP
    from repro.train.backends import MLPBackend, balanced_bounds
    from repro.verify import scenarios
    cfg, data, spec = scenarios.tiny_mlp(
        n_stages=2, epochs=(preset["n_ticks"],) * 2,
        n_train=preset["n_train"], batch_size=preset["batch_size"])
    if nan_guard:
        spec = replace(spec, nan_guard=True)
    be = MLPBackend(cfg, data, spec, bounds=balanced_bounds(cfg, 2))
    params = MLP.init_params(cfg, jax.random.PRNGKey(0))
    sils = be.make_sils(jax.random.PRNGKey(3), spec.kappa)
    hps = [spec.stage(k) for k in range(2)]
    return be, be.split(params), sils, hps, spec


def _executor(world, root):
    from repro.dist import placement
    from repro.dist.executor import StageExecutor
    from repro.train.backends import make_optimizer_for
    be, sp0, sils, hps, spec = world
    opts = [make_optimizer_for(hp, spec) for hp in hps]
    return StageExecutor(be, placement.round_robin(2), sp0, sils, opts, hps,
                         shuffle=True, ckpt_dir=root)


def _bitwise_equal(a, b) -> bool:
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        if not np.array_equal(np.asarray(x), np.asarray(y)):
            return False
    return True


def _cell_schedules(preset: dict, seed: int):
    """The fault matrix: (cell name, schedule, needs nan_guard)."""
    from repro.resilience import (CheckpointCorruption, FaultSchedule,
                                  NaNInjection, StageCrash, StragglerDelay,
                                  TransientError)
    n_ticks = preset["n_ticks"]
    mid = max(1, n_ticks // 2)
    cells = [
        ("crash", FaultSchedule([StageCrash(stage=1, tick=mid)]), False),
        ("transient", FaultSchedule(
            [TransientError(stage=0, tick=1, failures=2)]), False),
        ("ckpt_corruption/truncate_manifest", FaultSchedule(
            [CheckpointCorruption(stage=0, tick=mid,
                                  mode="truncate_manifest")]), False),
        ("ckpt_corruption/truncate_npz", FaultSchedule(
            [CheckpointCorruption(stage=1, tick=mid,
                                  mode="truncate_npz")]), False),
        ("ckpt_corruption/flip_bytes", FaultSchedule(
            [CheckpointCorruption(stage=0, tick=mid,
                                  mode="flip_bytes")]), False),
        ("straggler", FaultSchedule(
            [StragglerDelay(stage=1, tick=1, delay=1.5)]), False),
        # both on stage 0: MLP stages k>0 take sil_lookup(sils[k-1], y) as
        # input (int labels), so a poisoned float x never reaches them
        ("nan", FaultSchedule(
            [NaNInjection(stage=0, tick=1),
             NaNInjection(stage=0, tick=2, value=float("nan"))]), True),
    ]
    for s in preset["mixed_seeds"]:
        # mixed schedules stay bitwise-comparable: nan is excluded because
        # a guarded skip has no fault-free twin (it gets its own cell)
        cells.append((f"mixed/seed{seed + s}", FaultSchedule.sample(
            seed + s, n_stages=2, n_ticks=n_ticks, n_faults=3,
            kinds=("crash", "transient", "ckpt_corruption", "straggler")),
            False))
    return cells


def run_matrix(preset_name: str, seed: int, workdir: str) -> dict:
    from repro.optim import read_skipped
    from repro.resilience import FakeClock, RetryPolicy, SupervisedExecutor
    preset = PRESETS[preset_name]
    n_ticks = preset["n_ticks"]

    world = _world(preset)
    ref_ex = _executor(world, os.path.join(workdir, "ref"))
    ref_ex.run(n_ticks)
    ref = ref_ex.gather()

    cells = []
    for name, schedule, needs_guard in _cell_schedules(preset, seed):
        w = _world(preset, nan_guard=True) if needs_guard else world
        root = os.path.join(workdir, name.replace("/", "_"))
        ex = _executor(w, root)
        clk = FakeClock()
        sup = SupervisedExecutor(ex, schedule=schedule, clock=clk.monotonic,
                                 sleep=clk.sleep, ckpt_every=1,
                                 policy=RetryPolicy(max_retries=5, seed=seed),
                                 strict=False)
        sup.run(n_ticks)
        got = ex.gather()
        report = sup.report()
        if needs_guard:
            skipped = sum(int(jax.device_get(read_skipped(o)))
                          for o in ex.opt_states)
            n_inject = len(schedule.faults)
            finite = all(bool(jnp.all(jnp.isfinite(leaf)))
                         for p in got
                         for leaf in jax.tree_util.tree_leaves(p))
            ok = (skipped == n_inject and finite and not sup.unrecovered)
            equivalence = "skip-count"
            detail = {"skipped": skipped, "expected": n_inject,
                      "finite": finite}
        else:
            equal = _bitwise_equal(ref, got)
            ok = equal and not sup.unrecovered and not report["never_fired"]
            equivalence = "bitwise-vs-fault-free"
            detail = {"bitwise_equal": equal}
        cells.append({
            "cell": name,
            "ok": bool(ok),
            "equivalence": equivalence,
            "faults": schedule.describe(),
            "faults_seen": report["faults_seen"],
            "unrecovered": report["unrecovered"],
            "never_fired": report["never_fired"],
            "final_ticks": report["ticks"],
            **detail,
        })
        status = "PASS" if ok else "FAIL"
        print(f"[{status}] {name:36s} faults={len(schedule.faults)} "
              f"seen={len(report['faults_seen'])} "
              f"unrecovered={len(report['unrecovered'])}")

    n_failed = sum(not c["ok"] for c in cells)
    n_unrecovered = sum(len(c["unrecovered"]) for c in cells)
    return {
        "schema": SCHEMA,
        "preset": preset_name,
        "seed": seed,
        "env": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "n_devices": len(jax.devices()),
        },
        "n_ticks": n_ticks,
        "n_cells": len(cells),
        "n_passed": len(cells) - n_failed,
        "n_failed": n_failed,
        "n_unrecovered_faults": n_unrecovered,
        "cells": cells,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="sweep the resilience fault matrix through the "
                    "supervised executor")
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed for the sampled mixed schedules")
    ap.add_argument("--json", default="results/RESILIENCE_8.json",
                    help="report path ('' disables)")
    args = ap.parse_args(argv)

    print(f"# repro.resilience chaos sweep: preset={args.preset} "
          f"seed={args.seed}")
    with tempfile.TemporaryDirectory(prefix="chaos_") as workdir:
        report = run_matrix(args.preset, args.seed, workdir)
    print(f"# {report['n_passed']}/{report['n_cells']} cells passed, "
          f"{report['n_unrecovered_faults']} unrecovered faults")
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote {args.json}")
    return 1 if (report["n_failed"] or report["n_unrecovered_faults"]) else 0


if __name__ == "__main__":
    sys.exit(main())
