"""Production training launcher.

On a real TPU slice this runs the sharded train step over the production
mesh; on CPU (this container) it falls back to single-device execution with
the same code path (reduced configs via --smoke).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 20 --batch 8 --seq 128 [--mode pnn --stages 2] [--seq-shard]
      [--dist round_robin --devices 8] [--resume ckpts/run1]

``--stages`` accepts a count (uniform split), ``auto`` (cost-model searched
boundaries via ``repro.plan``, default K=2), or ``auto:K``.  ``--arch
paper_mlp`` runs the paper's EMNIST MLP experiment through the same flags.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import ARCH_NAMES, get
from repro.core import partition
from repro.data.lm import lm_batch_at, lm_batches, synthetic_token_stream
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import Policy
from repro.launch.steps import (build_train_step, pick_accum,
                                pick_optimizer_name, _shard_x_fn)
from repro.configs.base import InputShape
from repro.models import model as M
from repro.optim import cosine_warmup, make_optimizer
from repro.train import StageSpec, TrainSpec, recipes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b",
                    choices=ARCH_NAMES + ["paper_mlp"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20,
                    help="LM: optimizer steps; paper_mlp: epochs per stage")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mode", default="baseline", choices=["baseline", "pnn"])
    ap.add_argument("--stages", default="2",
                    help="PNN partition count: N (uniform split), 'auto' "
                         "(repro.plan searched boundaries, K=2), or "
                         "'auto:K'")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--precision", default=None,
                    choices=["fp32", "bf16", "fp16"],
                    help="precision policy: compute dtype for activations/"
                         "caches, fp32 accumulation, loss scaling + master "
                         "weights under fp16 (default: the arch config's "
                         "dtype)")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches per step "
                         "(fp32 accumulators inside the jitted step)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="per-stage checkpoint cadence in ticks "
                         "(--dist modes; 0 = final only)")
    ap.add_argument("--resume", default=None,
                    help="checkpoint dir to restore params from before "
                         "training (latest step; log lines carry the "
                         "step offset)")
    ap.add_argument("--dist", default="none",
                    choices=["none", "round_robin", "memory"],
                    help="PNN stage placement: run ParallelSilPhase through "
                         "the repro.dist StageExecutor with stages placed "
                         "across devices (requires --mode pnn)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU: sets XLA_FLAGS "
                         "--xla_force_host_platform_device_count pre-init) "
                         "and place stages across them")
    args = ap.parse_args()

    if args.devices:
        # must precede every jax backend touch in this process
        from repro.launch.mesh import force_host_device_count
        force_host_device_count(args.devices)
    if args.dist != "none" and args.mode != "pnn":
        raise SystemExit("--dist requires --mode pnn (stage placement only "
                         "exists for partitioned training)")

    from repro.plan import parse_stages
    stage_strategy, n_stages = parse_stages(args.stages)

    if args.arch == "paper_mlp":
        return _run_paper_mlp(args, stage_strategy, n_stages)

    cfg = get(args.arch, smoke=args.smoke)
    prec = None
    if args.precision:
        from repro.precision import get_policy
        prec = get_policy(args.precision)
        cfg = prec.apply_to_model(cfg)
        print(f"precision={prec.name}: compute={cfg.dtype} "
              f"params={cfg.param_dtype} accum=float32 "
              f"loss_scale={'dynamic' if prec.dynamic_scale else prec.loss_scale}")
    n_dev = len(jax.devices())
    use_mesh = n_dev >= 256
    print(f"arch={cfg.name} devices={n_dev} "
          f"mesh={'production 16x16' if use_mesh else 'single-device'}")

    stream = synthetic_token_stream(1_000_000, cfg.vocab_size, seed=0)
    it = lm_batches(stream, args.batch, args.seq, seed=0)

    def next_batch(_):
        return {k: jnp.asarray(v) for k, v in next(it).items()}

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    step0 = 0
    if args.resume:
        step0 = latest_step(args.resume) or 0
        params = restore_checkpoint(args.resume, {"params": params})["params"]
        params = jax.tree_util.tree_map(jnp.asarray, params)
        print(f"resumed params from {args.resume} @ step {step0} "
              f"(training continues to step {step0 + args.steps})")

    if args.mode == "pnn" and args.dist != "none":
        # repro.dist: every stage trains simultaneously, each pinned to its
        # own device (Fig. 5 actually executed; see src/repro/dist/)
        from repro.launch.mesh import stage_devices
        devs = stage_devices(args.devices or min(n_stages, n_dev))
        plan = partition.make_plan(cfg, n_stages, strategy=stage_strategy)
        _print_plan(stage_strategy, plan)
        spec = TrainSpec(
            n_stages=n_stages, kappa=1.0, precision=args.precision,
            stages=tuple(StageSpec(steps=args.steps, lr=args.lr,
                                   optimizer="adamw", accum=args.accum)
                         for _ in range(n_stages)))
        ckpt_dir = os.path.join(args.ckpt_dir, "stages") \
            if args.ckpt_dir else None

        def batch_at(i):
            # PURE function of the tick index (not the shared stateful
            # iterator): a resumed stage replaying ticks t..n must see
            # exactly the batches the other stages consumed at those ticks
            return {k: jnp.asarray(v) for k, v in
                    lm_batch_at(stream, args.batch, args.seq, i).items()}
        params, hist = recipes.run_lm_parallel(
            cfg, plan, params, batch_at, spec, jax.random.PRNGKey(1),
            dist=args.dist, dist_devices=devs, ckpt_dir=ckpt_dir,
            ckpt_every=args.ckpt_every)
        losses_tail = hist.column("loss")[-5:]
        print(f"dist={args.dist} over {len(devs)} devices; "
              "PNN parallel losses (tail):",
              [round(l, 3) for l in losses_tail])
    elif args.mode == "pnn":
        # PNN stage steps go through the SAME Policy/sharding plumbing as
        # baseline training; on sub-mesh hosts --seq-shard fails loudly
        # instead of being silently ignored (it used to be).
        shard_fn, pspecs_fn = None, None
        if use_mesh:
            mesh = make_production_mesh()
            policy = Policy(cfg, mesh)
            if args.seq_shard:
                shard_fn = _shard_x_fn(cfg, policy, args.batch, args.seq)
            # NamedShardings (not bare PartitionSpecs): the stage steps are
            # traced outside any `with mesh:` context
            pspecs_fn = policy.params_shardings
        elif args.seq_shard:
            raise SystemExit(
                "--seq-shard with --mode pnn requires the production mesh "
                f"(>=256 devices; have {n_dev}). Run without --seq-shard "
                "or on a full slice.")
        plan = partition.make_plan(cfg, n_stages, strategy=stage_strategy)
        _print_plan(stage_strategy, plan)
        spec = TrainSpec(
            n_stages=n_stages, kappa=1.0, precision=args.precision,
            stages=tuple(StageSpec(steps=args.steps // n_stages,
                                   lr=args.lr, optimizer="adamw",
                                   accum=args.accum)
                         for _ in range(n_stages)),
            recovery=StageSpec(steps=args.steps // 4, lr=args.lr / 10,
                               optimizer="adamw", accum=args.accum))
        params, hist = recipes.run_lm_sequential(
            cfg, plan, params, next_batch, spec, jax.random.PRNGKey(1),
            shard_x=shard_fn, grad_pspecs_fn=pspecs_fn)
        losses_tail = hist.column("loss")[-5:]
        print("PNN losses (tail):", [round(l, 3) for l in losses_tail])
    else:
        opt_name = pick_optimizer_name(cfg) if not args.smoke else "adamw"
        opt = make_optimizer(opt_name, cosine_warmup(args.lr, 10, args.steps))
        wrapped = prec is not None and prec.wraps_optimizer
        if wrapped:
            from repro.optim import mixed_precision
            opt = mixed_precision(opt, loss_scale=prec.loss_scale,
                                  dynamic=prec.dynamic_scale,
                                  growth_interval=prec.scale_growth_interval)
        state = opt.init(params)
        shape = InputShape("cli", args.seq, args.batch, "train")
        if use_mesh:
            mesh = make_production_mesh()
            policy = Policy(cfg, mesh)
            # an explicit --accum wins; otherwise the memory-aware default
            accum = args.accum if args.accum > 1 \
                else pick_accum(cfg, shape, policy)
            shard_fn = _shard_x_fn(cfg, policy, args.batch, args.seq) \
                if args.seq_shard else None
            step = build_train_step(cfg, opt, accum=accum,
                                    seq_shard_fn=shard_fn)
            p_sh = policy.params_shardings(params)
            o_sh = policy.opt_state_shardings(opt_name, params)
            if wrapped:
                # the mixed_precision wrapper nests the inner state and adds
                # replicated scalars (+ fp32 masters mirroring the params)
                from jax.sharding import NamedSharding, PartitionSpec as P
                rep = NamedSharding(mesh, P())
                o_sh = {"inner": o_sh, "loss_scale": rep, "good_steps": rep}
                if "master" in state:
                    o_sh["master"] = p_sh
            step_fn = jax.jit(step, in_shardings=(p_sh, o_sh, None),
                              out_shardings=(p_sh, o_sh, None),
                              donate_argnums=(0, 1))
            params = jax.device_put(params, p_sh)
            state = jax.device_put(state, o_sh)
        else:
            step_fn = jax.jit(build_train_step(cfg, opt, accum=args.accum))
        t0 = time.time()
        for i in range(args.steps):
            params, state, metrics = step_fn(params, state, next_batch(i))
            if (i + 1) % max(args.steps // 5, 1) == 0 or i == 0:
                print(f"step {step0+i+1:4d} ce={float(metrics['ce']):.3f} "
                      f"grad_norm={float(metrics['grad_norm']):.2f} "
                      f"({(i+1)*args.batch*args.seq/(time.time()-t0):.0f} tok/s)")

    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, step0 + args.steps,
                               {"params": params})
        print("saved:", path)


def _print_plan(strategy: str, plan) -> None:
    if strategy == "auto":
        print(f"plan[auto]: {plan.n_stages} stages, searched bounds "
              f"{plan.bounds} (repro.plan cost-model cut)")
    else:
        print(f"plan[uniform]: {plan.n_stages} stages, bounds {plan.bounds}")


def _run_paper_mlp(args, strategy: str, n_stages: int):
    """The paper's EMNIST MLP through the same CLI: baseline, or PNN with
    uniform/paper/searched stage bounds (``--steps`` = epochs per stage)."""
    from repro import plan as plan_lib
    from repro.data.images import emnist_like
    from repro.train import recipes
    from repro.train.backends import mlp_default_bounds, mlp_test_accuracy

    cfg = get("paper_mlp", smoke=args.smoke)
    n_train, n_test = (9400, 940) if args.smoke else (28200, 2820)
    data = emnist_like(n_train=n_train, n_test=n_test, seed=0, noise=0.5)
    epochs = args.steps
    spec = TrainSpec(
        batch_size=1410, kappa=10.0, shuffle=True, n_stages=n_stages,
        precision=args.precision,
        stages=tuple(StageSpec(epochs=epochs, lr=0.01, optimizer="sgdm",
                               momentum=0.9) for _ in range(n_stages)),
        baseline=StageSpec(epochs=epochs, lr=0.01, optimizer="sgdm",
                           momentum=0.9))
    key = jax.random.PRNGKey(0)  # repro: allow-const-key
    if args.mode == "baseline":
        params, hist = recipes.run_mlp_baseline(cfg, data, spec, key)
    else:
        if strategy == "auto":
            bounds = plan_lib.auto_mlp_bounds(cfg, n_stages,
                                              batch_size=spec.batch_size)
        else:
            bounds = mlp_default_bounds(cfg, n_stages)
        table = plan_lib.mlp_costs(cfg, batch_size=spec.batch_size)
        rows = table.stage_costs(bounds)
        print(f"plan[{strategy}]: {n_stages} stages, bounds {bounds}")
        for c in rows:
            print(f"  stage{c.stage}: layers[{c.lo},{c.hi}) "
                  f"bytes={c.bytes_total:,} flops={c.flops:.3g}")
        if args.dist != "none":
            params, hist = recipes.run_mlp_fig5(
                cfg, data, spec, key, n_stages=n_stages, bounds=bounds,
                dist=args.dist)
        else:
            params, hist = recipes.run_mlp_fig5(
                cfg, data, spec, key, n_stages=n_stages, bounds=bounds)
    acc = mlp_test_accuracy(cfg, params, data[2], data[3])
    print(f"paper_mlp {args.mode}: test acc {acc:.4f}")
    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, epochs, {"params": params})
        print("saved:", path)


if __name__ == "__main__":
    main()
