"""Loop-aware HLO analysis + analytic roofline terms.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, regardless of
trip count (verified empirically) — useless for scan-over-layers models.  Two
replacements:

* ``collective_bytes_loop_aware(hlo_text)`` — walks the computation call
  graph, multiplies collective bytes inside while bodies by the loop trip
  count (parsed from the loop condition's comparison constant).
* ``analytic_cost(cfg, shape, ...)`` — workload napkin math: matmul FLOPs
  from the parameter counts (6ND train / 2ND inference), attention-score
  FLOPs (causal/windowed), and an HBM traffic model (params + optimizer +
  activation/cache streams).  This is the methodology the §Roofline tables
  use; raw HLO numbers are kept alongside for reference.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.precision import dtype_itemsize

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text (optimized HLO module text).

    Headers look like ``%name (params...) -> type {`` (params may contain
    nested parens/tuples) or ``ENTRY %name (...) ... {``.
    """
    comps: Dict[str, str] = {}
    name = None
    buf = []
    for line in hlo.splitlines():
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m:
                name = m.group(1)
                buf = []
                continue
        if line.startswith("}"):
            if name is not None:
                comps[name] = "\n".join(buf)
            name = None
            continue
        if name is not None:
            buf.append(line)
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    return m.group(1) if m else None


def _direct_collectives(body: str) -> Dict[str, Dict[str, int]]:
    stats = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in body.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+(\w[\w\-]*)\(", ls)
        if not m:
            continue
        result_type, opname = m.group(1), m.group(2).replace("_", "-")
        for c in _COLLECTIVES:
            if opname == c or opname == c + "-start":
                stats[c]["count"] += 1
                stats[c]["bytes"] += _shape_bytes(result_type)
    return stats


def _trip_count(cond_body: str) -> int:
    """Loop trip count heuristic: the comparison constant in the condition."""
    consts = [int(x) for x in re.findall(r"constant\((\d+)\)", cond_body)]
    return max(consts) if consts else 1


def _sub_calls(body: str):
    """(kind, computation names) referenced by ops in this body."""
    out = []
    for line in body.splitlines():
        mw = re.search(r"\bwhile\(.*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)",
                       line)
        if mw:
            out.append(("while", mw.group(1), mw.group(2)))
            continue
        mc = re.findall(r"to_apply=%?([\w.\-]+)", line)
        for c in mc:
            out.append(("call", None, c))
        ms = re.search(r"\bconditional\(.*branch_computations=\{([^}]*)\}",
                       line)
        if ms:
            for c in ms.group(1).split(","):
                out.append(("call", None, c.strip().lstrip("%")))
    return out


def collective_stats_loop_aware(hlo: str) -> Dict:
    """Collective bytes/counts with while-loop trip multiplicity."""
    comps = _split_computations(hlo)
    entry = _entry_name(hlo)
    memo: Dict[str, Dict] = {}

    def walk(name: str, depth=0) -> Dict[str, Dict[str, int]]:
        if name in memo or depth > 32 or name not in comps:
            return memo.get(name,
                            {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES})
        body = comps[name]
        stats = _direct_collectives(body)
        for kind, cond, sub in _sub_calls(body):
            mult = 1
            if kind == "while":
                mult = _trip_count(comps.get(cond, ""))
            sub_stats = walk(sub, depth + 1)
            for c in _COLLECTIVES:
                stats[c]["count"] += mult * sub_stats[c]["count"]
                stats[c]["bytes"] += mult * sub_stats[c]["bytes"]
        memo[name] = stats
        return stats

    stats = walk(entry) if entry else {c: {"count": 0, "bytes": 0}
                                       for c in _COLLECTIVES}
    out = {c: dict(v) for c, v in stats.items()}
    out["total_bytes"] = sum(v["bytes"] for v in stats.values())
    out["total_count"] = sum(v["count"] for v in stats.values())
    return out


# --------------------------------------------------------------------------
# pytree byte accounting (shared by dryrun and repro.analysis)
# --------------------------------------------------------------------------

def dtype_byte_breakdown(tree, shardings=None, mesh=None) -> Dict[str, int]:
    """Per-dtype byte totals of a pytree of arrays / ShapeDtypeStructs.

    With ``shardings`` (a matching tree of NamedShardings) and ``mesh``,
    each leaf is divided by the product of its sharded mesh-axis sizes —
    i.e. per-chip bytes, the number the roofline tables and the donation
    evidence both want.  Without them, global bytes."""
    leaves = jax.tree_util.tree_leaves(tree)
    if shardings is not None:
        from jax.sharding import NamedSharding
        shards = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    else:
        shards = [None] * len(leaves)
    out: Dict[str, int] = {}
    for leaf, sh in zip(leaves, shards):
        shape = getattr(leaf, "shape", ())
        n = int(np.prod(shape)) if shape else 1
        den = 1
        if sh is not None:
            for ent in sh.spec:
                if ent is None:
                    continue
                axes = ent if isinstance(ent, tuple) else (ent,)
                for ax in axes:
                    den *= mesh.shape[ax]
        dt = str(getattr(leaf, "dtype", "float32"))
        out[dt] = out.get(dt, 0) + (n // max(den, 1)) * dtype_itemsize(dt)
    return out


def tree_bytes_per_chip(tree, shardings=None, mesh=None) -> int:
    """Total (per-chip, when sharded) bytes of a pytree — the sum of
    ``dtype_byte_breakdown``."""
    return sum(dtype_byte_breakdown(tree, shardings, mesh).values())


# --------------------------------------------------------------------------
# analytic workload model
# --------------------------------------------------------------------------

def _attn_layers(cfg: ModelConfig) -> int:
    return sum(1 for l in range(cfg.n_layers) if cfg.block_kind(l) == "attn")


def analytic_flops_per_chip(cfg: ModelConfig, shape: InputShape,
                            n_chips: int) -> float:
    """Matmul + attention-score FLOPs for one step, per chip."""
    pc = cfg.param_counts()
    n_mat = pc["active"] - pc["embed"]
    b, s = shape.global_batch, shape.seq_len
    h, hd = cfg.n_heads, cfg.hd
    la = _attn_layers(cfg)
    w = cfg.sliding_window
    if shape.kind == "train":
        tokens = b * s
        mat = 6.0 * n_mat * tokens
        # causal scores: 2*B*H*S^2*hd (QK) + same (PV), halved for causality,
        # x3 for fwd+bwd
        span = min(s, w) if w else s
        attn = 3.0 * la * (2.0 * b * h * s * span * hd * 2) * 0.5
        # unembed matmul (padded vocab)
        mat += 6.0 * tokens * cfg.d_model * cfg.vocab_padded
    elif shape.kind == "prefill":
        tokens = b * s
        mat = 2.0 * n_mat * tokens
        span = min(s, w) if w else s
        attn = la * (2.0 * b * h * s * span * hd * 2) * 0.5
        mat += 2.0 * b * cfg.d_model * cfg.vocab_padded  # last-token logits
    else:  # decode
        mat = 2.0 * n_mat * b
        lc = min(s, w) if w else s
        attn = la * (2.0 * b * h * lc * hd * 2)
        mat += 2.0 * b * cfg.d_model * cfg.vocab_padded
    return (mat + attn) / n_chips


def analytic_hbm_bytes_per_chip(cfg: ModelConfig, shape: InputShape,
                                n_chips: int, *, params_bytes_per_chip: int,
                                opt_bytes_per_chip: int = 0,
                                cache_bytes_per_chip: int = 0,
                                accum: int = 1) -> float:
    """HBM traffic model for one step, per chip.

    train:   fwd reads params (x accum microbatches under FSDP gathering the
             same shards), bwd reads again, optimizer reads+writes params and
             state; activation stream ~ 2 x (saved boundaries rw).
    prefill: params once, cache written once, activation stream.
    decode:  params once, cache read+written.
    """
    # activation-stream element size follows the precision policy's
    # compute dtype (bf16/fp16 halve it), not a hard-coded constant
    dt = dtype_itemsize(cfg.dtype)
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    g_boundaries = cfg.n_layers  # one residual save per layer (remat policy)
    if shape.kind == "train":
        tokens_per_chip = b * s / n_chips
        act = 4.0 * tokens_per_chip * d * dt * g_boundaries  # save+reread,f+b
        logits = 2.0 * tokens_per_chip * cfg.vocab_padded * 4
        pbytes = params_bytes_per_chip * (2.0 * accum + 2.0)
        obytes = 2.0 * opt_bytes_per_chip
        return pbytes + obytes + act + logits
    if shape.kind == "prefill":
        tokens_per_chip = b * s / n_chips
        act = 2.0 * tokens_per_chip * d * dt * g_boundaries
        return params_bytes_per_chip + cache_bytes_per_chip + act
    # decode
    return params_bytes_per_chip + 2.0 * cache_bytes_per_chip \
        + 2.0 * (b / max(n_chips, 1)) * d * dt * g_boundaries


def analytic_peak_bytes_per_chip(cfg: ModelConfig, shape: InputShape,
                                 n_chips: int, *, params_bytes_per_chip: int,
                                 opt_bytes_per_chip: int = 0,
                                 cache_bytes_per_chip: int = 0,
                                 accum: int = 1) -> float:
    """HBM-residency estimate for the fit check (CPU XLA's memory_analysis
    does not model TPU buffer reuse/remat, so we model the steady state:
    params + optimizer + grad accumulator + per-microbatch activation saves
    (one residual per layer under the remat policy) + logits + transient
    gathered layer weights)."""
    # activation-stream element size follows the precision policy's
    # compute dtype (bf16/fp16 halve it), not a hard-coded constant
    dt = dtype_itemsize(cfg.dtype)
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    if shape.kind == "train":
        # data shards only (model axis shards activations' hidden dims at
        # most; count unsharded = worst case)
        dp = min(b, 16 if n_chips >= 256 else n_chips)
        tokens_mb = (b // max(accum, 1)) * s / dp
        saves = tokens_mb * d * dt * cfg.n_layers
        logits = 2.0 * tokens_mb * cfg.vocab_padded * 4 / 16  # vocab sharded
        grads = params_bytes_per_chip * (2 if accum > 1 else 1)
        return (params_bytes_per_chip + opt_bytes_per_chip + grads
                + saves + logits)
    if shape.kind == "prefill":
        dp = min(b, 16 if n_chips >= 256 else n_chips)
        work = (b / dp) * s * d * dt * 4  # a few live layer tensors
        return params_bytes_per_chip + cache_bytes_per_chip + work
    return params_bytes_per_chip + cache_bytes_per_chip \
        + 0.1 * params_bytes_per_chip
