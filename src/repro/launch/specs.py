"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) pair.

No device allocation — the dry-run lowers against these.  The modality
frontends are stubbed here by construction: audio archs receive precomputed
frame embeddings (B, enc_seq, d), VLMs receive patch embeddings
(B, vision_tokens, d) (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import model as M

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, SDS]:
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, SDS] = {}
    s_text = s
    if cfg.frontend == "vision":
        s_text = s - cfg.vision_tokens
        specs["image_embeds"] = SDS((b, cfg.vision_tokens, cfg.d_model),
                                    jnp.bfloat16)
    specs["tokens"] = SDS((b, s_text), jnp.int32)
    if cfg.enc_dec:
        specs["frames"] = SDS((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    specs["labels"] = SDS((b, s_text), jnp.int32)
    return specs


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, SDS]:
    specs = train_batch_specs(cfg, shape)
    specs.pop("labels")
    return specs


def decode_specs(cfg: ModelConfig, shape: InputShape):
    """(cache_struct, token_struct, pos_struct) for serve_step."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: M.init_cache(cfg, b, s))
    token = SDS((b,), jnp.int32)
    pos = SDS((), jnp.int32)
    return cache, token, pos


def applicable(cfg: ModelConfig, shape: InputShape) -> tuple:
    """(ok, reason). Encodes DESIGN.md §4.2 skip policy."""
    if shape.name == "long_500k":
        if cfg.enc_dec:
            return False, ("enc-dec full-attention decoder; no faithful "
                           "sliding-window variant (DESIGN.md §4.2)")
    return True, ""


def arch_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Shape-conditional config tweaks (the long-context sliding window)."""
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm"):
        # sub-quadratic requirement: dense/moe/vlm attention runs the
        # sliding-window variant (SSM/hybrid are already sub-quadratic)
        return cfg.replace(sliding_window=8192)
    if shape.name == "long_500k" and cfg.family == "hybrid":
        # jamba: mamba layers are O(1); its sparse attention layers keep the
        # full 500k cache (9 layers — see DESIGN.md §4.2 memory accounting)
        return cfg
    return cfg
