import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against ShapeDtypeStruct stand-ins, and extract the roofline
terms (FLOPs, bytes, collective bytes) from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--mode pnn]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json

Results are cached in the output JSON; finished combinations are skipped
unless --force is given.
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, INPUT_SHAPES, get
from repro.core import partition
from repro.launch import specs as S
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.sharding import Policy
from repro.launch.hlo_analysis import (analytic_flops_per_chip,
                                        analytic_hbm_bytes_per_chip,
                                        collective_stats_loop_aware,
                                        tree_bytes_per_chip)
from repro.launch.steps import (build_decode_step, build_pnn_stage_step,
                                build_prefill_step, build_train_step,
                                pick_accum, pick_optimizer_name, _shard_x_fn)
from repro.models import model as M
from repro.optim import make_optimizer

def analyze(compiled, lowered, cfg, shape, n_chips, *,
            params_bytes=0, opt_bytes=0, cache_bytes=0, accum=1) -> Dict[str, Any]:
    """Roofline terms: analytic compute/memory + loop-aware HLO collectives.

    XLA cost_analysis counts while bodies once (verified), so raw HLO numbers
    are kept under 'hlo_raw' for reference only."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_stats_loop_aware(hlo)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes",
                         "alias_size_in_bytes"):
                if hasattr(ma, attr):
                    mem[attr] = int(getattr(ma, attr))
    except Exception:
        pass
    flops = analytic_flops_per_chip(cfg, shape, n_chips)
    hbm = analytic_hbm_bytes_per_chip(
        cfg, shape, n_chips, params_bytes_per_chip=params_bytes,
        opt_bytes_per_chip=opt_bytes, cache_bytes_per_chip=cache_bytes,
        accum=accum)
    out = {
        "analytic_flops_per_chip": flops,
        "analytic_hbm_bytes_per_chip": hbm,
        "collectives": coll,
        "memory_analysis": mem,
        "hlo_raw": {"flops": float(ca.get("flops", 0.0)),
                    "bytes_accessed": float(ca.get("bytes accessed", 0.0))},
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": hbm / HBM_BW,
        "collective_s": coll["total_bytes"] / ICI_BW,
    }
    terms = {k: out[k] for k in ("compute_s", "memory_s", "collective_s")}
    out["dominant"] = max(terms, key=terms.get)
    return out


def arg_bytes_per_chip(tree, shardings, mesh) -> int:
    """Analytic per-chip bytes of a sharded input tree (delegates to the
    public ``hlo_analysis.tree_bytes_per_chip`` helper)."""
    return tree_bytes_per_chip(tree, shardings, mesh)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N_active*tokens (train), 2*N_active*tokens (prefill),
    2*N_active*B (decode, per step)."""
    n = cfg.param_counts()["active"] - cfg.param_counts()["embed"]
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


# --------------------------------------------------------------------------

def dryrun_one(arch: str, shape_name: str, *, multi_pod=False, mode="baseline",
               seq_shard=False, rec_shard=False, accum_override=None,
               moe_local=False, mesh_shape=None, precision=None,
               pnn_stages=2, pnn_strategy="uniform", dist_devices=None,
               verbose=True) -> Dict[str, Any]:
    shape = INPUT_SHAPES[shape_name]
    if arch == "paper_mlp":
        return _dryrun_mlp(shape_name, pnn_strategy, pnn_stages, mode=mode)
    cfg0 = get(arch)
    ok, reason = S.applicable(cfg0, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "mode": mode, "seq_shard": seq_shard, "rec_shard": rec_shard,
    }
    if precision is not None:
        rec["precision"] = precision
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec
    cfg = S.arch_for_shape(cfg0, shape)
    if precision is not None:
        # re-dtype the compute path (activations / caches / boundary
        # streams); the analytic byte model and init_cache both follow
        # cfg.dtype, so every downstream estimate is policy-aware
        from repro.precision import get_policy
        cfg = get_policy(precision).apply_to_model(cfg)
    if mode == "pipeline" and not multi_pod:
        multi_pod = True  # pipeline baseline = stage-per-pod on 2 pods
        rec["multi_pod"] = True
    mesh = make_production_mesh(multi_pod=multi_pod, shape=mesh_shape)
    n_chips = mesh.size
    policy = Policy(cfg, mesh, pipeline=(mode == "pipeline"))
    if rec_shard:
        cfg = cfg.replace(
            recurrent_sharding=policy.batch_entry(shape.global_batch) or None)
    if seq_shard and shape.seq_len % mesh.shape["model"] == 0:
        cfg = cfg.replace(
            context_sharding=policy.batch_entry(shape.global_batch) or None)
    if moe_local and cfg.moe is not None:
        dp = 1
        for ax in policy.batch_entry(shape.global_batch):
            dp *= mesh.shape[ax]
        # moe_gather_weights=True was tried and REFUTED (adds weight-gather
        # traffic without removing the activation psums — EXPERIMENTS §Perf)
        cfg = cfg.replace(moe_dispatch_groups=dp if dp > 1 else 0)
    t0 = time.time()

    params_struct = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    p_sh = policy.params_shardings(params_struct)

    with mesh:
        if shape.kind == "train" and mode in ("baseline", "pipeline"):
            rec.update(_lower_train(cfg, shape, mesh, policy, params_struct,
                                    p_sh, seq_shard, accum_override,
                                    moe_local))
        elif shape.kind == "train" and mode == "pnn":
            rec.update(_lower_pnn(cfg, shape, mesh, policy, params_struct,
                                  p_sh, seq_shard, n_stages=pnn_stages,
                                  strategy=pnn_strategy,
                                  dist_devices=dist_devices))
        elif shape.kind == "prefill":
            rec.update(_lower_prefill(cfg, shape, mesh, policy, params_struct,
                                      p_sh))
        else:
            rec.update(_lower_decode(cfg, shape, mesh, policy, params_struct,
                                     p_sh))

    rec["n_chips"] = n_chips
    rec["elapsed_s"] = round(time.time() - t0, 1)
    rec["sharding_decisions"] = policy.explain()
    mf = model_flops(cfg, shape)
    rec["model_flops_per_chip"] = mf / n_chips
    if rec.get("analysis", {}).get("analytic_flops_per_chip"):
        rec["useful_flops_ratio"] = (mf / n_chips) / \
            rec["analysis"]["analytic_flops_per_chip"]
    rec["params_bytes_per_chip"] = arg_bytes_per_chip(params_struct, p_sh, mesh)
    rec["status"] = "ok"
    return rec


def _lower_train(cfg, shape, mesh, policy, params_struct, p_sh, seq_shard,
                 accum_override=None, moe_local=False):
    opt_name = pick_optimizer_name(cfg)
    opt = make_optimizer(opt_name, 1e-3)
    accum = accum_override or pick_accum(cfg, shape, policy)
    ostate_struct = jax.eval_shape(opt.init, params_struct)
    o_sh = policy.opt_state_shardings(opt_name, params_struct)
    batch_specs = S.train_batch_specs(cfg, shape)
    b_sh = policy.batch_shardings(batch_specs)
    shard_fn = _shard_x_fn(cfg, policy, shape.global_batch, shape.seq_len) \
        if seq_shard else None
    gspecs = policy.params_pspecs(params_struct) \
        if (seq_shard or moe_local) else None
    step = build_train_step(cfg, opt, accum=accum, seq_shard_fn=shard_fn,
                            grad_pspecs=gspecs)
    jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
    lowered = jitted.lower(params_struct, ostate_struct, batch_specs)
    compiled = lowered.compile()
    pbytes = arg_bytes_per_chip(params_struct, p_sh, mesh)
    obytes = arg_bytes_per_chip(ostate_struct, o_sh, mesh)
    return {"optimizer": opt_name, "accum": accum,
            "opt_bytes_per_chip": obytes,
            "analysis": analyze(compiled, lowered, cfg, shape, mesh.size,
                                params_bytes=pbytes, opt_bytes=obytes,
                                accum=accum)}


def _lower_prefill(cfg, shape, mesh, policy, params_struct, p_sh):
    batch_specs = S.prefill_batch_specs(cfg, shape)
    b_sh = policy.batch_shardings(batch_specs)
    step = build_prefill_step(cfg, shape.seq_len)
    cache_struct = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    c_sh = policy.cache_shardings(cache_struct, shape.global_batch)
    jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                     out_shardings=(None, c_sh, None))
    lowered = jitted.lower(params_struct, batch_specs)
    compiled = lowered.compile()
    pbytes = arg_bytes_per_chip(params_struct, p_sh, mesh)
    cbytes = arg_bytes_per_chip(cache_struct, c_sh, mesh)
    return {"cache_bytes_per_chip": cbytes,
            "analysis": analyze(compiled, lowered, cfg, shape, mesh.size,
                                params_bytes=pbytes, cache_bytes=cbytes)}


def _lower_decode(cfg, shape, mesh, policy, params_struct, p_sh):
    cache_struct, token_struct, pos_struct = S.decode_specs(cfg, shape)
    c_sh = policy.cache_shardings(cache_struct, shape.global_batch)
    t_sh = NamedSharding(mesh, policy.batch_pspec(token_struct.shape))
    pos_sh = NamedSharding(mesh, P())
    step = build_decode_step(cfg)
    jitted = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh, pos_sh),
                     out_shardings=(None, c_sh), donate_argnums=(1,))
    lowered = jitted.lower(params_struct, cache_struct, token_struct,
                           pos_struct)
    compiled = lowered.compile()
    pbytes = arg_bytes_per_chip(params_struct, p_sh, mesh)
    cbytes = arg_bytes_per_chip(cache_struct, c_sh, mesh)
    return {"cache_bytes_per_chip": cbytes,
            "analysis": analyze(compiled, lowered, cfg, shape, mesh.size,
                                params_bytes=pbytes, cache_bytes=cbytes)}


def _lower_pnn(cfg, shape, mesh, policy, params_struct, p_sh,
               seq_shard=False, n_stages=2, strategy="uniform",
               dist_devices=None):
    """Lower every PNN stage's step; report per-stage memory + collectives.

    This is the paper's claim measured: each stage's step touches only that
    stage's params/optimizer state, and stages train with zero inter-stage
    collectives (the pod axis carries nothing during training).

    strategy="auto" cuts via the ``repro.plan`` searcher and attaches the
    chosen cuts + predicted per-stage bytes/FLOPs next to the lowered
    numbers.

    dist_devices: also report the memory-balanced ``repro.dist`` placement
    of the stages onto that many devices, packed by these same per-stage
    byte numbers.
    """
    opt_name = pick_optimizer_name(cfg)
    plan = partition.make_plan(cfg, n_stages, strategy=strategy,
                               **({"optimizer": opt_name}
                                  if strategy == "auto" else {}))
    plan_rec = _predicted_plan(cfg, plan, strategy, opt_name)
    stages = []
    for k in range(plan.n_stages):
        opt = make_optimizer(opt_name, 1e-3)
        sp_struct = jax.eval_shape(
            lambda ps: partition.slice_stage_params(cfg, plan, ps, k),
            params_struct)
        sp_sh = policy.params_shardings(sp_struct)
        so_struct = jax.eval_shape(opt.init, sp_struct)
        so_sh = policy.opt_state_shardings(opt_name, sp_struct)
        shard_fn = _shard_x_fn(cfg, policy, shape.global_batch,
                               shape.seq_len) if seq_shard else None
        gspecs = policy.params_pspecs(sp_struct) if seq_shard else None
        step = build_pnn_stage_step(cfg, plan, k, opt, seq_shard_fn=shard_fn,
                                    grad_pspecs=gspecs)
        b, s = shape.global_batch, shape.seq_len
        s_text = s - (cfg.vision_tokens if cfg.frontend == "vision" else 0)
        labels = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
        lab_sh = NamedSharding(mesh, policy.batch_pspec(labels.shape))
        if k == 0:
            xin = S.train_batch_specs(cfg, shape)
            xin.pop("labels")
            x_sh = policy.batch_shardings(xin)
        else:
            xin = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                       cfg.activation_dtype())
            x_sh = NamedSharding(mesh, policy.batch_pspec(xin.shape))
            if cfg.enc_dec:
                enc = jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model),
                                           cfg.activation_dtype())
                xin = (xin, enc)
                x_sh = (x_sh, NamedSharding(mesh,
                                            policy.batch_pspec(enc.shape)))
        if k < plan.n_stages - 1:
            sil = jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab_padded),
                                       jnp.float32)
            sil_sh = NamedSharding(mesh, P(None, "model"))
        else:
            sil = jax.ShapeDtypeStruct((1, 1), jnp.float32)
            sil_sh = NamedSharding(mesh, P())
        jitted = jax.jit(step, in_shardings=(sp_sh, so_sh, x_sh, lab_sh,
                                             sil_sh),
                         out_shardings=(sp_sh, so_sh, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(sp_struct, so_struct, xin, labels, sil)
        compiled = lowered.compile()
        spb = arg_bytes_per_chip(sp_struct, sp_sh, mesh)
        sob = arg_bytes_per_chip(so_struct, so_sh, mesh)
        stages.append({
            "stage": k,
            "analysis": analyze(compiled, lowered, cfg, shape, mesh.size,
                                params_bytes=spb, opt_bytes=sob),
            "stage_params_bytes_per_chip": spb,
            "stage_opt_bytes_per_chip": sob,
        })
    out = {"optimizer": opt_name, "pnn_stages": stages,
           "n_stages": plan.n_stages, "plan": plan_rec}
    if dist_devices:
        # pack stages onto a smaller device set by the byte estimates just
        # computed — the plan repro.dist's "memory" strategy would pick
        from repro.dist.placement import memory_balanced
        per_stage = [s["stage_params_bytes_per_chip"]
                     + s["stage_opt_bytes_per_chip"] for s in stages]
        pl = memory_balanced(per_stage, devices=tuple(range(dist_devices)))
        out["placement"] = {"strategy": pl.strategy,
                            "assignments": list(pl.assignments),
                            "loads_bytes": list(pl.loads)}
    return out


def _predicted_plan(cfg, plan, strategy, opt_name):
    """The ``repro.plan`` side of the PNN record: chosen cuts + the cost
    model's predicted per-stage bytes/FLOPs, printed next to the lowered
    per-stage tables so prediction and measurement sit side by side.

    Predictions use the searcher's default SIL workload (DEFAULT_BATCH x
    DEFAULT_SEQ — per-stage training batches, not the pretrain shape), the
    same table ``make_plan(strategy="auto")`` optimized over."""
    from repro import plan as plan_lib
    table = plan_lib.lm_costs(cfg, optimizer=opt_name)
    rows = table.stage_costs(plan.bounds)
    return {
        "strategy": strategy,
        "bounds": [list(b) for b in plan.bounds],
        "cuts": [int(hi) for _, hi in plan.bounds[:-1]],
        "cost_batch": plan_lib.DEFAULT_BATCH,
        "cost_seq": plan_lib.DEFAULT_SEQ,
        "predicted_stages": [c.row() for c in rows],
        "predicted_imbalance": round(plan_lib.predicted_imbalance(rows), 6),
        "predicted_bottleneck_bytes": int(max(c.bytes_total for c in rows)),
    }


def _dryrun_mlp(shape_name: str, strategy: str, n_stages: int,
                mode: str = "pnn"):
    """Paper-MLP dry-run: no mesh (the MLP trains on one host) — report the
    chosen stage bounds + predicted per-stage bytes/FLOPs from the same
    ``repro.plan`` cost table the train CLI and auto-searcher use."""
    rec: Dict[str, Any] = {"arch": "paper_mlp", "shape": shape_name,
                           "mode": mode}
    shape = INPUT_SHAPES[shape_name]
    if shape.kind != "train":
        rec["status"] = "skipped"
        rec["reason"] = "paper_mlp only trains (no prefill/decode shapes)"
        return rec
    if mode != "pnn":
        rec["status"] = "skipped"
        rec["reason"] = "paper_mlp dry-run reports the PNN plan; " \
                        "use --mode pnn"
        return rec
    from repro import plan as plan_lib
    from repro.train.backends import mlp_default_bounds
    t0 = time.time()
    cfg = get("paper_mlp")
    table = plan_lib.mlp_costs(cfg)
    if strategy == "auto":
        bounds = plan_lib.auto_bounds(table, n_stages)
    else:
        bounds = mlp_default_bounds(cfg, n_stages)
    rows = table.stage_costs(bounds)
    rec["n_stages"] = n_stages
    rec["optimizer"] = table.optimizer
    rec["plan"] = {
        "strategy": strategy,
        "bounds": [list(b) for b in bounds],
        "cuts": [int(hi) for _, hi in bounds[:-1]],
        "predicted_stages": [c.row() for c in rows],
        "predicted_imbalance": round(plan_lib.predicted_imbalance(rows), 6),
        "predicted_bottleneck_bytes": int(max(c.bytes_total for c in rows)),
    }
    rec["n_chips"] = 1
    rec["elapsed_s"] = round(time.time() - t0, 1)
    rec["status"] = "ok"
    return rec


# --------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    choices=ARCH_NAMES + ["all", "paper_mlp"])
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + ["all"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="baseline",
                    choices=["baseline", "pnn", "pipeline"])
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-parallel residual sharding (perf variant)")
    ap.add_argument("--rec-shard", action="store_true",
                    help="pin recurrent scan carries to batch sharding "
                         "(perf variant)")
    ap.add_argument("--accum", type=int, default=None,
                    help="override microbatch count (perf variant)")
    ap.add_argument("--moe-local", action="store_true",
                    help="locality-grouped MoE dispatch (perf variant)")
    ap.add_argument("--mesh", default=None,
                    help="pod mesh shape override, e.g. 32x8 (perf variant)")
    ap.add_argument("--precision", default=None,
                    choices=["fp32", "bf16", "fp16"],
                    help="precision policy for the compute path (activation "
                         "+ cache dtypes; params keep their storage dtype)")
    ap.add_argument("--stages", default="2",
                    help="PNN partitioning for --mode pnn: N (uniform "
                         "split), 'auto' (repro.plan searched boundaries, "
                         "K=2), or 'auto:K'")
    ap.add_argument("--dist-devices", type=int, default=None,
                    help="report the memory-balanced repro.dist placement "
                         "of the PNN stages onto N devices")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    from repro.plan import parse_stages
    pnn_strategy, pnn_stages = parse_stages(args.stages)

    archs = ARCH_NAMES if (args.all or args.arch in (None, "all")) \
        else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape in (None, "all")) \
        else [args.shape]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for arch in archs:
        for shape in shapes:
            variant = "plain"
            if args.seq_shard and args.rec_shard:
                variant = "seqshard+recshard"
            elif args.seq_shard:
                variant = "seqshard"
            elif args.rec_shard:
                variant = "recshard"
            if args.moe_local:
                variant += "+moelocal"
            if args.mesh:
                variant += f"+mesh{args.mesh}"
            if args.accum:
                variant += f"+accum{args.accum}"
            if args.precision:
                variant += f"+{args.precision}"
            if args.mode == "pnn" and (pnn_strategy != "uniform"
                                       or pnn_stages != 2):
                variant += f"+stages{args.stages.strip().lower()}"
            if args.mode == "pnn" and args.dist_devices:
                variant += f"+dist{args.dist_devices}"
            is_multi = args.multi_pod or args.mode == "pipeline"
            key = f"{arch}|{shape}|{'multi' if is_multi else 'single'}" \
                f"|{args.mode}|{variant}"
            if key in results and results[key].get("status") in ("ok", "skipped") \
                    and not args.force:
                print(f"[cached] {key}")
                continue
            print(f"[dryrun] {key} ...", flush=True)
            try:
                rec = dryrun_one(arch, shape, multi_pod=args.multi_pod,
                                 mode=args.mode, seq_shard=args.seq_shard,
                                 rec_shard=args.rec_shard,
                                 accum_override=args.accum,
                                 moe_local=args.moe_local,
                                 mesh_shape=tuple(int(x) for x in
                                                  args.mesh.split("x"))
                                 if args.mesh else None,
                                 precision=args.precision,
                                 pnn_stages=pnn_stages,
                                 pnn_strategy=pnn_strategy,
                                 dist_devices=args.dist_devices)
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                print(f"  ERROR: {e}")
            results[key] = rec
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
            if rec.get("status") == "ok":
                if "analysis" in rec:
                    a = rec["analysis"]
                    print(f"  ok in {rec['elapsed_s']}s: "
                          f"compute={a['compute_s']*1e3:.2f}ms "
                          f"memory={a['memory_s']*1e3:.2f}ms "
                          f"collective={a['collective_s']*1e3:.2f}ms "
                          f"dominant={a['dominant']}")
                else:
                    if "plan" in rec:
                        p = rec["plan"]
                        print(f"  plan[{p['strategy']}]: cuts {p['cuts']} "
                              f"pred-imbalance {p['predicted_imbalance']:.3f}")
                        for r in p["predicted_stages"]:
                            print(f"    stage{r['stage']} "
                                  f"units{r['units']}: "
                                  f"pred {r['bytes_total']/2**20:.0f}MiB "
                                  f"flops {r['flops']:.3g}")
                    for st in rec.get("pnn_stages", []):
                        a = st["analysis"]
                        print(f"  stage{st['stage']}: "
                              f"params/chip={st['stage_params_bytes_per_chip']/2**20:.0f}MiB "
                              f"coll={a['collective_s']*1e3:.2f}ms")
                    if "placement" in rec:
                        pl = rec["placement"]
                        loads = "/".join(f"{b/2**20:.0f}MiB"
                                         for b in pl["loads_bytes"])
                        print(f"  placement[{pl['strategy']}]: "
                              f"stages->devices {pl['assignments']} "
                              f"loads {loads}")
            elif rec.get("status") == "skipped":
                print(f"  skipped: {rec['reason']}")
    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_err = sum(1 for r in results.values() if r.get("status") == "error")
    print(f"done: {n_ok} ok, {n_err} errors, "
          f"{sum(1 for r in results.values() if r.get('status') == 'skipped')} skipped")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
