"""The concurrent multi-device stage executor.

``StageExecutor`` turns a backend's stage list plus a ``PlacementPlan`` into
a genuinely device-placed program:

* **Pin once, up front** — each stage's params are ``jax.device_put`` onto
  its assigned device; the optimizer state is initialized FROM those
  committed buffers (so it materializes on the same device); the SIL tables
  a stage reads are replicated onto its device.  JAX's committed-data rule
  then compiles each stage's jitted step for that device — the modern
  spelling of ``jax.jit(..., device=)`` (deprecated in favor of placement
  via the data).
* **No host sync inside a tick** — ``tick(i)`` dispatches every due stage's
  step and returns; XLA's async dispatch lets the per-device programs
  overlap.  LM losses accumulate as device-resident scalars and drain in
  ONE transfer at ``finalize`` (the PR-1 contract); MLP ticks are whole
  scanned epochs per stage.
* **Independent per-stage progress** — ``ticks[k]`` counts how far stage k
  has advanced.  ``run(n, stages=[k])`` replays only stage k (deterministic
  data access by tick index), which is how a failed stage catches up after
  ``resume_stage(k)`` without perturbing the others.

Equivalence contract: with every stage placed on one device this executes
the exact ``ParallelSilPhase`` schedule; spread across devices the per-stage
programs are unchanged (same HLO per step), so results stay allclose to the
sequential path — pinned by tests/test_dist.py under 8 forced host devices.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.dist import lifecycle
from repro.dist.placement import PlacementPlan
from repro.obs.metrics import LOSS_BUCKETS
from repro.obs.registry import MetricsRegistry
from repro.obs.trace import TID_STAGE0, Tracer
from repro.train.backends import scanned_epoch_fn


class StageExecutor:
    """Runs all stages of one backend concurrently per the placement plan."""

    def __init__(self, backend, placement: PlacementPlan,
                 stage_params: Sequence, sils: Sequence, opts: Sequence,
                 hps: Sequence, *, seed_base: int = 0, shuffle: bool = True,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 ckpt_keep_last: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        placement.validate(backend.n_stages)
        self.be = backend
        self.placement = placement
        self.opts = list(opts)
        self.hps = list(hps)
        self.seed_base = seed_base
        self.shuffle = shuffle
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = int(ckpt_every or 0)
        self.ckpt_keep_last = ckpt_keep_last
        # fault-injection seam (repro.resilience): when set, every stage's
        # input batch passes through ``batch_hook(stage, tick, batch)``
        # before dispatch.  Deterministic data access by (stage, tick) is
        # what makes an injected fault — and its replay — reproducible
        self.batch_hook = None
        n = self.n = backend.n_stages
        self.devices = [placement.device_for(k) for k in range(n)]
        # pin per-stage state to its device ONCE; everything downstream
        # (optimizer init, step dispatch) follows the committed buffers
        self.params = [jax.device_put(stage_params[k], self.devices[k])
                       for k in range(n)]
        self.opt_states = [self.opts[k].init(backend.trainable(self.params[k]))
                           for k in range(n)]
        self.ticks: List[int] = [0] * n
        self.cum_macs = 0
        self._global_ticks = 0
        # metrics high-water mark per stage: a replayed tick (after
        # resume_stage) re-runs the math but must not re-log its loss or
        # re-count its MACs — finalize would double-report otherwise.
        # obs observes sit INSIDE this guard for the same reason (drain
        # after replay must not double-count; pinned in tests/test_obs.py)
        self._metrics_upto: List[int] = [0] * n
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer()
        self._loss_hist = self.metrics.device_histogram(
            "train_loss", LOSS_BUCKETS,
            help="per-step training loss (device-accumulated)")
        self._ticks_counter = self.metrics.counter(
            "executor_ticks_total", help="dispatched stage ticks, by stage")
        self._pending: list = []
        self._logged_steps: list = []
        self._logged_stages: list = []
        if backend.kind == "mlp":
            # each stage's scanned-epoch program reads SILs replicated on
            # its own device (cross-device constants would refuse to mix
            # with the committed params)
            sils_dev = [jax.device_put(list(sils), d) for d in self.devices]
            self._fns = [scanned_epoch_fn(backend.build_parallel_step(
                k, self.opts[k], sils_dev[k], accum=self.hps[k].accum))
                for k in range(n)]
        else:
            self._fns = []
            for k in range(n):
                dev = self.devices[k]
                sil_t = None if k == n - 1 else jax.device_put(sils[k], dev)
                if k == 0:
                    self._fns.append(backend.build_stage_step(
                        0, self.opts[0], sil_t, accum=self.hps[0].accum))
                else:
                    sil_in = jax.device_put(sils[k - 1], dev)
                    self._fns.append(backend.build_parallel_stage_step(
                        k, self.opts[k], sil_in, sil_t,
                        accum=self.hps[k].accum))

    # -- tick dispatch -----------------------------------------------------

    def _duration(self, k: int) -> int:
        hp = self.hps[k]
        return hp.epochs if self.be.kind == "mlp" else hp.steps

    def tick(self, i: int, stages: Optional[Sequence[int]] = None) -> None:
        """Dispatch tick `i` (epoch for MLP, step for LM) to every listed
        stage that is exactly at tick `i` and still within its duration.
        Returns without any host synchronization."""
        ks = range(self.n) if stages is None else stages
        ks = [k for k in ks if self.ticks[k] == i and i < self._duration(k)]
        if not ks:
            return
        if self.be.kind == "mlp":
            self._tick_mlp(i, ks)
        else:
            self._tick_lm(i, ks)
        self._global_ticks = max(self._global_ticks, i + 1)

    def _tick_mlp(self, ep: int, ks: Sequence[int]) -> None:
        be = self.be
        batches = be.epoch_arrays(self.seed_base + ep, self.shuffle)
        n_samples = batches[0].shape[0] * batches[0].shape[1]
        for k in ks:
            bk = batches if self.batch_hook is None \
                else self.batch_hook(k, ep, batches)
            bk = jax.device_put(bk, self.devices[k])
            with self.tracer.span(f"tick {ep}", cat="stage",
                                  tid=TID_STAGE0 + k, stage=k, tick=ep):
                self.params[k], self.opt_states[k], losses = self._fns[k](
                    self.params[k], self.opt_states[k], bk)
            if ep >= self._metrics_upto[k]:
                self.cum_macs += be.stage_macs(k) * n_samples
                self._loss_hist.observe_device(losses)
                self._ticks_counter.inc(1, stage=k)
                self._metrics_upto[k] = ep + 1
            self.ticks[k] = ep + 1

    def _tick_lm(self, i: int, ks: Sequence[int]) -> None:
        be = self.be
        batch = be.batch_fn(i)
        for k in ks:
            dev = self.devices[k]
            bk = batch if self.batch_hook is None \
                else self.batch_hook(k, i, batch)
            with self.tracer.span(f"tick {i}", cat="stage",
                                  tid=TID_STAGE0 + k, stage=k, tick=i):
                if k == 0:
                    b0 = jax.device_put(bk, dev)
                    self.params[0], self.opt_states[0], loss = self._fns[0](
                        self.params[0], self.opt_states[0], b0, b0["labels"])
                else:
                    labels = jax.device_put(bk["labels"], dev)
                    self.params[k], self.opt_states[k], loss = self._fns[k](
                        self.params[k], self.opt_states[k], labels)
            if i >= self._metrics_upto[k]:
                self._pending.append(loss)
                self._loss_hist.observe_device(loss)
                self._ticks_counter.inc(1, stage=k)
                self._logged_steps.append(i)
                self._logged_stages.append(k)
                self._metrics_upto[k] = i + 1
            self.ticks[k] = i + 1

    def run(self, n_ticks: int, stages: Optional[Sequence[int]] = None
            ) -> "StageExecutor":
        """Advance the listed stages (default: all) up to ``n_ticks``,
        checkpointing every ``ckpt_every`` ticks when a ``ckpt_dir`` is
        configured.  Resumed stages start from their own tick counter."""
        ks = list(range(self.n)) if stages is None else list(stages)
        start = min(self.ticks[k] for k in ks)
        for i in range(start, n_ticks):
            self.tick(i, stages=ks)
            if self.ckpt_dir and self.ckpt_every \
                    and (i + 1) % self.ckpt_every == 0:
                self.checkpoint(stages=ks)
        return self

    # -- lifecycle ---------------------------------------------------------

    def checkpoint(self, stages: Optional[Sequence[int]] = None) -> None:
        """One manifest per stage, at each stage's OWN tick counter."""
        if not self.ckpt_dir:
            raise ValueError("executor built without ckpt_dir")
        for k in (range(self.n) if stages is None else stages):
            lifecycle.save_stage(
                self.ckpt_dir, k, self.ticks[k], self.params[k],
                self.opt_states[k],
                metadata={"device": str(self.devices[k]),
                          "placement": self.placement.strategy,
                          "kind": self.be.kind},
                keep_last=self.ckpt_keep_last)

    def resume_stage(self, k: int, step: Optional[int] = None) -> int:
        """Reload stage k (params + optimizer state + tick counter) from its
        own checkpoints, committed back onto its assigned device.  The other
        stages' live state is untouched; follow with ``run(n, stages=[k])``
        to replay the lost ticks."""
        params, opt_state, tick = lifecycle.restore_stage(
            self.ckpt_dir, k, like_params=self.params[k],
            like_opt=self.opt_states[k], step=step, device=self.devices[k])
        self.params[k], self.opt_states[k] = params, opt_state
        self.ticks[k] = tick
        return tick

    # -- drain / handoff ---------------------------------------------------

    def gather(self) -> list:
        """Per-stage params pulled to host (ONE blocking point, at the end —
        committed buffers on different devices must not feed a joint op)."""
        return [jax.device_get(p) for p in self.params]  # repro: allow-host-sync

    def finalize(self, trainer, state, phase_name: str = "parallel") -> None:
        """Hand results back to the TrainState: params re-hosted (so joins,
        eval, and later phases never mix committed devices), the pending
        device-resident losses flushed in one transfer, counters folded in."""
        state.stage_params = [jax.tree_util.tree_map(jnp.asarray, sp)
                              for sp in self.gather()]
        state.cum_macs += self.cum_macs
        self.cum_macs = 0
        if self.be.kind == "mlp":
            state.history.log(phase=phase_name, stage=-1,
                              step=state.step_idx, macs=state.cum_macs,
                              acc=self.be.eval_joined(state.stage_params))
        else:
            state.step_idx += self._global_ticks
            trainer.flush_losses(state, self._pending, self._logged_steps,
                                 phase_name, self._logged_stages)
            self._pending, self._logged_steps, self._logged_stages = \
                [], [], []
        # NaN/inf-guard telemetry: one host read per stage, at the single
        # blocking point the executor already has
        for k in range(self.n):
            trainer.note_skipped(state, self.opt_states[k], phase_name, k)
        # executor-join flush boundary: fold the device-resident metric
        # accumulators into their host series (idempotent)
        self.metrics.drain()
