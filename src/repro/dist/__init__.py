"""`repro.dist` — device-placed concurrent stage execution.

The paper's central claim (Fig. 5) is that SIL-decoupled stages can train
*simultaneously on separate devices with zero inter-partition communication*.
`repro.train.ParallelSilPhase` models that decoupling but executes it as a
sequential Python loop on one implicit device; this package actually places
and runs it:

* ``placement``  — ``PlacementPlan`` maps stages onto devices.  Strategies:
                   ``round_robin`` (stage k -> device k mod D), ``explicit``
                   (caller-chosen assignment), and ``memory_balanced``
                   (greedy LPT packing by per-stage byte estimates — the
                   same params+optimizer byte model `launch/dryrun.py`
                   reports per stage).
* ``executor``   — ``StageExecutor`` pins each stage's params, optimizer
                   state, and a replicated SIL table to its assigned device
                   once up front, builds each stage's jitted step against
                   those committed buffers (JAX compiles one executable per
                   device; computation follows the pinned data), and
                   dispatches every stage's step per tick through JAX async
                   dispatch with no host sync inside the tick — XLA overlaps
                   the stage programs across devices.  Losses stay device-
                   resident and drain in one transfer at phase end.
* ``lifecycle``  — per-stage checkpoint/resume on ``repro.checkpoint``: one
                   manifest per stage with an independent tick counter,
                   ``resume_stage`` after a (simulated) stage failure, and
                   ``join_from_checkpoints`` to rebuild full params for eval
                   or hand per-stage trees to ``serve.Engine`` staged
                   deployment without ever joining.
* ``bench``      — sequential-vs-concurrent tick timings under 8 forced
                   host devices (the rows `benchmarks/run.py --only dist`
                   collects into ``results/BENCH_4.json``).

Everything runs on CPU CI under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` with results
allclose to the sequential path (same step programs, different placement).

Entry points: ``ParallelSilPhase(plan=...)`` in `repro.train.phases` routes
through the executor; ``launch/train.py --mode pnn --dist round_robin
--devices 8`` is the CLI spelling.
"""
from repro.dist.executor import StageExecutor  # noqa: F401
from repro.dist.lifecycle import (join_from_checkpoints,  # noqa: F401
                                  load_stage_params, restore_stage,
                                  save_stage, stage_dir, stage_ticks)
from repro.dist.placement import (PlacementPlan, estimate_stage_bytes,  # noqa: F401,E501
                                  explicit, memory_balanced, resolve,
                                  round_robin)

__all__ = [
    "StageExecutor",
    "PlacementPlan", "round_robin", "explicit", "memory_balanced",
    "resolve", "estimate_stage_bytes",
    "save_stage", "restore_stage", "load_stage_params",
    "join_from_checkpoints", "stage_dir", "stage_ticks",
]
