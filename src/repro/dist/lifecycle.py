"""Per-stage checkpoint / resume / join, on top of ``repro.checkpoint``.

Each stage owns its own checkpoint directory (``<root>/stage_NN``) with its
own manifest and an INDEPENDENT tick counter — the paper's partitions share
no training state, so a stage failure must be recoverable from that stage's
checkpoints alone, without touching (or even reading) the others:

    save_stage(root, k, tick, params, opt_state)     # one stage, one manifest
    restore_stage(root, k, like_params, like_opt,    # -> (params, opt, tick)
                  device=plan.device_for(k))
    join_from_checkpoints(root, like_stage_params,   # full params for eval /
                          join_fn=backend.join)      # deployment

``device=`` placement routes through ``restore_checkpoint``'s sharded-
restore path with a single ``jax.Device`` target, so a resumed stage lands
committed on its assigned device exactly like the executor pinned it at
startup.  ``join_from_checkpoints`` leaves placement to the caller (host
arrays) — the joined tree feeds eval or ``serve.Engine`` staged deployment,
both of which re-place params themselves.
"""
from __future__ import annotations

import os
from typing import Any, Callable, List, Optional, Sequence

from repro.checkpoint import (latest_step, restore_checkpoint,
                              restore_latest_valid, save_checkpoint)


def stage_dir(root: str, k: int) -> str:
    return os.path.join(root, f"stage_{k:02d}")


def save_stage(root: str, k: int, tick: int, stage_params,
               opt_state=None, metadata: Optional[dict] = None,
               keep_last: Optional[int] = None) -> str:
    """Checkpoint one stage: params (+ optimizer state) under the stage's
    own directory, at the stage's own tick counter.  ``keep_last=N``
    retains only the N newest ticks of this stage."""
    tree = {"params": stage_params}
    if opt_state is not None:
        tree["opt"] = opt_state
    meta = dict(metadata or {})
    meta.setdefault("stage", k)
    meta.setdefault("tick", int(tick))
    return save_checkpoint(stage_dir(root, k), int(tick), tree,
                           metadata=meta, keep_last=keep_last)


def restore_stage(root: str, k: int, like_params, like_opt=None, *,
                  step: Optional[int] = None, device=None):
    """Restore one stage -> ``(params, opt_state_or_None, tick)``.

    ``like_*`` supply tree structure only (live trees, or
    ``jax.ShapeDtypeStruct`` stand-ins).  ``device`` commits every restored
    leaf to that single device (the executor's pinning contract); None
    returns host arrays.

    With ``step=None`` the restore takes the newest tick that VALIDATES —
    a torn or corrupt latest checkpoint (the crash that forced this resume
    may have interrupted a save) falls back to the previous valid one, and
    the returned tick tells the executor how far to replay.  An explicit
    ``step`` stays pinned: corruption there raises."""
    d = stage_dir(root, k)
    like = {"params": like_params}
    if like_opt is not None:
        like["opt"] = like_opt
    if step is None:
        try:
            tree, tick = restore_latest_valid(d, like, shardings=device)
        except FileNotFoundError:
            raise FileNotFoundError(
                f"no checkpoints for stage {k} under {root}") from None
        return tree["params"], tree.get("opt"), tick
    tick = int(step)
    tree = restore_checkpoint(d, like, step=tick, shardings=device)
    return tree["params"], tree.get("opt"), tick


def stage_ticks(root: str, n_stages: int) -> List[Optional[int]]:
    """Latest checkpointed tick per stage (None where a stage has none) —
    the independent step counters, read without loading any arrays."""
    return [latest_step(stage_dir(root, k)) for k in range(n_stages)]


def load_stage_params(root: str, like_stage_params: Sequence, *,
                      step: Optional[int] = None,
                      devices: Optional[Sequence] = None) -> List[Any]:
    """All stages' params (no optimizer state), each from its own latest —
    or ``step``-pinned — manifest."""
    out = []
    for k, like in enumerate(like_stage_params):
        dev = devices[k] if devices is not None else None
        params, _, _ = restore_stage(root, k, like, step=step, device=dev)
        out.append(params)
    return out


def join_from_checkpoints(root: str, like_stage_params: Sequence,
                          join_fn: Callable[[List[Any]], Any], *,
                          step: Optional[int] = None):
    """Rebuild the full network from per-stage checkpoints (paper: "the
    partitions can be joined after this stage, to use the network").

    ``join_fn`` is the backend's joiner (``MLPBackend.join`` /
    ``LMBackend.join`` / ``partial(partition.join_stage_params, cfg,
    plan)``).  For staged serving, skip the join and pass
    ``load_stage_params`` output to ``serve.Engine(plan=, stage_params=)``
    directly."""
    return join_fn(load_stage_params(root, like_stage_params, step=step))
