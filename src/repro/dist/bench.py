"""Sequential-vs-concurrent stage-tick benchmark (the BENCH_4 rows).

Forces 8 host devices at import (so it must run in its own process — \
``benchmarks/run.py --only dist`` shells out here), then times the SAME
``StageExecutor`` tick under two placements per config:

* seq  — every stage explicitly packed onto device 0 (the pre-dist
         behavior: one device's worth of compute per tick);
* conc — stages round-robined across the forced host devices, all steps
         dispatched per tick with no host sync (XLA overlaps them).

On this 2-core CPU container the forced "devices" share cores, so conc/seq
wall-clock documents dispatch-overlap structure rather than an 8x win; on
real multi-accelerator hosts the same placement is the paper's Fig.-5
simultaneity.  Per-device byte loads come from ``placement``'s estimate —
the memory the plan actually pins per device.

Usage:  PYTHONPATH=src python -m repro.dist.bench [--ticks 3]
Prints one JSON object: {"rows": [{name, us, derived}...], "devices": N}.
"""
import os

# same contract as mesh.force_host_device_count (not imported — this must
# run before anything that could touch jax): an outer XLA_FLAGS export wins
if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402


def _time_ticks(make_ex, n_warm: int, n_timed: int) -> float:
    ex = make_ex()
    ex.run(n_warm)
    jax.block_until_ready(ex.params)
    t0 = time.perf_counter()
    ex.run(n_warm + n_timed)
    jax.block_until_ready(ex.params)
    return (time.perf_counter() - t0) / n_timed * 1e6   # us per tick


def _loads(placement, stage_bytes):
    per_dev = [0] * placement.n_devices
    for k, a in enumerate(placement.assignments):
        per_dev[a] += stage_bytes[k]
    return per_dev


def bench_mlp(n_ticks: int):
    from repro.data.images import emnist_like
    from repro.dist import StageExecutor, estimate_stage_bytes
    from repro.dist import placement as P
    from repro.models import mlp as MLP
    from repro.train import MLPBackend, StageSpec, TrainSpec
    from repro.train.backends import balanced_bounds, make_optimizer_for

    n_stages, n_warm = 4, 1
    cfg = MLP.MLPConfig()
    data = emnist_like(n_train=4096, n_test=128, seed=0, noise=0.5)
    spec = TrainSpec(batch_size=256, kappa=10.0, n_stages=n_stages,
                     stages=tuple(StageSpec(epochs=n_warm + n_ticks, lr=0.01)
                                  for _ in range(n_stages)))
    be = MLPBackend(cfg, data, spec, bounds=balanced_bounds(cfg, n_stages))
    params = MLP.init_params(cfg, jax.random.PRNGKey(0))
    sils = be.make_sils(jax.random.PRNGKey(1), spec.kappa)
    sp = be.split(params)
    hps = [spec.stage(k) for k in range(n_stages)]
    sbytes = [estimate_stage_bytes(sp[k], hps[k].optimizer)
              for k in range(n_stages)]

    def make(plan):
        opts = [make_optimizer_for(hp, spec) for hp in hps]
        return StageExecutor(be, plan, sp, sils, opts, hps, shuffle=False)

    seq = P.explicit([0] * n_stages)
    conc = P.round_robin(n_stages)
    us_seq = _time_ticks(lambda: make(seq), n_warm, n_ticks)
    us_conc = _time_ticks(lambda: make(conc), n_warm, n_ticks)
    loads = _loads(conc, sbytes)
    return [
        ("dist_parallel_mlp_seq_tick", us_seq,
         f"stages={n_stages};devices=1"),
        ("dist_parallel_mlp_conc_tick", us_conc,
         f"stages={n_stages};devices={conc.n_devices};"
         f"vs_seq={us_seq/us_conc:.2f}x;"
         f"per_device_bytes={'/'.join(str(b) for b in loads if b)}"),
    ]


def bench_lm(n_ticks: int):
    from repro.configs import get
    from repro.core import partition
    from repro.dist import StageExecutor, estimate_stage_bytes
    from repro.dist import placement as P
    from repro.models import model as M
    from repro.train import LMBackend, StageSpec, TrainSpec
    from repro.train.backends import make_optimizer_for

    n_stages, n_warm = 2, 1
    cfg = get("qwen2-1.5b", smoke=True)
    plan = partition.make_plan(cfg, n_stages)

    def batch_fn(i):
        k = jax.random.PRNGKey(1000 + i)
        toks = jax.random.randint(k, (4, 64), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": toks}

    spec = TrainSpec(n_stages=n_stages, kappa=1.0,
                     stages=tuple(StageSpec(steps=n_warm + n_ticks, lr=1e-3,
                                            optimizer="adamw")
                                  for _ in range(n_stages)))
    be = LMBackend(cfg, plan, batch_fn, spec)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    sils = be.make_sils(jax.random.PRNGKey(1), spec.kappa)
    sp = be.split(params)
    hps = [spec.stage(k) for k in range(n_stages)]
    sbytes = [estimate_stage_bytes(sp[k], hps[k].optimizer)
              for k in range(n_stages)]

    def make(pl):
        opts = [make_optimizer_for(hp, spec) for hp in hps]
        return StageExecutor(be, pl, sp, sils, opts, hps)

    seq = P.explicit([0] * n_stages)
    conc = P.round_robin(n_stages)
    us_seq = _time_ticks(lambda: make(seq), n_warm, n_ticks)
    us_conc = _time_ticks(lambda: make(conc), n_warm, n_ticks)
    loads = _loads(conc, sbytes)
    return [
        ("dist_parallel_lm_seq_tick", us_seq,
         f"stages={n_stages};devices=1"),
        ("dist_parallel_lm_conc_tick", us_conc,
         f"stages={n_stages};devices={conc.n_devices};"
         f"vs_seq={us_seq/us_conc:.2f}x;"
         f"per_device_bytes={'/'.join(str(b) for b in loads if b)}"),
    ]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ticks", type=int, default=3,
                    help="timed ticks per measurement (1 extra for compile)")
    args = ap.parse_args(argv)
    rows = bench_mlp(args.ticks) + bench_lm(args.ticks)
    print(json.dumps({
        "devices": len(jax.devices()),
        "rows": [{"name": n, "us": us, "derived": d} for n, us, d in rows],
    }, indent=1))


if __name__ == "__main__":
    main()
