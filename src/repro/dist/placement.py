"""Stage -> device placement plans.

A ``PlacementPlan`` is the static answer to "which device trains partition
k" — the part the model-parallelism literature calls the hard part of
partitioned training (placement + per-partition scheduling).  Three
strategies:

* ``round_robin``     — stage k on device k mod D (the load-oblivious
                        default; exact when stages are balanced, which
                        ``partition.make_plan`` aims for).
* ``explicit``        — caller-chosen assignment (reproduce a known-good
                        layout, or co-locate stages deliberately).
* ``memory_balanced`` — greedy LPT packing by per-stage byte estimates
                        (params + optimizer slots), the same byte model
                        ``launch/dryrun.py`` reports per PNN stage.  Use
                        when stages are uneven (embedding-heavy stage 0,
                        unembedding-heavy last stage) or when D < stages.

``devices`` entries are opaque to this module — real ``jax.Device`` objects
in production, any hashable stand-ins (ints) in pure planning/tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence, Tuple, Union

# optimizer-state slots per param (fp32 each), for the byte estimate —
# owned by the shared repro.plan cost model (single source of truth with
# the dryrun tables and the auto-partitioner's searcher).
from repro.plan.costs import OPT_SLOTS as _OPT_SLOTS


@dataclass(frozen=True)
class PlacementPlan:
    """``assignments[k]`` is the ordinal (into ``devices``) of the device
    that owns stage k's params, optimizer state, and step program."""
    assignments: Tuple[int, ...]
    devices: Tuple[Any, ...]
    strategy: str = "explicit"
    loads: Tuple[int, ...] = ()    # per-device byte estimate (memory plans)

    @property
    def n_devices(self) -> int:
        return len(self.devices)

    def device_for(self, k: int):
        return self.devices[self.assignments[k]]

    def validate(self, n_stages: int) -> "PlacementPlan":
        if len(self.assignments) != n_stages:
            raise ValueError(f"plan places {len(self.assignments)} stages; "
                             f"the backend has {n_stages}")
        if not self.devices:
            raise ValueError("plan has no devices")
        bad = [a for a in self.assignments
               if not 0 <= a < len(self.devices)]
        if bad:
            raise ValueError(f"assignments {bad} out of range for "
                             f"{len(self.devices)} devices")
        return self

    def describe(self) -> str:
        per_dev = {}
        for k, a in enumerate(self.assignments):
            per_dev.setdefault(a, []).append(k)
        parts = [f"dev{a}<-stages{v}" for a, v in sorted(per_dev.items())]
        return f"{self.strategy}: " + " ".join(parts)


def _default_devices(devices):
    if devices is not None:
        return tuple(devices)
    import jax
    return tuple(jax.devices())


def round_robin(n_stages: int, devices: Optional[Sequence] = None
                ) -> PlacementPlan:
    devs = _default_devices(devices)
    return PlacementPlan(tuple(k % len(devs) for k in range(n_stages)),
                         devs, strategy="round_robin").validate(n_stages)


def explicit(assignments: Sequence[int], devices: Optional[Sequence] = None
             ) -> PlacementPlan:
    devs = _default_devices(devices)
    plan = PlacementPlan(tuple(int(a) for a in assignments), devs,
                         strategy="explicit")
    return plan.validate(len(assignments))


def memory_balanced(stage_bytes: Sequence[int],
                    devices: Optional[Sequence] = None) -> PlacementPlan:
    """Greedy LPT bin packing: place stages largest-first onto the device
    with the least byte load so far.  Deterministic (ties break toward the
    lower stage index / lower device ordinal); max per-device load is never
    worse than round-robin's."""
    devs = _default_devices(devices)
    loads = [0] * len(devs)
    assignments = [0] * len(stage_bytes)
    order = sorted(range(len(stage_bytes)),
                   key=lambda k: (-int(stage_bytes[k]), k))
    for k in order:
        a = min(range(len(devs)), key=lambda d: (loads[d], d))
        assignments[k] = a
        loads[a] += int(stage_bytes[k])
    plan = PlacementPlan(tuple(assignments), devs, strategy="memory",
                         loads=tuple(loads))
    return plan.validate(len(stage_bytes))


# --------------------------------------------------------------------------
# byte estimates (the dryrun/hlo_analysis per-stage memory model)
# --------------------------------------------------------------------------

def tree_param_bytes(tree, itemsize: Optional[int] = None) -> int:
    """Bytes of a param tree from shapes+dtypes alone (delegates to the
    shared ``repro.plan`` cost model; see its docstring)."""
    from repro.plan.costs import tree_param_bytes as _tpb
    return _tpb(tree, itemsize)


def estimate_stage_bytes(stage_params, optimizer: str = "sgdm") -> int:
    """Resident bytes of one training stage: params + fp32 optimizer slots
    (delegates to ``repro.plan.costs.estimate_stage_bytes`` — the same
    numbers ``launch/dryrun.py --mode pnn`` and the auto-partitioner's
    searcher use, so packing and boundary search can never disagree)."""
    from repro.plan.costs import estimate_stage_bytes as _esb
    return _esb(stage_params, optimizer)


def resolve(plan: Union[PlacementPlan, str], n_stages: int, *,
            devices: Optional[Sequence] = None,
            stage_bytes: Optional[Union[Sequence[int], Callable]] = None
            ) -> PlacementPlan:
    """Turn a plan-or-strategy-name into a validated ``PlacementPlan``.

    ``stage_bytes`` feeds the ``"memory"`` strategy: a byte list, or a
    zero-arg callable producing one (deferred so the estimate runs only
    when that strategy is actually chosen)."""
    if isinstance(plan, PlacementPlan):
        return plan.validate(n_stages)
    if plan == "round_robin":
        return round_robin(n_stages, devices)
    if plan == "memory":
        if stage_bytes is None:
            raise ValueError("memory placement needs stage_bytes")
        sizes = stage_bytes() if callable(stage_bytes) else stage_bytes
        return memory_balanced(sizes, devices)
    if isinstance(plan, (list, tuple)):
        return explicit(plan, devices)
    raise ValueError(f"unknown placement plan {plan!r}; expected a "
                     "PlacementPlan, 'round_robin', 'memory', or an "
                     "explicit assignment sequence")
