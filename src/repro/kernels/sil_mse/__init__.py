from .ops import sil_mse  # noqa: F401
