"""Pallas TPU fused SIL-MSE loss (+ activation gradient).

For LM-scale PNN the synthetic target ``SIL[:, y_t]`` per token is a gathered
column of a (d_model, vocab) table; materializing the gathered (T, d) target
in HBM costs a full activation tensor.  This kernel uses **scalar-prefetched
labels to drive the SIL BlockSpec index map**: grid step (it, i, id) DMAs
exactly the (BD, 1) column SIL[id*BD:(id+1)*BD, labels[it*BT+i]] into VMEM —
the gathered target never exists in HBM.

Outputs: per-token-block partial loss sums (summed on the host side of the
call) and the activation gradient, fused in one pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.plan import (BlockPlan, KernelPlan, ScalarPrefetchPlan,
                                as_block_spec)

DEFAULT_BT = 128
DEFAULT_BD = 512


def plan(t, d, m, *, bt=DEFAULT_BT, bd=DEFAULT_BD,
         dtype="float32") -> KernelPlan:
    """Launch geometry for ``sil_mse_fwd_tpu``: act:(t,d), sil:(d,m),
    labels:(t,) int in [0, m).  The scalar-prefetched labels drive the SIL
    column index map — the gathered target never exists in HBM."""
    bt_ = min(bt, t)
    bd_ = min(bd, d)
    t_p = t + (-t) % bt_
    d_p = d + (-d) % bd_
    nt = t_p // bt_
    nd = d_p // bd_
    return KernelPlan(
        family="sil_mse", entry="sil_mse",
        grid=(nt, bt_, nd),
        scalar_prefetch=(
            ScalarPrefetchPlan("labels", (t_p,), "int32", max_value=m - 1),
        ),
        inputs=(
            BlockPlan("act", (1, bd_), lambda it, i, idd, lab_ref:
                      (it * bt_ + i, idd), (t_p, d_p), dtype),
            BlockPlan("sil", (bd_, 1), lambda it, i, idd, lab_ref:
                      (idd, lab_ref[it * bt_ + i]), (d_p, m), "float32"),
        ),
        outputs=(
            BlockPlan("partial_loss", (1,), lambda it, i, idd, lab_ref:
                      (it,), (nt,), "float32"),
            BlockPlan("grad", (1, bd_), lambda it, i, idd, lab_ref:
                      (it * bt_ + i, idd), (t_p, d_p), dtype),
        ),
    )


def _sil_kernel(lab_ref, act_ref, sil_ref, loss_ref, grad_ref, *, bt, bd,
                t_total, scale):
    it = pl.program_id(0)
    i = pl.program_id(1)
    idd = pl.program_id(2)

    @pl.when((i == 0) & (idd == 0))
    def _init():
        loss_ref[0] = jnp.zeros_like(loss_ref[0])

    a = act_ref[0].astype(jnp.float32)            # (BD,)
    tgt = sil_ref[:, 0].astype(jnp.float32)       # (BD,)
    row = it * bt + i
    valid = row < t_total
    diff = jnp.where(valid, a - tgt, 0.0)
    loss_ref[0] += jnp.sum(diff * diff)
    grad_ref[0] = (scale * diff).astype(grad_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "bd", "interpret"))
def sil_mse_fwd_tpu(act, sil, labels, *, bt=DEFAULT_BT, bd=DEFAULT_BD,
                    interpret=None):
    """act: (T, d); sil: (d, M); labels: (T,) -> (mean loss, dloss/dact)."""
    t, d = act.shape
    m = sil.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kp = plan(t, d, m, bt=bt, bd=bd, dtype=str(act.dtype))
    bt_ = kp.grid[1]
    bd_ = kp.inputs[0].block_shape[1]
    pad_t = kp.inputs[0].array_shape[0] - t
    pad_d = kp.inputs[0].array_shape[1] - d
    a = jnp.pad(act, ((0, pad_t), (0, pad_d))) if (pad_t or pad_d) else act
    s = jnp.pad(sil, ((0, pad_d), (0, 0))) if pad_d else sil
    lab = jnp.pad(labels, (0, pad_t)).astype(jnp.int32) if pad_t \
        else labels.astype(jnp.int32)
    nt = kp.grid[0]
    scale = 2.0 / (t * d)

    kernel = functools.partial(_sil_kernel, bt=bt_, bd=bd_, t_total=t,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(kp.scalar_prefetch),
        grid=kp.grid,
        in_specs=[as_block_spec(bp) for bp in kp.inputs],
        out_specs=[as_block_spec(bp) for bp in kp.outputs],
    )
    partial_loss, grad = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nt,), jnp.float32),
            jax.ShapeDtypeStruct(a.shape, act.dtype),
        ],
        interpret=interpret,
    )(lab, a, s)
    loss = partial_loss.sum() / (t * d)
    return loss, grad[:t, :d]


@functools.partial(jax.jit, static_argnames=("bt", "bd", "interpret"))
def sil_mse_tpu(act, sil, labels, *, bt=DEFAULT_BT, bd=DEFAULT_BD,
                interpret=None):
    loss, _ = sil_mse_fwd_tpu(act, sil, labels, bt=bt, bd=bd,
                              interpret=interpret)
    return loss
