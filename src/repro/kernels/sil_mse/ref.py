"""Pure-jnp oracle for the fused SIL-MSE loss (paper Eq. 1 target, MSE loss).

loss = mean_t mean_i ( act[t, i] - SIL[i, y_t] )^2

The fused kernel never materializes the gathered (T, d) synthetic target in
HBM; this reference does (it is the oracle, not the production path).
Also provides the analytic gradient wrt the activations so the kernel's
custom_vjp can be checked.
"""
from __future__ import annotations

import jax.numpy as jnp


def sil_mse(act, sil, labels):
    """act: (T, d) boundary activations; sil: (d, M); labels: (T,) int.

    Returns scalar mean-squared error (paper's left-partition loss).
    """
    target = sil[:, labels].T.astype(jnp.float32)  # (T, d)
    diff = act.astype(jnp.float32) - target
    return jnp.mean(diff * diff)


def sil_mse_grad_act(act, sil, labels):
    """d loss / d act  — (T, d)."""
    t, d = act.shape
    target = sil[:, labels].T.astype(jnp.float32)
    return (2.0 / (t * d)) * (act.astype(jnp.float32) - target)
