"""Fused SIL-MSE loss with custom VJP; Pallas on TPU, jnp reference elsewhere
(``REPRO_FORCE_REF=1`` pins the reference on TPU).  Activations may be in
the policy's compute dtype — both backends difference and reduce in fp32 and
return a fp32 scalar; the activation gradient comes back in the activation's
dtype."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import decide

from . import ref


@jax.custom_vjp
def sil_mse(act, sil, labels):
    return _fwd_impl(act, sil, labels)


def _fwd_impl(act, sil, labels):
    if decide("sil_mse", act.shape, act.dtype).use_pallas:
        from .kernel import sil_mse_tpu
        return sil_mse_tpu(act, sil, labels)
    return ref.sil_mse(act, sil, labels)


def _fwd(act, sil, labels):
    return _fwd_impl(act, sil, labels), (act, sil, labels)


def _bwd(res, g):
    act, sil, labels = res
    gact = (ref.sil_mse_grad_act(act, sil, labels) * g).astype(act.dtype)
    # SIL is a frozen random table (not trained) and labels are ints.
    gsil = jnp.zeros_like(sil)
    glab = jnp.zeros(labels.shape, dtype=jax.dtypes.float0)
    return gact, gsil, glab


sil_mse.defvjp(_fwd, _bwd)
