"""Shared backend dispatch for the Pallas kernels.

Every kernel family routes through these two predicates: Pallas on TPU,
pure-jnp reference elsewhere, with ``REPRO_FORCE_REF=1`` pinning the
reference even on TPU so bf16-in/fp32-accum numerics can be cross-checked
against the same math on both paths (tests/test_precision.py).
"""
from __future__ import annotations

import os

import jax


def on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def force_ref() -> bool:
    return os.environ.get("REPRO_FORCE_REF", "") == "1"


def use_pallas() -> bool:
    return on_tpu() and not force_ref()
