"""Shared backend dispatch for the Pallas kernels.

Every kernel family routes through ``decide()``: Pallas on TPU, pure-jnp
reference elsewhere, with ``REPRO_FORCE_REF=1`` pinning the reference even
on TPU so bf16-in/fp32-accum numerics can be cross-checked against the same
math on both paths (tests/test_precision.py).

Decisions are cached by (family, shape, dtype, backend, force) — the ops
wrappers call in from inside jit traces, so the predicate chain must stay
cheap — and a fallback to the reference path is logged ONCE per (family,
reason) instead of per call.
"""
from __future__ import annotations

import functools
import logging
import os
from dataclasses import dataclass
from typing import Optional, Tuple

import jax

log = logging.getLogger("repro.kernels")


@dataclass(frozen=True)
class Decision:
    """One resolved dispatch: which path a kernel family takes and why."""
    family: str
    use_pallas: bool
    reason: str
    backend: str


def on_tpu() -> bool:
    return _default_backend() == "tpu"


def force_ref() -> bool:
    return os.environ.get("REPRO_FORCE_REF", "") == "1"


def _default_backend() -> str:
    try:
        return jax.default_backend()
    except Exception:  # pragma: no cover
        return "cpu"


@functools.lru_cache(maxsize=1024)
def _decide(family: str, shape: Optional[Tuple[int, ...]],
            dtype: Optional[str], backend: str, force: bool) -> Decision:
    if force:
        return Decision(family, False, "REPRO_FORCE_REF=1", backend)
    if backend != "tpu":
        return Decision(family, False,
                        f"no Pallas lowering on backend={backend!r}", backend)
    return Decision(family, True, "tpu", backend)


_logged_fallbacks = set()


def decide(family: str, shape=None, dtype=None, *, backend: Optional[str]
           = None, force: Optional[bool] = None) -> Decision:
    """Resolve (and cache) the dispatch for one kernel call site.

    ``force`` / ``backend`` override the environment for introspection (the
    ``repro.analysis`` dispatch-symmetry rule probes both paths without
    flipping env vars); callers inside jit traces pass the traced operand's
    ``shape`` / ``dtype`` so distinct workloads get distinct cache rows."""
    if backend is None:
        # on_tpu() is the patchable seam tests use to simulate a TPU host.
        backend = "tpu" if on_tpu() else _default_backend()
    force = force_ref() if force is None else force
    d = _decide(family, tuple(shape) if shape is not None else None,
                str(dtype) if dtype is not None else None, backend,
                bool(force))
    if not d.use_pallas:
        key = (family, d.reason)
        if key not in _logged_fallbacks:
            _logged_fallbacks.add(key)
            log.info("kernels.%s -> reference path (%s)", family, d.reason)
    return d


def use_pallas() -> bool:
    """Back-compat predicate (family-agnostic dispatch)."""
    return decide("_any").use_pallas


def cache_clear() -> None:
    """Reset the decision cache + the log-once set (tests flip env vars)."""
    _decide.cache_clear()
    _logged_fallbacks.clear()


def cache_info():
    return _decide.cache_info()
