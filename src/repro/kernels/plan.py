"""Declarative kernel launch geometry (grid / block / scratch plans).

Every Pallas kernel family exposes a pure ``plan(...)`` function that
computes the launch geometry — grid, per-operand block shapes + index maps
over the PADDED array shapes, scalar-prefetch operands, and scratch
accumulators — as plain data, *before* any ``pallas_call`` is constructed.
The ``*_tpu`` entry points consume the plan (one source of truth for the
blocking arithmetic), and ``repro.analysis.rules_pallas`` validates the same
plans statically for every arch config: divisibility, index-map bounds at
the grid corners, and fp32 accumulator dtypes — without executing a kernel.

This module is importable without Pallas; the ``as_block_spec`` /
``as_scratch`` converters import it lazily at call time.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Tuple


@dataclass(frozen=True)
class BlockPlan:
    """One pallas_call operand: block tiling of a (padded) array.

    ``index_map`` takes the grid indices (plus one ref argument per
    scalar-prefetch operand, appended) and returns block-unit indices."""
    name: str
    block_shape: Tuple[int, ...]
    index_map: Callable[..., Tuple[int, ...]]
    array_shape: Tuple[int, ...]
    dtype: str = "float32"
    memory_space: str = "vmem"         # "vmem" | "smem"


@dataclass(frozen=True)
class ScratchPlan:
    """VMEM scratch buffer; ``accumulator=True`` marks running state that
    must accumulate in fp32 regardless of the operand compute dtype."""
    name: str
    shape: Tuple[int, ...]
    dtype: str = "float32"
    accumulator: bool = False


@dataclass(frozen=True)
class ScalarPrefetchPlan:
    """A scalar-prefetched operand whose values drive index maps.

    ``max_value`` is the inclusive upper bound of the values the operand can
    legally hold (e.g. vocab-1 for label ids) — the static checker evaluates
    index maps at both 0 and ``max_value`` to bound the DMA addresses."""
    name: str
    shape: Tuple[int, ...]
    dtype: str = "int32"
    max_value: int = 0


@dataclass(frozen=True)
class KernelPlan:
    """The full launch geometry of one pallas_call."""
    family: str                        # kernels.FAMILIES key
    entry: str                         # entry-point name within the family
    grid: Tuple[int, ...]
    inputs: Tuple[BlockPlan, ...]
    outputs: Tuple[BlockPlan, ...]
    scratch: Tuple[ScratchPlan, ...] = ()
    scalar_prefetch: Tuple[ScalarPrefetchPlan, ...] = ()

    @property
    def blocks(self) -> Tuple[BlockPlan, ...]:
        return self.inputs + self.outputs


def as_block_spec(bp: BlockPlan):
    """BlockPlan -> pl.BlockSpec (lazy Pallas import)."""
    from jax.experimental import pallas as pl
    if bp.memory_space == "smem":
        from jax.experimental.pallas import tpu as pltpu
        return pl.BlockSpec(bp.block_shape, bp.index_map,
                            memory_space=pltpu.SMEM)
    return pl.BlockSpec(bp.block_shape, bp.index_map)


def as_scratch(sp: ScratchPlan):
    """ScratchPlan -> pltpu.VMEM scratch shape (lazy Pallas import)."""
    import jax.numpy as jnp
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(sp.shape, jnp.dtype(sp.dtype))
