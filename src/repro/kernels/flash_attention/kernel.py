"""Pallas TPU flash attention (forward) with causal + sliding-window masking.

Blocking: grid (batch, q_heads, Sq/BQ, Sk/BK); the KV axis is the minor-most
grid dim, iterated sequentially per TPU core, so the online-softmax running
state (m, l, acc) lives in VMEM scratch across KV steps.  Q/K/V blocks are
(BQ, D) / (BK, D) VMEM tiles (BQ = BK = 128, MXU-aligned; head_dim of the
assigned archs is 64..384 so a (128, D) tile is <= 192 KiB).

GQA is handled in the index map: query head h reads KV head h // (H // KV) —
KV is never materialized per-Q-head.  Validated against ref.py in
interpret mode (tests/test_kernels.py sweeps shapes and dtypes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, window, bq, bk, sk):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (BQ, BK)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < sk
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_tpu(q, k, v, *, causal=True, window=0, bq=DEFAULT_BQ,
                        bk=DEFAULT_BK, interpret=None):
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D) -> (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = d ** -0.5
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    qt = q.transpose(0, 2, 1, 3)     # (B, H, Sq, D)
    kt = k.transpose(0, 2, 1, 3)     # (B, KV, Sk, D)
    vt = v.transpose(0, 2, 1, 3)
    bq_ = min(bq, sq)
    bk_ = min(bk, sk)
    pad_q = (-sq) % bq_
    pad_k = (-sk) % bk_
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = qt.shape[2] // bq_
    nk = kt.shape[2] // bk_

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq_, bk=bk_, sk=sk)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq_, d),
                         lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk_, d),
                         lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, bk_, d),
                         lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nq * bq_, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_,), jnp.float32),       # m
            pltpu.VMEM((bq_,), jnp.float32),       # l
            pltpu.VMEM((bq_, d), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :sq]
    return out.transpose(0, 2, 1, 3)
