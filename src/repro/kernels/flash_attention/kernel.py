"""Pallas TPU flash attention (forward) with causal + sliding-window masking.

Blocking: grid (batch, q_heads, Sq/BQ, Sk/BK); the KV axis is the minor-most
grid dim, iterated sequentially per TPU core, so the online-softmax running
state (m, l, acc) lives in VMEM scratch across KV steps.  Q/K/V blocks are
(BQ, D) / (BK, D) VMEM tiles (BQ = BK = 128, MXU-aligned; head_dim of the
assigned archs is 64..384 so a (128, D) tile is <= 192 KiB).

GQA is handled in the index map: query head h reads KV head h // (H // KV) —
KV is never materialized per-Q-head.  Validated against ref.py in
interpret mode (tests/test_kernels.py sweeps shapes and dtypes).

``decode_attention_tpu`` is the single-token serving variant: grid
(batch, kv_head, Lc/BK), one program per KV head attending all of its G
query heads at once (the (G, D) q tile rides along the whole cache sweep),
with the per-request position vector prefetched into SMEM so ragged
continuous batches mask their own history.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.plan import (BlockPlan, KernelPlan, ScalarPrefetchPlan,
                                ScratchPlan, as_block_spec, as_scratch)

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def plan(b, sq, sk, h, kv, d, *, bq=DEFAULT_BQ, bk=DEFAULT_BK,
         dtype="float32") -> KernelPlan:
    """Launch geometry for ``flash_attention_tpu`` over logical shapes
    q:(b,sq,h,d), k/v:(b,sk,kv,d).  Arrays are transposed to head-major and
    padded to block multiples before the call; the plan describes those
    padded layouts."""
    g = h // kv
    bq_ = min(bq, sq)
    bk_ = min(bk, sk)
    sq_p = sq + (-sq) % bq_
    sk_p = sk + (-sk) % bk_
    nq = sq_p // bq_
    nk = sk_p // bk_
    kv_map = lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)  # noqa: E731
    return KernelPlan(
        family="flash_attention", entry="flash_attention",
        grid=(b, h, nq, nk),
        inputs=(
            BlockPlan("q", (1, 1, bq_, d),
                      lambda b_, h_, iq, ik: (b_, h_, iq, 0),
                      (b, h, sq_p, d), dtype),
            BlockPlan("k", (1, 1, bk_, d), kv_map, (b, kv, sk_p, d), dtype),
            BlockPlan("v", (1, 1, bk_, d), kv_map, (b, kv, sk_p, d), dtype),
        ),
        outputs=(
            BlockPlan("o", (1, 1, bq_, d),
                      lambda b_, h_, iq, ik: (b_, h_, iq, 0),
                      (b, h, sq_p, d), dtype),
        ),
        scratch=(
            ScratchPlan("m", (bq_,), "float32", accumulator=True),
            ScratchPlan("l", (bq_,), "float32", accumulator=True),
            ScratchPlan("acc", (bq_, d), "float32", accumulator=True),
        ),
    )


def decode_plan(b, lc, h, kv, d, *, bk=DEFAULT_BK,
                dtype="float32") -> KernelPlan:
    """Launch geometry for ``decode_attention_tpu``: q:(b,1,h,d) over a
    (b,lc,kv,d) cache, with the per-request position vector in SMEM."""
    g = h // kv
    bk_ = min(bk, lc)
    lc_p = lc + (-lc) % bk_
    nk = lc_p // bk_
    return KernelPlan(
        family="flash_attention", entry="decode_attention",
        grid=(b, kv, nk),
        inputs=(
            BlockPlan("pos", (1,), lambda b_, kv_, ik: (b_,), (b,),
                      "int32", memory_space="smem"),
            BlockPlan("q", (1, 1, g, d), lambda b_, kv_, ik: (b_, kv_, 0, 0),
                      (b, kv, g, d), dtype),
            BlockPlan("k", (1, 1, bk_, d),
                      lambda b_, kv_, ik: (b_, kv_, ik, 0),
                      (b, kv, lc_p, d), dtype),
            BlockPlan("v", (1, 1, bk_, d),
                      lambda b_, kv_, ik: (b_, kv_, ik, 0),
                      (b, kv, lc_p, d), dtype),
        ),
        outputs=(
            BlockPlan("o", (1, 1, g, d), lambda b_, kv_, ik: (b_, kv_, 0, 0),
                      (b, kv, g, d), dtype),
        ),
        scratch=(
            ScratchPlan("m", (g,), "float32", accumulator=True),
            ScratchPlan("l", (g,), "float32", accumulator=True),
            ScratchPlan("acc", (g, d), "float32", accumulator=True),
        ),
    )


def paged_decode_plan(b, nb, bs, h, kv, d, *, n_blocks,
                      dtype="float32") -> KernelPlan:
    """Launch geometry for ``paged_decode_attention_tpu``: q:(b,1,h,d) over
    (n_blocks, bs, kv, d) physical K/V blocks, gathered through a
    scalar-prefetched (b, nb) block table.

    The grid's minor axis walks the request's nb LOGICAL blocks; the K/V
    index maps read the prefetched table to aim each DMA at the mapped
    PHYSICAL block — the gathered logical cache never exists in HBM.  The
    static checker bounds the maps with the table filled at 0 and at
    ``n_blocks - 1`` (the garbage block and the last physical block)."""
    g = h // kv
    return KernelPlan(
        family="flash_attention", entry="paged_decode_attention",
        grid=(b, kv, nb),
        scalar_prefetch=(
            ScalarPrefetchPlan("block_tables", (b, nb), "int32",
                               max_value=n_blocks - 1),
        ),
        inputs=(
            BlockPlan("pos", (1,), lambda b_, kv_, ik, bt_ref: (b_,), (b,),
                      "int32", memory_space="smem"),
            BlockPlan("q", (1, 1, g, d),
                      lambda b_, kv_, ik, bt_ref: (b_, kv_, 0, 0),
                      (b, kv, g, d), dtype),
            BlockPlan("k", (1, 1, bs, d),
                      lambda b_, kv_, ik, bt_ref: (bt_ref[b_, ik], kv_, 0, 0),
                      (n_blocks, kv, bs, d), dtype),
            BlockPlan("v", (1, 1, bs, d),
                      lambda b_, kv_, ik, bt_ref: (bt_ref[b_, ik], kv_, 0, 0),
                      (n_blocks, kv, bs, d), dtype),
        ),
        outputs=(
            BlockPlan("o", (1, 1, g, d),
                      lambda b_, kv_, ik, bt_ref: (b_, kv_, 0, 0),
                      (b, kv, g, d), dtype),
        ),
        scratch=(
            ScratchPlan("m", (g,), "float32", accumulator=True),
            ScratchPlan("l", (g,), "float32", accumulator=True),
            ScratchPlan("acc", (g, d), "float32", accumulator=True),
        ),
    )


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, window, bq, bk, sk):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (BQ, BK)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < sk
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_tpu(q, k, v, *, causal=True, window=0, bq=DEFAULT_BQ,
                        bk=DEFAULT_BK, interpret=None):
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D) -> (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = d ** -0.5
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kp = plan(b, sq, sk, h, kv, d, bq=bq, bk=bk, dtype=str(q.dtype))
    bq_ = kp.inputs[0].block_shape[2]
    bk_ = kp.inputs[1].block_shape[2]
    pad_q = kp.inputs[0].array_shape[2] - sq
    pad_k = kp.inputs[1].array_shape[2] - sk

    qt = q.transpose(0, 2, 1, 3)     # (B, H, Sq, D)
    kt = k.transpose(0, 2, 1, 3)     # (B, KV, Sk, D)
    vt = v.transpose(0, 2, 1, 3)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq_, bk=bk_, sk=sk)
    out = pl.pallas_call(
        kernel,
        grid=kp.grid,
        in_specs=[as_block_spec(bp) for bp in kp.inputs],
        out_specs=as_block_spec(kp.outputs[0]),
        out_shape=jax.ShapeDtypeStruct(kp.outputs[0].array_shape, q.dtype),
        scratch_shapes=[as_scratch(sp) for sp in kp.scratch],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :sq]
    return out.transpose(0, 2, 1, 3)


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale, bk, lc):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, BK)

    # valid slots: arange(lc) <= pos (ring caches: every written slot is
    # valid once pos >= lc — same contract as ref.decode_attention)
    slot = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (slot < lc) & (slot <= pos_ref[0])
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention_tpu(q, k_cache, v_cache, pos, *, window=0,
                         bk=DEFAULT_BK, interpret=None):
    """Single-token decode over a (possibly ring-buffered) KV cache.

    q: (B, 1, H, D); caches: (B, Lc, KV, D); pos: scalar int32 or per-request
    (B,) vector.  `window` only affects the cache LAYOUT (ring), not the
    validity mask, so it is accepted for signature parity with the ref.
    Returns (B, 1, H, D).
    """
    b, _, h, d = q.shape
    lc, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = d ** -0.5
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kp = decode_plan(b, lc, h, kv, d, bk=bk, dtype=str(q.dtype))
    bk_ = kp.inputs[2].block_shape[2]
    pad = kp.inputs[2].array_shape[2] - lc

    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    qt = q[:, 0].reshape(b, kv, g, d)                    # (B, KV, G, D)
    kt = k_cache.transpose(0, 2, 1, 3)                   # (B, KV, Lc, D)
    vt = v_cache.transpose(0, 2, 1, 3)
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk_, lc=lc)
    out = pl.pallas_call(
        kernel,
        grid=kp.grid,
        in_specs=[as_block_spec(bp) for bp in kp.inputs],
        out_specs=as_block_spec(kp.outputs[0]),
        out_shape=jax.ShapeDtypeStruct(kp.outputs[0].array_shape, q.dtype),
        scratch_shapes=[as_scratch(sp) for sp in kp.scratch],
        interpret=interpret,
    )(pos_b, qt, kt, vt)
    return out.reshape(b, 1, h, d)


def _paged_decode_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale, bs, lc):
    """Block-paged decode: identical online-softmax math to
    ``_decode_kernel`` — the block table is consumed entirely by the K/V
    index maps (scalar prefetch), so the kernel body only needs the grid's
    logical-block step to reconstruct slot ids."""
    del bt_ref  # routing happened in the index maps
    _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, scale=scale, bk=bs, lc=lc)


@functools.partial(jax.jit, static_argnames=("logical_len", "window",
                                             "interpret"))
def paged_decode_attention_tpu(q, k_pages, v_pages, block_tables, pos, *,
                               logical_len, window=0, interpret=None):
    """Single-token decode over a block-paged KV cache.

    q: (B, 1, H, D); k/v_pages: (NB_phys, BS, KV, D); block_tables: (B, nb)
    int32 physical block ids (garbage-padded); logical_len: true logical
    cache length (the validity mask `slot < logical_len` covers both the
    block pad and the ring modulus).  The table is scalar-prefetched so the
    per-block DMAs gather physical blocks directly — the contiguous logical
    view never materializes.  `window` only affects cache LAYOUT (ring),
    not the mask — signature parity with the ref.  Returns (B, 1, H, D).
    """
    from jax.experimental.pallas import tpu as pltpu
    b, _, h, d = q.shape
    n_blocks, bs, kv = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    nb = block_tables.shape[1]
    g = h // kv
    scale = d ** -0.5
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kp = paged_decode_plan(b, nb, bs, h, kv, d, n_blocks=n_blocks,
                           dtype=str(q.dtype))
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    qt = q[:, 0].reshape(b, kv, g, d)                    # (B, KV, G, D)
    kt = k_pages.transpose(0, 2, 1, 3)                   # (NB, KV, BS, D)
    vt = v_pages.transpose(0, 2, 1, 3)

    kernel = functools.partial(_paged_decode_kernel, scale=scale, bs=bs,
                               lc=logical_len)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(kp.scalar_prefetch),
        grid=kp.grid,
        in_specs=[as_block_spec(bp) for bp in kp.inputs],
        out_specs=as_block_spec(kp.outputs[0]),
        scratch_shapes=[as_scratch(sp) for sp in kp.scratch],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(kp.outputs[0].array_shape, q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), pos_b, qt, kt, vt)
    return out.reshape(b, 1, h, d)
