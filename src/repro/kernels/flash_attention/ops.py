"""Dispatching wrapper: Pallas flash attention on TPU, chunked-jnp elsewhere.

The dry-run lowers on the CPU backend (512 host devices), where pallas_call has
no lowering path — so model code always goes through this wrapper.
"""
from __future__ import annotations

import jax

from . import ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def flash_attention(q, k, v, *, causal=True, window=0, chunk=512):
    """Training/prefill attention. q:(B,S,H,D) k,v:(B,S,KV,D)."""
    if _on_tpu():
        from .kernel import flash_attention_tpu
        return flash_attention_tpu(q, k, v, causal=causal, window=window)
    return ref.chunked_attention(q, k, v, causal=causal, window=window, chunk=chunk)


def decode_attention(q, k_cache, v_cache, pos, *, window=0):
    """Single-token decode over a KV cache (ring-buffered if window>0)."""
    return ref.decode_attention(q, k_cache, v_cache, pos, window=window)
