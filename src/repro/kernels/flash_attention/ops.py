"""Dispatching wrapper: Pallas flash attention on TPU, chunked-jnp elsewhere.

The dry-run lowers on the CPU backend (512 host devices), where pallas_call
has no lowering path — so model code always goes through this wrapper.  Both
the training/prefill path and the single-token decode path dispatch the same
way; ``REPRO_FORCE_REF=1`` pins the reference implementation even on TPU so
the serving engine is testable against both.
"""
from __future__ import annotations

import os

import jax

from . import ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def _force_ref() -> bool:
    return os.environ.get("REPRO_FORCE_REF", "") == "1"


def flash_attention(q, k, v, *, causal=True, window=0, chunk=512):
    """Training/prefill attention. q:(B,S,H,D) k,v:(B,S,KV,D)."""
    if _on_tpu() and not _force_ref():
        from .kernel import flash_attention_tpu
        return flash_attention_tpu(q, k, v, causal=causal, window=window)
    return ref.chunked_attention(q, k, v, causal=causal, window=window, chunk=chunk)


def decode_attention(q, k_cache, v_cache, pos, *, window=0):
    """Single-token decode over a KV cache (ring-buffered if window>0)."""
    if _on_tpu() and not _force_ref():
        from .kernel import decode_attention_tpu
        return decode_attention_tpu(q, k_cache, v_cache, pos, window=window)
    return ref.decode_attention(q, k_cache, v_cache, pos, window=window)
