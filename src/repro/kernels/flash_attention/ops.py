"""Dispatching wrapper: Pallas flash attention on TPU, chunked-jnp elsewhere.

The dry-run lowers on the CPU backend (512 host devices), where pallas_call
has no lowering path — so model code always goes through this wrapper.  Both
the training/prefill path and the single-token decode path dispatch the same
way; ``REPRO_FORCE_REF=1`` pins the reference implementation even on TPU so
the serving engine is testable against both.
"""
from __future__ import annotations

from repro.kernels.dispatch import decide

from . import ref


def flash_attention(q, k, v, *, causal=True, window=0, chunk=512):
    """Training/prefill attention. q:(B,S,H,D) k,v:(B,S,KV,D).

    Inputs may be any float dtype (bf16/fp16 under a reduced-precision
    policy); both backends accumulate scores and the softmax in fp32 and
    return the input dtype."""
    if decide("flash_attention", q.shape, q.dtype).use_pallas:
        from .kernel import flash_attention_tpu
        return flash_attention_tpu(q, k, v, causal=causal, window=window)
    return ref.chunked_attention(q, k, v, causal=causal, window=window, chunk=chunk)


def decode_attention(q, k_cache, v_cache, pos, *, window=0):
    """Single-token decode over a KV cache (ring-buffered if window>0)."""
    if decide("flash_attention", k_cache.shape, q.dtype).use_pallas:
        from .kernel import decode_attention_tpu
        return decode_attention_tpu(q, k_cache, v_cache, pos, window=window)
    return ref.decode_attention(q, k_cache, v_cache, pos, window=window)


def paged_decode_attention(q, k_pages, v_pages, block_tables, pos, *,
                           logical_len, window=0):
    """Single-token decode gathering K/V through a per-request block table.

    k/v_pages: (NB_phys, BS, KV, D); block_tables: (B, nb) int32.  The Pallas
    path scalar-prefetches the table so each K/V block DMA reads the physical
    block directly; the ref path gathers the logical view and defers to
    ``decode_attention``."""
    if decide("flash_attention", k_pages.shape, q.dtype).use_pallas:
        from .kernel import paged_decode_attention_tpu
        return paged_decode_attention_tpu(
            q, k_pages, v_pages, block_tables, pos,
            logical_len=logical_len, window=window)
    return ref.paged_decode_attention(
        q, k_pages, v_pages, block_tables, pos,
        logical_len=logical_len, window=window)
