from .ops import flash_attention, decode_attention, paged_decode_attention  # noqa: F401
