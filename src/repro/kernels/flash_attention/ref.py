"""Pure-jnp oracle for the flash attention kernel.

Two entry points:

* ``chunked_attention`` — training/prefill attention (Sq == Sk), causal with an
  optional sliding window, GQA-aware, O(S * chunk) score memory.  This is also
  what model code runs on non-TPU backends (the dry-run lowers this HLO).
* ``naive_attention`` — O(S^2) direct softmax; ground truth for tests only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _gqa_expand(q, kv_heads):
    """Reshape q (B,S,H,D) -> (B,S,KV,G,D) where G = H // KV."""
    b, s, h, d = q.shape
    g = h // kv_heads
    return q.reshape(b, s, kv_heads, g, d)


def naive_attention(q, k, v, *, causal=True, window=0, scale=None):
    """Direct attention. q:(B,Sq,H,D) k,v:(B,Sk,KV,D). Returns (B,Sq,H,D)."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    qh = _gqa_expand(q, kvh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qh, kf) * scale
    sk = k.shape[1]
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # q aligned to the end of k
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, vf)
    return out.reshape(b, sq, h, d).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "chunk"))
def chunked_attention(q, k, v, *, causal=True, window=0, chunk=512, scale=None):
    """Flash-style attention: scan over KV chunks with running (m, l, acc).

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D); Sq == Sk or q aligned to end of k.
    Score memory is O(Sq * chunk) instead of O(Sq * Sk).
    """
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    chunk = min(chunk, sk)
    # pad Sk to a multiple of chunk (padded keys masked off)
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk

    qh = _gqa_expand(q, kvh).astype(jnp.float32) * scale
    kc = k.reshape(b, n_chunks, chunk, kvh, d).astype(jnp.float32)
    vc = v.reshape(b, n_chunks, chunk, kvh, d).astype(jnp.float32)
    qpos = jnp.arange(sq) + (sk - sq)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, c_idx = xs
        kpos = c_idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bskgd,btkd->bkgst", qh, kb)
        mask = kpos[None, :] < sk  # padding
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard all -inf rows
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        corr = jnp.exp(jnp.where(jnp.isinf(m), -jnp.inf, m - m_safe))
        corr = jnp.where(jnp.isnan(corr), 0.0, corr)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bkgst,btkd->bkgsd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, sq), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), dtype=jnp.float32)
    acc0 = jnp.zeros((b, kvh, g, sq, d), dtype=jnp.float32)
    kc_t = jnp.moveaxis(kc, 1, 0)
    vc_t = jnp.moveaxis(vc, 1, 0)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kc_t, vc_t, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, d)
    return out.astype(q.dtype)


def paged_decode_attention(q, k_pages, v_pages, block_tables, pos, *,
                           logical_len, window=0, scale=None):
    """Single-token decode over a block-paged KV cache.

    q: (B, 1, H, D); k/v_pages: (NB_phys, BS, KV, D) physical token blocks;
    block_tables: (B, nb) int32 physical ids per logical block (garbage-
    padded past the allocation); logical_len: the true per-request cache
    length (the ring modulus when window > 0 — storage pads up to whole
    blocks, and this slice masks the pad).  Gathers the logical view and
    reuses ``decode_attention``'s exact masking math, so paged == contiguous
    is bitwise on the gathered values.
    """
    b = q.shape[0]
    nb = block_tables.shape[1]
    bs = k_pages.shape[1]
    kc = k_pages[block_tables].reshape(
        b, nb * bs, *k_pages.shape[2:])[:, :logical_len]
    vc = v_pages[block_tables].reshape(
        b, nb * bs, *v_pages.shape[2:])[:, :logical_len]
    return decode_attention(q, kc, vc, pos, window=window, scale=scale)


def decode_attention(q, k_cache, v_cache, pos, *, window=0, scale=None):
    """Single-token decode attention over a (possibly ring-buffered) cache.

    q: (B, 1, H, D); caches: (B, Lc, KV, D); pos: int32 absolute position of
    the current token — scalar or per-request (B,) vector (ragged batches).
    Valid slots are arange(Lc) <= pos (when the cache is a ring of length
    Lc < full seq, every written slot is valid once pos >= Lc).
    """
    b, _, h, d = q.shape
    lc, kvh = k_cache.shape[1], k_cache.shape[2]
    scale = scale if scale is not None else d ** -0.5
    qh = _gqa_expand(q, kvh)[:, 0].astype(jnp.float32) * scale  # (B,KV,G,D)
    s = jnp.einsum("bkgd,btkd->bkgt", qh, k_cache.astype(jnp.float32))
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (b,))
    valid = jnp.arange(lc)[None, :] <= pos_b[:, None]           # (B, Lc)
    s = jnp.where(valid[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)
