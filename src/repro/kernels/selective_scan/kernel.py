"""Pallas TPU selective scan (Mamba recurrence), time-chunked.

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * u_t) * B_t ;  y_t = C_t . h_t

Blocking: grid (batch, d_inner/BD, S/CHUNK) with the time-chunk axis
minor-most (sequential), so the (BD, N) recurrent state stays resident in
VMEM scratch across chunks.  Within a chunk the recurrence is a fori_loop of
vector ops over CHUNK steps — the state never round-trips to HBM, which is
the entire point of the kernel (the jnp reference re-materializes
(B, chunk, BD, N) tensors per chunk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.plan import (BlockPlan, KernelPlan, ScratchPlan,
                                as_block_spec, as_scratch)

DEFAULT_CHUNK = 128
DEFAULT_BD = 256


def plan(ba, s, di, n, *, chunk=DEFAULT_CHUNK, bd=DEFAULT_BD,
         dtype="float32") -> KernelPlan:
    """Launch geometry for ``selective_scan_tpu``: u/dt:(ba,s,di), A:(di,n),
    B/C:(ba,s,n), D:(di,) — the time-chunk axis minor-most so the (BD, N)
    recurrent state stays VMEM-resident across chunks."""
    ch = min(chunk, s)
    bd_ = min(bd, di)
    s_p = s + (-s) % ch
    di_p = di + (-di) % bd_
    nc = s_p // ch
    nd = di_p // bd_
    return KernelPlan(
        family="selective_scan", entry="selective_scan",
        grid=(ba, nd, nc),
        inputs=(
            BlockPlan("u", (1, ch, bd_), lambda b, idd, ic: (b, ic, idd),
                      (ba, s_p, di_p), dtype),
            BlockPlan("dt", (1, ch, bd_), lambda b, idd, ic: (b, ic, idd),
                      (ba, s_p, di_p), dtype),
            BlockPlan("A", (bd_, n), lambda b, idd, ic: (idd, 0),
                      (di_p, n), "float32"),
            BlockPlan("B", (1, ch, n), lambda b, idd, ic: (b, ic, 0),
                      (ba, s_p, n), dtype),
            BlockPlan("C", (1, ch, n), lambda b, idd, ic: (b, ic, 0),
                      (ba, s_p, n), dtype),
            BlockPlan("D", (bd_,), lambda b, idd, ic: (idd,),
                      (di_p,), "float32"),
        ),
        outputs=(
            BlockPlan("y", (1, ch, bd_), lambda b, idd, ic: (b, ic, idd),
                      (ba, s_p, di_p), dtype),
            BlockPlan("h_last", (1, bd_, n), lambda b, idd, ic: (b, idd, 0),
                      (ba, di_p, n), "float32"),
        ),
        scratch=(ScratchPlan("h", (bd_, n), "float32", accumulator=True),),
    )


def _scan_kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, hout_ref,
                 h_ref, *, chunk, s_total):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)            # (BD, N)
    dvec = d_ref[...].astype(jnp.float32)         # (BD,)

    def step(i, carry):
        h, ys = carry
        u_i = u_ref[0, i].astype(jnp.float32)     # (BD,)
        dt_i = dt_ref[0, i].astype(jnp.float32)   # (BD,)
        b_i = b_ref[0, i].astype(jnp.float32)     # (N,)
        c_i = c_ref[0, i].astype(jnp.float32)     # (N,)
        abar = jnp.exp(dt_i[:, None] * a)         # (BD, N)
        h = abar * h + (dt_i * u_i)[:, None] * b_i[None, :]
        y = (h * c_i[None, :]).sum(axis=1) + dvec * u_i
        return h, ys.at[i].set(y)

    h0 = h_ref[...]
    h1, ys = jax.lax.fori_loop(
        0, chunk, step, (h0, jnp.zeros((chunk, h0.shape[0]), jnp.float32)))
    h_ref[...] = h1
    y_ref[0] = ys.astype(y_ref.dtype)

    @pl.when(ic == pl.num_programs(2) - 1)
    def _final():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "bd", "interpret"))
def selective_scan_tpu(u, dt, A, B, C, D, *, chunk=DEFAULT_CHUNK,
                       bd=DEFAULT_BD, interpret=None, h0=None):
    """u, dt: (Ba, S, Di); A: (Di, N); B, C: (Ba, S, N); D: (Di,).

    Returns (y (Ba,S,Di), h_last (Ba,Di,N)).  h0 (initial state) is folded in
    by the caller via the reference path when resuming — the kernel assumes
    zero initial state (training/prefill from scratch).
    """
    if h0 is not None:  # decode-resume path: defer to reference
        from . import ref
        return ref.selective_scan(u, dt, A, B, C, D, chunk=chunk, h0=h0)
    ba, s, di = u.shape
    n = A.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kp = plan(ba, s, di, n, chunk=chunk, bd=bd, dtype=str(u.dtype))
    ch = kp.inputs[0].block_shape[1]
    pad_s = kp.inputs[0].array_shape[1] - s
    pad_d = kp.inputs[0].array_shape[2] - di

    def padsd(x):  # pad time and channel dims
        return jnp.pad(x, ((0, 0), (0, pad_s), (0, pad_d)))

    up = padsd(u) if (pad_s or pad_d) else u
    dtp = padsd(dt) if (pad_s or pad_d) else dt
    bp = jnp.pad(B, ((0, 0), (0, pad_s), (0, 0))) if pad_s else B
    cp = jnp.pad(C, ((0, 0), (0, pad_s), (0, 0))) if pad_s else C
    ap = jnp.pad(A, ((0, pad_d), (0, 0))) if pad_d else A
    dp = jnp.pad(D, (0, pad_d)) if pad_d else D

    kernel = functools.partial(_scan_kernel, chunk=ch, s_total=s)
    y, h_last = pl.pallas_call(
        kernel,
        grid=kp.grid,
        in_specs=[as_block_spec(bpn) for bpn in kp.inputs],
        out_specs=[as_block_spec(bpn) for bpn in kp.outputs],
        out_shape=[
            jax.ShapeDtypeStruct(kp.outputs[0].array_shape, u.dtype),
            jax.ShapeDtypeStruct(kp.outputs[1].array_shape, jnp.float32),
        ],
        scratch_shapes=[as_scratch(sp) for sp in kp.scratch],
        interpret=interpret,
    )(up, dtp, ap, bp, cp, dp)
    return y[:, :s, :di], h_last[:, :di]
