from .ops import selective_scan, selective_scan_step  # noqa: F401
