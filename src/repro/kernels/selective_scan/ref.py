"""Pure-jnp oracle for the Mamba selective-scan kernel.

h_t = Abar_t * h_{t-1} + Bbar_t * u_t ;  y_t = C_t . h_t + D * u_t

Chunked formulation: lax.scan over time chunks, associative_scan inside each
chunk, so the materialized (B, chunk, d_inner, d_state) tensor stays bounded —
this is the same blocking the Pallas kernel uses for VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _combine(left, right):
    a_l, b_l = left
    a_r, b_r = right
    return a_r * a_l, a_r * b_l + b_r


@functools.partial(jax.jit, static_argnames=("chunk",))
def selective_scan(u, dt, A, B, C, D, *, chunk=128, h0=None):
    """u:(Ba,S,Di) dt:(Ba,S,Di) A:(Di,N) B,C:(Ba,S,N) D:(Di,).

    Returns (y:(Ba,S,Di), h_last:(Ba,Di,N)).
    """
    ba, s, di = u.shape
    n = A.shape[1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    uf = u.astype(jnp.float32)
    if pad:
        uf = jnp.pad(uf, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    sp = uf.shape[1]
    nc = sp // chunk

    def chunk_body(h, xs):
        uc, dtc, bc, cc = xs  # (Ba, chunk, ...)
        # discretize: Abar = exp(dt*A), Bu = dt * B * u  (ZOH-Euler mix, std mamba)
        abar = jnp.exp(dtc[..., None] * A[None, None])           # (Ba,c,Di,N)
        bu = (dtc * uc)[..., None] * bc[:, :, None, :]           # (Ba,c,Di,N)
        a_all, h_all = jax.lax.associative_scan(_combine, (abar, bu), axis=1)
        h_all = h_all + a_all * h[:, None]                       # fold in carry
        y = jnp.einsum("bcdn,bcn->bcd", h_all, cc)
        return h_all[:, -1], y

    h = h0.astype(jnp.float32) if h0 is not None else jnp.zeros((ba, di, n), jnp.float32)
    xs = (
        uf.reshape(ba, nc, chunk, di).swapaxes(0, 1),
        dt.astype(jnp.float32).reshape(ba, nc, chunk, di).swapaxes(0, 1),
        B.astype(jnp.float32).reshape(ba, nc, chunk, n).swapaxes(0, 1),
        C.astype(jnp.float32).reshape(ba, nc, chunk, n).swapaxes(0, 1),
    )
    h_last, ys = jax.lax.scan(chunk_body, h, xs)
    y = ys.swapaxes(0, 1).reshape(ba, sp, di)[:, :s]
    y = y + uf[:, :s] * D[None, None]
    return y.astype(u.dtype), h_last


def selective_scan_step(u, dt, A, B, C, D, h):
    """Single decode step. u,dt:(Ba,Di) B,C:(Ba,N) h:(Ba,Di,N) -> (y, h_new)."""
    abar = jnp.exp(dt[..., None] * A[None])
    bu = (dt * u)[..., None] * B[:, None, :]
    h_new = abar * h + bu
    y = jnp.einsum("bdn,bn->bd", h_new, C) + u * D[None]
    return y.astype(u.dtype), h_new
