"""Dispatching wrapper for the selective scan: Pallas on TPU, jnp elsewhere
(``REPRO_FORCE_REF=1`` pins the reference on TPU, same as the other
kernels — both backends take compute-dtype inputs and keep the recurrent
state in fp32)."""
from __future__ import annotations

from repro.kernels.dispatch import decide

from . import ref


def selective_scan(u, dt, A, B, C, D, *, chunk=128, h0=None):
    if decide("selective_scan", u.shape, u.dtype).use_pallas:
        from .kernel import selective_scan_tpu
        return selective_scan_tpu(u, dt, A, B, C, D, chunk=chunk, h0=h0)
    return ref.selective_scan(u, dt, A, B, C, D, chunk=chunk, h0=h0)


selective_scan_step = ref.selective_scan_step
