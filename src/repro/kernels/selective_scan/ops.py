"""Dispatching wrapper for the selective scan: Pallas on TPU, jnp elsewhere."""
from __future__ import annotations

import jax

from . import ref


def selective_scan(u, dt, A, B, C, D, *, chunk=128, h0=None):
    if jax.default_backend() == "tpu":
        from .kernel import selective_scan_tpu
        return selective_scan_tpu(u, dt, A, B, C, D, chunk=chunk, h0=h0)
    return ref.selective_scan(u, dt, A, B, C, D, chunk=chunk, h0=h0)


selective_scan_step = ref.selective_scan_step
