"""Pallas TPU kernels (with pure-jnp oracles) for the perf-critical paths.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (dispatching
jit'd wrapper), ref.py (pure-jnp oracle used for tests and CPU lowering).

``FAMILIES`` is the declarative kernel inventory: every family listed here
must keep a registered kernel-vs-reference oracle in ``repro.verify``
(asserted by tests/test_verify_oracles.py) — adding a kernel without its
conformance contract is a test failure, not an oversight.
"""
from .flash_attention import flash_attention, decode_attention  # noqa: F401
from .selective_scan import selective_scan, selective_scan_step  # noqa: F401
from .sil_mse import sil_mse  # noqa: F401

# family name -> the entry points whose Pallas and reference paths the
# repro.verify oracle registry must cover
FAMILIES = {
    "flash_attention": ("flash_attention", "decode_attention"),
    "selective_scan": ("selective_scan",),
    "sil_mse": ("sil_mse",),
}
