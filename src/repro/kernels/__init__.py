"""Pallas TPU kernels (with pure-jnp oracles) for the perf-critical paths.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (dispatching
jit'd wrapper), ref.py (pure-jnp oracle used for tests and CPU lowering).
"""
from .flash_attention import flash_attention, decode_attention  # noqa: F401
from .selective_scan import selective_scan, selective_scan_step  # noqa: F401
from .sil_mse import sil_mse  # noqa: F401
