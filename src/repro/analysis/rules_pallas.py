"""Pallas kernel static checker: validate every family's KernelPlan.

The kernels expose their launch geometry as pure ``plan()`` functions
(``repro.kernels.plan.KernelPlan``) — the same plans the ``*_tpu`` entry
points consume at call time.  That single-source-of-truth is what makes a
*static* checker possible: these rules validate the exact grid / BlockSpec /
scratch geometry a TPU launch would use, on a CPU host, without executing
(or even lowering) a kernel.

Shapes come from the arch's config: attention geometry from
(n_heads, n_kv_heads, hd), the SSM scan from (expand * d_model, d_state),
SIL-MSE from (tokens, d_model, vocab).  Both the smoke and the full-size
config are checked — padding bugs tend to hide at full size.
"""
from __future__ import annotations

import itertools
from typing import Dict, List

import numpy as np

from repro.analysis.core import AnalysisContext, Finding, register
from repro.configs import get
from repro.kernels import FAMILIES
from repro.kernels.dispatch import decide
from repro.kernels.plan import KernelPlan
from repro.models.mlp import MLPConfig


def build_plans(ctx: AnalysisContext) -> List[KernelPlan]:
    """KernelPlans for every family applicable to ctx.arch (smoke + full)."""
    key = f"plans:{ctx.arch}"
    if key in ctx.cache:
        return ctx.cache[key]
    from repro.kernels.flash_attention import kernel as fa
    from repro.kernels.selective_scan import kernel as ssm
    from repro.kernels.sil_mse import kernel as sm
    plans: List[KernelPlan] = []
    for smoke in (True, False):
        cfg = get(ctx.arch, smoke=smoke)
        if isinstance(cfg, MLPConfig):
            # smoke batch vs the paper's full batch (1410, §3)
            plans.append(sm.plan(64 if smoke else 1410, cfg.boundary_width,
                                 cfg.n_classes))
            continue
        b, s = (2, 32) if smoke else (1, 512)
        plans.append(fa.plan(b, s, s, cfg.n_heads, cfg.n_kv_heads, cfg.hd))
        plans.append(fa.decode_plan(4, s + 32, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.hd))
        # paged decode: the block-table scalar prefetch is exercised at its
        # 0 / max_value fills by index_map_bounds
        nb = -(-(s + 32) // 16)
        plans.append(fa.paged_decode_plan(4, nb, 16, cfg.n_heads,
                                          cfg.n_kv_heads, cfg.hd,
                                          n_blocks=4 * nb + 1))
        if cfg.ssm is not None:
            plans.append(ssm.plan(b, s, cfg.ssm.expand * cfg.d_model,
                                  cfg.ssm.d_state))
        plans.append(sm.plan(b * s, cfg.d_model, cfg.vocab_size))
    ctx.cache[key] = plans
    return plans


@register("pallas/grid_divisibility",
          "Every BlockPlan's (padded) array shape divides into whole blocks "
          "and the grid is positive.", tags=("pallas",))
def grid_divisibility(ctx: AnalysisContext) -> List[Finding]:
    out = []
    for kp in build_plans(ctx):
        tgt = f"{kp.family}.{kp.entry}"
        if not kp.grid or any(g < 1 for g in kp.grid):
            out.append(Finding(
                rule="pallas/grid_divisibility", severity="fail", target=tgt,
                message=f"degenerate grid {kp.grid}",
                evidence={"grid": list(kp.grid)}))
        for bp in kp.blocks:
            if len(bp.block_shape) != len(bp.array_shape):
                out.append(Finding(
                    rule="pallas/grid_divisibility", severity="fail",
                    target=tgt,
                    message=f"{bp.name}: block rank {len(bp.block_shape)} "
                            f"!= array rank {len(bp.array_shape)}",
                    evidence={"block": list(bp.block_shape),
                              "array": list(bp.array_shape)}))
                continue
            bad = [i for i, (blk, arr) in
                   enumerate(zip(bp.block_shape, bp.array_shape))
                   if blk < 1 or arr % blk]
            if bad:
                out.append(Finding(
                    rule="pallas/grid_divisibility", severity="fail",
                    target=tgt,
                    message=f"{bp.name}: array {tuple(bp.array_shape)} not "
                            f"divisible by block {tuple(bp.block_shape)} "
                            f"on dims {bad}",
                    evidence={"block": list(bp.block_shape),
                              "array": list(bp.array_shape), "dims": bad}))
    return out


def _prefetch_fills(kp: KernelPlan):
    """Ref-array fill values exercising both ends of each prefetch range."""
    if not kp.scalar_prefetch:
        yield ()
        return
    for fill in ("zero", "max"):
        yield tuple(np.full(sp.shape,
                            0 if fill == "zero" else sp.max_value,
                            dtype=sp.dtype)
                    for sp in kp.scalar_prefetch)


@register("pallas/index_map_bounds",
          "Index maps stay in-bounds at every grid corner, including the "
          "extremes of scalar-prefetched operands.", tags=("pallas",))
def index_map_bounds(ctx: AnalysisContext) -> List[Finding]:
    out = []
    for kp in build_plans(ctx):
        tgt = f"{kp.family}.{kp.entry}"
        corners = itertools.product(*({0, g - 1} for g in kp.grid))
        for corner in corners:
            for refs in _prefetch_fills(kp):
                for bp in kp.blocks:
                    try:
                        idx = bp.index_map(*corner, *refs)
                    except Exception as e:  # map crashed: also a finding
                        out.append(Finding(
                            rule="pallas/index_map_bounds", severity="fail",
                            target=tgt,
                            message=f"{bp.name}: index_map raised at grid "
                                    f"{corner}: {e!r}",
                            evidence={"corner": list(corner)}))
                        continue
                    idx = tuple(int(i) for i in idx)
                    if len(idx) != len(bp.block_shape):
                        out.append(Finding(
                            rule="pallas/index_map_bounds", severity="fail",
                            target=tgt,
                            message=f"{bp.name}: index_map arity "
                                    f"{len(idx)} != block rank "
                                    f"{len(bp.block_shape)}",
                            evidence={"idx": list(idx)}))
                        continue
                    oob = [i for i, (ix, blk, arr) in enumerate(
                        zip(idx, bp.block_shape, bp.array_shape))
                        if ix < 0 or (ix + 1) * blk > arr]
                    if oob:
                        out.append(Finding(
                            rule="pallas/index_map_bounds", severity="fail",
                            target=tgt,
                            message=f"{bp.name}: block index {idx} out of "
                                    f"bounds at grid {corner} on dims {oob}",
                            evidence={"corner": list(corner),
                                      "idx": list(idx), "dims": oob,
                                      "block": list(bp.block_shape),
                                      "array": list(bp.array_shape)}))
    return out


@register("pallas/accum_dtype",
          "Accumulator scratch buffers are fp32 (never the compute dtype).",
          tags=("pallas",))
def accum_dtype(ctx: AnalysisContext) -> List[Finding]:
    out = []
    for kp in build_plans(ctx):
        for sp in kp.scratch:
            if sp.accumulator and sp.dtype != "float32":
                out.append(Finding(
                    rule="pallas/accum_dtype", severity="fail",
                    target=f"{kp.family}.{kp.entry}",
                    message=f"accumulator scratch {sp.name!r} is "
                            f"{sp.dtype} (must be float32)",
                    evidence={"scratch": sp.name, "dtype": sp.dtype}))
    return out


@register("pallas/dispatch_symmetry",
          "REPRO_FORCE_REF and non-TPU backends pin the reference path for "
          "every kernel family; TPU without force takes Pallas.",
          tags=("pallas",))
def dispatch_symmetry(ctx: AnalysisContext) -> List[Finding]:
    out = []
    probes: Dict[str, tuple] = {
        "forced ref on tpu": (dict(backend="tpu", force=True), False),
        "pallas on tpu": (dict(backend="tpu", force=False), True),
        "ref off tpu": (dict(backend="cpu", force=False), False),
    }
    for family in FAMILIES:
        for label, (kw, want_pallas) in probes.items():
            d = decide(family, **kw)
            if d.use_pallas != want_pallas:
                out.append(Finding(
                    rule="pallas/dispatch_symmetry", severity="fail",
                    target=family,
                    message=f"{label}: decide() returned "
                            f"use_pallas={d.use_pallas} ({d.reason})",
                    evidence={"probe": label, "reason": d.reason}))
    return out
