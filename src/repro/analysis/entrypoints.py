"""The registered hot-path entry points the trace lint inspects.

Each builder constructs ONE production entry point — the same step builders
``phases`` / ``dist`` / ``serve`` use, on the same tiny scenario worlds the
conformance oracles use (``verify.scenarios``) — plus concrete example args,
and returns a ``TraceTarget``.  Nothing is compiled or executed; the args
exist only to drive ``jax.make_jaxpr``.

Everything is built under ``runtime.assume_donation()``: the CPU hosts that
run the analyzer can't *execute* donation, but the jitted steps read
``donate_argnums`` at wrap time, and tracing only needs the requested masks
to land in the jaxpr's pjit params.  That env contract (REPRO_ASSUME_DONATION)
is exactly what makes the donation-coverage rule meaningful off-TPU.

Arch routing: MLP configs (paper_mlp) get the MLP epoch steps; LM configs
get the PartitionPlan stage steps and the serving engine steps.  The SIL
lookup+loss kernel entry exists for both.

These targets double as the repro.obs instrumentation proof: the builders
go through the instrumented classes (``Engine``, the backends the
``Trainer``/``StageExecutor`` drive), so the trace lint failing clean on
``train/mlp_guarded_epoch`` / ``train/lm_parallel_stage_step`` /
``serve/decode_chunk`` certifies that metrics/span collection lives
entirely OUTSIDE the jitted steps — zero host callbacks added
(tests/test_obs.py also pins the jaxprs byte-identical).
"""
from __future__ import annotations

from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

from repro import runtime
from repro.analysis.core import AnalysisContext
from repro.analysis.trace import TraceArtifact, TraceTarget, trace
from repro.configs import get
from repro.core import losses
from repro.core import sil as sil_lib
from repro.models.mlp import MLPConfig
from repro.train.backends import (LMBackend, MLPBackend, make_optimizer_for,
                                  scanned_epoch_fn)
from repro.verify import scenarios


def _mlp_world(ctx: AnalysisContext):
    cfg = get(ctx.arch, smoke=True)
    _, data, spec = scenarios.tiny_mlp(
        n_stages=3, n_train=256, n_test=64, batch_size=64,
        sizes=cfg.sizes, precision=ctx.precision)
    from repro.models import mlp as MLP
    be = MLPBackend(cfg, data, spec)
    params = MLP.init_params(cfg, jax.random.PRNGKey(0))
    sps = be.split(params)
    sils = be.make_sils(jax.random.PRNGKey(1), spec.kappa)
    return be, spec, sps, sils


def _mlp_targets(ctx: AnalysisContext) -> List[TraceTarget]:
    be, spec, sps, sils = _mlp_world(ctx)
    batches = be.epoch_arrays(0, shuffle=False)
    opt = make_optimizer_for(spec.stage(0), spec)
    # the NaN/inf-guarded variant (repro.resilience): skip-and-count must
    # stay on-device — the trace lint proves the guard adds no host callback
    from repro.optim import step_guard
    gopt = step_guard(make_optimizer_for(spec.stage(0), spec))
    entries = (
        ("train/mlp_sil_epoch", be.build_sil_step(0, opt, sils[0]), sps[0],
         opt),
        ("train/mlp_parallel_epoch", be.build_parallel_step(1, opt, sils),
         sps[1], opt),
        ("train/mlp_guarded_epoch", be.build_sil_step(0, gopt, sils[0]),
         sps[0], gopt),
    )
    return [TraceTarget(name=name, fn=scanned_epoch_fn(step),
                        args=(p, o.init(p), batches), donate=(0, 1),
                        policy=ctx.precision, state_map=((0, 0), (1, 1)),
                        tags=("train", "mlp"))
            for name, step, p, o in entries]


def _lm_train_targets(ctx: AnalysisContext) -> List[TraceTarget]:
    cfg, plan, batch_fn, spec, params = scenarios.tiny_lm(
        ctx.arch, n_stages=2, precision=ctx.precision)
    be = LMBackend(cfg, plan, batch_fn, spec)
    sps = be.split(params)
    sils = be.make_sils(jax.random.PRNGKey(1), spec.kappa)
    batch = batch_fn(0)
    opt = make_optimizer_for(spec.stage(0), spec)
    st0 = opt.init(be.trainable(sps[0]))
    step0 = be.build_stage_step(0, opt, sils[0])
    st1 = opt.init(be.trainable(sps[1]))
    # n_stages=2 -> stage 1 is the last stage: CE head, sil_target=None
    step1 = be.build_parallel_stage_step(1, opt, sils[0], None)
    # the searched-cut variant: the same step builder over a repro.plan
    # auto partition — the lint rules must hold for searched bounds too
    # (the cut changes which groups each stage's step closes over)
    from repro.core import partition
    aplan = partition.make_plan(cfg, 2, strategy="auto")
    abe = LMBackend(cfg, aplan, batch_fn, spec)
    asps = abe.split(params)
    asils = abe.make_sils(jax.random.PRNGKey(1), spec.kappa)
    ast = opt.init(abe.trainable(asps[1]))
    astep = abe.build_parallel_stage_step(1, opt, asils[0], None)
    return [
        TraceTarget(name="train/lm_stage_step", fn=step0,
                    args=(sps[0], st0, batch, batch["labels"]),
                    donate=(0, 1), policy=ctx.precision,
                    state_map=((0, 0), (1, 1)), tags=("train", "lm")),
        TraceTarget(name="train/lm_parallel_stage_step", fn=step1,
                    args=(sps[1], st1, batch["labels"]),
                    donate=(0, 1), policy=ctx.precision,
                    state_map=((0, 0), (1, 1)), tags=("train", "lm")),
        TraceTarget(name="train/lm_auto_parallel_stage_step", fn=astep,
                    args=(asps[1], ast, batch["labels"]),
                    donate=(0, 1), policy=ctx.precision,
                    state_map=((0, 0), (1, 1)), tags=("train", "lm", "plan")),
    ]


def _serve_targets(ctx: AnalysisContext) -> List[TraceTarget]:
    from repro.serve.engine import Engine
    cfg = get(ctx.arch, smoke=True)
    eng = Engine(cfg, key=jax.random.PRNGKey(0), max_slots=4,
                 precision=ctx.precision)
    cfg = eng.cfg
    b, plen, new = 2, 8, 8
    extra = cfg.vision_tokens if cfg.frontend == "vision" else 0
    pool = eng._pool_for(plen + new + extra)
    cache_len = pool.cache_len
    n_slots = eng.max_slots
    batch = {"tokens": jnp.zeros((b, plen), jnp.int32)}
    if cfg.enc_dec:
        batch["frames"] = jnp.zeros((b, cfg.enc_seq, cfg.d_model),
                                    jnp.float32)
    if cfg.frontend == "vision":
        batch["image_embeds"] = jnp.zeros((b, cfg.vision_tokens, cfg.d_model),
                                          jnp.float32)
    tok = jnp.zeros((n_slots,), jnp.int32)
    pos = jnp.zeros((n_slots,), jnp.int32)
    keys = jnp.zeros((n_slots, 2), jnp.uint32)
    temps = jnp.zeros((n_slots,), jnp.float32)
    tks = jnp.zeros((n_slots,), jnp.int32)
    tps = jnp.ones((n_slots,), jnp.float32)
    admit = eng._admit_step(batch["tokens"].shape, cache_len, "greedy")
    admit_args = (eng.params, batch, pool.cache, tok, pos, keys, temps, tks,
                  tps, jnp.asarray([0, 1], jnp.int32),
                  jnp.zeros((b,), jnp.uint32), jnp.zeros((b,), jnp.float32),
                  jnp.zeros((b,), jnp.int32), jnp.ones((b,), jnp.float32))
    chunk = eng._decode_chunk(4, "greedy")
    chunk_args = (eng.params, pool.cache, tok, pos, keys, temps, tks, tps)
    return [
        TraceTarget(name="serve/prefill_admit", fn=admit, args=admit_args,
                    donate=tuple(range(2, 9)), policy=ctx.precision,
                    state_map=tuple((i + 2, i) for i in range(7)),
                    tags=("serve",)),
        TraceTarget(name="serve/decode_chunk", fn=chunk, args=chunk_args,
                    donate=(1, 2, 3, 4), policy=ctx.precision,
                    state_map=((1, 0), (2, 1), (3, 2), (4, 3)),
                    tags=("serve",)),
    ]


def _sil_target(ctx: AnalysisContext) -> List[TraceTarget]:
    cfg = get(ctx.arch, smoke=True)
    if isinstance(cfg, MLPConfig):
        d, m = cfg.sizes[cfg.cut], cfg.n_classes
        h = jnp.zeros((64, d), _compute_dtype(ctx))
        labels = jnp.zeros((64,), jnp.int32)
    else:
        d, m = cfg.d_model, cfg.vocab_size
        h = jnp.zeros((2, 16, d), _compute_dtype(ctx))
        labels = jnp.zeros((2, 16), jnp.int32)
    sil = sil_lib.make_sil(jax.random.PRNGKey(0), d, m, kappa=1.0)

    @jax.jit
    def lookup_loss(sil, h, labels):
        return losses.sil_stage_loss(h, sil, labels), \
            sil_lib.sil_lookup(sil, labels)

    return [TraceTarget(name="sil/lookup_loss", fn=lookup_loss,
                        args=(sil, h, labels), donate=(),
                        policy=ctx.precision, tags=("sil",))]


def _compute_dtype(ctx: AnalysisContext):
    from repro.precision import get_policy
    return get_policy(ctx.precision).compute_jnp


_BUILDERS: Dict[str, Callable[[AnalysisContext], List[TraceTarget]]] = {
    "mlp": _mlp_targets,
    "lm_train": _lm_train_targets,
    "serve": _serve_targets,
    "sil": _sil_target,
}


def build_targets(ctx: AnalysisContext) -> List[TraceTarget]:
    """All entry points applicable to ctx.arch (built under donation)."""
    cfg = get(ctx.arch, smoke=True)
    groups = ["mlp", "sil"] if isinstance(cfg, MLPConfig) \
        else ["lm_train", "serve", "sil"]
    out = []
    with runtime.assume_donation():
        for g in groups:
            out.extend(_BUILDERS[g](ctx))
    return out


def cache_key(ctx: AnalysisContext) -> str:
    """ctx.cache key for the traced artifacts (fixture tests seed this)."""
    return f"artifacts:{ctx.arch}:{ctx.precision}"


def artifacts(ctx: AnalysisContext) -> Dict[str, TraceArtifact]:
    """Traced artifacts for ctx.arch, built+traced once per context."""
    key = cache_key(ctx)
    if key not in ctx.cache:
        with runtime.assume_donation():
            arts = {t.name: trace(t) for t in build_targets(ctx)}
        ctx.cache[key] = arts
    return ctx.cache[key]
