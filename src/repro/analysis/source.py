"""AST-level source lint for the hot-path modules — stdlib only.

Bans the idioms that silently serialize a training/serving loop on the
host, at the source level (the jaxpr trace lint can only see what got
traced; this catches the call sites that never should exist):

* ``.item()`` — per-element device sync
* ``jax.device_get`` — explicit device-to-host copy
* ``.block_until_ready()`` — host barrier
* ``jax.random.PRNGKey(<constant>)`` — an ad-hoc fixed key minted at a
  call site (keys must be threaded in or derived; a constant key silently
  reuses randomness across calls)

Sanctioned sites carry a line pragma::

    values = jax.device_get(jnp.stack(pending))  # repro: allow-host-sync
    key = jax.random.PRNGKey(0)                  # repro: allow-const-key

``bench*.py`` files are excluded wholesale: a benchmark's entire job is to
sync the device, and its fixed seeds are the reproducibility contract.

This module must import without jax (CI's lint job has only ruff + stdlib):
it registers its rules into the jax-free ``repro.analysis.core`` registry
and doubles as a CLI — ``python -m repro.analysis.source [paths]`` — that
exits 1 on any finding.
"""
from __future__ import annotations

import ast
import os
import sys
from typing import Iterator, List, Sequence

from repro.analysis.core import AnalysisContext, Finding, register

HOT_PATH_DIRS = ("train", "serve", "dist", "kernels", "core", "models",
                 "resilience", "obs")
PRAGMA = "# repro: allow-"
HOST_SYNC_ATTRS = ("item", "device_get", "block_until_ready")


def _repro_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def default_paths() -> List[str]:
    root = _repro_root()
    return [os.path.join(root, d) for d in HOT_PATH_DIRS]


def _allows(line: str, check: str) -> bool:
    i = line.find(PRAGMA)
    return i >= 0 and line[i + len(PRAGMA):].startswith(check)


def _check_call(node: ast.Call) -> Iterator[tuple]:
    """Yield (check, message) for one call node."""
    fn = node.func
    if isinstance(fn, ast.Attribute):
        if fn.attr in HOST_SYNC_ATTRS:
            yield ("host-sync", f".{fn.attr}() syncs the host")
        if fn.attr == "PRNGKey" and node.args and \
                isinstance(node.args[0], ast.Constant):
            yield ("const-key",
                   f"ad-hoc constant PRNGKey({node.args[0].value!r})")
    elif isinstance(fn, ast.Name) and fn.id in HOST_SYNC_ATTRS:
        yield ("host-sync", f"{fn.id}() syncs the host")


def scan_file(path: str, rel: str = "") -> List[Finding]:
    with open(path, encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding(rule="source/host_sync", severity="fail",
                        target=rel or path,
                        message=f"unparseable: {e.msg} (line {e.lineno})")]
    lines = src.splitlines()
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for check, msg in _check_call(node):
            line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
            if _allows(line, check):
                continue
            rule = "source/host_sync" if check == "host-sync" \
                else "source/const_key"
            out.append(Finding(
                rule=rule, severity="fail",
                target=f"{rel or path}:{node.lineno}", message=msg,
                evidence={"line": line.strip()[:120]}))
    return out


def scan_paths(paths: Sequence[str]) -> List[Finding]:
    root = os.path.dirname(_repro_root())        # .../src
    out = []
    for p in paths:
        files = [p] if os.path.isfile(p) else sorted(
            os.path.join(dp, f) for dp, _, fs in os.walk(p) for f in fs
            if f.endswith(".py"))
        for f in files:
            if os.path.basename(f).startswith("bench"):
                continue
            rel = os.path.relpath(f, root) if f.startswith(root) else f
            out.extend(scan_file(f, rel))
    return out


@register("source/host_sync",
          "No .item() / device_get / block_until_ready in hot-path modules "
          "outside pragma-allowed lines.", tags=("source",))
def host_sync(ctx: AnalysisContext) -> List[Finding]:
    return [f for f in scan_paths(default_paths())
            if f.rule == "source/host_sync"]


@register("source/const_key",
          "No ad-hoc constant PRNGKey() minted in hot-path modules outside "
          "pragma-allowed lines.", tags=("source",))
def const_key(ctx: AnalysisContext) -> List[Finding]:
    return [f for f in scan_paths(default_paths())
            if f.rule == "source/const_key"]


def main(argv: Sequence[str] = ()) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.source",
        description="AST lint for hot-path modules (stdlib-only).")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to scan (default: the hot-path dirs)")
    args = ap.parse_args(argv or None)
    findings = scan_paths(args.paths or default_paths())
    for f in findings:
        print(f"{f.target}: [{f.rule}] {f.message}")
    print(f"source lint: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
