"""Schema-versioned analysis reports (mirrors ``repro.verify.report``).

``results/ANALYSIS_<pr>.json`` is the machine-readable artifact CI uploads;
the schema string is the compatibility contract — bump it when row shapes
change, never silently.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence

from repro.analysis.core import RuleResult

SCHEMA = "repro.analysis/1"


def _env_stamp() -> Dict:
    try:
        import jax
        return {"jax": jax.__version__, "backend": jax.default_backend(),
                "n_devices": len(jax.devices()),
                "assume_donation": os.environ.get("REPRO_ASSUME_DONATION",
                                                  ""),
                "force_ref": os.environ.get("REPRO_FORCE_REF", "")}
    except Exception:           # source-lint-only environments have no jax
        return {"jax": None}


def build_report(results_by_arch: Dict[str, Sequence[RuleResult]],
                 extra: Optional[Dict] = None) -> Dict:
    """{arch: [RuleResult]} -> the ANALYSIS_*.json payload."""
    rows: List[Dict] = []
    for arch, results in sorted(results_by_arch.items()):
        for r in results:
            row = r.row()
            row["arch"] = arch
            rows.append(row)
    n_fail = sum(r["n_fail"] for r in rows)
    n_warn = sum(r["n_warn"] for r in rows)
    errors = sorted({r["rule"] for r in rows if r["error"]})
    report = {
        "schema": SCHEMA,
        "env": _env_stamp(),
        "archs": sorted(results_by_arch),
        "n_rules": len({r["rule"] for r in rows}),
        "n_fail_findings": n_fail,
        "n_warn_findings": n_warn,
        "rules_errored": errors,
        "ok": n_fail == 0 and not errors,
        "results": rows,
    }
    if extra:
        report.update(extra)
    return report


def write_report(report: Dict, path: str) -> str:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")
    return path
