"""Trace-lint rules: jaxpr-level checks over the registered entry points.

Each rule walks the traced jaxprs from ``entrypoints.artifacts(ctx)`` —
tracing happens once per (arch, precision) context, rules share the cache.

Detection notes that shaped these rules (verified against JAX's actual
lowering, not the docs):

* ``jnp.sum(x, dtype=bfloat16)`` lowers identically to ``jnp.sum(x)`` on a
  bf16 operand — convert-to-f32, f32 reduce, convert back — so a jnp-level
  "bf16 accumulation" is *invisible* in the jaxpr.  What IS visible: a raw
  lax-level reduce whose operand and output are both bf16, and a bf16 scan
  carry fed directly into an ``add`` in the scan body (a running
  accumulator kept in bf16).  Both are warns, not fails: autodiff of any
  bf16 forward mass-produces bf16 ``add_any`` / ``reduce_sum`` for the
  cotangents (fan-out sums, broadcast transposes) — that is inherent to
  bf16 training, while this repo's *deliberate* accumulations (microbatch
  grads, optimizer moments, loss reductions) are all explicitly fp32.  The
  warn aggregates per (target, primitive) so a hand-written bf16 reduce is
  visible without 29 lines of AD noise; bf16-*stored* state likewise flows
  through adds legitimately (a bf16 param update), and the decode cache's
  bf16 carry feeds ``dynamic_update_slice``, not ``add``, staying silent.
* Host transfers inside a jitted region surface as callback primitives
  (``debug_callback`` / ``pure_callback`` / ``io_callback``); a plain
  ``jax.debug.print`` in a scan body is the classic accidental one.
* ``donated_invars`` lives on the top-level pjit equation's params,
  leaf-expanded in argument order — comparing it against the donation the
  call site *requested* catches donation silently dropped by a wrapper.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.analysis.core import AnalysisContext, Finding, register
from repro.analysis.entrypoints import artifacts
from repro.analysis.trace import donated_invars, iter_eqns, leaf_counts

HOST_CALLBACK_PRIMS = ("debug_callback", "pure_callback", "io_callback",
                       "callback")
REDUCE_PRIMS = ("reduce_sum", "cumsum", "add_any", "reduce_window_sum")
LOW_PRECISION = (jnp.bfloat16, jnp.float16)


def _dtype(aval):
    return getattr(aval, "dtype", None)


def _is_low(dt) -> bool:
    return dt is not None and any(dt == jnp.dtype(t) for t in LOW_PRECISION)


@register("trace/host_transfer",
          "No host callbacks / implicit device-to-host transfers inside "
          "jitted hot-path regions.", tags=("trace",))
def host_transfer(ctx: AnalysisContext) -> List[Finding]:
    out = []
    for name, art in artifacts(ctx).items():
        if art.jaxpr is None:
            continue
        for eqn in iter_eqns(art.jaxpr):
            if eqn.primitive.name in HOST_CALLBACK_PRIMS:
                cb = eqn.params.get("callback", "")
                out.append(Finding(
                    rule="trace/host_transfer", severity="fail", target=name,
                    message=f"{eqn.primitive.name} inside the jitted step "
                            "(host sync every invocation)",
                    evidence={"primitive": eqn.primitive.name,
                              "callback": repr(cb)[:120]}))
    return out


@register("trace/dtype_policy",
          "Compute-dtype discipline under the precision policy: no mixed-"
          "dtype matmuls, no bf16-accumulated reductions, no f64 leaks, no "
          "dtype drift on carried state.", tags=("trace",))
def dtype_policy(ctx: AnalysisContext) -> List[Finding]:
    out = []
    for name, art in artifacts(ctx).items():
        if art.jaxpr is None:
            continue
        low_reduces: dict = {}
        for eqn in iter_eqns(art.jaxpr):
            prim = eqn.primitive.name
            avals = [v.aval for v in eqn.invars
                     if hasattr(v, "aval") and hasattr(v.aval, "dtype")]
            dts = [a.dtype for a in avals
                   if jnp.issubdtype(a.dtype, jnp.floating)]
            if prim == "dot_general" and len(set(map(str, dts))) > 1:
                out.append(Finding(
                    rule="trace/dtype_policy", severity="fail", target=name,
                    message="mixed-dtype dot_general (silent upcast: one "
                            "operand missed the compute-dtype cast)",
                    evidence={"operand_dtypes": sorted(map(str, dts))}))
            if prim in REDUCE_PRIMS and dts and all(_is_low(d) for d in dts):
                odts = [str(v.aval.dtype) for v in eqn.outvars
                        if hasattr(v.aval, "dtype")]
                if all(_is_low(jnp.dtype(d)) for d in odts):
                    k = (prim, odts[0])
                    low_reduces[k] = low_reduces.get(k, 0) + 1
            if any(str(d) == "float64" for d in dts):
                out.append(Finding(
                    rule="trace/dtype_policy", severity="fail", target=name,
                    message=f"float64 operand reached {prim} (x64 leak)",
                    evidence={"primitive": prim}))
            if prim == "scan":
                out.extend(_scan_carry_accumulators(name, eqn))
        for (prim, dt), n in sorted(low_reduces.items()):
            out.append(Finding(
                rule="trace/dtype_policy", severity="warn", target=name,
                message=f"{n}x {prim} accumulating in {dt} (AD cotangent "
                        "sums are expected under bf16; audit any "
                        "hand-written lax reduce)",
                evidence={"primitive": prim, "dtype": dt, "count": n}))
        out.extend(_state_dtype_drift(name, art))
    return out


def _scan_carry_accumulators(target: str, eqn) -> List[Finding]:
    """bf16/f16 scan carries that feed DIRECTLY into an add in the body."""
    body = eqn.params["jaxpr"].jaxpr
    n_consts = eqn.params.get("num_consts", 0)
    n_carry = eqn.params.get("num_carry", 0)
    carry_vars = body.invars[n_consts:n_consts + n_carry]
    low = {id(v) for v in carry_vars if _is_low(_dtype(v.aval))}
    if not low:
        return []
    out = []
    for beqn in body.eqns:
        if beqn.primitive.name in ("add", "add_any") and \
                any(id(v) in low for v in beqn.invars):
            dt = str(beqn.outvars[0].aval.dtype)
            out.append(Finding(
                rule="trace/dtype_policy", severity="warn", target=target,
                message=f"scan carry in {dt} is summed in the body "
                        "(low-precision running accumulator?)",
                evidence={"carry_dtype": dt}))
    return out


def _state_dtype_drift(target: str, art) -> List[Finding]:
    """Carried-state args must come back with identical leaf dtypes."""
    out = []
    outs = art.out_shape
    if outs is None or not isinstance(outs, (tuple, list)):
        return out
    for arg_i, out_i in art.target.state_map:
        if out_i >= len(outs):
            continue
        a_dts = [str(x.dtype) for x in
                 jax.tree_util.tree_leaves(art.target.args[arg_i])]
        o_dts = [str(x.dtype) for x in jax.tree_util.tree_leaves(outs[out_i])]
        if a_dts != o_dts:
            drift = sorted({(a, o) for a, o in zip(a_dts, o_dts) if a != o})
            out.append(Finding(
                rule="trace/dtype_policy", severity="fail", target=target,
                message=f"carried state arg[{arg_i}] -> out[{out_i}] "
                        "changes dtype across the step",
                evidence={"drift": [f"{a}->{o}" for a, o in drift][:8]}))
    return out


@register("trace/donation",
          "Every buffer the call site requests donated is donated in the "
          "traced program (params/opt-state/caches reuse their memory).",
          tags=("trace",))
def donation(ctx: AnalysisContext) -> List[Finding]:
    from repro.launch.hlo_analysis import dtype_byte_breakdown
    out = []
    for name, art in artifacts(ctx).items():
        if art.jaxpr is None or not art.target.donate:
            continue
        counts = leaf_counts(art.target.args)
        expected = sum(counts[i] for i in art.target.donate)
        mask = donated_invars(art)
        if mask is None:
            out.append(Finding(
                rule="trace/donation", severity="fail", target=name,
                message="entry point requests donation but the trace "
                        "carries no donated_invars (donation dropped "
                        "by a wrapper?)",
                evidence={"requested_argnums": list(art.target.donate)}))
            continue
        actual = sum(mask)
        if actual < expected:
            # attribute the undonated leaves back to their argnums
            starts = [sum(counts[:i]) for i in range(len(counts))]
            undonated_bytes = {}
            for i in art.target.donate:
                seg = mask[starts[i]:starts[i] + counts[i]]
                if not all(seg):
                    bb = dtype_byte_breakdown(art.target.args[i])
                    for k, v in bb.items():
                        undonated_bytes[k] = undonated_bytes.get(k, 0) + v
            out.append(Finding(
                rule="trace/donation", severity="fail", target=name,
                message=f"only {actual}/{expected} requested leaves are "
                        "donated in the traced program",
                evidence={"expected": expected, "actual": actual,
                          "undonated_bytes_by_dtype": undonated_bytes}))
        else:
            out.append(Finding(
                rule="trace/donation", severity="info", target=name,
                message=f"all {expected} requested leaves donated",
                evidence={"donated_leaves": expected}))
    return out


@register("trace/recompile_hazard",
          "Entry points trace cleanly (no unhashable static args / shape-"
          "dependent Python branches) and are single jitted programs.",
          tags=("trace",))
def recompile_hazard(ctx: AnalysisContext) -> List[Finding]:
    from repro.analysis.trace import top_pjit_eqn
    out = []
    for name, art in artifacts(ctx).items():
        if art.error is not None:
            out.append(Finding(
                rule="trace/recompile_hazard", severity="fail", target=name,
                message="entry point failed to trace (unhashable static "
                        "arg or data-dependent Python control flow?)",
                evidence={"error": art.error.splitlines()[-1]}))
            continue
        if top_pjit_eqn(art.jaxpr) is None:
            out.append(Finding(
                rule="trace/recompile_hazard", severity="warn", target=name,
                message="entry point is not one top-level jitted program "
                        "(op-by-op dispatch / partial jit)",
                evidence={"n_top_eqns": len(art.jaxpr.jaxpr.eqns)}))
    return out
