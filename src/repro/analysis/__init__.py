"""repro.analysis — static hot-path lint + Pallas kernel checker.

Two front ends over one rule registry (the ``verify.Oracle`` pattern):

* **trace lint** — traces registered hot-path entry points to jaxprs and
  checks host-transfer freedom, dtype-policy conformance, buffer-donation
  coverage, and recompile hazards (``rules_trace``);
* **pallas checker** — validates every kernel family's declarative
  ``KernelPlan`` (grid divisibility, index-map bounds, accumulator dtypes,
  dispatch symmetry) without executing kernels (``rules_pallas``);

plus an AST-level source lint (``repro.analysis.source``) banning host-sync
idioms in hot-path modules.

This package root imports only the jax-free core so ``repro.analysis.source``
stays usable in jax-less environments (CI's lint job).  The CLI —
``python -m repro.launch.analyze`` — loads the jax-backed rule modules.
"""
from repro.analysis.core import (AnalysisContext, Finding, Rule, RuleResult,
                                 SEVERITIES, all_rules, get_rule, register,
                                 run_rule)

__all__ = ["AnalysisContext", "Finding", "Rule", "RuleResult", "SEVERITIES",
           "all_rules", "get_rule", "register", "run_rule"]
