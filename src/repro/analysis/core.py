"""Rule registry core for repro.analysis — deliberately jax-free.

Mirrors the ``repro.verify`` Oracle registry one-for-one (frozen
descriptor dataclass, duplicate-rejecting ``register``, name-sorted
``all_rules``, a ``run_rule`` wrapper that turns exceptions into result
rows) so the two subsystems read the same.  The split from the jax-touching
modules is load-bearing: the AST source lint (``repro.analysis.source``)
must run in environments that only have the stdlib — CI's lint job installs
ruff and nothing else — so this module and ``source`` import no third-party
code.  Everything jaxpr-shaped lives in ``trace`` / ``rules_trace`` /
``rules_pallas`` and is pulled in lazily by the CLI.

A ``Rule`` inspects static artifacts (jaxprs, KernelPlans, source text) and
emits ``Finding``s.  Severity contract:

* ``fail`` — violates a hot-path invariant; CI gates on these.
* ``warn`` — suspicious but has known-legitimate instances; reported,
  never gating.
* ``info`` — measurement the rule wants on the record (e.g. donated-bytes
  accounting) with nothing wrong.
"""
from __future__ import annotations

import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

SEVERITIES = ("info", "warn", "fail")


@dataclass(frozen=True)
class Finding:
    """One observation by one rule against one target."""
    rule: str
    severity: str          # "info" | "warn" | "fail"
    target: str            # entry point / kernel family / file:line
    message: str
    evidence: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r} not in {SEVERITIES}")

    def row(self) -> Dict[str, Any]:
        return {"rule": self.rule, "severity": self.severity,
                "target": self.target, "message": self.message,
                "evidence": self.evidence}


@dataclass(frozen=True)
class Rule:
    """A named static check.  ``run(ctx) -> Sequence[Finding]``."""
    name: str
    doc: str
    run: Callable[["AnalysisContext"], Sequence[Finding]]
    tags: Tuple[str, ...] = ()


_REGISTRY: Dict[str, Rule] = {}


def register(name: str, doc: str, *, tags: Sequence[str] = ()):
    """Decorator: add a rule function to the registry (duplicates rejected)."""
    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"duplicate rule {name!r}")
        _REGISTRY[name] = Rule(name=name, doc=doc, run=fn, tags=tuple(tags))
        return fn
    return deco


def get_rule(name: str) -> Rule:
    return _REGISTRY[name]


def all_rules(tags: Sequence[str] = ()) -> List[Rule]:
    rules = sorted(_REGISTRY.values(), key=lambda r: r.name)
    if tags:
        want = set(tags)
        rules = [r for r in rules if want & set(r.tags)]
    return rules


class AnalysisContext:
    """Per-run state handed to every rule.

    ``arch`` is a configs name ("paper_mlp", "qwen2-1.5b", ...);
    ``precision`` the policy preset the hot paths are checked under.
    ``cache`` is a scratch dict rules share — the trace rules stash built
    entry-point artifacts there so each target is traced once per run,
    not once per rule.
    """

    def __init__(self, arch: str = "qwen2-1.5b", precision: str = "bf16"):
        self.arch = arch
        self.precision = precision
        self.cache: Dict[str, Any] = {}


@dataclass(frozen=True)
class RuleResult:
    name: str
    ok: bool                      # no fail-severity findings and no crash
    seconds: float
    findings: Tuple[Finding, ...] = ()
    error: Optional[str] = None

    @property
    def n_fail(self) -> int:
        return sum(f.severity == "fail" for f in self.findings)

    @property
    def n_warn(self) -> int:
        return sum(f.severity == "warn" for f in self.findings)

    def row(self) -> Dict[str, Any]:
        return {"rule": self.name, "ok": self.ok,
                "seconds": round(self.seconds, 3),
                "n_fail": self.n_fail, "n_warn": self.n_warn,
                "findings": [f.row() for f in self.findings],
                "error": self.error}


def run_rule(rule: Rule, ctx: AnalysisContext) -> RuleResult:
    """Execute one rule; a crash is a failed result, not a crashed run."""
    t0 = time.perf_counter()
    try:
        findings = tuple(rule.run(ctx))
    except Exception:
        return RuleResult(name=rule.name, ok=False,
                          seconds=time.perf_counter() - t0,
                          error=traceback.format_exc(limit=8))
    ok = not any(f.severity == "fail" for f in findings)
    return RuleResult(name=rule.name, ok=ok,
                      seconds=time.perf_counter() - t0, findings=findings)
