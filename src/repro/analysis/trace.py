"""Jaxpr tracing utilities for the trace-lint front end.

A ``TraceTarget`` names one hot-path entry point — a callable plus concrete
example args (real arrays or ShapeDtypeStructs; tracing never needs values).
``trace`` turns it into a ``TraceArtifact``: the closed jaxpr, the abstract
output, and any exception raised during tracing (a trace that *can't* be
built is itself a finding — see trace/recompile_hazard).

Tracing is the whole story here: nothing in this package compiles or runs
a step.  ``jax.make_jaxpr`` on a jitted function yields a single top-level
``pjit`` equation whose params carry ``donated_invars`` — that plus a
recursive equation walk is enough for every rule in ``rules_trace``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional, Sequence, Tuple

import jax
from jax import core as jcore


@dataclass(frozen=True)
class TraceTarget:
    """One registered hot-path entry point.

    ``donate`` is the argnums the call site *requests* (the analyzer checks
    the traced jaxpr actually honors them).  ``state_map`` pairs
    ``(arg_index, out_index)`` for carried state whose dtype must be
    preserved across the step (param/opt-state trees under a policy).
    """
    name: str
    fn: Callable
    args: Tuple[Any, ...]
    donate: Tuple[int, ...] = ()
    policy: str = "fp32"
    state_map: Tuple[Tuple[int, int], ...] = ()
    tags: Tuple[str, ...] = ()


@dataclass(frozen=True)
class TraceArtifact:
    target: TraceTarget
    jaxpr: Optional[Any] = None          # jax.core.ClosedJaxpr
    out_shape: Optional[Any] = None      # pytree of ShapeDtypeStruct
    error: Optional[str] = None
    meta: Dict[str, Any] = field(default_factory=dict)


def trace(target: TraceTarget) -> TraceArtifact:
    """Trace one target to (jaxpr, abstract outputs); never raises."""
    import traceback
    try:
        jaxpr = jax.make_jaxpr(target.fn)(*target.args)
        out_shape = jax.eval_shape(target.fn, *target.args)
    except Exception:
        return TraceArtifact(target=target,
                             error=traceback.format_exc(limit=8))
    return TraceArtifact(target=target, jaxpr=jaxpr, out_shape=out_shape)


# --------------------------------------------------------------------------
# equation walking
# --------------------------------------------------------------------------

def _sub_jaxprs(params: Dict[str, Any]) -> Iterator[Any]:
    """Yield every jaxpr nested in an equation's params (scan/cond/pjit/...)."""
    for v in params.values():
        leaves = v if isinstance(v, (tuple, list)) else (v,)
        for leaf in leaves:
            if isinstance(leaf, jcore.ClosedJaxpr):
                yield leaf.jaxpr
            elif isinstance(leaf, jcore.Jaxpr):
                yield leaf


def iter_eqns(jaxpr) -> Iterator[Any]:
    """Depth-first over all equations, descending into nested jaxprs.

    Accepts a ClosedJaxpr or raw Jaxpr.
    """
    inner = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
    for eqn in inner.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def top_pjit_eqn(jaxpr):
    """The sole top-level pjit equation of a traced jitted fn, or None.

    make_jaxpr of ``jax.jit(f)`` produces exactly one pjit eqn wrapping the
    body; its params hold ``donated_invars`` (leaf-expanded, one bool per
    flattened input).
    """
    inner = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
    pjits = [e for e in inner.eqns if e.primitive.name == "pjit"]
    if len(inner.eqns) == len(pjits) == 1:
        return pjits[0]
    return None


def donated_invars(artifact: TraceArtifact) -> Optional[Tuple[bool, ...]]:
    """Leaf-level donation mask of the target's top-level jit, or None."""
    if artifact.jaxpr is None:
        return None
    eqn = top_pjit_eqn(artifact.jaxpr)
    if eqn is None or "donated_invars" not in eqn.params:
        return None
    return tuple(eqn.params["donated_invars"])


def leaf_counts(args: Sequence[Any]) -> Tuple[int, ...]:
    """Flattened-leaf count per positional argument (donation accounting)."""
    return tuple(len(jax.tree_util.tree_leaves(a)) for a in args)
