"""Optimizers in pure JAX (no optax in this container).

API: ``opt.init(params) -> state``; ``opt.update(grads, state, params) ->
(new_params, new_state)``.  Learning-rate schedules are functions of
``state['count']``.

The paper trains with SGD + momentum (lr=0.01, momentum=0.9); the large
assigned architectures default to Adafactor (factored second moments — the
memory-efficient optimizer family the paper cites as [23, 24]).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable
    name: str


def _tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def sgd_momentum(lr=0.01, momentum=0.9, weight_decay=0.0) -> Optimizer:
    """SGD+momentum with fp32 momentum and fp32 update math (bit-identical
    to the historical behavior for fp32 params; half-precision params get
    the same fp32 accumulate-then-round treatment as adamw/adafactor)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"mu": _tree_map(lambda p: jnp.zeros_like(p, jnp.float32),
                                params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step_lr = lr_fn(state["count"])
        if weight_decay:
            grads = _tree_map(lambda g, p: g + weight_decay * p, grads, params)
        mu = _tree_map(lambda m, g: momentum * m + g.astype(jnp.float32),
                       state["mu"], grads)
        new_params = _tree_map(
            lambda p, m: (p.astype(jnp.float32) - step_lr * m).astype(p.dtype),
            params, mu)
        return new_params, {"mu": mu, "count": state["count"] + 1}

    return Optimizer(init, update, "sgdm")


def adamw(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"m": _tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                "v": _tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        step_lr = lr_fn(state["count"])
        m = _tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                      state["m"], grads)
        v = _tree_map(lambda v_, g: b2 * v_ + (1 - b2)
                      * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(p, m_, v_):
            mhat = m_ / bc1
            vhat = v_ / bc2
            step = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step_lr * step).astype(p.dtype)

        return _tree_map(upd, params, m, v), {"m": m, "v": v, "count": c}

    return Optimizer(init, update, "adamw")


def adafactor(lr=1e-3, decay=0.8, eps=1e-30, clip_threshold=1.0,
              min_dim_size_to_factor=32) -> Optimizer:
    """Shazeer & Stern Adafactor (factored 2nd moments, no momentum).

    >=2D params whose trailing two dims are both >= min_dim_size_to_factor get
    factored (row, col) accumulators — O(n+m) instead of O(n*m) state.
    """
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_size_to_factor \
            and p.shape[-2] >= min_dim_size_to_factor

    def init(params):
        def st(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"v": _tree_map(st, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        step_lr = lr_fn(state["count"])
        beta = 1.0 - c.astype(jnp.float32) ** -decay

        def upd(p, g, v):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if "vr" in v:
                vr = beta * v["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr / jnp.maximum(vr.mean(-1, keepdims=True), eps))[..., None] \
                    * vc[..., None, :]
                u = gf * jax.lax.rsqrt(jnp.maximum(denom, eps))
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta * v["v"] + (1 - beta) * g2}
                u = gf * jax.lax.rsqrt(jnp.maximum(nv["v"], eps))
            # update clipping by RMS
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            scale = jnp.maximum(jnp.sqrt(jnp.mean(jnp.square(
                p.astype(jnp.float32)))), 1e-3)
            return (p.astype(jnp.float32) - step_lr * scale * u).astype(p.dtype), nv

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_v = tdef.unflatten([o[1] for o in out])
        return new_params, {"v": new_v, "count": c}

    return Optimizer(init, update, "adafactor")


def _finite_tree(tree):
    """Scalar bool: every element of every leaf is finite."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.bool_(True)
    return jnp.stack([jnp.all(jnp.isfinite(g)) for g in leaves]).all()


def mixed_precision(inner: Optimizer, *, loss_scale: float = 1.0,
                    dynamic: bool = False,
                    growth_interval: int = 200) -> Optimizer:
    """Loss-scaling + fp32-master-weight wrapper (repro.precision policies).

    Contract: the step builder computes gradients of ``loss *
    state["loss_scale"]`` (see ``precision.read_loss_scale``); this wrapper
    unscales them in fp32, applies the inner optimizer to fp32 master weights
    (materialized only when params are stored in half precision), and casts
    the result back to the params' storage dtype.

    With ``dynamic=True`` a step whose unscaled gradients contain inf/nan is
    skipped entirely (params, inner state untouched) and the scale halves;
    after ``growth_interval`` consecutive clean steps it doubles.  With
    ``loss_scale=1`` and fp32 params the wrapper is bit-exact with the inner
    optimizer (dividing by 1.0 and selecting on an always-true predicate are
    exact) — verified by tests/test_precision.py.
    """

    def needs_master(params):
        return any(jnp.issubdtype(p.dtype, jnp.floating)
                   and p.dtype != jnp.float32
                   for p in jax.tree_util.tree_leaves(params))

    def init(params):
        state = {"loss_scale": jnp.float32(loss_scale),
                 "good_steps": jnp.zeros((), jnp.int32),
                 "skipped": jnp.zeros((), jnp.int32)}
        if needs_master(params):
            state["master"] = _tree_map(
                lambda p: p.astype(jnp.float32)
                if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
            state["inner"] = inner.init(state["master"])
        else:
            state["inner"] = inner.init(params)
        return state

    def update(grads, state, params):
        scale = state["loss_scale"]
        g = _tree_map(lambda x: x.astype(jnp.float32) / scale, grads)
        finite = _finite_tree(g)
        # inner update always runs (jit-safe); non-finite steps are selected
        # away below, and the zeroed grads keep the inner math finite
        g_safe = _tree_map(lambda x: jnp.where(finite, x, 0.0), g)
        master = state.get("master", params)
        new_master, new_inner = inner.update(g_safe, state["inner"], master)
        new_master = _tree_map(lambda n, o: jnp.where(finite, n, o),
                               new_master, master)
        new_inner = _tree_map(lambda n, o: jnp.where(finite, n, o),
                              new_inner, state["inner"])
        if dynamic:
            good = jnp.where(finite, state["good_steps"] + 1, 0)
            grow = finite & (good >= growth_interval)
            new_scale = jnp.where(
                grow, scale * 2.0,
                jnp.where(finite, scale, jnp.maximum(scale * 0.5, 1.0)))
            good = jnp.where(grow, 0, good)
        else:
            new_scale, good = scale, state["good_steps"]
        new_state = {"inner": new_inner, "loss_scale": new_scale,
                     "good_steps": good,
                     "skipped": state.get("skipped", jnp.int32(0))
                     + jnp.where(finite, 0, 1).astype(jnp.int32)}
        if "master" in state:
            new_state["master"] = new_master
            new_params = _tree_map(lambda m, p: m.astype(p.dtype),
                                   new_master, params)
        else:
            new_params = new_master
        return new_params, new_state

    return Optimizer(init, update, f"mp({inner.name})")


def step_guard(inner: Optimizer) -> Optimizer:
    """NaN/inf step guard for precisions with no loss-scaling wrapper
    (fp32, bf16 — repro.resilience).

    A step whose gradients contain inf/nan leaves params AND inner optimizer
    state untouched and increments a device-resident ``skipped`` counter —
    the exact skip-and-count semantics ``mixed_precision(dynamic=True)``
    already gives fp16, generalized to unscaled precisions.  Everything is
    ``jnp.where`` selects inside the jitted step: no host sync, no control
    flow divergence, scan-compatible.  Never stack this *outside*
    ``mixed_precision`` — it would see scaled gradients and veto steps the
    dynamic scale is supposed to cure by halving; ``mixed_precision`` counts
    its own skips into the same ``skipped`` key instead
    (``precision.read_skipped`` reads either wrapper's counter).

    On clean steps the selects are on an always-true predicate, so the
    wrapper is bit-exact with the inner optimizer.
    """

    def init(params):
        return {"inner": inner.init(params),
                "skipped": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        finite = _finite_tree(grads)
        # inner update always runs (jit-safe); zeroed grads keep its math
        # finite, the selects below discard the whole step when not finite
        g_safe = _tree_map(lambda g: jnp.where(finite, g, jnp.zeros_like(g)),
                           grads)
        new_params, new_inner = inner.update(g_safe, state["inner"], params)
        new_params = _tree_map(lambda n, o: jnp.where(finite, n, o),
                               new_params, params)
        new_inner = _tree_map(lambda n, o: jnp.where(finite, n, o),
                              new_inner, state["inner"])
        return new_params, {
            "inner": new_inner,
            "skipped": state["skipped"]
            + jnp.where(finite, 0, 1).astype(jnp.int32)}

    return Optimizer(init, update, f"guard({inner.name})")


def read_skipped(opt_state):
    """Device-resident skipped-step counter from a ``step_guard`` or
    ``mixed_precision`` state, or ``None`` when the optimizer is unguarded.
    Host-transferring the result is the caller's (end-of-phase) decision."""
    if isinstance(opt_state, dict) and "skipped" in opt_state:
        return opt_state["skipped"]
    return None


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    return {"sgdm": sgd_momentum, "adamw": adamw,
            "adafactor": adafactor}[name](lr=lr, **kw)
