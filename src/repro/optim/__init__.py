from .optimizers import (  # noqa: F401
    Optimizer, sgd_momentum, adamw, adafactor, make_optimizer,
    mixed_precision)
from .schedules import constant, cosine_warmup  # noqa: F401
