from .optimizers import (  # noqa: F401
    Optimizer, sgd_momentum, adamw, adafactor, make_optimizer,
    mixed_precision, step_guard, read_skipped)
from .schedules import constant, cosine_warmup  # noqa: F401
