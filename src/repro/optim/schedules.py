"""Learning-rate schedules (functions of step count)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_warmup(peak_lr, warmup_steps, total_steps, min_ratio=0.1):
    def fn(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = peak_lr * jnp.minimum(1.0, (s + 1) / max(warmup_steps, 1))
        frac = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
        cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(s < warmup_steps, warm, cos)
    return fn
