from .faults import (  # noqa: F401
    CheckpointCorruption, FakeClock, Fault, FaultSchedule, NaNInjection,
    StageCrash, StragglerDelay, TransientError)
from .supervisor import (  # noqa: F401
    RetryPolicy, StageHealth, SupervisedExecutor, UnrecoveredFaultError)
