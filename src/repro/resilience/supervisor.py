"""Self-healing stage execution: ``SupervisedExecutor``.

Wraps ``repro.dist.StageExecutor`` with per-stage health tracking, bounded
retry with exponential backoff + jitter, and automatic checkpoint-based
recovery.  The paper's zero-inter-stage-communication property is what
makes this cheap: a dead stage is restored from its OWN last valid
checkpoint and replays its OWN lost ticks — no other stage rolls back, no
other stage even pauses (contrast pipeline parallelism, where failure and
communication domains coincide and one rank's death stalls the world).

Correctness contract, pinned by the ``resilience/crash_equivalence``
oracle: because each stage's data access is deterministic by tick index
and the executor's metrics high-water mark suppresses replayed logging, a
run that crashes and recovers finishes **bitwise identical** to the
fault-free run.

The supervisor is host-side control plane by construction — it decides
*whether* to dispatch a tick, never touches the math inside one — so its
handful of host syncs (restoring checkpoints, trashing a crashed stage's
buffers) sit outside the hot path the `repro.analysis` trace lint guards.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.obs.events import EventLog, default_log
from repro.obs.registry import MetricsRegistry
from repro.resilience.faults import FaultSchedule, apply_corruption


class UnrecoveredFaultError(RuntimeError):
    """A stage exhausted its retry budget (or has no checkpoint to recover
    from) — the supervised run cannot reach the fault-free result."""


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with deterministic seeded jitter.

    Delay for attempt a (0-based) is ``base * factor**a * (1 + jitter*u)``
    with ``u ~ U[0,1)`` from a dedicated ``random.Random(seed)`` stream —
    replayable, and never synchronized across stages (each stage draws from
    its own offset seed, so two stages failing together don't retry in
    lockstep and re-collide)."""
    max_retries: int = 3
    base: float = 0.05
    factor: float = 2.0
    jitter: float = 0.5
    seed: int = 0

    def delays(self, stage: int):
        rng = random.Random(self.seed * 1_000_003 + stage)
        for a in range(self.max_retries):
            yield self.base * (self.factor ** a) \
                * (1.0 + self.jitter * rng.random())


class StageHealth:
    """One stage's control-plane state machine:
    ok -> retrying -> (recovering ->) ok, or -> failed."""
    OK = "ok"
    RETRYING = "retrying"        # backoff armed, live state intact
    RECOVERING = "recovering"    # backoff armed, live state LOST
    FAILED = "failed"            # retry budget exhausted

    def __init__(self, stage: int, policy: RetryPolicy):
        self.stage = stage
        self.state = self.OK
        self.attempts = 0
        self.retry_at = 0.0
        self._delays = policy.delays(stage)
        self._policy = policy

    def arm_retry(self, now: float, *, lost_state: bool) -> bool:
        """Move to retrying/recovering with the next backoff delay armed;
        False when the retry budget is exhausted (-> FAILED)."""
        try:
            delay = next(self._delays)
        except StopIteration:
            self.state = self.FAILED
            return False
        self.attempts += 1
        self.retry_at = now + delay
        if lost_state or self.state == self.RECOVERING:
            # once live state is lost it stays lost until a restore succeeds
            self.state = self.RECOVERING
        else:
            self.state = self.RETRYING
        return True

    def healthy(self) -> None:
        self.state = self.OK
        self.attempts = 0
        self.retry_at = 0.0
        self._delays = self._policy.delays(self.stage)


class SupervisedExecutor:
    """Drives a ``StageExecutor`` tick-by-tick under (injected or real)
    faults, keeping surviving stages on schedule while broken ones back
    off, restore, and replay.

    ``schedule``: a ``FaultSchedule`` consulted at the dispatch seam; None
    supervises real faults only (any exception out of a stage's dispatch
    is treated as transient until the retry budget runs out, then the
    stage is restored from checkpoint like a crash).
    ``clock``/``sleep``: injectable time (see ``faults.FakeClock``) so
    backoff costs no wall time in tests.
    ``strict=True`` raises ``UnrecoveredFaultError`` on the first stage
    that cannot be brought back; ``strict=False`` records it and keeps the
    other stages running (the chaos CLI counts the wreckage)."""

    def __init__(self, executor, *, schedule: Optional[FaultSchedule] = None,
                 policy: Optional[RetryPolicy] = None, ckpt_every: int = 1,
                 clock=None, sleep=None, strict: bool = True,
                 metrics: Optional[MetricsRegistry] = None,
                 event_log: Optional[EventLog] = None):
        if not executor.ckpt_dir:
            raise ValueError("SupervisedExecutor needs an executor with "
                             "ckpt_dir: recovery restores from per-stage "
                             "checkpoints")
        self.ex = executor
        self.schedule = schedule
        self.policy = policy or RetryPolicy()
        self.ckpt_every = max(1, int(ckpt_every))
        self.clock = clock or time.monotonic
        self.sleep = sleep or time.sleep
        self.strict = strict
        self.health = [StageHealth(k, self.policy)
                       for k in range(executor.n)]
        self.events: List[tuple] = []
        self.faults_seen: List[tuple] = []
        self.unrecovered: List[tuple] = []
        # observability (repro.obs): fault/recover/give_up tuples mirror into
        # the structured event log; health-state flips emit "health" records
        self.metrics = metrics if metrics is not None \
            else getattr(executor, "metrics", None) or MetricsRegistry()
        self.event_log = event_log if event_log is not None else default_log()
        self._faults_counter = self.metrics.counter(
            "supervisor_faults_total", help="faults seen, by kind")
        self._recoveries = self.metrics.counter(
            "supervisor_recoveries_total",
            help="successful checkpoint restores after a fault")
        self._give_ups = self.metrics.counter(
            "supervisor_give_ups_total", help="stages left unrecovered")
        if schedule is not None:
            hook = schedule.nan_batch_hook()
            if hook is not None:
                executor.batch_hook = hook

    # -- seam helpers ------------------------------------------------------

    def _emit(self, *event) -> None:
        self.events.append(event)
        kind = event[0]
        if kind == "fault":
            self.event_log.emit("fault", fault=event[1], stage=event[2],
                                tick=event[3])
            self._faults_counter.inc(1, kind=event[1])
        elif kind == "recover":
            self.event_log.emit("recover", stage=event[1], tick=event[2])
            self._recoveries.inc()
        elif kind == "give_up":
            self.event_log.emit("give_up", stage=event[1], why=event[2])
            self._give_ups.inc()
        # "tick"/"checkpoint" tuples stay legacy-only: the structured
        # checkpoint_save records come from checkpoint.checkpoint itself
        # (emitting here too would double-report every save)

    def _duration(self, k: int) -> int:
        return self.ex._duration(k)

    def _done(self, k: int) -> bool:
        return self.ex.ticks[k] >= self._duration(k) \
            or self.health[k].state == StageHealth.FAILED

    def _give_up(self, k: int, why: str) -> None:
        self.health[k].state = StageHealth.FAILED
        self.unrecovered.append((k, why))
        self._emit("give_up", k, why)
        if self.strict:
            raise UnrecoveredFaultError(
                f"stage {k} unrecovered: {why} "
                f"(events so far: {self.events[-5:]})")

    def _trash_stage(self, k: int) -> None:
        """Simulate the crash's effect: the stage's live device state is
        gone.  Zeros (not garbage) so that accidentally *using* the trashed
        state shows up as a loud bitwise mismatch, never flaky."""
        self.ex.params[k] = jax.tree_util.tree_map(
            jnp.zeros_like, self.ex.params[k])
        self.ex.opt_states[k] = jax.tree_util.tree_map(
            jnp.zeros_like, self.ex.opt_states[k])

    def _try_restore(self, k: int) -> bool:
        try:
            tick = self.ex.resume_stage(k)
        except (ValueError, FileNotFoundError) as e:
            self._give_up(k, f"restore failed: {e}")
            return False
        self.health[k].healthy()
        self._emit("recover", k, tick)
        return True

    def _checkpoint_if_due(self, k: int) -> None:
        if self.ex.ticks[k] % self.ckpt_every == 0 \
                or self.ex.ticks[k] >= self._duration(k):
            self.ex.checkpoint(stages=[k])
            self._emit("checkpoint", k, self.ex.ticks[k])

    # -- the supervised loop ----------------------------------------------

    def _advance(self, k: int) -> bool:
        """One visit to stage k (see ``_advance_inner``), with the health
        state machine's transitions published as structured "health" events
        — the supervisor's own logic never reads them back."""
        before = self.health[k].state
        try:
            return self._advance_inner(k)
        finally:
            # finally: strict-mode give_up raises out of the visit, but the
            # ok->failed flip must still reach the log
            after = self.health[k].state
            if after != before:
                self.event_log.emit("health", stage=k, old=before, new=after)

    def _advance_inner(self, k: int) -> bool:
        """One visit to stage k: dispatch its next tick, or handle/arm a
        fault.  Returns True when the visit made progress (so the outer
        loop knows whether anyone is merely waiting on a clock)."""
        h = self.health[k]
        now = self.clock()
        if h.state in (StageHealth.RETRYING, StageHealth.RECOVERING):
            if now < h.retry_at:
                return False                      # still backing off
            if h.state == StageHealth.RECOVERING and not self._try_restore(k):
                return False
            # RETRYING past its deadline falls through to the dispatch
            # attempt below; health resets only on SUCCESS — resetting here
            # would hand a repeatedly-failing stage a fresh budget per round
        i = self.ex.ticks[k]
        sched = self.schedule
        if sched is not None:
            straggler = sched.straggler_at(k, i)
            if straggler is not None:
                sched.consume(straggler)
                self.faults_seen.append(("straggler", k, i))
                self._emit("fault", "straggler", k, i)
                h.state = StageHealth.RETRYING    # state intact; just late
                h.retry_at = now + straggler.delay
                return True
            corruption = sched.corruption_at(k, i)
            if corruption is not None:
                sched.consume(corruption)
                self.faults_seen.append(("ckpt_corruption", k, i))
                self._emit("fault", "ckpt_corruption", k, i)
                apply_corruption(self.ex.ckpt_dir, k, corruption.mode)
                # the write that tore also takes the writer down: lose the
                # live state so recovery MUST route around the bad file
                self._trash_stage(k)
                if not h.arm_retry(now, lost_state=True):
                    self._give_up(k, f"ckpt_corruption at tick {i}")
                return True
            crash = sched.crash_at(k, i)
            if crash is not None:
                sched.consume(crash)
                self.faults_seen.append(("crash", k, i))
                self._emit("fault", "crash", k, i)
                self._trash_stage(k)
                if not h.arm_retry(now, lost_state=True):
                    self._give_up(k, f"crash at tick {i}")
                return True
            if sched.transient_failing(k, i):
                self.faults_seen.append(("transient", k, i))
                self._emit("fault", "transient", k, i)
                if not h.arm_retry(now, lost_state=False):
                    self._give_up(k, f"transient at tick {i}")
                return True
        try:
            self.ex.tick(i, stages=[k])
        except Exception as e:                    # a REAL dispatch failure
            self.faults_seen.append(("error", k, i))
            self._emit("fault", "error", k, i, repr(e))
            if not h.arm_retry(now, lost_state=False):
                self._give_up(k, f"dispatch error at tick {i}: {e!r}")
            return True
        h.healthy()
        self._emit("tick", k, i)
        self._checkpoint_if_due(k)
        return True

    def run(self, n_ticks: Optional[int] = None,
            stages: Optional[Sequence[int]] = None) -> "SupervisedExecutor":
        """Supervised round-robin: every healthy stage advances one tick per
        round, so a stage stuck in backoff never blocks the others.  Ends
        when every stage reaches its duration (or ``n_ticks``) or is FAILED.
        """
        ks = list(range(self.ex.n)) if stages is None else list(stages)

        def target(k):
            d = self._duration(k)
            return d if n_ticks is None else min(d, n_ticks)

        # tick-0 checkpoints first: a stage that crashes on its very first
        # tick must still have a restore point
        for k in ks:
            if self.ex.ticks[k] == 0:
                self.ex.checkpoint(stages=[k])
                self._emit("checkpoint", k, 0)
        while True:
            live = [k for k in ks if self.ex.ticks[k] < target(k)
                    and self.health[k].state != StageHealth.FAILED]
            if not live:
                break
            progressed = False
            for k in live:
                progressed = self._advance(k) or progressed
            if not progressed:
                # everyone alive is waiting on a retry_at deadline — jump
                # the clock to the earliest one instead of spinning
                now = self.clock()
                wake = min(self.health[k].retry_at for k in live
                           if self.health[k].state != StageHealth.OK)
                self.sleep(max(0.0, wake - now))
        return self

    # -- reporting ---------------------------------------------------------

    def report(self) -> dict:
        pending = [f.describe() for f in self.schedule.unconsumed()] \
            if self.schedule else []
        return {
            "ticks": list(self.ex.ticks),
            "faults_seen": [list(f) for f in self.faults_seen],
            "unrecovered": [[k, why] for k, why in self.unrecovered],
            "never_fired": pending,
            "health": [h.state for h in self.health],
            "n_events": len(self.events),
        }
