"""Deterministic, seeded fault injection for the stage-training stack.

The paper's zero-communication property makes per-stage fault tolerance
*testable*: a stage failure touches exactly one stage's state, so an
injected fault plus a correct recovery must reproduce the fault-free run
bit-for-bit.  This module supplies the faults; ``resilience.supervisor``
supplies the recovery.

Design rules:

* **Typed faults, explicit seams.** Each fault targets one seam the real
  system has anyway — the executor's tick dispatch (``StageCrash``,
  ``TransientError``, ``StragglerDelay``), its batch input path
  (``NaNInjection``, via ``StageExecutor.batch_hook``), or the checkpoint
  files on disk (``CheckpointCorruption``).  Nothing monkeypatches jitted
  code: injected faults live at the same host-level boundaries real faults
  (OOM, preemption, torn write, bad batch) arrive at.
* **Replayable from a seed.** ``FaultSchedule.sample(seed, ...)`` draws a
  schedule with a dedicated ``random.Random`` stream; the same seed always
  yields the same faults at the same (stage, tick) coordinates, so every
  chaos-CLI failure is reproducible by its seed alone.
* **Deterministic time.** ``FakeClock`` stands in for wall time in tests
  and the chaos CLI — backoff/straggler delays advance a counter instead
  of sleeping, keeping chaos runs fast and bit-stable.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

FAULT_KINDS = ("crash", "transient", "ckpt_corruption", "nan", "straggler")


@dataclass(frozen=True)
class Fault:
    """Base: a typed fault aimed at one stage at one tick."""
    stage: int
    tick: int

    kind = "fault"

    def describe(self) -> str:
        return f"{self.kind}(stage={self.stage}, tick={self.tick})"


@dataclass(frozen=True)
class StageCrash(Fault):
    """The stage process dies: its live params/optimizer state are lost and
    must come back from the stage's own checkpoints."""
    kind = "crash"


@dataclass(frozen=True)
class TransientError(Fault):
    """A device error that clears on retry (XLA async dispatch surfacing a
    transient RESOURCE_EXHAUSTED / network blip).  The stage's live state
    survives; the tick just has to be re-attempted.  ``failures`` is how
    many consecutive attempts fail before the error clears."""
    failures: int = 1
    kind = "transient"


@dataclass(frozen=True)
class CheckpointCorruption(Fault):
    """A torn/corrupted checkpoint file for this stage at (or nearest below)
    this tick — what a crash mid-``save_stage`` leaves behind without the
    atomic-write path, and what bit rot leaves behind with it.  ``mode``
    picks the damage: truncate the manifest, truncate the npz archive, or
    flip bytes inside the archive (checksum-detectable)."""
    mode: str = "truncate_manifest"   # | "truncate_npz" | "flip_bytes"
    kind = "ckpt_corruption"


@dataclass(frozen=True)
class NaNInjection(Fault):
    """Poison the stage's input batch at this tick with inf/NaN — a bad
    data shard or an upstream numeric blowup.  The NaN step guard must skip
    the poisoned optimizer step on-device."""
    value: float = float("inf")
    kind = "nan"


@dataclass(frozen=True)
class StragglerDelay(Fault):
    """The stage's device stalls for ``delay`` clock units at this tick.
    Zero inter-stage communication means the supervisor must keep every
    OTHER stage ticking at full speed while this one waits."""
    delay: float = 1.0
    kind = "straggler"


_KIND_TO_CLS = {"crash": StageCrash, "transient": TransientError,
                "ckpt_corruption": CheckpointCorruption, "nan": NaNInjection,
                "straggler": StragglerDelay}


@dataclass
class FaultSchedule:
    """An ordered, replayable set of faults keyed by (stage, tick).

    The schedule is data, not behavior: the supervisor consults it at each
    seam (``crash_at``, ``transient_at``, ...) and marks faults consumed so
    a replayed tick — the whole point of recovery — does not re-fire the
    fault that killed it the first time."""
    faults: List[Fault] = field(default_factory=list)
    seed: Optional[int] = None

    def __post_init__(self):
        self._consumed: set = set()
        self._transient_left: Dict[Tuple[int, int], int] = {
            (f.stage, f.tick): f.failures for f in self.faults
            if isinstance(f, TransientError)}

    # -- construction ------------------------------------------------------

    @classmethod
    def sample(cls, seed: int, *, n_stages: int, n_ticks: int,
               n_faults: int = 3,
               kinds: Sequence[str] = FAULT_KINDS) -> "FaultSchedule":
        """Draw a random schedule — same seed, same faults, forever.

        Faults land on distinct (stage, tick) coordinates with tick >= 1
        (tick 0 must complete once so every stage has a recovery point
        beyond its init checkpoint)."""
        rng = random.Random(seed)
        unknown = [k for k in kinds if k not in _KIND_TO_CLS]
        if unknown:
            raise ValueError(f"unknown fault kinds {unknown}; "
                             f"choose from {sorted(_KIND_TO_CLS)}")
        coords = [(s, t) for s in range(n_stages) for t in range(1, n_ticks)]
        rng.shuffle(coords)
        faults: List[Fault] = []
        for stage, tick in coords[:n_faults]:
            kind = rng.choice(list(kinds))
            if kind == "crash":
                faults.append(StageCrash(stage, tick))
            elif kind == "transient":
                faults.append(TransientError(stage, tick,
                                             failures=rng.randint(1, 2)))
            elif kind == "ckpt_corruption":
                mode = rng.choice(("truncate_manifest", "truncate_npz",
                                   "flip_bytes"))
                faults.append(CheckpointCorruption(stage, tick, mode=mode))
            elif kind == "nan":
                value = rng.choice((float("inf"), float("nan")))
                faults.append(NaNInjection(stage, tick, value=value))
            else:
                faults.append(StragglerDelay(stage, tick,
                                             delay=rng.uniform(0.5, 2.0)))
        faults.sort(key=lambda f: (f.tick, f.stage))
        return cls(faults=faults, seed=seed)

    # -- seam queries ------------------------------------------------------

    def _find(self, cls, stage: int, tick: int) -> Optional[Fault]:
        for f in self.faults:
            if (isinstance(f, cls) and f.stage == stage and f.tick == tick
                    and id(f) not in self._consumed):
                return f
        return None

    def consume(self, fault: Fault) -> None:
        self._consumed.add(id(fault))

    def crash_at(self, stage: int, tick: int) -> Optional[StageCrash]:
        return self._find(StageCrash, stage, tick)

    def straggler_at(self, stage: int, tick: int) -> Optional[StragglerDelay]:
        return self._find(StragglerDelay, stage, tick)

    def corruption_at(self, stage: int,
                      tick: int) -> Optional[CheckpointCorruption]:
        return self._find(CheckpointCorruption, stage, tick)

    def transient_failing(self, stage: int, tick: int) -> bool:
        """True while the transient fault at (stage, tick) still has
        failures left; each call consumes one failure."""
        f = self._find(TransientError, stage, tick)
        if f is None:
            return False
        left = self._transient_left.get((stage, tick), 0)
        if left <= 0:
            self.consume(f)
            return False
        self._transient_left[(stage, tick)] = left - 1
        if left - 1 <= 0:
            self.consume(f)
        return True

    def nan_batch_hook(self):
        """``StageExecutor.batch_hook`` implementing every ``NaNInjection``
        in this schedule: poisons element 0 of the first float array of the
        target stage's batch at the target tick.  Consumption is not needed
        — the poisoned step is *skipped* by the guard, so its replay (there
        is none: skipping IS the handling) never re-runs."""
        injections = {(f.stage, f.tick): f for f in self.faults
                      if isinstance(f, NaNInjection)}
        if not injections:
            return None

        def hook(stage: int, tick: int, batch):
            f = injections.get((stage, tick))
            if f is None:
                return batch
            return poison_batch(batch, f.value)

        return hook

    def unconsumed(self) -> List[Fault]:
        return [f for f in self.faults if id(f) not in self._consumed
                and not isinstance(f, NaNInjection)]

    def describe(self) -> List[str]:
        return [f.describe() for f in self.faults]


def poison_batch(batch, value: float = float("inf")):
    """Copy of ``batch`` with ``value`` written into element 0 of the first
    floating-point array found (tuple of arrays for the MLP backend, dict
    for the LM backend).  Integer-only batches (token ids) raise — poison
    the float mask/loss channel for those."""
    def poison_arr(a):
        a = np.array(a)            # host copy — never mutate the original
        a.reshape(-1)[0] = value
        return a

    if isinstance(batch, dict):
        for key in sorted(batch):
            if np.issubdtype(np.asarray(batch[key]).dtype, np.floating):
                out = dict(batch)
                out[key] = poison_arr(batch[key])
                return out
        raise ValueError("no floating-point array in dict batch to poison "
                         f"(keys={sorted(batch)})")
    seq = list(batch)
    for j, a in enumerate(seq):
        if np.issubdtype(np.asarray(a).dtype, np.floating):
            seq[j] = poison_arr(a)
            return tuple(seq)
    raise ValueError("no floating-point array in batch tuple to poison")


def apply_corruption(ckpt_root: str, stage: int,
                     mode: str = "truncate_manifest") -> Optional[str]:
    """Damage the NEWEST checkpoint of ``stage`` under ``ckpt_root`` the way
    ``mode`` says; returns the damaged path (None when the stage has no
    checkpoint yet).  Deterministic: the same mode on the same file always
    produces the same bytes."""
    import os

    from repro.checkpoint import available_steps
    from repro.dist.lifecycle import stage_dir

    d = stage_dir(ckpt_root, stage)
    steps = available_steps(d)
    if not steps:
        return None
    step = steps[-1]
    npz = os.path.join(d, f"ckpt_{step:08d}.npz")
    manifest = os.path.join(d, f"ckpt_{step:08d}.json")
    if mode == "truncate_manifest":
        data = open(manifest, "rb").read()
        with open(manifest, "wb") as f:
            f.write(data[: len(data) // 2])
        return manifest
    if mode == "truncate_npz":
        data = open(npz, "rb").read()
        with open(npz, "wb") as f:
            f.write(data[: len(data) // 2])
        return npz
    if mode == "flip_bytes":
        data = bytearray(open(npz, "rb").read())
        # flip a byte in the back half — payload bytes, so either the zip
        # CRC or the manifest leaf checksum must catch it
        pos = len(data) // 2 + len(data) // 4
        data[pos] ^= 0xFF
        with open(npz, "wb") as f:
            f.write(bytes(data))
        return npz
    raise ValueError(f"unknown corruption mode {mode!r}")


class FakeClock:
    """Deterministic stand-in for (time.monotonic, time.sleep).

    ``sleep`` advances the clock instead of blocking, so backoff and
    straggler delays cost zero wall time in tests and chaos runs while
    still exercising the deadline arithmetic."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)
        self.sleeps: List[float] = []

    def monotonic(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        dt = max(0.0, float(dt))
        self.sleeps.append(dt)
        self.t += dt

    def advance(self, dt: float) -> None:
        self.t += float(dt)
