"""Slot-based decode cache pool.

One device-resident cache pytree sized for ``n_slots`` concurrent requests
(the batch dim of every leaf), reusing the ring-buffered sliding-window
layouts from ``models.model.init_cache``.  Admitting a request scatters its
prefill cache rows into free slots via ``place_rows`` (the engine fuses the
same function into its jitted admission step); every cache family (KV
attention, ring window, mamba conv/ssm, xLSTM states, whisper cross-KV)
shares the same (G, B, ...) layout, so one scatter covers them all.
"""
from __future__ import annotations

import jax

from repro.models import model as M
from repro.precision import tree_bytes


def place_rows(pool_cache, group_cache, slots):
    """Scatter the rows of a prefilled group cache into pool slots `slots`
    ((R,) int32; batch axis is 1 under the group stack).  Full overwrite —
    a reused slot never leaks its predecessor.  jit-safe."""
    return jax.tree_util.tree_map(
        lambda p, c: p.at[:, slots].set(c.astype(p.dtype)),
        pool_cache, group_cache)


class CachePool:
    """Owns the decode cache for up to ``n_slots`` in-flight requests.
    Placement happens via ``place_rows`` fused into the engine's jitted
    admission step; this class owns allocation, sizing, and sharding."""

    def __init__(self, cfg, n_slots: int, cache_len: int, *, policy=None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.cache_len = cache_len
        # KV/conv leaves follow cfg.dtype (the precision policy's compute
        # dtype — bf16 halves the pool); recurrent carries (ssm/xLSTM/sLSTM
        # states) stay fp32, they are accumulators, not streams
        self.cache = M.init_cache(cfg, n_slots, cache_len)
        if policy is not None:
            self.cache = jax.device_put(
                self.cache, policy.cache_shardings(self.cache, n_slots))

    @property
    def nbytes(self) -> int:
        """Device bytes of the pool (dtype-aware memory accounting)."""
        return tree_bytes(self.cache)
