"""Decode cache pools: contiguous slots and block-paged allocation.

Two pool flavors share the ``(G, B, ...)`` leaf layout from
``models.model.init_cache``:

* ``CachePool`` — the original slot-contiguous pool: one full ``cache_len``
  row per slot.  Admitting a request scatters its prefill cache rows into
  free slots via ``place_rows`` (the engine fuses the same function into
  its jitted admission step).
* ``PagedCachePool`` — vLLM-style block paging over the same layouts.  The
  attention K/V leaves become ``(G, n_blocks, block_size, KV, hd)`` pools
  of fixed-size token blocks; a host-side ``BlockAllocator`` hands out
  refcounted physical blocks and per-request block tables, so a short
  request pins ``ceil(span / block_size)`` blocks instead of a whole
  max-length row and ``max_cache_tokens`` becomes an exact total-token
  budget.  Recurrent carries (mamba conv/ssm, xLSTM states) and whisper
  cross-KV are O(1) per request and stay slot-resident.  Shared-prefix
  reuse: the allocator keeps a registry of fully-filled prompt blocks
  keyed by their token prefix — a request whose prompt starts with a
  registered prefix increfs those blocks instead of re-prefilling them
  into fresh ones (the engine routes the duplicate writes to the reserved
  garbage block, so the first writer's values are the shared truth).

Physical block 0 is reserved as the **garbage block**: unallocated block-
table entries point at it, scatters for masked-off logical blocks land in
it, and no reader ever sees it (the ``slot <= pos`` validity mask in
decode attention covers exactly the allocated logical span).
"""
from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.precision import tree_bytes

GARBAGE_BLOCK = 0
# cache leaf names that page over token blocks; everything else (recurrent
# carries, cross-attention KV) is O(1) per request and stays slot-resident
PAGED_LEAVES = ("k", "v")
# bounded shared-prefix registry (FIFO eviction) — correctness never
# depends on an entry surviving, only on live entries being valid
PREFIX_REGISTRY_CAP = 512


def place_rows(pool_cache, group_cache, slots):
    """Scatter the rows of a prefilled group cache into pool slots `slots`
    ((R,) int32; batch axis is 1 under the group stack).  Full overwrite —
    a reused slot never leaks its predecessor.  jit-safe."""
    return jax.tree_util.tree_map(
        lambda p, c: p.at[:, slots].set(c.astype(p.dtype)),
        pool_cache, group_cache)


def place_blocks(pool_cache, group_cache, slots, write_rows, *,
                 block_size: int):
    """Paged admission scatter (jit-safe, fused into the admit step).

    Attention K/V leaves of ``group_cache`` ((G, R, lc, KV, hd)) are padded
    to whole blocks and scattered to the physical blocks in ``write_rows``
    ((R, nb) int32 — shared-prefix blocks point at the garbage block so the
    first writer's values survive); every other leaf row-scatters into
    ``slots`` exactly like ``place_rows``."""
    r, nb = write_rows.shape
    flat = write_rows.reshape(-1)
    out = {}
    for sk, grp in pool_cache.items():
        c = {}
        for name, p in grp.items():
            gc = group_cache[sk][name]
            if name in PAGED_LEAVES:
                g, _, lc = gc.shape[:3]
                pad = nb * block_size - lc
                if pad:
                    gc = jnp.pad(gc, ((0, 0), (0, 0), (0, pad),
                                      (0, 0), (0, 0)))
                gc = gc.reshape(g, r * nb, block_size, *p.shape[3:])
                c[name] = p.at[:, flat].set(gc.astype(p.dtype))
            else:
                c[name] = p.at[:, slots].set(gc.astype(p.dtype))
        out[sk] = c
    return out


class CachePool:
    """Owns the decode cache for up to ``n_slots`` in-flight requests, one
    contiguous ``cache_len`` row per slot.  Placement happens via
    ``place_rows`` fused into the engine's jitted admission step; this
    class owns allocation, sizing, and sharding."""

    def __init__(self, cfg, n_slots: int, cache_len: int, *, policy=None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.cache_len = cache_len
        # KV/conv leaves follow cfg.dtype (the precision policy's compute
        # dtype — bf16 halves the pool); recurrent carries (ssm/xLSTM/sLSTM
        # states) stay fp32, they are accumulators, not streams
        self.cache = M.init_cache(cfg, n_slots, cache_len)
        if policy is not None:
            self.cache = jax.device_put(
                self.cache, policy.cache_shardings(self.cache, n_slots))

    @property
    def nbytes(self) -> int:
        """Device bytes of the pool (dtype-aware memory accounting)."""
        return tree_bytes(self.cache)


# --------------------------------------------------------------------------
# block-paged pool
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class PagedAlloc:
    """One request's block allocation: ``ids`` in logical-block order (the
    first ``n_shared`` increfed from the shared-prefix registry, the rest
    freshly owned)."""
    ids: Tuple[int, ...]
    n_shared: int


class BlockAllocator:
    """Host-side refcounted allocator over physical cache blocks.

    Block 0 is the reserved garbage block — never allocated, never freed.
    ``gen`` counts how many times a block has been returned to the free
    pool; the shared-prefix registry snapshots it so stale entries (block
    recycled under a new owner) are detected on lookup.  ``check()``
    mirrors the scheduler's slot-leak discipline: every block is either
    free with refcount 0 or live with refcount > 0, exactly once."""

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the garbage "
                             f"block), got {n_blocks}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.refcount: List[int] = [0] * n_blocks
        self.gen: List[int] = [0] * n_blocks
        self.free_list: Deque[int] = deque(range(1, n_blocks))
        self.peak_used = 0

    @property
    def n_free(self) -> int:
        return len(self.free_list)

    @property
    def n_used(self) -> int:
        return (self.n_blocks - 1) - self.n_free

    def alloc(self, n: int) -> Optional[List[int]]:
        """n fresh blocks (refcount 1 each), or None if not enough free —
        all-or-nothing, so a failed admission never holds partial blocks."""
        if n > len(self.free_list):
            return None
        ids = [self.free_list.popleft() for _ in range(n)]
        for i in ids:
            assert self.refcount[i] == 0, f"block {i} on free list with refs"
            self.refcount[i] = 1
        self.peak_used = max(self.peak_used, self.n_used)
        return ids

    def incref(self, ids: Sequence[int]) -> None:
        for i in ids:
            assert i != GARBAGE_BLOCK and self.refcount[i] > 0, (
                f"incref of dead block {i}")
            self.refcount[i] += 1

    def free(self, ids: Sequence[int]) -> List[int]:
        """Drop one reference per id; blocks whose refcount hits zero go
        back to the free pool (gen bumped).  Returns the released ids."""
        released = []
        for i in ids:
            assert i != GARBAGE_BLOCK, "freeing the garbage block"
            assert self.refcount[i] > 0, f"double free of block {i}"
            self.refcount[i] -= 1
            if self.refcount[i] == 0:
                self.gen[i] += 1
                self.free_list.append(i)
                released.append(i)
        self._check()
        return released

    def _check(self) -> None:
        free = set(self.free_list)
        assert len(free) == len(self.free_list), "free-list duplicate"
        for i in range(1, self.n_blocks):
            if i in free:
                assert self.refcount[i] == 0, f"block {i} free with refs"
            else:
                assert self.refcount[i] > 0, f"block {i} leaked (0 refs, " \
                    "not free)"

    # alias so callers can run the invariant sweep explicitly (tests)
    check = _check


class PagedCachePool:
    """Block-paged decode cache: attention K/V over physical token blocks,
    recurrent/cross leaves slot-resident; presents the same stacked
    ``(G, B, ...)`` leaf layout to the engine's jitted scatters.

    ``max_tokens`` (the engine's ``max_cache_tokens``) is the exact total
    K/V token budget: ``max_tokens // block_size`` allocatable blocks
    shared by ALL in-flight requests, instead of the contiguous pool's
    per-slot rows.  Without it the pool matches the contiguous capacity
    (``n_slots`` full logical rows)."""

    def __init__(self, cfg, n_slots: int, cache_len: int, *,
                 block_size: int = 16, max_tokens: Optional[int] = None,
                 policy=None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.block_size = bs = block_size
        window = cfg.sliding_window
        # true logical length (the decode ring modulus); storage pads up to
        # whole blocks, reads mask `slot < attn_len` so the pad is inert
        self.attn_len = min(cache_len, window) if window else cache_len
        self.blocks_per_slot = nb = max(1, -(-self.attn_len // bs))
        structs = jax.eval_shape(lambda: M.init_cache(cfg, n_slots,
                                                      cache_len))
        self.has_attn = any("k" in grp for grp in structs.values())
        if max_tokens is not None:
            n_alloc = max(1, max_tokens // bs)
        else:
            n_alloc = n_slots * nb
        self.n_blocks = n_alloc + 1          # +1: the garbage block
        self.allocator = BlockAllocator(self.n_blocks, bs)
        # shared-prefix reuse needs token-determined K/V: absolute positions
        # only (no ring wraparound) and no per-request side inputs
        self.share_prefixes = (not window and not cfg.enc_dec
                               and cfg.frontend != "vision")
        self._prefix: "OrderedDict[Tuple[int, ...], Tuple[Tuple[int, ...], Tuple[int, ...]]]" = OrderedDict()  # noqa: E501
        self.prefix_hits = 0                 # shared blocks reused (total)
        self.prefix_lookups = 0
        self.cache = self._init_cache(structs)
        if policy is not None:
            self.cache = jax.device_put(
                self.cache, policy.cache_shardings(self.cache, n_slots))

    def _init_cache(self, structs) -> Dict[str, Dict[str, Any]]:
        bs, npb = self.block_size, self.n_blocks
        cache: Dict[str, Dict[str, Any]] = {}
        for sk, grp in structs.items():
            c = {}
            for name, sd in grp.items():
                if name in PAGED_LEAVES:
                    g, _, _, kvh, hd = sd.shape
                    c[name] = jnp.zeros((g, npb, bs, kvh, hd), sd.dtype)
                elif name == "m":            # sLSTM max-state identity
                    c[name] = jnp.full(sd.shape, -1e9, sd.dtype)
                else:
                    c[name] = jnp.zeros(sd.shape, sd.dtype)
            cache[sk] = c
        return cache

    @property
    def nbytes(self) -> int:
        return tree_bytes(self.cache)

    def blocks_for_span(self, span: int) -> int:
        """Blocks one request of ``span`` total tokens pins.  Windowed
        caches ring over the full per-slot block set regardless of span."""
        if not self.has_attn:
            return 0
        if self.cfg.sliding_window:
            return self.blocks_per_slot
        return min(self.blocks_per_slot, -(-span // self.block_size))

    def allocate(self, prompt_tokens: Sequence[int],
                 span: int) -> Optional[PagedAlloc]:
        """Blocks for one admission (None = not enough free blocks).

        Leading fully-filled prompt blocks are looked up in the shared-
        prefix registry; on a hit they are increfed instead of allocated
        (the engine then routes their prefill writes to the garbage
        block).  Only blocks strictly inside the prompt are shareable —
        decode writes land at pos >= prompt_len, past every shared block."""
        need = self.blocks_for_span(span)
        if need == 0:
            return PagedAlloc(ids=(), n_shared=0)
        bs = self.block_size
        tokens = tuple(int(t) for t in prompt_tokens)
        shareable = min(len(tokens) // bs, need) if self.share_prefixes \
            else 0
        shared: List[int] = []
        if shareable:
            self.prefix_lookups += 1
            for k in range(shareable, 0, -1):
                ent = self._prefix.get(tokens[:k * bs])
                if ent is None:
                    continue
                ids, gens = ent
                if all(self.allocator.refcount[i] > 0
                       and self.allocator.gen[i] == g
                       for i, g in zip(ids, gens)):
                    shared = list(ids)
                    break
                del self._prefix[tokens[:k * bs]]    # stale: owner retired
        fresh = self.allocator.alloc(need - len(shared))
        if fresh is None:
            return None
        self.allocator.incref(shared)
        ids = shared + fresh
        self.prefix_hits += len(shared)
        for k in range(len(shared) + 1, shareable + 1):
            key = tokens[:k * bs]
            self._prefix[key] = (tuple(ids[:k]),
                                 tuple(self.allocator.gen[i]
                                       for i in ids[:k]))
            self._prefix.move_to_end(key)
            while len(self._prefix) > PREFIX_REGISTRY_CAP:
                self._prefix.popitem(last=False)
        return PagedAlloc(ids=tuple(ids), n_shared=len(shared))

    def release(self, ids: Sequence[int]) -> None:
        """Retire one owner: decref every block; last owner frees them
        (the registry detects recycled blocks via the bumped gen)."""
        self.allocator.free(ids)

    def table_row(self, alloc: PagedAlloc) -> List[int]:
        """(nb,) physical ids for the decode block table, garbage-padded."""
        row = list(alloc.ids)
        return row + [GARBAGE_BLOCK] * (self.blocks_per_slot - len(row))

    def write_row(self, alloc: PagedAlloc) -> List[int]:
        """(nb,) physical ids for the admission scatter: shared-prefix
        blocks are redirected to the garbage block (already filled by the
        first writer — rewriting them would race ulp-level duplicates)."""
        row = [GARBAGE_BLOCK] * alloc.n_shared + list(
            alloc.ids[alloc.n_shared:])
        return row + [GARBAGE_BLOCK] * (self.blocks_per_slot - len(row))
