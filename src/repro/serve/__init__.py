"""`repro.serve` — the serving counterpart of `repro.train`.

A typed request/response API fronted by an ``Engine`` that owns the params,
a KV/state cache pool (contiguous slots, or block-paged with shared-prefix
reuse via ``paged=True``), a continuous-batching scheduler, and a fused
decode+sample inner loop:

    from repro.serve import Engine, GenerationConfig, Request

    engine = Engine(cfg, params, max_slots=8)
    outs = engine.generate([
        Request(tokens=[1, 2, 3],
                gen=GenerationConfig(max_new_tokens=16)),
        Request(tokens=[4, 5], gen=GenerationConfig(temperature=0.8,
                                                    top_p=0.95, seed=7)),
    ])

    for ev in engine.stream(reqs):          # per-token streaming deltas
        ...

Pass ``plan=``/``stage_params=`` to serve the paper's partitions as
deployable stages, and ``policy=`` to route through the production-mesh
sharding plumbing.
"""
from repro.serve.api import (Completion, GenerationConfig, Request,
                             StreamEvent)
from repro.serve.engine import Engine
from repro.serve.kv_cache import (BlockAllocator, CachePool, PagedAlloc,
                                  PagedCachePool)
from repro.serve.scheduler import Scheduler, SlotState
from repro.serve.staged import staged_decode_step, staged_prefill

__all__ = [
    "Completion", "GenerationConfig", "Request", "StreamEvent", "Engine",
    "CachePool", "BlockAllocator", "PagedAlloc", "PagedCachePool",
    "Scheduler", "SlotState", "staged_decode_step", "staged_prefill",
]
