"""Batched, jit-safe token sampling.

All knobs are per-slot ARRAYS (temperature, top_k, top_p), so one jitted
function serves a continuous batch of requests with heterogeneous configs —
and the whole thing folds into the fused decode scan: no ``jax.random.split``
or ``argmax`` round-trips through the host per token.

Conventions (matching ``GenerationConfig``): temperature <= 0 -> greedy,
top_k == 0 -> no top-k filter, top_p >= 1 -> no nucleus filter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-1e30)


def make_key(seed) -> jax.Array:
    """Raw uint32 key data for one request's private sampling stream."""
    return jax.random.PRNGKey(seed)


def split_keys(keys):
    """Per-slot split. keys: (S, 2) uint32 -> (carry (S,2), sample (S,2))."""
    ks = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
    return ks[:, 0], ks[:, 1]


def filter_logits(logits, top_k, top_p):
    """Fused top-k + nucleus filter off ONE descending sort (this runs per
    token inside the fused decode scan — the hottest serving loop).

    logits: (S, V); top_k: (S,) int32 (0 disables); top_p: (S,) float
    (>= 1 disables).  Both filters keep a prefix of the descending sort:
    top-k caps the prefix at k, top-p at the smallest prefix with
    cumulative prob >= p over the top-k-renormalized distribution (so the
    argmax token always survives)."""
    v = logits.shape[-1]
    desc = jnp.sort(logits, axis=-1)[:, ::-1]
    rank = jnp.arange(v)[None, :]
    keep_k = (top_k <= 0)[:, None] | (rank < top_k[:, None])
    probs = jax.nn.softmax(jnp.where(keep_k, desc, NEG_INF), axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # rank 0 survives unconditionally so degenerate knobs (top_p <= 0)
    # degrade to greedy, never to an all-masked uniform draw
    keep_p = (top_p >= 1.0)[:, None] | ((cum - probs) < top_p[:, None]) \
        | (rank == 0)
    keep = keep_k & keep_p
    cutoff = jnp.min(jnp.where(keep, desc, jnp.inf), axis=-1)
    return jnp.where(logits >= cutoff[:, None], logits, NEG_INF)


def mode_for(configs) -> str:
    """Cheapest statically-sufficient sampling mode for a set of
    GenerationConfigs.  Disabled knobs are mathematical no-ops, so dropping
    them changes compile cost only, never tokens: "greedy" skips sampling
    entirely, "temp" skips the top-k/top-p sorts, "full" does everything.
    """
    if all(g.temperature <= 0 for g in configs):
        return "greedy"
    if all(g.top_k == 0 and g.top_p >= 1.0 for g in configs):
        return "temp"
    return "full"


def sample_tokens(logits, keys, temperature, top_k, top_p, *, mode="full"):
    """One sampling step for a continuous batch.

    logits: (S, V) ALREADY sliced to the real vocab (pad rows of the 128-
    aligned unembedding must never be sampled); keys: (S, 2) uint32;
    temperature/top_k/top_p: (S,) arrays.  `mode` (static): see mode_for.
    Returns (S,) int32 tokens.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if mode == "greedy":
        return greedy
    lg = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)[:, None]
    if mode == "full":
        lg = filter_logits(lg, top_k, top_p)
    drawn = jax.vmap(jax.random.categorical)(keys, lg).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, drawn)
