"""Continuous-batching scheduler: slot bookkeeping + admission control.

Pure host-side logic (the device side lives in ``kv_cache`` / ``engine``).
Slots move free -> active on ``admit`` and back on ``retire``; every
transition is audited (``events``) and checked (``_check``) so a leaked or
double-booked slot fails loudly instead of silently serving two requests
from one cache row.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


@dataclass
class SlotState:
    """Host-side state of one in-flight request."""
    req_idx: int                     # position in the generate() request list
    request: Any
    n_prompt: int
    emitted: List[int] = field(default_factory=list)
    finish_reason: Optional[str] = None

    @property
    def remaining(self) -> int:
        return self.request.gen.max_new_tokens - len(self.emitted)


class Scheduler:
    """Admit requests into free cache slots; retire on EOS / length."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.free: List[int] = list(range(n_slots))
        self.active: Dict[int, SlotState] = {}
        self.events: List[Tuple[str, int]] = []
        self.max_concurrent = 0

    def admit(self, req_idx: int, request, n_prompt: int) -> int:
        if not self.free:
            raise RuntimeError("admit() with no free slot")
        slot = self.free.pop(0)
        assert slot not in self.active, f"slot {slot} double-booked"
        self.active[slot] = SlotState(req_idx, request, n_prompt)
        self.events.append(("admit", slot))
        self.max_concurrent = max(self.max_concurrent, len(self.active))
        self._check()
        return slot

    def retire(self, slot: int) -> SlotState:
        st = self.active.pop(slot)
        self.free.append(slot)
        self.events.append(("retire", slot))
        self._check()
        return st

    def min_remaining(self) -> int:
        """Tokens until the nearest guaranteed retirement (schedules the
        fused-decode chunk length)."""
        return min(st.remaining for st in self.active.values())

    def _check(self) -> None:
        ids = sorted(self.free) + sorted(self.active)
        assert sorted(ids) == list(range(self.n_slots)), (
            f"slot leak: free={self.free} active={sorted(self.active)}")
