"""Continuous-batching scheduler: slot bookkeeping + admission control.

Pure host-side logic (the device side lives in ``kv_cache`` / ``engine``).
Slots move free -> active on ``admit`` and back on ``retire``; every
transition is audited (``events``) and checked (``_check``) so a leaked or
double-booked slot fails loudly instead of silently serving two requests
from one cache row.

The scheduler also owns the wait queue (repro.resilience): requests enter
via ``submit`` stamped with their submission time, and ``expire_queued`` /
``overdue_active`` implement graceful degradation — a request that has
outwaited ``max_queue_wait_ms`` or its own ``deadline_ms`` is REJECTED
(audited ``("reject", req_idx)`` event) instead of leaking in a stalled
engine.  With no deadlines configured the queue is plain FIFO and the
event stream is exactly the legacy admit/retire sequence.

Observability (repro.obs): every audited transition is mirrored into the
structured ``event_log`` exactly once, at the same site the legacy tuple
is appended — ``admit``/``retire`` records carry ``slot`` (+ ``req``),
``reject`` records carry ``req``.  The legacy ``events`` tuple list is
unchanged; tests pin the one-to-one mapping.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

from repro.obs.events import EventLog, default_log


@dataclass
class SlotState:
    """Host-side state of one in-flight request."""
    req_idx: int                     # position in the generate() request list
    request: Any
    n_prompt: int
    emitted: List[int] = field(default_factory=list)
    finish_reason: Optional[str] = None
    arrival: float = 0.0             # submission time (deadline epoch)
    # physical cache block ids owned by this request (paged pool only) —
    # the engine releases them back to the BlockAllocator at retirement
    blocks: Optional[Tuple[int, ...]] = None

    @property
    def remaining(self) -> int:
        return self.request.gen.max_new_tokens - len(self.emitted)


class Scheduler:
    """Admit requests into free cache slots; retire on EOS / length;
    reject on queue timeout / missed deadline."""

    def __init__(self, n_slots: int, *,
                 max_queue_wait_ms: Optional[float] = None,
                 event_log: Optional[EventLog] = None):
        self.n_slots = n_slots
        self.max_queue_wait_ms = max_queue_wait_ms
        self.free: List[int] = list(range(n_slots))
        self.active: Dict[int, SlotState] = {}
        self.queue: Deque[Tuple[int, Any, float]] = deque()
        self.events: List[Tuple[str, int]] = []
        self.event_log = event_log if event_log is not None else default_log()
        self.max_concurrent = 0

    # -- queue -------------------------------------------------------------

    def submit(self, req_idx: int, request, now: float = 0.0) -> None:
        """Enqueue a request, stamped with its submission time — the epoch
        both the queue-wait limit and the request's own deadline count
        from."""
        self.queue.append((req_idx, request, now))

    def queued(self) -> int:
        return len(self.queue)

    def take(self, n: int,
             now: Optional[float] = None) -> List[Tuple[int, Any, float]]:
        """Pop up to ``n`` queued entries in arrival order.  With ``now``
        (open-loop traffic), only entries whose stamped submission time has
        passed are eligible — and ALL of them are scanned, not just a
        prefix: a future-stamped head (out-of-order ``submit``) must not
        starve an already-arrived entry queued behind it."""
        if now is None:
            out: List[Tuple[int, Any, float]] = []
            while self.queue and len(out) < n:
                out.append(self.queue.popleft())
            return out
        arrived = [e for e in self.queue if e[2] <= now]
        arrived.sort(key=lambda e: e[2])  # stable: FIFO within equal stamps
        out = arrived[:n]
        taken = {id(e) for e in out}
        self.queue = deque(e for e in self.queue if id(e) not in taken)
        return out

    def requeue_front(self,
                      entries: List[Tuple[int, Any, float]]) -> None:
        """Push taken entries back to the head (original order preserved) —
        used when paged-cache admission runs out of free blocks mid-batch
        and the tail of a ``take`` must wait for the next retirement."""
        for e in reversed(entries):
            self.queue.appendleft(e)

    def next_arrival(self) -> Optional[float]:
        """Earliest stamped submission time still queued (None if empty)."""
        return min((t for _, _, t in self.queue), default=None)

    def expire_queued(self, now: float) -> List[Tuple[int, Any]]:
        """Drop every queued request that has outwaited the queue limit or
        its own ``deadline_ms``; returns the rejected (req_idx, request)
        pairs (audited, in arrival order)."""
        kept: Deque[Tuple[int, Any, float]] = deque()
        rejected: List[Tuple[int, Any]] = []
        for req_idx, request, t in self.queue:
            waited_ms = (now - t) * 1000.0
            deadline = getattr(request, "deadline_ms", None)
            if (self.max_queue_wait_ms is not None
                    and waited_ms > self.max_queue_wait_ms) \
                    or (deadline is not None and waited_ms > deadline):
                rejected.append((req_idx, request))
                self.events.append(("reject", req_idx))
                self.event_log.emit("reject", req=req_idx)
            else:
                kept.append((req_idx, request, t))
        self.queue = kept
        return rejected

    def overdue_active(self, now: float) -> List[int]:
        """Slots whose request blew its ``deadline_ms`` mid-decode — the
        engine sheds these (retire with "rejected", partial tokens kept)
        so one slow request can't hold a cache slot forever."""
        return [slot for slot, st in self.active.items()
                if getattr(st.request, "deadline_ms", None) is not None
                and (now - st.arrival) * 1000.0 > st.request.deadline_ms]

    # -- slots -------------------------------------------------------------

    def admit(self, req_idx: int, request, n_prompt: int,
              arrival: float = 0.0) -> int:
        if not self.free:
            raise RuntimeError("admit() with no free slot")
        slot = self.free.pop(0)
        assert slot not in self.active, f"slot {slot} double-booked"
        self.active[slot] = SlotState(req_idx, request, n_prompt,
                                      arrival=arrival)
        self.events.append(("admit", slot))
        self.event_log.emit("admit", slot=slot, req=req_idx)
        self.max_concurrent = max(self.max_concurrent, len(self.active))
        self._check()
        return slot

    def retire(self, slot: int) -> SlotState:
        st = self.active.pop(slot)
        self.free.append(slot)
        self.events.append(("retire", slot))
        self.event_log.emit("retire", slot=slot, req=st.req_idx)
        self._check()
        return st

    def min_remaining(self) -> int:
        """Tokens until the nearest guaranteed retirement (schedules the
        fused-decode chunk length).  Returns 0 when no slot is active —
        e.g. every active slot was shed mid-tick by ``overdue_active`` —
        so the engine idles to the next arrival instead of dying on a
        ``min()`` of an empty sequence."""
        if not self.active:
            return 0
        return min(st.remaining for st in self.active.values())

    def _check(self) -> None:
        ids = sorted(self.free) + sorted(self.active)
        assert sorted(ids) == list(range(self.n_slots)), (
            f"slot leak: free={self.free} active={sorted(self.active)}")
