"""Typed request/response surface of `repro.serve`.

A ``Request`` carries one prompt (plus any modality payloads the arch needs)
and a ``GenerationConfig``; the ``Engine`` turns it into a ``Completion``.
Prompts in one ``Engine.generate`` call may have different lengths and
different generation configs — the scheduler batches them continuously.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional, Tuple


@dataclass(frozen=True)
class GenerationConfig:
    """Per-request sampling/termination knobs.

    temperature <= 0 means greedy; top_k == 0 and top_p >= 1 disable the
    respective filters.  ``seed`` keys this request's private sampling stream
    (continuous batching never couples streams across requests).
    """
    max_new_tokens: int = 32
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    eos_id: Optional[int] = None
    seed: int = 0

    def replace(self, **kw) -> "GenerationConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class Request:
    """One prompt. ``tokens``: 1-D int sequence (list/np/jnp).

    frames / image_embeds: optional modality payloads (whisper / VLM); the
    engine fills in zero stubs when the arch needs them and they are omitted.
    """
    tokens: Any
    gen: GenerationConfig = GenerationConfig()
    frames: Any = None
    image_embeds: Any = None
    id: Optional[str] = None
    # total latency budget in milliseconds, measured from submission: the
    # engine rejects the request (finish_reason "rejected", partial tokens
    # kept) once the budget elapses — queued OR mid-decode.  None = no
    # deadline (the pre-resilience behavior)
    deadline_ms: Optional[float] = None


@dataclass(frozen=True)
class StreamEvent:
    """One increment from ``Engine.stream``.

    kind == "delta": ``token`` is the next generated token of request
    ``req_idx`` (deltas for one request arrive in order; deltas of
    different requests interleave with the continuous batch).
    kind == "done": ``completion`` is the request's final ``Completion``
    (its ``tokens`` are exactly the deltas streamed before it).
    """
    kind: str                        # "delta" | "done"
    req_idx: int
    id: Optional[str]
    token: Optional[int] = None
    completion: Optional["Completion"] = None


@dataclass(frozen=True)
class Completion:
    """The engine's answer to one Request."""
    id: Optional[str]
    prompt_tokens: Tuple[int, ...]
    tokens: Tuple[int, ...]          # generated tokens (eos included if hit)
    # "eos" | "length" | "rejected" — "rejected" marks load shedding (queue
    # timeout, missed deadline, or cache-pressure admission control); its
    # tokens are whatever was emitted before the cut, possibly none
    finish_reason: str

    @property
    def n_prompt(self) -> int:
        return len(self.prompt_tokens)

    @property
    def n_generated(self) -> int:
        return len(self.tokens)
