"""PartitionPlan-aware serving: prefill/decode over per-stage param trees.

The paper's partitions are independently trainable AND independently
deployable — this module serves directly from the per-stage trees
(``partition.slice_stage_params``) without joining them.  Stage 0 owns the
embedding (+ encoder/frontend), the last stage owns the final norm and
unembedding (reading the frozen ``tied_unembed`` snapshot when embeddings
are tied).  The caches stay in the full stacked (G, B, ...) layout so the
same ``CachePool`` serves both modes; each stage touches only its group
slice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import model as M


def stage_params_from_checkpoints(cfg, plan, ckpt_root, *, step=None,
                                  devices=None):
    """Per-stage param trees for staged serving, restored straight from a
    ``repro.dist.lifecycle`` per-stage checkpoint directory — the paper's
    partitions deploy WITHOUT ever being joined.

    The restore needs only tree *structure*, so the ``like`` trees are
    ``jax.eval_shape`` stand-ins (no weights materialize besides the
    checkpointed ones).  Feed the result to ``serve.Engine(cfg, plan=plan,
    stage_params=...)``; ``devices`` optionally pins stage k's tree to
    ``devices[k]`` on the way in."""
    from repro.core import partition
    from repro.dist import lifecycle

    def all_likes():
        params = M.init_params(cfg, jax.random.PRNGKey(0))  # repro: allow-const-key
        return [partition.slice_stage_params(cfg, plan, params, k)
                for k in range(plan.n_stages)]
    likes = jax.eval_shape(all_likes)   # ONE abstract trace for all stages
    sps = lifecycle.load_stage_params(ckpt_root, likes, step=step,
                                      devices=devices)
    if devices is None:
        sps = [jax.tree_util.tree_map(jnp.asarray, sp) for sp in sps]
    return sps


def _unembed_params(cfg, last_stage_params):
    """Param view for the last stage's unembedding (tied-snapshot aware)."""
    if "tied_unembed" in last_stage_params:
        return {"tok_embed": last_stage_params["tied_unembed"]}
    return last_stage_params


def _stage_cache(plan, k, cache):
    g0, g1 = plan.bounds[k]
    return jax.tree_util.tree_map(lambda a: a[g0:g1], cache)


def staged_prefill(cfg, plan, stage_params, batch, cache_len):
    """Prompt forward through the stage chain, building the decode cache.

    Same contract as ``model.prefill``: (last_token_logits, cache, next_pos);
    the returned cache is stacked over ALL groups (stage slices concatenated)
    so it drops into the shared CachePool.
    """
    x, enc_out, _ = M.embed_inputs(cfg, stage_params[0], batch)
    s = x.shape[1]
    rope_cs = M.rope_for(cfg, jnp.arange(s))
    caches = []
    for k in range(plan.n_stages):
        x, _, c = M.forward_groups(cfg, stage_params[k]["groups"], x,
                                   rope_cs=rope_cs, enc_out=enc_out,
                                   collect_cache=True, remat=False)
        caches.append(c)
    full = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *caches)
    cache = M.repack_prefill_cache(cfg, full, cache_len)
    last = stage_params[-1]
    xl = L.norm_apply(last["final_norm"], x[:, -1:])
    logits = M.unembed(cfg, _unembed_params(cfg, last), xl)[:, 0]
    return logits, cache, jnp.int32(s)


def staged_decode_step(cfg, plan, stage_params, cache, tok, pos, paged=None):
    """One decode step through the stage chain. Same contract as
    ``model.decode_step`` (pos: scalar or per-request vector; ``paged``
    routes attention K/V through one block table shared by every stage —
    the paged leaves keep the leading G axis, so stage slices still work)."""
    x, rope_cs = M.decode_embed(cfg, stage_params[0], tok, pos)
    new_parts = []
    for k in range(plan.n_stages):
        x, nc = M.decode_groups(cfg, stage_params[k]["groups"],
                                _stage_cache(plan, k, cache), x, rope_cs, pos,
                                paged=paged)
        new_parts.append(nc)
    new_cache = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *new_parts)
    last = stage_params[-1]
    x = L.norm_apply(last["final_norm"], x)
    logits = M.unembed(cfg, _unembed_params(cfg, last), x)[:, 0]
    return logits, new_cache
