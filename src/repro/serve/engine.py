"""The serving engine: continuous batching over a contiguous or paged cache pool.

``Engine.generate(requests)`` runs prefill-on-admit + a fused multi-token
decode inner loop:

* Admission: queued requests are grouped by prompt length (mixed-length
  prompts never pad each other) and each group runs ONE jitted call that
  prefills, samples the first tokens, and scatters caches + per-slot decode
  state into the free slots.
* Decode: between scheduler events the engine runs ONE jitted ``lax.scan``
  of up to ``decode_block`` steps with sampling folded in — per-slot
  positions, PRNG keys, temperature/top-k/top-p all live on device, so
  nothing round-trips through the host per token.  The chunk length tracks
  the nearest guaranteed retirement (rounded to a power of two so the
  compile set stays ~log2(decode_block); overshoot is truncated at sync).
* Retirement: at each sync the host checks EOS / max-token per slot,
  retires finished requests, and admits queued ones into the freed slots.

Modes: pass ``plan=`` + per-stage params for PartitionPlan-aware serving
(paper partitions as deployable stages), and/or ``policy=`` (a
``launch.sharding.Policy``) to route params and the cache pool through the
production mesh plumbing.

Known limit: admission compiles one prefill program per distinct prompt
length (decode programs are bounded at ~log2(decode_block) per sampling
mode, and the cache pool is bucketed).  Bucketing prompts needs left-pad
masking in the prefill attention path — not built yet; until then, callers
with adversarially varied prompt lengths should quantize lengths upstream.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Iterator, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro.models import model as M
from repro.obs.events import EventLog, default_log
from repro.obs.metrics import DEPTH_BUCKETS, TTFT_MS_BUCKETS
from repro.obs.registry import MetricsRegistry
from repro.obs.trace import TID_LOOP, TID_REQ0, Tracer
from repro.serve import sampling, staged
from repro.serve.api import Completion, Request, StreamEvent
from repro.serve.kv_cache import (GARBAGE_BLOCK, CachePool, PagedCachePool,
                                  place_blocks, place_rows)
from repro.serve.scheduler import Scheduler


class Engine:
    """Serves one model (or one PartitionPlan stage chain) from resident
    params.  Thread-compatible with one ``generate`` call at a time."""

    def __init__(self, cfg, params=None, *, key=None, max_slots: int = 4,
                 decode_block: int = 16, plan=None, stage_params=None,
                 policy=None, precision=None,
                 max_queue_wait_ms: Optional[float] = None,
                 max_cache_tokens: Optional[int] = None, clock=None,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 event_log: Optional[EventLog] = None, sleep=None,
                 paged: bool = False, block_size: int = 16):
        """precision: optional repro.precision preset name or PrecisionPolicy
        — re-dtypes the serving compute path (activations + the slot cache
        pool run in the policy's compute dtype; params keep their storage
        dtype; sampling always sees fp32 logits).

        Degradation knobs (repro.resilience; all default OFF, preserving
        exact legacy behavior):
        max_queue_wait_ms — a request still queued after this long is
        rejected instead of waiting forever behind a stalled batch.
        max_cache_tokens — admission control under cache pressure: a request
        whose prompt+generation span exceeds this never enters the queue
        (rejected up front), and the grow-only pool is capped at it.
        clock — injectable ``time.monotonic`` substitute (deterministic
        deadline tests; see ``resilience.FakeClock``).

        Observability (repro.obs; all optional):
        metrics — a ``MetricsRegistry``; defaults to a PRIVATE registry so
        the cumulative-per-engine semantics of the legacy ``stats`` dict
        are preserved (pass a shared one to aggregate, as loadgen does).
        tracer — span timelines (request lifecycles on tid 1000+i, the
        admit/decode driving loop on tid 0); defaults to a fresh ``Tracer``
        on this engine's clock.
        event_log — structured event stream shared with the scheduler;
        defaults to the process-wide ``obs.default_log()``.
        sleep — injectable ``time.sleep`` substitute, used only by the
        open-loop ``arrivals=`` path in ``generate``.

        paged — serve from a block-paged cache (``PagedCachePool``):
        attention K/V pages over ``block_size``-token physical blocks with
        per-request block tables, ``max_cache_tokens`` becomes an exact
        total-token budget shared by all in-flight requests, and common
        prompt prefixes are prefilled once (shared-prefix reuse).  OFF by
        default — the contiguous path is byte-identical to before."""
        if precision is not None:
            from repro.precision import get_policy
            cfg = get_policy(precision).apply_to_model(cfg)
        if (plan is None) != (stage_params is None):
            raise ValueError("pass plan= and stage_params= together")
        if params is not None and stage_params is not None:
            raise ValueError("pass either joined params= or staged "
                             "stage_params=, not both")
        if params is None and stage_params is None:
            # random weights only on explicit opt-in (benches/smoke tests) —
            # a serving engine must never silently invent its weights
            if key is None:
                raise ValueError("pass params= / stage_params=, or key= to "
                                 "explicitly serve random-init weights")
            params = M.init_params(cfg, key)
        self.cfg = cfg
        self.max_slots = max_slots
        self.decode_block = decode_block
        self.paged = paged
        self.block_size = block_size
        self.plan = plan
        self.policy = policy
        if plan is not None:
            if policy is not None:
                stage_params = [jax.device_put(sp, policy.params_shardings(sp))
                                for sp in stage_params]
            self.params = list(stage_params)
        else:
            if policy is not None:
                params = jax.device_put(params, policy.params_shardings(params))
            self.params = params
        self._prefill_jit: Dict[Any, Any] = {}
        self._decode_jit: Dict[Any, Any] = {}
        self._pool: Optional[CachePool] = None      # grow-only, one per engine
        # donate the cache/state buffers into the jitted steps (in-place
        # updates; halves peak cache memory) — CPU can't donate and would
        # just warn per call; repro.runtime owns the decision so trace-only
        # introspection (REPRO_ASSUME_DONATION=1) sees the real masks
        self._donate = runtime.donation_enabled()
        self.scheduler: Optional[Scheduler] = None  # last generate()'s
        self.max_queue_wait_ms = max_queue_wait_ms
        self.max_cache_tokens = max_cache_tokens
        self._clock = clock or time.monotonic
        self._sleep = sleep or time.sleep
        # observability: per-engine registry (cumulative across generate()
        # calls, like the legacy stats dict it now backs), spans, events
        self.tracer = tracer if tracer is not None else Tracer(
            clock=self._clock)
        self.event_log = event_log if event_log is not None else default_log()
        self.bind_metrics(metrics if metrics is not None
                          else MetricsRegistry())

    def bind_metrics(self, metrics: MetricsRegistry) -> None:
        """(Re-)home the engine's series in ``metrics``.  Called once from
        ``__init__``; loadgen calls it again with a fresh registry after its
        warmup pass, so compile-time TTFTs never pollute the measured
        distribution."""
        self.metrics = metrics
        self._rejected = metrics.counter(
            "serve_rejected_total",
            help="requests shed, by reason (cache/queue/deadline)")
        self._requests = metrics.counter(
            "serve_requests_total", help="completions, by finish reason")
        self._tokens = metrics.counter(
            "serve_tokens_total", help="generated tokens (incl. partial)")
        self._ttft = metrics.histogram(
            "serve_ttft_ms", TTFT_MS_BUCKETS,
            help="submit -> first sampled token, ms")
        self._queue_depth = metrics.histogram(
            "serve_queue_depth", DEPTH_BUCKETS,
            help="wait-queue depth sampled at each decode sync")
        self._slots_busy = metrics.histogram(
            "serve_slots_busy", DEPTH_BUCKETS,
            help="active slots sampled at each decode sync")
        self._peak_slots = metrics.gauge(
            "serve_peak_slots_busy", help="max concurrent active slots")
        self._cache_tokens = metrics.gauge(
            "serve_cache_tokens", help="cache-pool length, tokens per slot")
        if self.paged:
            # block-utilization series exist only on paged engines, so a
            # contiguous engine's metric/report surface is unchanged
            self._blocks_busy = metrics.histogram(
                "serve_blocks_busy", DEPTH_BUCKETS,
                help="allocated cache blocks sampled at each decode sync")
            self._peak_blocks = metrics.gauge(
                "serve_peak_blocks_busy",
                help="max concurrently allocated cache blocks")
            self._prefix_hits = metrics.counter(
                "serve_prefix_hits_total",
                help="prompt blocks reused via shared-prefix registry")

    @property
    def stats(self) -> Dict[str, int]:
        """Degraded-mode telemetry, cumulative across ``generate()`` calls.

        Legacy read-through view: the source of truth is now the
        ``serve_rejected_total`` counter; the dict shape (exactly these
        three keys) is pinned byte-for-byte in tests."""
        return {"rejected_cache": self._rejected.value(reason="cache"),
                "rejected_queue": self._rejected.value(reason="queue"),
                "rejected_deadline": self._rejected.value(reason="deadline")}

    # -- forward fns (plain vs staged) --------------------------------------

    def _decode_fn(self, params, cache, tok, pos, paged=None):
        if self.plan is not None:
            return staged.staged_decode_step(self.cfg, self.plan, params,
                                             cache, tok, pos, paged=paged)
        return M.decode_step(self.cfg, params, cache, tok, pos, paged=paged)

    def _prefill_fn(self, params, batch, cache_len):
        if self.plan is not None:
            return staged.staged_prefill(self.cfg, self.plan, params, batch,
                                         cache_len)
        return M.prefill(self.cfg, params, batch, cache_len)

    def _admit_step(self, bshape, cache_len: int, mode: str):
        """ONE jitted call per admitted group: prefill + first-token sample +
        cache-pool scatter + per-slot state scatter (cached per group shape).

        Paged engines append a ``write_rows`` (R, nb) physical-block arg and
        scatter attention K/V via ``place_blocks`` (shared-prefix rows point
        at the garbage block); everything else is identical.
        """
        key = (("paged", bshape, cache_len, mode) if self.paged
               else (bshape, cache_len, mode))
        fn = self._prefill_jit.get(key)
        if fn is not None:
            return fn
        vs = self.cfg.vocab_size
        bs = self.block_size

        def admit(params, batch, pool_cache, tok, pos, keys, temps, tks,
                  tps, slots, seeds, g_temps, g_tks, g_tps, *rest):
            logits, group_cache, p1 = self._prefill_fn(params, batch,
                                                       cache_len)
            k0s, s0s = sampling.split_keys(
                jax.vmap(sampling.make_key)(seeds))
            # sampling always runs on fp32 logits regardless of the cache /
            # activation compute dtype (precision-policy contract)
            t0 = sampling.sample_tokens(logits[:, :vs].astype(jnp.float32),
                                        s0s, g_temps, g_tks, g_tps, mode=mode)
            if self.paged:
                pool_cache = place_blocks(pool_cache, group_cache, slots,
                                          rest[0], block_size=bs)
            else:
                pool_cache = place_rows(pool_cache, group_cache, slots)
            tok = tok.at[slots].set(t0)
            pos = pos.at[slots].set(p1)
            keys = keys.at[slots].set(k0s)
            temps = temps.at[slots].set(g_temps)
            tks = tks.at[slots].set(g_tks)
            tps = tps.at[slots].set(g_tps)
            return pool_cache, tok, pos, keys, temps, tks, tps, t0

        donate = tuple(range(2, 9)) if self._donate else ()
        fn = self._prefill_jit[key] = jax.jit(admit, donate_argnums=donate)
        return fn

    def _decode_chunk(self, n: int, mode: str, lc: Optional[int] = None):
        """Jitted scan of n fused decode+sample steps (cached per n, mode).

        Paged engines append the (n_slots, nb) block-table arg and key the
        cache on ``lc`` too (the logical cache length is baked into the
        traced program as the attention ring modulus / validity bound)."""
        key = ("paged", n, mode, lc) if self.paged else (n, mode)
        fn = self._decode_jit.get(key)
        if fn is not None:
            return fn
        vs = self.cfg.vocab_size
        paged_mode = self.paged

        def chunk(params, cache, tok, pos, keys, temps, tks, tps, *rest):
            def body(carry, _):
                cache, tok, pos, keys = carry
                paged = (rest[0], lc) if paged_mode else None
                logits, cache = self._decode_fn(params, cache, tok, pos,
                                                paged=paged)
                if mode != "greedy":
                    keys, sub = sampling.split_keys(keys)
                else:
                    sub = keys
                tok = sampling.sample_tokens(
                    logits[:, :vs].astype(jnp.float32), sub, temps, tks, tps,
                    mode=mode)
                return (cache, tok, pos + 1, keys), tok

            (cache, tok, pos, keys), toks = jax.lax.scan(
                body, (cache, tok, pos, keys), None, length=n)
            return cache, tok, pos, keys, toks

        donate = (1, 2, 3, 4) if self._donate else ()
        fn = self._decode_jit[key] = jax.jit(chunk, donate_argnums=donate)
        return fn

    # -- request plumbing ---------------------------------------------------

    def _request_batch(self, reqs: Sequence[Request]):
        """Batch for a group of SAME-LENGTH prompts (batched admission)."""
        cfg = self.cfg
        b = len(reqs)
        toks = np.stack([np.asarray(r.tokens, np.int32).reshape(-1)
                         for r in reqs])
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.enc_dec:
            batch["frames"] = jnp.stack([
                jnp.zeros((cfg.enc_seq, cfg.d_model), jnp.float32)
                if r.frames is None else
                jnp.asarray(r.frames).reshape(cfg.enc_seq, cfg.d_model)
                for r in reqs])
        if cfg.frontend == "vision":
            batch["image_embeds"] = jnp.stack([
                jnp.zeros((cfg.vision_tokens, cfg.d_model), jnp.float32)
                if r.image_embeds is None else
                jnp.asarray(r.image_embeds).reshape(cfg.vision_tokens,
                                                    cfg.d_model)
                for r in reqs])
        assert batch["tokens"].shape[0] == b
        return batch

    def _cache_len_for(self, requests: Sequence[Request]) -> int:
        extra = self.cfg.vision_tokens if self.cfg.frontend == "vision" else 0
        return max(len(np.asarray(r.tokens).reshape(-1))
                   + r.gen.max_new_tokens for r in requests) + extra

    def _pool_for(self, need_len: int) -> CachePool:
        """The engine's single cache pool, grow-only and bucketed to 32
        tokens, so serving varied request lengths reuses one device cache
        instead of allocating per distinct length."""
        if self.max_cache_tokens is not None:
            # admission control already rejected anything that needs more —
            # the grow-only pool must never outgrow the configured budget
            need_len = min(need_len, self.max_cache_tokens)
        if self._pool is None or self._pool.cache_len < need_len:
            size = -(-need_len // 32) * 32
            if self.paged:
                self._pool = PagedCachePool(
                    self.cfg, self.max_slots, size,
                    block_size=self.block_size,
                    max_tokens=self.max_cache_tokens, policy=self.policy)
            else:
                self._pool = CachePool(self.cfg, self.max_slots, size,
                                       policy=self.policy)
        return self._pool

    def _chunk_len(self, remaining: int) -> int:
        """Fused steps until the next sync: the nearest guaranteed
        retirement, rounded up to a power of two (bounds the jit-compile set
        at ~log2(decode_block) scan lengths; overshoot tokens are truncated
        at the sync, so the round-up costs at most a few cheap steps)."""
        if remaining >= self.decode_block:
            return self.decode_block
        return min(1 << max(remaining - 1, 0).bit_length(), self.decode_block)

    # -- the loop -----------------------------------------------------------

    def generate(self, requests: Sequence[Request],
                 cache_len: Optional[int] = None,
                 arrivals: Optional[Sequence[float]] = None
                 ) -> List[Completion]:
        """Continuously-batched generation; completions in request order.

        cache_len is a minimum — the engine may serve from a larger pooled
        cache (validity masks make extra slots inert).

        arrivals — optional per-request submission offsets (seconds from
        call start): open-loop traffic.  Request i is only admissible once
        the clock passes ``start + arrivals[i]``; the engine sleeps (via
        the injectable ``sleep``) when all slots are idle and the next
        arrival is in the future.  ``None`` (default) is the legacy
        closed-loop path: everything arrives at once."""
        done: Dict[int, Completion] = {}
        for ev in self.stream(requests, cache_len=cache_len,
                              arrivals=arrivals):
            if ev.kind == "done":
                done[ev.req_idx] = ev.completion
        return [done[i] for i in range(len(requests))]

    def stream(self, requests: Sequence[Request],
               cache_len: Optional[int] = None,
               arrivals: Optional[Sequence[float]] = None
               ) -> Iterator[StreamEvent]:
        """Streaming form of ``generate``: yields a "delta" ``StreamEvent``
        per generated token (in emission order; different requests
        interleave) and one "done" event per request carrying its final
        ``Completion``.  TTFT can be measured on the first "delta" of a
        request instead of waiting for the whole batch.  ``generate`` is a
        thin wrapper that collects the "done" events."""
        if not requests:
            return
        if arrivals is not None and len(arrivals) != len(requests):
            raise ValueError("arrivals must align 1:1 with requests")
        n_slots = self.max_slots
        extra = self.cfg.vision_tokens if self.cfg.frontend == "vision" else 0

        def span(r) -> int:
            return np.asarray(r.tokens).reshape(-1).shape[0] \
                + r.gen.max_new_tokens + extra

        def completion(r, tokens, reason) -> Completion:
            self._requests.inc(1, reason=reason)
            return Completion(
                id=r.id,
                prompt_tokens=tuple(int(t) for t in
                                    np.asarray(r.tokens).reshape(-1)),
                tokens=tokens, finish_reason=reason)

        sched = self.scheduler = Scheduler(
            n_slots, max_queue_wait_ms=self.max_queue_wait_ms,
            event_log=self.event_log)
        paged = self.paged
        done: Dict[int, Completion] = {}
        evq: List[StreamEvent] = []          # events pending the next yield

        def flush() -> List[StreamEvent]:
            out = evq[:]
            evq.clear()
            return out

        def ev_done(req_idx: int, r, comp: Completion) -> None:
            done[req_idx] = comp
            evq.append(StreamEvent("done", req_idx, r.id, completion=comp))

        accepted: List[Request] = []
        now0 = self._clock()
        self.event_log.emit("generate_begin", n=len(requests))
        for i, r in enumerate(requests):
            if self.max_cache_tokens is not None \
                    and span(r) > self.max_cache_tokens:
                # cache-pressure admission control: this request could never
                # fit a slot of the capped pool — shed it up front, loudly
                ev_done(i, r, completion(r, (), "rejected"))
                self._rejected.inc(1, reason="cache")
                self.event_log.emit("reject", req=i)
            elif r.gen.max_new_tokens <= 0:    # prefill-only: nothing to emit
                ev_done(i, r, completion(r, (), "length"))
            else:
                t = now0 + (arrivals[i] if arrivals is not None else 0.0)
                sched.submit(i, r, t)
                accepted.append(r)
        yield from flush()
        if not accepted:
            self.event_log.emit("generate_end", n=len(requests))
            return
        # pools are reusable without zeroing: admission fully overwrites a
        # slot before it decodes, and free slots never reach a Completion
        pool = self._pool_for(max(cache_len or 0,
                                  self._cache_len_for(accepted)))
        cache_len = pool.cache_len
        if paged:
            # host-side block tables: one row per slot, garbage-padded; free
            # slots stay all-garbage so their (ignored) decode writes land
            # in the garbage block
            tables = np.zeros((n_slots, pool.blocks_per_slot), np.int32)
            lc = pool.attn_len

        tok = jnp.zeros((n_slots,), jnp.int32)
        pos = jnp.zeros((n_slots,), jnp.int32)
        keys = jnp.zeros((n_slots, 2), jnp.uint32)
        temps = jnp.zeros((n_slots,), jnp.float32)
        tks = jnp.zeros((n_slots,), jnp.int32)
        tps = jnp.ones((n_slots,), jnp.float32)

        mode = sampling.mode_for([r.gen for r in requests])
        # degradation is active only when some limit can actually fire —
        # otherwise shed() stays a no-op and the loop is the legacy loop
        shedding = self.max_queue_wait_ms is not None or any(
            r.deadline_ms is not None for r in accepted)
        open_loop = arrivals is not None
        admit_t: Dict[int, float] = {}       # req_idx -> admission walltime

        def finish(slot: int, reason: str) -> None:
            st = sched.retire(slot)
            st.finish_reason = reason
            ev_done(st.req_idx, st.request,
                    completion(st.request, tuple(st.emitted), reason))
            if paged and st.blocks is not None:
                pool.release(st.blocks)     # last owner frees the blocks
                tables[slot] = GARBAGE_BLOCK
                st.blocks = None
            self._tokens.inc(len(st.emitted))
            t_adm = admit_t.pop(st.req_idx, None)
            if t_adm is not None:
                self.tracer.add_span(
                    f"req {st.req_idx} active", t_adm,
                    self._clock() - t_adm, cat="request",
                    tid=TID_REQ0 + st.req_idx, reason=reason,
                    tokens=len(st.emitted))

        def shed() -> None:
            """Degraded mode: reject what can no longer be served in time —
            queued requests past their wait budget, active slots past their
            deadline (partial tokens kept) — instead of stalling everyone."""
            if not shedding:
                return
            now = self._clock()
            for req_idx, r in sched.expire_queued(now):
                ev_done(req_idx, r, completion(r, (), "rejected"))
                self._rejected.inc(1, reason="queue")
                self.tracer.instant(f"req {req_idx} shed", ts=now,
                                    cat="request", tid=TID_REQ0 + req_idx)
            for slot in sched.overdue_active(now):
                finish(slot, "rejected")
                self._rejected.inc(1, reason="deadline")

        def admit_group(items, allocs=None) -> None:
            """Admit same-prompt-length requests via ONE jitted batched
            prefill+sample+scatter call.  ``allocs`` (paged mode) carries
            each request's ``PagedAlloc``, already reserved by
            ``admit_ready``."""
            nonlocal tok, pos, keys, temps, tks, tps
            reqs = [r for _, r, _ in items]
            batch = self._request_batch(reqs)
            t_adm = self._clock()
            slots = [sched.admit(i, r, batch["tokens"].shape[1], arrival=t)
                     for i, r, t in items]
            if paged:
                wrows = []
                for slot, alloc in zip(slots, allocs):
                    sched.active[slot].blocks = alloc.ids
                    tables[slot] = pool.table_row(alloc)
                    wrows.append(pool.write_row(alloc))
                    if alloc.n_shared:
                        self._prefix_hits.inc(alloc.n_shared)
            for i, _, t in items:
                admit_t[i] = t_adm
                self.tracer.add_span(f"req {i} queued", t, t_adm - t,
                                     cat="request", tid=TID_REQ0 + i)
            step = self._admit_step(batch["tokens"].shape, cache_len, mode)
            with self.tracer.span("admit", cat="serve", tid=TID_LOOP,
                                  batch=len(reqs)):
                args = [
                    self.params, batch, pool.cache, tok, pos, keys, temps,
                    tks, tps, jnp.asarray(slots, jnp.int32),
                    jnp.asarray([r.gen.seed for r in reqs], jnp.uint32),
                    jnp.asarray([r.gen.temperature for r in reqs],
                                jnp.float32),
                    jnp.asarray([r.gen.top_k for r in reqs], jnp.int32),
                    jnp.asarray([r.gen.top_p for r in reqs], jnp.float32)]
                if paged:
                    args.append(jnp.asarray(wrows, jnp.int32))
                pool.cache, tok, pos, keys, temps, tks, tps, t0 = step(*args)
                t0h = np.asarray(t0)     # the sync: first tokens are real
            now = self._clock()
            for _, _, t in items:        # TTFT measured at the sync point
                self._ttft.observe((now - t) * 1000.0)
            for row, (slot, (i, r, _)) in enumerate(zip(slots, items)):
                g = r.gen
                tv = int(t0h[row])
                sched.active[slot].emitted.append(tv)
                evq.append(StreamEvent("delta", i, r.id, token=tv))
                if g.eos_id is not None and tv == g.eos_id:
                    finish(slot, "eos")
                elif g.max_new_tokens <= 1:
                    finish(slot, "length")

        def admit_ready() -> None:
            now = self._clock() if open_loop else None
            while sched.queued() and sched.free:
                take = sched.take(len(sched.free), now=now)
                if not take:         # head of queue hasn't arrived yet
                    break
                stalled = False
                if paged:
                    # block-granular admission control: reserve each
                    # request's blocks (shared-prefix lookup included)
                    # before it reaches a slot; when blocks run out the
                    # tail goes back to the queue head (FIFO preserved)
                    # and waits for the next retirement
                    admitted: List[Any] = []
                    allocs: List[Any] = []
                    for j, (i, r, t) in enumerate(take):
                        ptoks = np.asarray(r.tokens,
                                           np.int32).reshape(-1).tolist()
                        alloc = pool.allocate(ptoks, span(r))
                        if alloc is None:
                            if pool.allocator.n_used == 0:
                                # alone with every block free and still no
                                # fit — this request can NEVER be served
                                # under the block budget; shed it instead
                                # of deadlocking the queue
                                ev_done(i, r, completion(r, (), "rejected"))
                                self._rejected.inc(1, reason="cache")
                                self.event_log.emit("reject", req=i)
                                continue
                            sched.requeue_front(take[j:])
                            stalled = True
                            break
                        admitted.append((i, r, t))
                        allocs.append(alloc)
                    groups: Dict[int, list] = {}
                    for item, alloc in zip(admitted, allocs):
                        plen = np.asarray(item[1].tokens).reshape(-1).shape[0]
                        groups.setdefault(plen, []).append((item, alloc))
                    for pairs in groups.values():
                        admit_group([it for it, _ in pairs],
                                    [al for _, al in pairs])
                else:
                    groups = {}
                    for i, r, t in take:
                        plen = np.asarray(r.tokens).reshape(-1).shape[0]
                        groups.setdefault(plen, []).append((i, r, t))
                    for items in groups.values():
                        admit_group(items)
                if stalled:
                    break

        shed()
        admit_ready()
        yield from flush()
        while sched.active or sched.queued():
            if not sched.active:
                # open-loop idle: nothing in flight and the next arrival is
                # still in the future — sleep the gap (injectable) and retry
                na = sched.next_arrival()
                if na is None:
                    break
                gap = na - self._clock()
                if gap > 0:
                    self._sleep(gap)
                shed()
                admit_ready()
                yield from flush()
                continue
            self._queue_depth.observe(sched.queued())
            self._slots_busy.observe(len(sched.active))
            if paged:
                self._blocks_busy.observe(pool.allocator.n_used)
            n = self._chunk_len(sched.min_remaining())
            step = (self._decode_chunk(n, mode, lc) if paged
                    else self._decode_chunk(n, mode))
            with self.tracer.span(f"decode[{n}]", cat="serve", tid=TID_LOOP,
                                  active=len(sched.active)):
                args = [self.params, pool.cache, tok, pos, keys, temps, tks,
                        tps]
                if paged:
                    args.append(jnp.asarray(tables))
                pool.cache, tok, pos, keys, toks = step(*args)
                toks_h = np.asarray(toks)                  # (n, n_slots)
            for slot in list(sched.active):
                st = sched.active[slot]
                eos = st.request.gen.eos_id
                for t in toks_h[:, slot]:
                    tv = int(t)
                    st.emitted.append(tv)
                    evq.append(StreamEvent("delta", st.req_idx,
                                           st.request.id, token=tv))
                    if eos is not None and tv == eos:
                        finish(slot, "eos")
                        break
                    if st.remaining <= 0:
                        finish(slot, "length")
                        break
            shed()
            admit_ready()
            yield from flush()
        self._peak_slots.set_max(sched.max_concurrent)
        self._cache_tokens.set(pool.cache_len)
        if paged:
            self._peak_blocks.set_max(pool.allocator.peak_used)
        self.metrics.drain()         # flush boundary (idempotent, host-only)
        self.event_log.emit("generate_end", n=len(requests),
                            completed=len(done))
        yield from flush()
