"""`repro.serve` engine: continuous batching must be a pure latency/throughput
optimization — never a tokens change.

Covers: (a) continuous-batched generation token-identical to one-request-at-
a-time generation at temperature 0 (standard decoder, sliding-window ring,
and a recurrent-state arch); (b) ring cache == full cache within the window;
(c) staggered admit/retire never leaks a slot, including the retire-on-admit
tick and zero-free-slot edges; (d) sampler sanity under a fixed key; plus
PartitionPlan-staged serving and Policy plumbing.

Setup comes from the shared ``repro.verify.scenarios`` builders via the
session-scoped ``serve_world`` fixture (params built once per arch/window).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import partition
from repro.serve import (Engine, GenerationConfig, Request, Scheduler,
                         sampling)
from repro.verify.scenarios import greedy_reference, serve_requests


# -- (a) continuous batching == sequential, greedy --------------------------

@pytest.mark.parametrize("name,window", [
    ("qwen2-1.5b", 0),      # standard decoder
    ("qwen2-1.5b", 8),      # sliding-window ring cache
    ("xlstm-125m", 0),      # recurrent-state caches
])
def test_continuous_batching_token_identical(serve_world, name, window):
    cfg, params = serve_world(name, window)
    reqs = serve_requests(cfg)
    outs = Engine(cfg, params, max_slots=2, decode_block=4).generate(reqs)
    for req, c in zip(reqs, outs):
        assert c.tokens == greedy_reference(cfg, params, req), c
        assert c.finish_reason == "length"
        assert c.n_generated == req.gen.max_new_tokens


def test_slots_one_equals_slots_many(serve_world):
    cfg, params = serve_world()
    reqs = serve_requests(cfg)
    a = Engine(cfg, params, max_slots=1, decode_block=4).generate(reqs)
    b = Engine(cfg, params, max_slots=4, decode_block=4).generate(reqs)
    assert [c.tokens for c in a] == [c.tokens for c in b]


# -- (b) ring cache == full cache within the window -------------------------

def test_ring_cache_matches_full_within_window(serve_world):
    base, params = serve_world()
    reqs = serve_requests(base, lens=(8, 6), news=(6, 8))
    # window covers prompt+generation entirely -> identical tokens
    full = Engine(base, params, max_slots=2, decode_block=4).generate(reqs)
    ring = Engine(base.replace(sliding_window=32), params, max_slots=2,
                  decode_block=4).generate(reqs)
    assert [c.tokens for c in full] == [c.tokens for c in ring]


# -- (c) staggered admit/retire never leaks a slot --------------------------

def test_scheduler_never_leaks_slots(serve_world):
    cfg, params = serve_world()
    # more requests than slots, wildly varied durations (incl. 1-token)
    reqs = serve_requests(cfg, lens=(8, 5, 8, 5, 7, 8), news=(1, 5, 3, 7, 2, 4))
    eng = Engine(cfg, params, max_slots=2, decode_block=4)
    outs = eng.generate(reqs)
    sched = eng.scheduler
    assert sorted(sched.free) == [0, 1] and not sched.active
    admits = [s for e, s in sched.events if e == "admit"]
    retires = [s for e, s in sched.events if e == "retire"]
    assert len(admits) == len(retires) == len(reqs)
    assert sched.max_concurrent <= 2
    for req, c in zip(reqs, outs):
        assert c.n_generated == req.gen.max_new_tokens
    # Scheduler rejects double-admission beyond capacity
    s = Scheduler(1)
    s.admit(0, reqs[0], 8)
    with pytest.raises(RuntimeError):
        s.admit(1, reqs[1], 5)


def test_retire_on_admit_tick_reuses_slot(serve_world):
    """A 1-token request retires DURING its admission tick; with one slot
    and a queue behind it, the freed slot must be re-admitted into in the
    same scheduling round, never leaked, never double-booked."""
    cfg, params = serve_world()
    reqs = serve_requests(cfg, lens=(8, 8, 6), news=(1, 1, 4))
    eng = Engine(cfg, params, max_slots=1, decode_block=4)
    outs = eng.generate(reqs)
    sched = eng.scheduler
    # all three served through the single slot, one at a time
    assert [s for e, s in sched.events] == [0] * 6
    assert [e for e, _ in sched.events] == ["admit", "retire"] * 3
    assert sched.max_concurrent == 1
    assert outs[0].n_generated == outs[1].n_generated == 1
    assert outs[2].n_generated == 4
    # the 1-token completions match the sequential reference's first token
    for i in (0, 1):
        assert outs[i].tokens == greedy_reference(cfg, params, reqs[i])[:1]


def test_eos_on_first_token_retires_at_admission(serve_world):
    """EOS hit on the token sampled inside the admission call itself (the
    earliest possible retire) frees the slot for the queued request."""
    cfg, params = serve_world()
    base = serve_requests(cfg, lens=(8, 6), news=(6, 5))
    first = greedy_reference(cfg, params, base[0])[0]
    reqs = [Request(tokens=base[0].tokens,
                    gen=GenerationConfig(max_new_tokens=6, eos_id=first)),
            base[1]]
    eng = Engine(cfg, params, max_slots=1, decode_block=4)
    outs = eng.generate(reqs)
    assert outs[0].finish_reason == "eos"
    assert outs[0].tokens == (first,)
    assert outs[1].n_generated == 5
    assert eng.scheduler.max_concurrent == 1


def test_zero_free_slot_admission_is_rejected():
    """admit() with no free slot is a programming error and fails loudly
    (the engine's admit_ready loop must gate on sched.free)."""
    s = Scheduler(2)
    r = Request(tokens=[1, 2, 3], gen=GenerationConfig(max_new_tokens=2))
    s.admit(0, r, 3)
    s.admit(1, r, 3)
    with pytest.raises(RuntimeError, match="no free slot"):
        s.admit(2, r, 3)
    # retire -> the slot is admissible again, audit trail intact
    s.retire(0)
    slot = s.admit(2, r, 3)
    assert slot == 0
    assert s.events == [("admit", 0), ("admit", 1), ("retire", 0),
                        ("admit", 0)]


def test_eos_retires_and_frees_slot(serve_world):
    cfg, params = serve_world()
    ref = greedy_reference(cfg, params, serve_requests(cfg)[0])
    eos = ref[2]
    reqs = serve_requests(cfg)
    reqs[0] = Request(tokens=reqs[0].tokens,
                      gen=GenerationConfig(max_new_tokens=6, eos_id=eos))
    outs = Engine(cfg, params, max_slots=2, decode_block=4).generate(reqs)
    assert outs[0].finish_reason == "eos"
    assert outs[0].tokens == ref[:3]          # eos included, then retired
    assert outs[1].n_generated == reqs[1].gen.max_new_tokens


# -- (d) samplers are distribution-sane under a fixed key -------------------

def test_samplers_sane_fixed_key():
    key = jax.random.PRNGKey(0)
    v, n = 64, 256
    logits = jnp.tile(jax.random.normal(key, (1, v)) * 3.0, (n, 1))
    keys = jax.vmap(lambda s: jax.random.PRNGKey(s))(jnp.arange(n))
    ones = jnp.ones((n,), jnp.float32)

    # temperature 0 -> argmax regardless of keys/filters
    out = sampling.sample_tokens(logits, keys, ones * 0.0,
                                 jnp.full((n,), 5, jnp.int32), ones * 0.5)
    assert set(np.asarray(out).tolist()) == {int(jnp.argmax(logits[0]))}

    # top_k=1 -> argmax even at high temperature
    out = sampling.sample_tokens(logits, keys, ones * 5.0,
                                 jnp.ones((n,), jnp.int32), ones)
    assert set(np.asarray(out).tolist()) == {int(jnp.argmax(logits[0]))}

    # top_k=5 -> support is exactly within the top-5 set, and >1 distinct
    top5 = set(np.asarray(jnp.argsort(logits[0])[::-1][:5]).tolist())
    out = sampling.sample_tokens(logits, keys, ones * 2.0,
                                 jnp.full((n,), 5, jnp.int32), ones)
    seen = set(np.asarray(out).tolist())
    assert seen <= top5 and len(seen) > 1

    # top_p -> smallest prefix covering p (peaked dist: tiny p == argmax)
    out = sampling.sample_tokens(logits, keys, ones, jnp.zeros((n,), jnp.int32),
                                 ones * 1e-4)
    assert set(np.asarray(out).tolist()) == {int(jnp.argmax(logits[0]))}

    # unfiltered sampling roughly follows softmax: the argmax token must be
    # the modal sample under a peaked distribution
    out = np.asarray(sampling.sample_tokens(logits, keys, ones,
                                            jnp.zeros((n,), jnp.int32), ones))
    vals, counts = np.unique(out, return_counts=True)
    assert vals[np.argmax(counts)] == int(jnp.argmax(logits[0]))

    # per-slot independence: same key row -> same token, different -> varies
    out1 = sampling.sample_tokens(logits, keys, ones * 2.0,
                                  jnp.zeros((n,), jnp.int32), ones)
    out2 = sampling.sample_tokens(logits, keys, ones * 2.0,
                                  jnp.zeros((n,), jnp.int32), ones)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


# -- staged + policy serving ------------------------------------------------

def test_partitioned_engine_matches_joined(serve_world):
    cfg, params = serve_world()
    reqs = serve_requests(cfg, lens=(8, 5), news=(5, 4))
    joined = Engine(cfg, params, max_slots=2, decode_block=4).generate(reqs)
    plan = partition.make_plan(cfg, 2)
    sp = [partition.slice_stage_params(cfg, plan, params, k)
          for k in range(plan.n_stages)]
    stagedo = Engine(cfg, plan=plan, stage_params=sp, max_slots=2,
                     decode_block=4).generate(reqs)
    assert [c.tokens for c in joined] == [c.tokens for c in stagedo]


def test_policy_plumbing_single_device(serve_world):
    from repro.launch.sharding import Policy
    cfg, params = serve_world()
    reqs = serve_requests(cfg, lens=(8,), news=(4,))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plain = Engine(cfg, params, max_slots=1, decode_block=4).generate(reqs)
    sharded = Engine(cfg, params, max_slots=1, decode_block=4,
                     policy=Policy(cfg, mesh)).generate(reqs)
    assert [c.tokens for c in plain] == [c.tokens for c in sharded]


def test_sampled_stream_independent_of_batching(serve_world):
    """A request's sampled tokens depend only on its own seed, not on what
    else is in the batch (continuous batching must not couple streams)."""
    cfg, params = serve_world()
    gen = GenerationConfig(max_new_tokens=6, temperature=0.8, top_k=16,
                           top_p=0.9, seed=13)
    rng = np.random.RandomState(1)
    r = Request(tokens=rng.randint(0, cfg.vocab_size, size=(8,)), gen=gen)
    other = serve_requests(cfg, lens=(5, 10), news=(7, 3))
    solo = Engine(cfg, params, max_slots=1, decode_block=4).generate([r])
    crowd = Engine(cfg, params, max_slots=3,
                   decode_block=4).generate([other[0], r, other[1]])
    assert solo[0].tokens == crowd[1].tokens
