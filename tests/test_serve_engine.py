"""`repro.serve` engine: continuous batching must be a pure latency/throughput
optimization — never a tokens change.

Covers: (a) continuous-batched generation token-identical to one-request-at-
a-time generation at temperature 0 (standard decoder, sliding-window ring,
and a recurrent-state arch); (b) ring cache == full cache within the window;
(c) staggered admit/retire never leaks a slot; (d) sampler sanity under a
fixed key; plus PartitionPlan-staged serving and Policy plumbing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import partition
from repro.models import model as M
from repro.serve import (Engine, GenerationConfig, Request, Scheduler,
                         sampling)


def _cfg(name, window=0):
    cfg = get(name, smoke=True).replace(dtype="float32")
    if window:
        cfg = cfg.replace(sliding_window=window)
    return cfg


def _params(cfg, seed=0):
    return M.init_params(cfg, jax.random.PRNGKey(seed))


def _requests(cfg, lens=(8, 12, 5, 10), news=(6, 9, 4, 7)):
    """Mixed-length prompts + mixed durations: staggers admits/retires."""
    rng = np.random.RandomState(0)
    return [Request(tokens=rng.randint(0, cfg.vocab_size, size=(ln,)),
                    gen=GenerationConfig(max_new_tokens=nn), id=f"r{i}")
            for i, (ln, nn) in enumerate(zip(lens, news))]


def _greedy_loop(cfg, params, req):
    """One-request-at-a-time reference: prefill + per-token python decode."""
    toks = jnp.asarray(np.asarray(req.tokens, np.int32)[None])
    lc = toks.shape[1] + req.gen.max_new_tokens \
        + (cfg.vision_tokens if cfg.frontend == "vision" else 0)
    batch = {"tokens": toks}
    if cfg.enc_dec:
        batch["frames"] = jnp.zeros((1, cfg.enc_seq, cfg.d_model))
    logits, cache, pos = M.prefill(cfg, params, batch, cache_len=lc)
    tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    out = [int(tok[0])]
    for i in range(req.gen.max_new_tokens - 1):
        logits, cache = M.decode_step(cfg, params, cache, tok, pos + i)
        tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
        out.append(int(tok[0]))
    return tuple(out)


# -- (a) continuous batching == sequential, greedy --------------------------

@pytest.mark.parametrize("name,window", [
    ("qwen2-1.5b", 0),      # standard decoder
    ("qwen2-1.5b", 8),      # sliding-window ring cache
    ("xlstm-125m", 0),      # recurrent-state caches
])
def test_continuous_batching_token_identical(name, window):
    cfg = _cfg(name, window)
    params = _params(cfg)
    reqs = _requests(cfg)
    outs = Engine(cfg, params, max_slots=2, decode_block=4).generate(reqs)
    for req, c in zip(reqs, outs):
        assert c.tokens == _greedy_loop(cfg, params, req), c
        assert c.finish_reason == "length"
        assert c.n_generated == req.gen.max_new_tokens


def test_slots_one_equals_slots_many():
    cfg = _cfg("qwen2-1.5b")
    params = _params(cfg)
    reqs = _requests(cfg)
    a = Engine(cfg, params, max_slots=1, decode_block=4).generate(reqs)
    b = Engine(cfg, params, max_slots=4, decode_block=4).generate(reqs)
    assert [c.tokens for c in a] == [c.tokens for c in b]


# -- (b) ring cache == full cache within the window -------------------------

def test_ring_cache_matches_full_within_window():
    base = _cfg("qwen2-1.5b")
    params = _params(base)
    reqs = _requests(base, lens=(8, 6), news=(6, 8))
    # window covers prompt+generation entirely -> identical tokens
    full = Engine(base, params, max_slots=2, decode_block=4).generate(reqs)
    ring = Engine(base.replace(sliding_window=32), params, max_slots=2,
                  decode_block=4).generate(reqs)
    assert [c.tokens for c in full] == [c.tokens for c in ring]


# -- (c) staggered admit/retire never leaks a slot --------------------------

def test_scheduler_never_leaks_slots():
    cfg = _cfg("qwen2-1.5b")
    params = _params(cfg)
    # more requests than slots, wildly varied durations (incl. 1-token)
    reqs = _requests(cfg, lens=(8, 5, 8, 5, 7, 8), news=(1, 5, 3, 7, 2, 4))
    eng = Engine(cfg, params, max_slots=2, decode_block=4)
    outs = eng.generate(reqs)
    sched = eng.scheduler
    assert sorted(sched.free) == [0, 1] and not sched.active
    admits = [s for e, s in sched.events if e == "admit"]
    retires = [s for e, s in sched.events if e == "retire"]
    assert len(admits) == len(retires) == len(reqs)
    assert sched.max_concurrent <= 2
    for req, c in zip(reqs, outs):
        assert c.n_generated == req.gen.max_new_tokens
    # Scheduler rejects double-admission beyond capacity
    s = Scheduler(1)
    s.admit(0, reqs[0], 8)
    with pytest.raises(RuntimeError):
        s.admit(1, reqs[1], 5)


def test_eos_retires_and_frees_slot():
    cfg = _cfg("qwen2-1.5b")
    params = _params(cfg)
    ref = _greedy_loop(cfg, params, _requests(cfg)[0])
    eos = ref[2]
    reqs = _requests(cfg)
    reqs[0] = Request(tokens=reqs[0].tokens,
                      gen=GenerationConfig(max_new_tokens=6, eos_id=eos))
    outs = Engine(cfg, params, max_slots=2, decode_block=4).generate(reqs)
    assert outs[0].finish_reason == "eos"
    assert outs[0].tokens == ref[:3]          # eos included, then retired
    assert outs[1].n_generated == reqs[1].gen.max_new_tokens


# -- (d) samplers are distribution-sane under a fixed key -------------------

def test_samplers_sane_fixed_key():
    key = jax.random.PRNGKey(0)
    v, n = 64, 256
    logits = jnp.tile(jax.random.normal(key, (1, v)) * 3.0, (n, 1))
    keys = jax.vmap(lambda s: jax.random.PRNGKey(s))(jnp.arange(n))
    ones = jnp.ones((n,), jnp.float32)

    # temperature 0 -> argmax regardless of keys/filters
    out = sampling.sample_tokens(logits, keys, ones * 0.0,
                                 jnp.full((n,), 5, jnp.int32), ones * 0.5)
    assert set(np.asarray(out).tolist()) == {int(jnp.argmax(logits[0]))}

    # top_k=1 -> argmax even at high temperature
    out = sampling.sample_tokens(logits, keys, ones * 5.0,
                                 jnp.ones((n,), jnp.int32), ones)
    assert set(np.asarray(out).tolist()) == {int(jnp.argmax(logits[0]))}

    # top_k=5 -> support is exactly within the top-5 set, and >1 distinct
    top5 = set(np.asarray(jnp.argsort(logits[0])[::-1][:5]).tolist())
    out = sampling.sample_tokens(logits, keys, ones * 2.0,
                                 jnp.full((n,), 5, jnp.int32), ones)
    seen = set(np.asarray(out).tolist())
    assert seen <= top5 and len(seen) > 1

    # top_p -> smallest prefix covering p (peaked dist: tiny p == argmax)
    out = sampling.sample_tokens(logits, keys, ones, jnp.zeros((n,), jnp.int32),
                                 ones * 1e-4)
    assert set(np.asarray(out).tolist()) == {int(jnp.argmax(logits[0]))}

    # unfiltered sampling roughly follows softmax: the argmax token must be
    # the modal sample under a peaked distribution
    out = np.asarray(sampling.sample_tokens(logits, keys, ones,
                                            jnp.zeros((n,), jnp.int32), ones))
    vals, counts = np.unique(out, return_counts=True)
    assert vals[np.argmax(counts)] == int(jnp.argmax(logits[0]))

    # per-slot independence: same key row -> same token, different -> varies
    out1 = sampling.sample_tokens(logits, keys, ones * 2.0,
                                  jnp.zeros((n,), jnp.int32), ones)
    out2 = sampling.sample_tokens(logits, keys, ones * 2.0,
                                  jnp.zeros((n,), jnp.int32), ones)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


# -- staged + policy serving ------------------------------------------------

def test_partitioned_engine_matches_joined():
    cfg = _cfg("qwen2-1.5b")
    params = _params(cfg)
    reqs = _requests(cfg, lens=(8, 5), news=(5, 4))
    joined = Engine(cfg, params, max_slots=2, decode_block=4).generate(reqs)
    plan = partition.make_plan(cfg, 2)
    sp = [partition.slice_stage_params(cfg, plan, params, k)
          for k in range(plan.n_stages)]
    stagedo = Engine(cfg, plan=plan, stage_params=sp, max_slots=2,
                     decode_block=4).generate(reqs)
    assert [c.tokens for c in joined] == [c.tokens for c in stagedo]


def test_policy_plumbing_single_device():
    from repro.launch.sharding import Policy
    cfg = _cfg("qwen2-1.5b")
    params = _params(cfg)
    reqs = _requests(cfg, lens=(8,), news=(4,))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plain = Engine(cfg, params, max_slots=1, decode_block=4).generate(reqs)
    sharded = Engine(cfg, params, max_slots=1, decode_block=4,
                     policy=Policy(cfg, mesh)).generate(reqs)
    assert [c.tokens for c in plain] == [c.tokens for c in sharded]


def test_sampled_stream_independent_of_batching():
    """A request's sampled tokens depend only on its own seed, not on what
    else is in the batch (continuous batching must not couple streams)."""
    cfg = _cfg("qwen2-1.5b")
    params = _params(cfg)
    gen = GenerationConfig(max_new_tokens=6, temperature=0.8, top_k=16,
                           top_p=0.9, seed=13)
    rng = np.random.RandomState(1)
    r = Request(tokens=rng.randint(0, cfg.vocab_size, size=(8,)), gen=gen)
    other = _requests(cfg, lens=(5, 10), news=(7, 3))
    solo = Engine(cfg, params, max_slots=1, decode_block=4).generate([r])
    crowd = Engine(cfg, params, max_slots=3,
                   decode_block=4).generate([other[0], r, other[1]])
    assert solo[0].tokens == crowd[1].tokens
