"""Mixed-precision policy invariants (repro.precision).

* params keep param_dtype through training under any policy
* norms / attention-softmax / residual adds accumulate in fp32
* loss_scale=1 wrapped steps bit-match unscaled steps
* dynamic loss scaling halves on overflow (step skipped) and regrows
* Pallas kernels + refs take compute-dtype inputs with fp32 accumulators,
  cross-checked under REPRO_FORCE_REF
* paper-MLP smoke accuracy under bf16 within 1% of fp32
* serve engine: batched == sequential token identity under a bf16 cache
* StageSpec.accum: accumulated fp32 grads match the single-shot step
* dtype-aware memory accounting: bf16 halves activation/cache byte estimates
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import precision as P
from repro.configs import get
from repro.models import mlp as MLP
from repro.models import model as M
from repro.optim import make_optimizer, mixed_precision
from repro.train import (BaselinePhase, BoundaryMaterializePhase, MLPBackend,
                         StageSpec, Trainer, TrainSpec)
from repro.train.trainer import TrainState

KEY = jax.random.PRNGKey(0)


# ==========================================================================
# policy object
# ==========================================================================

def test_policy_presets():
    bf16 = P.get_policy("bf16")
    assert bf16.compute_jnp == jnp.bfloat16
    assert bf16.param_jnp == jnp.float32
    assert bf16.accum_jnp == jnp.float32
    assert not bf16.wraps_optimizer          # full exponent range, no scale
    fp16 = P.get_policy("fp16")
    assert fp16.wraps_optimizer and fp16.dynamic_scale
    assert P.get_policy(None).name == "fp32"
    assert P.get_policy(bf16) is bf16
    with pytest.raises(ValueError):
        P.get_policy("int4")


def test_apply_to_model_keeps_param_dtype():
    cfg = get("qwen2-1.5b", smoke=True)
    out = P.get_policy("fp16").apply_to_model(cfg)
    assert out.dtype == "float16" and out.param_dtype == cfg.param_dtype
    assert P.dtype_itemsize(out.dtype) == 2
    assert P.dtype_itemsize("float32") == 4


def test_cast_floating_skips_ints():
    tree = {"w": jnp.ones((2,), jnp.float32), "i": jnp.ones((2,), jnp.int32)}
    out = P.get_policy("bf16").cast_compute(tree)
    assert out["w"].dtype == jnp.bfloat16 and out["i"].dtype == jnp.int32


# ==========================================================================
# fp32 accumulation invariants in the model blocks
# ==========================================================================

def test_norm_stats_accumulate_fp32():
    """With d=8192 a bf16-accumulated mean-square would be off by far more
    than one bf16 ulp; the fp32-stats norm stays within rounding."""
    from repro.models import layers as L
    d = 8192
    x = jax.random.normal(KEY, (2, 4, d), jnp.float32)
    p = {"scale": jnp.ones((d,), jnp.float32)}
    ref = L.norm_apply(p, x)
    out = L.norm_apply(p, x.astype(jnp.bfloat16))
    # atol covers bf16 input/output rounding only (~4e-2 at |y|~4); bf16
    # accumulation of the d=8192 mean-square would miss by ~0.5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=4e-2)
    assert out.dtype == jnp.bfloat16


def test_residual_add_promotes():
    from repro.models.layers import residual_add
    x = jax.random.normal(KEY, (64,), jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(1), (64,), jnp.float32)
    out = residual_add(x.astype(jnp.bfloat16), y.astype(jnp.bfloat16))
    assert out.dtype == jnp.bfloat16
    exp = (x.astype(jnp.bfloat16).astype(jnp.float32)
           + y.astype(jnp.bfloat16).astype(jnp.float32)).astype(jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(exp, np.float32))
    # fp32 inputs take the untouched legacy path
    assert residual_add(x, y).dtype == jnp.float32


def test_params_stay_param_dtype_under_bf16_train():
    from repro.launch.steps import build_train_step
    cfg = P.get_policy("bf16").apply_to_model(get("qwen2-1.5b", smoke=True))
    params = M.init_params(cfg, KEY)
    opt = make_optimizer("adamw", 1e-3)
    state = opt.init(params)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    step = jax.jit(build_train_step(cfg, opt))
    params, state, metrics = step(params, state, batch)
    for leaf in jax.tree_util.tree_leaves(params):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert leaf.dtype == jnp.dtype(cfg.param_dtype)
    assert np.isfinite(float(metrics["ce"]))


# ==========================================================================
# loss scaling / master weights (optim.mixed_precision)
# ==========================================================================

def _mlp_setup(precision, optimizer="sgdm", loss_scale=None):
    from repro.data.images import emnist_like
    cfg = MLP.MLPConfig(sizes=(784, 32, 16, 16, 47), cut=2)
    data = emnist_like(n_train=1880, n_test=470, seed=0, noise=0.5)
    spec = TrainSpec(batch_size=470, precision=precision,
                     baseline=StageSpec(epochs=1, lr=0.01,
                                        optimizer=optimizer))
    return cfg, data, spec


def test_loss_scale_one_bitmatches_unscaled():
    """mixed_precision(loss_scale=1) must be bit-exact with the raw
    optimizer: dividing by 1.0 and an always-true select are exact."""
    cfg, data, spec = _mlp_setup(None)
    be = MLPBackend(cfg, data, spec)
    params0 = MLP.init_params(cfg, KEY)
    batches = be.epoch_arrays(0, shuffle=False)

    def run(opt):
        params = jax.tree_util.tree_map(jnp.copy, params0)
        st = opt.init(params)
        step = be.build_baseline_step(opt)
        for i in range(batches[0].shape[0]):
            params, st, loss = step(params, st, batches[0][i], batches[1][i])
        return params, loss

    p_plain, l_plain = run(make_optimizer("sgdm", 0.01, momentum=0.9))
    p_mp, l_mp = run(mixed_precision(
        make_optimizer("sgdm", 0.01, momentum=0.9), loss_scale=1.0))
    assert float(l_plain) == float(l_mp)
    for a, b in zip(jax.tree_util.tree_leaves(p_plain),
                    jax.tree_util.tree_leaves(p_mp)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dynamic_scale_overflow_skips_and_halves():
    opt = mixed_precision(make_optimizer("sgdm", 0.1, momentum=0.0),
                          loss_scale=8.0, dynamic=True, growth_interval=2)
    params = {"w": jnp.ones((4,), jnp.float32)}
    st = opt.init(params)
    bad = {"w": jnp.full((4,), jnp.inf, jnp.float32)}
    p1, st1 = opt.update(bad, st, params)
    np.testing.assert_array_equal(np.asarray(p1["w"]), np.asarray(params["w"]))
    assert float(st1["loss_scale"]) == 4.0
    assert int(st1["good_steps"]) == 0
    # scaled finite grads: update applies the UNSCALED gradient
    good = {"w": jnp.full((4,), 4.0 * 0.5, jnp.float32)}  # 0.5 at scale 4
    p2, st2 = opt.update(good, st1, params)
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               np.asarray(params["w"]) - 0.1 * 0.5, rtol=1e-6)
    assert int(st2["good_steps"]) == 1
    _, st3 = opt.update(good, st2, p2)
    assert float(st3["loss_scale"]) == 8.0       # regrown after 2 clean steps
    assert int(st3["good_steps"]) == 0


def test_master_weights_for_half_params():
    params = {"w": jnp.ones((8,), jnp.float16)}
    opt = mixed_precision(make_optimizer("adamw", 1e-2), loss_scale=2.0)
    st = opt.init(params)
    assert st["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.full((8,), 2.0 * 1e-3, jnp.float16)}
    p1, st1 = opt.update(g, st, params)
    assert p1["w"].dtype == jnp.float16          # storage dtype preserved
    # master moved even though the fp16 rounding of the step may be tiny
    assert float(jnp.abs(st1["master"]["w"] - 1.0).max()) > 0


def test_sgdm_momentum_is_fp32():
    opt = make_optimizer("sgdm", 0.01, momentum=0.9)
    st = opt.init({"w": jnp.ones((4,), jnp.bfloat16)})
    assert st["mu"]["w"].dtype == jnp.float32


# ==========================================================================
# kernels: compute-dtype inputs, fp32 accumulators (REPRO_FORCE_REF x-check)
# ==========================================================================

def test_flash_attention_bf16_vs_fp32_ref(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_REF", "1")
    from repro.kernels import dispatch
    from repro.kernels.flash_attention import flash_attention, ref
    assert dispatch.force_ref() and not dispatch.use_pallas()
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 128, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 128, 2, 64), jnp.float32)
    exp = ref.naive_attention(q, k, v)
    out = flash_attention(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                          v.astype(jnp.bfloat16))
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(exp),
                               atol=3e-2)
    # pallas kernel (interpret) under the same bf16-in/fp32-accum contract
    from repro.kernels.flash_attention.kernel import flash_attention_tpu
    out_k = flash_attention_tpu(q.astype(jnp.bfloat16),
                                k.astype(jnp.bfloat16),
                                v.astype(jnp.bfloat16))
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(exp), atol=3e-2)


def test_selective_scan_bf16_vs_fp32_ref(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_REF", "1")
    from repro.kernels.selective_scan import selective_scan
    from repro.kernels.selective_scan import ref as ss_ref
    from repro.kernels.selective_scan.kernel import selective_scan_tpu
    ks = jax.random.split(KEY, 5)
    ba, s, di, n = 2, 64, 32, 8
    u = jax.random.normal(ks[0], (ba, s, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (ba, s, di))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.2)
    B = jax.random.normal(ks[3], (ba, s, n), jnp.float32)
    C = jax.random.normal(ks[4], (ba, s, n), jnp.float32)
    D = jnp.ones((di,), jnp.float32)
    y_ref, h_ref = ss_ref.selective_scan(u, dt, A, B, C, D)
    y, h = selective_scan(u.astype(jnp.bfloat16), dt, A, B, C, D)
    assert h.dtype == jnp.float32                # state accumulates fp32
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref), atol=5e-2)
    y_k, h_k = selective_scan_tpu(u.astype(jnp.bfloat16), dt, A, B, C, D)
    assert h_k.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_ref), atol=5e-2)


def test_sil_mse_bf16_vs_fp32_ref(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_REF", "1")
    from repro.kernels.sil_mse import sil_mse
    from repro.kernels.sil_mse import ref as sm_ref
    from repro.kernels.sil_mse.kernel import sil_mse_fwd_tpu
    ks = jax.random.split(KEY, 3)
    act = jax.random.normal(ks[0], (256, 128), jnp.float32)
    sil = jax.random.uniform(ks[1], (128, 64)) * 5
    lab = jax.random.randint(ks[2], (256,), 0, 64)
    exp = float(sm_ref.sil_mse(act, sil, lab))
    got = float(sil_mse(act.astype(jnp.bfloat16), sil, lab))
    assert got == pytest.approx(exp, rel=2e-2)
    loss_k, grad_k = sil_mse_fwd_tpu(act.astype(jnp.bfloat16), sil, lab)
    assert float(loss_k) == pytest.approx(exp, rel=2e-2)
    assert grad_k.dtype == jnp.bfloat16          # grad in activation dtype
    # the loss gradient wrt bf16 activations flows (custom VJP path)
    g = jax.grad(lambda a: sil_mse(a, sil, lab))(act.astype(jnp.bfloat16))
    assert g.dtype == jnp.bfloat16 and bool(jnp.isfinite(
        g.astype(jnp.float32)).all())


# ==========================================================================
# end-to-end: paper MLP under bf16, engine under bf16, accum
# ==========================================================================

def test_mlp_smoke_accuracy_bf16_within_1pct():
    from repro.data.images import emnist_like
    cfg = MLP.MLPConfig(sizes=(784, 32, 16, 16, 47), cut=2)
    data = emnist_like(n_train=9400, n_test=940, seed=0, noise=0.5)
    accs = {}
    for prec in (None, "bf16"):
        spec = TrainSpec(batch_size=470, precision=prec, eval_every=100,
                         baseline=StageSpec(epochs=15, lr=0.02,
                                            optimizer="sgdm"))
        be = MLPBackend(cfg, data, spec)
        _, hist = Trainer(be, spec).run(
            [BaselinePhase()], params=MLP.init_params(cfg, KEY))
        accs[prec] = hist.column("acc")[-1]
    assert accs[None] > 0.9                      # actually learned
    assert abs(accs[None] - accs["bf16"]) < 0.01


def test_boundary_spill_in_compute_dtype():
    """The materialized boundary (the paper's one communication) stores in
    the policy's compute dtype — half the memmap bytes under bf16."""
    from repro.data.images import emnist_like
    cfg = MLP.MLPConfig(sizes=(784, 32, 16, 16, 47), cut=2)
    data = emnist_like(n_train=940, n_test=470, seed=0, noise=0.5)
    spec = TrainSpec(batch_size=470, precision="bf16",
                     stages=(StageSpec(epochs=1, lr=0.01),
                             StageSpec(epochs=1, lr=0.01)))
    be = MLPBackend(cfg, data, spec)
    assert be.boundary_dtype() == np.dtype(jnp.bfloat16)
    tr = Trainer(be, spec)
    state = TrainState(stage_params=be.split(MLP.init_params(cfg, KEY)))
    BoundaryMaterializePhase(upto=1).run(tr, state)
    h = state.boundary["h"]
    assert h.array().dtype == np.dtype(jnp.bfloat16)
    assert h.nbytes == h.n_rows * cfg.boundary_width * 2
    h.close()


def test_engine_bf16_batched_equals_sequential():
    from repro.serve import Engine, GenerationConfig, Request
    cfg = get("qwen2-1.5b", smoke=True).replace(n_layers=2)
    params = M.init_params(cfg, KEY)
    rng = np.random.RandomState(0)
    reqs = [Request(tokens=rng.randint(0, cfg.vocab_size, size=(12,)),
                    gen=GenerationConfig(max_new_tokens=8), id=f"r{i}")
            for i in range(3)]
    eng = Engine(cfg, params, max_slots=3, precision="bf16")
    assert eng.cfg.dtype == "bfloat16"
    batched = eng.generate(reqs)
    seq = [Engine(cfg, params, max_slots=1, precision="bf16")
           .generate([r])[0] for r in reqs]
    for b, s in zip(batched, seq):
        assert b.tokens == s.tokens


def test_stage_accum_matches_single_shot():
    """StageSpec.accum: fp32-accumulated microbatch grads == one big batch
    (sgdm, fp32 — equality up to reduction order)."""
    cfg, data, spec = _mlp_setup(None)
    be = MLPBackend(cfg, data, spec)
    params0 = MLP.init_params(cfg, KEY)
    batches = be.epoch_arrays(0, shuffle=False)
    x, y = batches[0][0], batches[1][0]

    outs = {}
    for accum in (1, 2):
        opt = make_optimizer("sgdm", 0.01, momentum=0.9)
        params = jax.tree_util.tree_map(jnp.copy, params0)
        st = opt.init(params)
        step = be.build_baseline_step(opt, accum=accum)
        params, st, loss = step(params, st, x, y)
        outs[accum] = (params, float(loss))
    assert outs[1][1] == pytest.approx(outs[2][1], rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(outs[1][0]),
                    jax.tree_util.tree_leaves(outs[2][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


def test_lm_stage_step_accum_and_bf16():
    """LM stage step under an explicit bf16 TrainSpec with accum=2 runs and
    keeps params in param_dtype."""
    from repro.core import partition
    from repro.train import LMBackend
    cfg = get("stablelm-3b", smoke=True)
    plan = partition.make_plan(cfg, 2)
    params = M.init_params(cfg, KEY)
    spec = TrainSpec(n_stages=2, kappa=1.0, precision="bf16",
                     stages=(StageSpec(steps=1, lr=1e-3, optimizer="adamw",
                                       accum=2),) * 2)
    batch = {"tokens": jnp.ones((4, 16), jnp.int32),
             "labels": jnp.ones((4, 16), jnp.int32)}
    be = LMBackend(cfg, plan, lambda i: batch, spec)
    assert be.cfg.dtype == "bfloat16"
    sp = be.split(params)[0]
    from repro.train.backends import make_optimizer_for
    opt = make_optimizer_for(spec.stage(0), spec)
    st = opt.init(be.trainable(sp))
    sil = jnp.ones((cfg.d_model, cfg.vocab_padded), jnp.float32)
    step = be.build_stage_step(0, opt, sil, sp, accum=2)
    sp2, st2, loss = step(sp, st, batch, batch["labels"])
    assert np.isfinite(float(loss))
    for leaf in jax.tree_util.tree_leaves(sp2):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert leaf.dtype == jnp.dtype(cfg.param_dtype)


# ==========================================================================
# dtype-aware memory accounting
# ==========================================================================

def test_cache_pool_bytes_halve_under_bf16():
    from repro.serve.kv_cache import CachePool
    base = get("qwen2-1.5b", smoke=True)
    pool16 = CachePool(P.get_policy("bf16").apply_to_model(base), 4, 64)
    pool32 = CachePool(P.get_policy("fp32").apply_to_model(base), 4, 64)
    assert pool32.nbytes == 2 * pool16.nbytes


def test_analytic_hbm_bytes_follow_policy():
    from repro.configs import INPUT_SHAPES
    from repro.launch.hlo_analysis import analytic_hbm_bytes_per_chip
    base = get("qwen2-1.5b")
    shape = INPUT_SHAPES["train_4k"]
    kw = dict(params_bytes_per_chip=0, opt_bytes_per_chip=0)
    b16 = analytic_hbm_bytes_per_chip(
        P.get_policy("bf16").apply_to_model(base), shape, 256, **kw)
    b32 = analytic_hbm_bytes_per_chip(
        P.get_policy("fp32").apply_to_model(base), shape, 256, **kw)
    assert b32 > b16                              # activation stream shrank
    # the activation term itself halves: subtract the dtype-independent
    # logits term (fp32 both ways) and compare
    shape_dec = INPUT_SHAPES["decode_32k"]
    d16 = analytic_hbm_bytes_per_chip(
        P.get_policy("bf16").apply_to_model(base), shape_dec, 256,
        cache_bytes_per_chip=0, **kw)
    d32 = analytic_hbm_bytes_per_chip(
        P.get_policy("fp32").apply_to_model(base), shape_dec, 256,
        cache_bytes_per_chip=0, **kw)
    assert d32 == 2 * d16                         # pure activation stream
