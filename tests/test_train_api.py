"""repro.train phase API: equivalence with the legacy trainers, the
BoundaryCache, tail-drop surfacing, and the tied-embedding fix."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import losses, partition, pnn, sil as sil_lib
from repro.data.images import emnist_like
from repro.models import mlp as MLP
from repro.models import model as M
from repro.optim import make_optimizer
from repro.train import (BoundaryCache, StageSpec, TrainSpec, recipes,
                         spec_from_paper_hp)
from repro.train.backends import mlp_test_accuracy


# ==========================================================================
# Fig. 3 phase list == the hand-rolled sequential PNN loop (same seeds)
# ==========================================================================

def _reference_mlp_pnn(cfg, data, hp, key, eval_every):
    """The pre-redesign train_mlp_pnn loop, verbatim math: per-step python
    loop, float(loss) syncs, numpy concat for the boundary."""
    tx, ty, vx, vy = data
    kp, ks = jax.random.split(key)
    params = MLP.init_params(cfg, kp)
    left, right = params[:cfg.cut], params[cfg.cut:]
    sil = sil_lib.make_sil(ks, cfg.boundary_width, cfg.n_classes, hp.kappa)
    opt_l = make_optimizer("sgdm", hp.lr, momentum=hp.momentum)
    opt_r = make_optimizer("sgdm", hp.lr_right or hp.lr, momentum=hp.momentum)
    st_l, st_r = opt_l.init(left), opt_r.init(right)
    lstep, rstep = pnn._make_left_step(cfg, opt_l), \
        pnn._make_right_step(cfg, opt_r)
    macs_l = MLP.macs(cfg, 0, cfg.cut)
    macs_r = MLP.macs(cfg, cfg.cut, cfg.n_layers)
    hist = {"macs": [], "acc": [], "phase": []}
    cum = 0

    def log(phase):
        hist["macs"].append(cum)
        hist["acc"].append(mlp_test_accuracy(cfg, left + right, vx, vy))
        hist["phase"].append(phase)

    for ep in range(hp.n_left):
        for x, y in pnn._batches(tx, ty, hp.batch_size, shuffle=hp.shuffle,
                                 seed=ep):
            left, st_l, _ = lstep(left, st_l, x, y, sil)
            cum += macs_l * len(x)
        if (ep + 1) % eval_every == 0:
            log("left")

    fwd = jax.jit(lambda p, x: MLP.forward_range(cfg, p, x, 0, cfg.cut))
    stored = [np.asarray(fwd(left, x))
              for x, _ in pnn._batches(tx, ty, hp.batch_size, shuffle=False,
                                       seed=0)]
    boundary = np.concatenate(stored)
    ty_trunc = ty[: len(boundary)]

    for ep in range(hp.n_right):
        for h, y in pnn._batches(boundary, ty_trunc, hp.batch_size,
                                 shuffle=hp.shuffle, seed=100 + ep):
            right, st_r, _ = rstep(right, st_r, h, y)
            cum += macs_r * len(h)
        if (ep + 1) % eval_every == 0 or ep == hp.n_right - 1:
            log("right")

    if hp.n_recovery:
        rec_lr = hp.lr_recovery or (hp.lr_right or hp.lr) / 10.0
        opt_rec = make_optimizer("sgdm", rec_lr, momentum=hp.momentum)
        st_rec = opt_rec.init(left)

        @jax.jit
        def rec(pl, st, pr, x, y):
            def loss_fn(pl_):
                h = MLP.forward_range(cfg, pl_, x, 0, cfg.cut)
                logits = MLP.forward_range(
                    cfg, jax.lax.stop_gradient(pr), h, cfg.cut, cfg.n_layers)
                return losses.cross_entropy(logits, y)
            l, g = jax.value_and_grad(loss_fn)(pl)
            pl2, st2 = opt_rec.update(g, st, pl)
            return pl2, st2, l

        macs_full = MLP.macs(cfg)
        for ep in range(hp.n_recovery):
            for x, y in pnn._batches(tx, ty, hp.batch_size,
                                     shuffle=hp.shuffle, seed=200 + ep):
                left, st_rec, _ = rec(left, st_rec, right, x, y)
                cum += macs_full * len(x)
            log("recovery")
    return left + right, hist


@pytest.fixture(scope="module")
def small_data():
    return emnist_like(n_train=4700, n_test=940, seed=1, noise=0.5)


def test_fig3_phase_list_reproduces_sequential_pnn(small_data):
    """Trainer + fig3 phases == the bespoke loop, same seeds, same history."""
    cfg = MLP.MLPConfig(sizes=(784, 32, 16, 16, 47), cut=2)
    hp = pnn.PaperHP(n_left=2, n_right=4, n_recovery=2, batch_size=470,
                     lr=0.01, lr_right=0.003)
    key = jax.random.PRNGKey(42)
    p_ref, h_ref = _reference_mlp_pnn(cfg, small_data, hp, key, eval_every=2)
    _, hist = recipes.run_mlp_fig3(cfg, small_data, spec_from_paper_hp(hp),
                                   key, eval_every=2)
    h_new = hist.to_mlp_legacy()
    assert h_new["phase"] == h_ref["phase"]
    assert h_new["macs"] == h_ref["macs"]
    np.testing.assert_allclose(h_new["acc"], h_ref["acc"], atol=5e-3)
    # joined accuracy agrees at convergence tolerance
    assert abs(h_new["acc"][-1] - h_ref["acc"][-1]) < 5e-3


# ==========================================================================
# Fig. 5 phase list == the hand-rolled all-parallel LM loop
# ==========================================================================

def _reference_lm_parallel(cfg, plan, params, batch_fn, steps, kappa, lr,
                           key):
    """The pre-redesign pnn_parallel_train_lm loop, verbatim math."""
    keys = jax.random.split(key, plan.n_stages)
    sils = [sil_lib.make_sil(keys[k], cfg.d_model, cfg.vocab_size, kappa)
            for k in range(plan.n_stages - 1)]
    stage_params = [partition.slice_stage_params(cfg, plan, params, k)
                    for k in range(plan.n_stages)]
    opts = [make_optimizer("adamw", lr) for _ in range(plan.n_stages)]
    states = [opts[k].init(stage_params[k]) for k in range(plan.n_stages)]
    steps_fns = [pnn.build_stage_step(
        cfg, plan, k, sils[k] if k < plan.n_stages - 1 else None, opts[k])
        for k in range(plan.n_stages)]
    hist = {"stage": [], "step": [], "loss": []}
    for i in range(steps):
        batch = batch_fn(i)
        labels = batch["labels"]
        for k in range(plan.n_stages):
            if k == 0:
                xin = batch
            else:
                syn = sil_lib.sil_lookup(sils[k - 1], labels).astype(
                    cfg.activation_dtype())
                xin = (syn, None) if cfg.enc_dec else syn
            stage_params[k], states[k], loss = steps_fns[k](
                stage_params[k], states[k], xin, labels)
            hist["stage"].append(k)
            hist["step"].append(i)
            hist["loss"].append(float(loss))
    return partition.join_stage_params(cfg, plan, stage_params), hist


def test_fig5_phase_list_reproduces_parallel_lm():
    cfg = get("stablelm-3b", smoke=True)  # untied embeddings: exact parity
    plan = partition.make_plan(cfg, 2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    from repro.data.lm import synthetic_token_stream, lm_batches
    stream = synthetic_token_stream(8000, cfg.vocab_size, seed=0)
    it = lm_batches(stream, 4, 32, seed=0)
    bs = [{k: jnp.asarray(v) for k, v in next(it).items()} for _ in range(4)]
    bf = lambda i: bs[i % 4]  # noqa: E731
    key = jax.random.PRNGKey(1)
    _, h_ref = _reference_lm_parallel(cfg, plan, params, bf, steps=4,
                                      kappa=1.0, lr=1e-3, key=key)
    spec = TrainSpec(n_stages=2, kappa=1.0,
                     stages=tuple(StageSpec(steps=4, lr=1e-3,
                                            optimizer="adamw")
                                  for _ in range(2)))
    _, hist = recipes.run_lm_parallel(cfg, plan, params, bf, spec, key)
    h_new = hist.to_lm_legacy()
    assert h_new["stage"] == h_ref["stage"]
    assert h_new["step"] == h_ref["step"]
    np.testing.assert_allclose(h_new["loss"], h_ref["loss"], rtol=1e-4,
                               atol=1e-5)


# ==========================================================================
# tail-drop surfacing
# ==========================================================================

def test_batches_tail_drop_is_surfaced(small_data):
    tx, ty = small_data[0], small_data[1]
    bs = 450                      # 4700 = 10*450 + 200 dropped
    batches = list(pnn._batches(tx, ty, bs, shuffle=False, seed=0))
    assert len(batches) == len(tx) // bs
    assert sum(len(x) for x, _ in batches) == (len(tx) // bs) * bs
    assert pnn.dropped_sample_count(len(tx), bs) == len(tx) % bs == 200

    cfg = MLP.MLPConfig(sizes=(784, 16, 16, 47), cut=1)
    hp = pnn.PaperHP(n_left=1, n_right=1, n_baseline=1, batch_size=bs,
                     lr_right=0.003)
    _, hist = pnn.train_mlp_pnn(cfg, small_data, hp, jax.random.PRNGKey(0))
    assert hist["dropped_per_epoch"] == 200   # no longer silent


# ==========================================================================
# BoundaryCache
# ==========================================================================

def test_boundary_cache_chunked_fill():
    cache = BoundaryCache()
    cache.reserve(10, (4,), np.float32)
    for i in range(5):
        cache.append(np.full((2, 4), i, np.float32))
    assert cache.n_rows == 10 and not cache.spilled
    np.testing.assert_array_equal(cache.array()[2:4], np.full((2, 4), 1))
    with pytest.raises(ValueError):
        cache.append(np.zeros((1, 4), np.float32))   # overflow guarded
    cache.close()


def test_boundary_cache_disk_spill(tmp_path):
    cache = BoundaryCache(spill_dir=str(tmp_path), spill_threshold_bytes=0)
    cache.reserve(6, (3,), np.float32)
    cache.append(np.ones((6, 3), np.float32))
    assert cache.spilled
    assert len(os.listdir(tmp_path)) == 1
    np.testing.assert_array_equal(cache.array(), np.ones((6, 3)))
    cache.close()
    assert len(os.listdir(tmp_path)) == 0   # spill file removed


# ==========================================================================
# tied-embedding join hazard (regression)
# ==========================================================================

def test_tied_unembed_is_frozen_and_join_keeps_stage0():
    cfg = get("qwen2-1.5b", smoke=True)
    assert cfg.tie_embeddings
    plan = partition.make_plan(cfg, 2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    sp = [partition.slice_stage_params(cfg, plan, params, k) for k in (0, 1)]
    # the last stage holds a frozen snapshot, not a trainable tok_embed
    assert "tied_unembed" in sp[1] and "tok_embed" not in sp[1]
    assert "tok_embed" in sp[0]

    # gradients do not flow into the snapshot
    from conftest import make_batch
    batch = make_batch(cfg)
    h = jax.random.normal(jax.random.PRNGKey(1),
                          (2, 16, cfg.d_model), jnp.float32)

    def loss_fn(p1):
        out, _ = partition.stage_forward(cfg, plan, 1, p1, h, remat=False)
        return losses.cross_entropy(out[..., :cfg.vocab_size],
                                    batch["labels"])
    grads = jax.grad(loss_fn)(sp[1])
    assert float(jnp.abs(grads["tied_unembed"]).max()) == 0.0
    assert float(jnp.abs(grads["final_norm"]["scale"]).max()) > 0.0

    # join keeps stage 0's (trained) embedding even if the stale snapshot
    # differs — the legacy bug kept the last stage's copy
    sp[0]["tok_embed"] = sp[0]["tok_embed"] + 1.0
    joined = partition.join_stage_params(cfg, plan, sp)
    np.testing.assert_array_equal(np.asarray(joined["tok_embed"]),
                                  np.asarray(sp[0]["tok_embed"]))
    assert "tied_unembed" not in joined

    # refresh syncs the snapshot to stage 0's current embedding
    partition.refresh_tied_unembed(cfg, plan, sp)
    np.testing.assert_array_equal(np.asarray(sp[1]["tied_unembed"]),
                                  np.asarray(sp[0]["tok_embed"]))


def test_lm_baseline_phase_trains_tied_unpartitioned():
    """BaselinePhase on a tied LM is true unpartitioned training: loss
    drops and the tied embedding receives unembedding gradients."""
    from repro.train import BaselinePhase, LMBackend, Trainer
    cfg = get("qwen2-1.5b", smoke=True)
    plan = partition.make_plan(cfg, 2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    from repro.data.lm import synthetic_token_stream, lm_batches
    stream = synthetic_token_stream(8000, cfg.vocab_size, seed=0)
    it = lm_batches(stream, 4, 32, seed=0)
    bs = [{k: jnp.asarray(v) for k, v in next(it).items()} for _ in range(4)]
    spec = TrainSpec(n_stages=2, baseline=StageSpec(steps=6, lr=1e-3,
                                                    optimizer="adamw"))
    be = LMBackend(cfg, plan, lambda i: bs[i % 4], spec)
    joined, hist = Trainer(be, spec).run([BaselinePhase()], params=params)
    ls = hist.column("loss")
    assert ls[-1] < ls[0]
    assert float(jnp.abs(joined["tok_embed"] -
                         params["tok_embed"]).max()) > 0.0


def test_tied_sequential_training_still_learns():
    """End-to-end: sequential PNN on a tied arch still trains every stage
    and produces a finite joined model with stage 0's embedding."""
    cfg = get("qwen2-1.5b", smoke=True)
    plan = partition.make_plan(cfg, 2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    from repro.data.lm import synthetic_token_stream, lm_batches
    stream = synthetic_token_stream(8000, cfg.vocab_size, seed=0)
    it = lm_batches(stream, 4, 32, seed=0)
    bs = [{k: jnp.asarray(v) for k, v in next(it).items()} for _ in range(4)]
    pc = pnn.PNNLMConfig(n_stages=2, kappa=1.0,
                         stages=[pnn.PNNStageHP(steps=4, lr=2e-3)] * 2)
    joined, hist = pnn.pnn_train_lm(cfg, plan, params, lambda i: bs[i % 4],
                                    pc, jax.random.PRNGKey(1))
    s0 = [l for s, l in zip(hist["stage"], hist["loss"]) if s == 0]
    s1 = [l for s, l in zip(hist["stage"], hist["loss"]) if s == 1]
    assert s0[-1] < s0[0]
    assert s1[-1] < s1[0]
    assert bool(jnp.isfinite(
        M.forward(cfg, joined, bs[0])[0].astype(jnp.float32)).all())
    # stage 0 trained the embedding; the joined model keeps that copy
    assert float(jnp.abs(joined["tok_embed"] -
                         params["tok_embed"]).max()) > 0.0
