"""kernels.dispatch: decision caching, env force-flip symmetry, log-once."""
import logging

import pytest

from repro.kernels import FAMILIES
from repro.kernels import dispatch


@pytest.fixture(autouse=True)
def _fresh_cache():
    dispatch.cache_clear()
    yield
    dispatch.cache_clear()


def test_decisions_are_cached():
    d1 = dispatch.decide("flash_attention", (2, 32, 4, 64), "float32",
                         backend="tpu", force=False)
    before = dispatch.cache_info().hits
    d2 = dispatch.decide("flash_attention", (2, 32, 4, 64), "float32",
                         backend="tpu", force=False)
    assert d2 is d1                      # same frozen Decision instance
    assert dispatch.cache_info().hits == before + 1
    # a different shape is a different cache row, not a hit
    dispatch.decide("flash_attention", (2, 64, 4, 64), "float32",
                    backend="tpu", force=False)
    assert dispatch.cache_info().currsize >= 2


def test_force_ref_flips_every_family(monkeypatch):
    """REPRO_FORCE_REF=1 pins the reference path for EVERY kernel family,
    even when the backend reports TPU; unset, TPU dispatches Pallas."""
    monkeypatch.setenv("REPRO_FORCE_REF", "1")
    dispatch.cache_clear()
    for family in FAMILIES:
        d = dispatch.decide(family, backend="tpu")
        assert not d.use_pallas, family
        assert d.reason == "REPRO_FORCE_REF=1"
    monkeypatch.delenv("REPRO_FORCE_REF")
    dispatch.cache_clear()
    for family in FAMILIES:
        assert dispatch.decide(family, backend="tpu").use_pallas, family
        assert not dispatch.decide(family, backend="cpu").use_pallas, family


def test_fallback_logged_once(caplog):
    with caplog.at_level(logging.INFO, logger="repro.kernels"):
        for _ in range(5):
            dispatch.decide("sil_mse", (64, 16), "float32", backend="cpu",
                            force=False)
        dispatch.decide("sil_mse", (128, 16), "float32", backend="cpu",
                        force=False)   # same family+reason: still no new log
    msgs = [r.getMessage() for r in caplog.records]
    assert msgs.count("kernels.sil_mse -> reference path "
                      "(no Pallas lowering on backend='cpu')") == 1


def test_ops_route_through_decide(monkeypatch):
    """The back-compat use_pallas() predicate and the family decide() agree
    with the patchable on_tpu() seam."""
    monkeypatch.setattr(dispatch, "on_tpu", lambda: True)
    dispatch.cache_clear()
    assert dispatch.use_pallas()
    assert dispatch.decide("selective_scan", (1, 32, 64), "float32").use_pallas
    monkeypatch.setattr(dispatch, "on_tpu", lambda: False)
    dispatch.cache_clear()
    if dispatch._default_backend() not in ("tpu",):
        assert not dispatch.use_pallas()
