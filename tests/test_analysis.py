"""repro.analysis conformance: every rule fires on a known-bad fixture and
stays silent on the real (clean) hot paths.

The bad fixtures are hand-built TraceTargets / KernelPlans seeded straight
into the AnalysisContext cache — the rules can't tell them from production
entry points, so "rule fires here" is a real regression assertion, not a
mock of one.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import AnalysisContext, all_rules, get_rule, run_rule
from repro.analysis import entrypoints, source
from repro.analysis.report import SCHEMA, build_report, write_report
from repro.analysis.rules_pallas import build_plans  # noqa: F401 (registers)
from repro.analysis.rules_trace import dtype_policy  # noqa: F401 (registers)
from repro.analysis.trace import TraceTarget, donated_invars, iter_eqns, trace
from repro.kernels.plan import BlockPlan, KernelPlan, ScratchPlan

FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "fixtures")


def _ctx_with(targets):
    """Context whose traced-artifact cache holds exactly these targets."""
    ctx = AnalysisContext(arch="qwen2-1.5b", precision="bf16")
    ctx.cache[entrypoints.cache_key(ctx)] = {t.name: trace(t)
                                             for t in targets}
    return ctx


def _findings(rule_name, ctx):
    res = run_rule(get_rule(rule_name), ctx)
    assert res.error is None, res.error
    return res.findings


# ==========================================================================
# trace rules fire on bad fixtures
# ==========================================================================

def test_host_transfer_fires_on_debug_print_in_scan():
    @jax.jit
    def step(xs):
        def body(c, x):
            jax.debug.print("loss={l}", l=c)
            return c + x, c
        return jax.lax.scan(body, 0.0, xs)

    ctx = _ctx_with([TraceTarget(name="bad/scan_print", fn=step,
                                 args=(jnp.ones(4),))])
    fs = _findings("trace/host_transfer", ctx)
    assert [f.severity for f in fs] == ["fail"]
    assert "debug_callback" in fs[0].message


def test_dtype_policy_fires_on_mixed_dot():
    @jax.jit
    def f(a, b):
        return jax.lax.dot(a, b, preferred_element_type=jnp.float32)

    ctx = _ctx_with([TraceTarget(
        name="bad/mixed_dot", fn=f,
        args=(jnp.ones((4, 8), jnp.bfloat16), jnp.ones((8, 4))))])
    fs = [f for f in _findings("trace/dtype_policy", ctx)
          if f.severity == "fail"]
    assert fs and "mixed-dtype dot_general" in fs[0].message


def test_dtype_policy_warns_on_bf16_scan_accumulator():
    @jax.jit
    def f(xs):
        def body(acc, x):
            return acc + x, x
        return jax.lax.scan(body, jnp.bfloat16(0), xs)

    ctx = _ctx_with([TraceTarget(name="bad/bf16_carry", fn=f,
                                 args=(jnp.ones(8, jnp.bfloat16),))])
    fs = _findings("trace/dtype_policy", ctx)
    assert any(f.severity == "warn" and "scan carry" in f.message
               for f in fs)


def test_dtype_policy_fires_on_state_dtype_drift():
    @jax.jit
    def f(p, x):
        return jax.tree_util.tree_map(lambda l: l.astype(jnp.bfloat16), p), x

    p = {"w": jnp.ones((4, 4))}
    ctx = _ctx_with([TraceTarget(name="bad/drift", fn=f,
                                 args=(p, jnp.ones(4)),
                                 state_map=((0, 0),))])
    fs = [f for f in _findings("trace/dtype_policy", ctx)
          if f.severity == "fail"]
    assert fs and "changes dtype" in fs[0].message


def test_donation_fires_on_missing_donation():
    def f(p, st, x):
        return jax.tree_util.tree_map(lambda l: l + 1, p), st, x.sum()

    p = {"w": jnp.ones((8, 8)), "b": jnp.ones(8)}
    st = (jnp.zeros((8, 8)),)
    args = (p, st, jnp.ones(8))
    # requested donate=(0, 1) but only argnum 0 actually jit-donated
    half = jax.jit(f, donate_argnums=(0,))
    ctx = _ctx_with([TraceTarget(name="bad/half_donated", fn=half,
                                 args=args, donate=(0, 1))])
    fs = [f for f in _findings("trace/donation", ctx)
          if f.severity == "fail"]
    assert len(fs) == 1
    ev = fs[0].evidence
    assert ev["actual"] == 2 and ev["expected"] == 3
    assert ev["undonated_bytes_by_dtype"]["float32"] == 8 * 8 * 4

    # ...and the fully-donated version reports clean (info only)
    full = jax.jit(f, donate_argnums=(0, 1))
    ctx2 = _ctx_with([TraceTarget(name="ok/donated", fn=full,
                                  args=args, donate=(0, 1))])
    fs2 = _findings("trace/donation", ctx2)
    assert [f.severity for f in fs2] == ["info"]


def test_donation_regression_runtime_gate(monkeypatch):
    """The in-tree fix this rule guards: donate_argnums() used to return ()
    off-TPU unconditionally, making donation invisible to tracing.  The
    REPRO_ASSUME_DONATION override must surface the real masks on CPU."""
    from repro import runtime
    from repro.train.backends import donate_argnums
    monkeypatch.delenv("REPRO_ASSUME_DONATION", raising=False)
    with runtime.assume_donation():
        assert donate_argnums(0, 1) == (0, 1)

        def f(p, x):
            return jax.tree_util.tree_map(lambda l: l + 1, p), x.sum()

        jf = jax.jit(f, donate_argnums=donate_argnums(0))
        art = trace(TraceTarget(name="t", fn=jf,
                                args=({"w": jnp.ones(4)}, jnp.ones(4)),
                                donate=(0,)))
        assert donated_invars(art) == (True, False)
    if jax.default_backend() not in ("gpu", "tpu"):
        assert donate_argnums(0, 1) == ()


def test_recompile_hazard_fires_on_untraceable_entry():
    @jax.jit
    def f(x):
        if x.sum() > 0:          # python branch on a traced value
            return x
        return -x

    ctx = _ctx_with([TraceTarget(name="bad/py_branch", fn=f,
                                 args=(jnp.ones(4),))])
    fs = _findings("trace/recompile_hazard", ctx)
    assert [f.severity for f in fs] == ["fail"]
    assert "failed to trace" in fs[0].message


def test_recompile_hazard_warns_on_unjitted_entry():
    def f(x):
        return x * 2 + 1         # two top-level eqns, no pjit wrapper

    ctx = _ctx_with([TraceTarget(name="bad/unjitted", fn=f,
                                 args=(jnp.ones(4),))])
    fs = _findings("trace/recompile_hazard", ctx)
    assert [f.severity for f in fs] == ["warn"]


# ==========================================================================
# pallas rules fire on bad plans
# ==========================================================================

def _plan_ctx(*plans):
    ctx = AnalysisContext(arch="qwen2-1.5b")
    ctx.cache[f"plans:{ctx.arch}"] = list(plans)
    return ctx


def _bad_plan(**kw):
    base = dict(
        family="flash_attention", entry="flash_attention", grid=(2, 4),
        inputs=(BlockPlan("x", (1, 32), lambda i, j: (i, j), (2, 128)),),
        outputs=(BlockPlan("o", (1, 32), lambda i, j: (i, j), (2, 128)),),
        scratch=(ScratchPlan("acc", (8, 128), "float32", accumulator=True),))
    base.update(kw)
    return KernelPlan(**base)


def test_grid_divisibility_fires_on_indivisible_block():
    kp = _bad_plan(inputs=(BlockPlan("x", (1, 48), lambda i, j: (i, j),
                                     (2, 128)),))
    fs = _findings("pallas/grid_divisibility", _plan_ctx(kp))
    assert any(f.severity == "fail" and "not divisible" in f.message
               for f in fs)


def test_index_map_bounds_fires_on_oob_map():
    kp = _bad_plan(inputs=(BlockPlan("x", (1, 32), lambda i, j: (i, j + 1),
                                     (2, 128)),))
    fs = _findings("pallas/index_map_bounds", _plan_ctx(kp))
    assert any(f.severity == "fail" and "out of bounds" in f.message
               for f in fs)


def test_accum_dtype_fires_on_bf16_accumulator():
    kp = _bad_plan(scratch=(ScratchPlan("acc", (8, 128), "bfloat16",
                                        accumulator=True),))
    fs = _findings("pallas/accum_dtype", _plan_ctx(kp))
    assert [f.severity for f in fs] == ["fail"]


def test_real_kernel_plans_are_clean():
    for arch in ("paper_mlp", "qwen2-1.5b", "xlstm-125m"):
        ctx = AnalysisContext(arch=arch)
        for rule in ("pallas/grid_divisibility", "pallas/index_map_bounds",
                     "pallas/accum_dtype", "pallas/dispatch_symmetry"):
            fs = _findings(rule, ctx)
            assert not [f for f in fs if f.severity == "fail"], (arch, rule)


# ==========================================================================
# source lint
# ==========================================================================

def test_source_lint_fires_on_bad_fixture():
    fs = source.scan_file(os.path.join(FIXTURE_DIR, "bad_hotpath_source.py"))
    msgs = {(f.rule, f.target.rsplit(":", 1)[-1]) for f in fs}
    # one finding per banned idiom; the two pragma'd lines stay silent
    assert len(fs) == 4
    rules = sorted(f.rule for f in fs)
    assert rules == ["source/const_key"] + ["source/host_sync"] * 3, msgs


def test_source_lint_clean_on_hot_paths():
    fs = source.scan_paths(source.default_paths())
    assert fs == [], [f.target for f in fs]


# ==========================================================================
# the full pipeline is clean on the acceptance archs
# ==========================================================================

@pytest.fixture(scope="module")
def clean_results():
    out = {}
    for arch in ("paper_mlp", "qwen2-1.5b"):
        ctx = AnalysisContext(arch=arch, precision="bf16")
        out[arch] = (ctx, [run_rule(r, ctx) for r in all_rules()])
    return out


def test_no_false_positives_on_clean_archs(clean_results):
    for arch, (_, results) in clean_results.items():
        for res in results:
            assert res.error is None, (arch, res.name, res.error)
            fails = [f for f in res.findings if f.severity == "fail"]
            assert not fails, (arch, res.name,
                               [f.message for f in fails])


def test_entry_points_cover_all_surfaces(clean_results):
    mlp = entrypoints.artifacts(clean_results["paper_mlp"][0])
    lm = entrypoints.artifacts(clean_results["qwen2-1.5b"][0])
    assert set(mlp) == {"train/mlp_sil_epoch", "train/mlp_parallel_epoch",
                        "train/mlp_guarded_epoch", "sil/lookup_loss"}
    assert set(lm) == {"train/lm_stage_step", "train/lm_parallel_stage_step",
                       "train/lm_auto_parallel_stage_step",
                       "serve/prefill_admit", "serve/decode_chunk",
                       "sil/lookup_loss"}
    for art in list(mlp.values()) + list(lm.values()):
        assert art.error is None, (art.target.name, art.error)
        assert sum(1 for _ in iter_eqns(art.jaxpr)) > 0


def test_report_schema(clean_results, tmp_path):
    import json
    rep = build_report({a: rs for a, (_, rs) in clean_results.items()})
    assert rep["schema"] == SCHEMA == "repro.analysis/1"
    assert rep["ok"] and rep["n_fail_findings"] == 0
    assert sorted(rep["archs"]) == ["paper_mlp", "qwen2-1.5b"]
    p = write_report(rep, str(tmp_path / "ANALYSIS.json"))
    assert json.load(open(p))["schema"] == SCHEMA


# ==========================================================================
# byte accounting helper (shared with dryrun)
# ==========================================================================

def test_dtype_byte_breakdown():
    from repro.launch.hlo_analysis import (dtype_byte_breakdown,
                                           tree_bytes_per_chip)
    tree = {"a": jnp.zeros((4, 8), jnp.bfloat16),
            "b": jnp.zeros((2, 2), jnp.float32),
            "c": np.zeros((3,), np.int32)}
    bb = dtype_byte_breakdown(tree)
    assert bb == {"bfloat16": 64, "float32": 16, "int32": 12}
    assert tree_bytes_per_chip(tree) == 92
    # ShapeDtypeStructs work too (dryrun's path)
    structs = {"a": jax.ShapeDtypeStruct((4, 8), jnp.bfloat16)}
    assert dtype_byte_breakdown(structs) == {"bfloat16": 64}


def test_arg_bytes_per_chip_delegates():
    from repro.launch.dryrun import arg_bytes_per_chip
    from repro.launch.hlo_analysis import tree_bytes_per_chip
    tree = {"a": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    assert arg_bytes_per_chip(tree, None, None) \
        == tree_bytes_per_chip(tree) == 256
