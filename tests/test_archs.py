"""Per-architecture smoke tests: reduced variants of every assigned config
run one forward + one train step on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs import ARCH_NAMES, get
from repro.core import losses
from repro.models import model as M
from repro.optim import make_optimizer


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward(name, smoke_params_cache):
    cfg, params = smoke_params_cache(name)
    batch = make_batch(cfg)
    logits, aux = M.forward(cfg, params, batch)
    s_total = 16 + (cfg.vision_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, s_total, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_train_step(name, smoke_params_cache):
    cfg, params = smoke_params_cache(name)
    batch = make_batch(cfg)
    opt = make_optimizer("adamw", 1e-3)
    state = opt.init(params)

    @jax.jit
    def step(p, st, b):
        def loss_fn(p_):
            logits, aux = M.forward(cfg, p_, b)
            if cfg.frontend == "vision":
                logits = logits[:, cfg.vision_tokens:]
            loss, _ = losses.train_objective(cfg, logits, b["labels"], aux)
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(p)
        p2, st2 = opt.update(grads, st, p)
        return p2, st2, loss

    p1, st1, l0 = step(params, state, batch)
    p2, _, l1 = step(p1, st1, batch)
    assert jnp.isfinite(l0) and jnp.isfinite(l1)
    # a second step on the same batch should not increase loss much
    assert float(l1) < float(l0) + 0.5
    # params actually changed
    changed = any(
        not jnp.array_equal(a, b)
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p1)))
    assert changed


def test_xlstm_multi_step_stays_finite(smoke_params_cache):
    """Regression: masked-exp in the mLSTM chunk must not NaN the backward
    pass after a few steps (0 * inf poisoning)."""
    cfg, params = smoke_params_cache("xlstm-125m")
    batch = make_batch(cfg, b=2, s=32)
    opt = make_optimizer("adamw", 3e-4)
    state = opt.init(params)

    @jax.jit
    def step(p, st, b):
        def loss_fn(p_):
            logits, aux = M.forward(cfg, p_, b)
            loss, _ = losses.train_objective(cfg, logits, b["labels"], aux)
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(p)
        p2, st2 = opt.update(grads, st, p)
        return p2, st2, loss

    p = params
    for _ in range(5):
        p, state, l = step(p, state, batch)
        assert bool(jnp.isfinite(l)), "loss went non-finite"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_structure(name):
    """The FULL configs must at least build valid plans/specs (no alloc)."""
    cfg = get(name)
    g = M.n_groups(cfg)
    assert g * M.group_size(cfg) == cfg.n_layers
    import math
    struct = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    n = sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(struct))
    # structural param count should be within 25% of the analytic one
    analytic = cfg.param_counts()["total"]
    assert 0.75 < n / analytic < 1.35, (n, analytic)
