import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 host devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import pytest  # noqa: E402

from repro.configs import get  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.verify import scenarios  # noqa: E402


def make_batch(cfg, b=2, s=16, key=0):
    """A well-formed training batch for any assigned architecture family."""
    rng = jax.random.PRNGKey(key)
    ks = jax.random.split(rng, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size),
    }
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            ks[2], (b, cfg.enc_seq, cfg.d_model), jnp.float32) * 0.02
    if cfg.frontend == "vision":
        batch["image_embeds"] = jax.random.normal(
            ks[3], (b, cfg.vision_tokens, cfg.d_model), jnp.float32) * 0.02
    return batch


@pytest.fixture(scope="session")
def smoke_params_cache():
    cache = {}

    def get_params(name):
        if name not in cache:
            cfg = get(name, smoke=True)
            cache[name] = (cfg, M.init_params(cfg, jax.random.PRNGKey(0)))
        return cache[name]
    return get_params


# --------------------------------------------------------------------------
# shared tiny-config worlds (repro.verify.scenarios — the same builders the
# conformance oracles use, so tests and oracles can never drift on setup)
# --------------------------------------------------------------------------

@pytest.fixture(scope="session")
def tiny_mlp():
    """Factory: (cfg, data, spec) for a CPU-sized paper-MLP experiment."""
    return scenarios.tiny_mlp


@pytest.fixture(scope="session")
def tiny_lm():
    """Factory: (cfg, plan, batch_fn, spec, params) on a smoke LM config."""
    return scenarios.tiny_lm


@pytest.fixture(scope="session")
def serve_world():
    """Factory: (cfg, params) for serving tests, cached per (arch, window,
    seed) across the whole session — param init used to be re-run per test."""
    cache = {}

    def get_world(arch="qwen2-1.5b", window=0, seed=0):
        key = (arch, window, seed)
        if key not in cache:
            cfg = scenarios.serve_cfg(arch, window)
            cache[key] = (cfg, scenarios.serve_params(cfg, seed))
        return cache[key]
    return get_world
