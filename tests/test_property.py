"""Hypothesis property-based tests on the system's invariants.

hypothesis is a CI dependency (see .github/workflows/ci.yml) — these run on
every CI push; the importorskip only spares ad-hoc local environments that
never installed it.

Strategy groups: partition-boundary shapes (any stage count over any layer
stack composes back to the full forward), SIL tables (label dtypes/ranges/
shapes and table dtype survive the lookup), scheduler admit/retire
sequences (random interleavings never leak or double-book a slot), plus the
numeric invariants (RoPE norms, CE bounds, kappa scaling, attention refs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional dep; skip, don't error
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import ARCH_NAMES
from repro.core import sil as sil_lib
from repro.core.losses import cross_entropy
from repro.models import layers as L
from repro.models import mlp as MLP

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


@given(n=st.integers(2, 128), m=st.integers(2, 64),
       kappa=st.floats(0.01, 100.0))
@settings(**SETTINGS)
def test_sil_range_property(n, m, kappa):
    """Eq. 1 invariant: entries in [0, kappa], shape (N_P, M)."""
    s = sil_lib.make_sil(jax.random.PRNGKey(0), n, m, kappa)
    assert s.shape == (n, m)
    assert float(s.min()) >= 0.0
    assert float(s.max()) <= kappa + 1e-5


@given(g=st.integers(1, 97), k=st.integers(1, 8))
@settings(**SETTINGS)
def test_partition_plan_properties(g, k):
    """Plans are contiguous, cover [0, G), and are balanced within 1."""
    if k > g:
        return
    # replicate the balanced-split logic used by make_plan
    base, rem = divmod(g, k)
    sizes = [base + (1 if i < rem else 0) for i in range(k)]
    assert sum(sizes) == g
    assert max(sizes) - min(sizes) <= 1


@given(n_layers=st.integers(1, 24), n_stages=st.integers(1, 8))
@settings(**SETTINGS)
def test_balanced_bounds_invariants(n_layers, n_stages):
    """Partition-boundary shapes: contiguous, cover [0, n_layers), balanced
    within one layer, and the 2-stage default is the paper's cut."""
    from repro.train.backends import balanced_bounds, mlp_default_bounds
    if n_stages > n_layers:
        return
    sizes = tuple([16] * (n_layers + 1))
    cfg = MLP.MLPConfig(sizes=sizes, cut=max(1, n_layers // 2), n_classes=16)
    bounds = balanced_bounds(cfg, n_stages)
    assert bounds[0][0] == 0 and bounds[-1][1] == n_layers
    for (a0, a1), (b0, b1) in zip(bounds, bounds[1:]):
        assert a1 == b0 and a1 > a0          # contiguous, non-empty
    widths = [b1 - b0 for b0, b1 in bounds]
    assert max(widths) - min(widths) <= 1
    two = mlp_default_bounds(cfg, 2)
    assert two == ((0, cfg.cut), (cfg.cut, cfg.n_layers))


@given(layers=st.lists(st.integers(4, 16), min_size=3, max_size=6),
       n_stages=st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_mlp_multi_stage_chain_equals_full(layers, n_stages):
    """forward_range composed over ANY balanced stage split == the full
    forward (the boundary-shape contract every phase relies on)."""
    from repro.train.backends import balanced_bounds
    sizes = tuple([12] + layers + [8])
    cfg = MLP.MLPConfig(sizes=sizes, cut=1, n_classes=8)
    if n_stages > cfg.n_layers:
        return
    params = MLP.init_params(cfg, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 12))
    h = x
    for b0, b1 in balanced_bounds(cfg, n_stages):
        h = MLP.forward_range(cfg, params[b0:b1], h, b0, b1)
    full = MLP.forward(cfg, params, x)
    np.testing.assert_allclose(np.asarray(h), np.asarray(full), rtol=1e-5,
                               atol=1e-5)


@given(layers=st.lists(st.integers(4, 32), min_size=2, max_size=6),
       cut=st.integers(1, 5))
@settings(**SETTINGS)
def test_mlp_stage_chain_equals_full(layers, cut):
    """forward_range composition == full forward for any cut point."""
    sizes = tuple([16] + layers + [8])
    cfg = MLP.MLPConfig(sizes=sizes, cut=min(cut, len(sizes) - 2),
                        n_classes=8)
    params = MLP.init_params(cfg, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16))
    h = MLP.forward_range(cfg, params[:cfg.cut], x, 0, cfg.cut)
    out2 = MLP.forward_range(cfg, params[cfg.cut:], h, cfg.cut, cfg.n_layers)
    full = MLP.forward(cfg, params, x)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(full), rtol=1e-5,
                               atol=1e-5)


@given(b=st.integers(1, 3), s=st.integers(2, 33), h=st.sampled_from([2, 4]),
       d=st.sampled_from([8, 16]))
@settings(**SETTINGS)
def test_rope_preserves_norm(b, s, h, d):
    x = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, d))
    cos, sin = L.rope_tables(jnp.arange(s), d, 1.0, 10000.0)
    y = L.rope_apply(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4, atol=1e-4)


@given(b=st.integers(1, 4), v=st.integers(3, 40))
@settings(**SETTINGS)
def test_cross_entropy_bounds(b, v):
    """CE of uniform logits == log V; CE >= 0; padded vocab invariant."""
    logits = jnp.zeros((b, v))
    labels = jnp.zeros((b,), jnp.int32)
    ce = float(cross_entropy(logits, labels))
    assert abs(ce - np.log(v)) < 1e-5
    padded = jnp.concatenate([logits, jnp.full((b, 7), 123.0)], -1)
    ce_pad = float(cross_entropy(padded, labels, vocab_size=v))
    assert abs(ce_pad - ce) < 1e-5


@given(kappa=st.floats(0.1, 50.0), lr_scale=st.floats(0.1, 10.0))
@settings(max_examples=10, deadline=None)
def test_sil_loss_scales_quadratically(kappa, lr_scale):
    """MSE vs kappa-scaled SIL scales ~ quadratically when act == 0 — the
    analytic backbone of the paper's kappa<->lr analogy (Fig. 9)."""
    key = jax.random.PRNGKey(3)
    sil1 = sil_lib.make_sil(key, 32, 10, kappa)
    sil2 = sil_lib.make_sil(key, 32, 10, kappa * 2)
    act = jnp.zeros((20, 32))
    lab = jnp.arange(20, dtype=jnp.int32) % 10
    from repro.core.losses import sil_stage_loss
    l1 = float(sil_stage_loss(act, sil1, lab))
    l2 = float(sil_stage_loss(act, sil2, lab))
    assert abs(l2 / l1 - 4.0) < 1e-3


@given(n=st.integers(2, 64), m=st.integers(2, 64),
       batch_shape=st.sampled_from([(7,), (2, 5), (3, 2, 2)]),
       label_dtype=st.sampled_from([np.int8, np.int16, np.int32, np.int64]),
       table_dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
@settings(max_examples=20, deadline=None)
def test_sil_lookup_dtypes_and_ranges(n, m, batch_shape, label_dtype,
                                      table_dtype):
    """SIL lookups must work for any int label dtype and label shape, keep
    the table's dtype (bf16 tables stay bf16 on the way to the loss), and
    return exactly the labelled columns."""
    if m > np.iinfo(label_dtype).max:
        return
    sil = sil_lib.make_sil(jax.random.PRNGKey(0), n, m, 10.0,
                           dtype=table_dtype)
    assert sil.dtype == table_dtype
    rng = np.random.RandomState(1)
    labels = rng.randint(0, m, size=batch_shape).astype(label_dtype)
    out = sil_lib.sil_lookup(sil, jnp.asarray(labels))
    assert out.shape == batch_shape + (n,)
    assert out.dtype == table_dtype
    flat = labels.reshape(-1)
    got = np.asarray(out, np.float32).reshape(len(flat), n)
    want = np.asarray(sil, np.float32).T[flat]
    np.testing.assert_array_equal(got, want)


@given(n_slots=st.integers(1, 4),
       choices=st.lists(st.booleans(), min_size=1, max_size=60))
@settings(max_examples=30, deadline=None)
def test_scheduler_random_admit_retire_sequences(n_slots, choices):
    """Any admit/retire interleaving preserves the slot partition (free +
    active == all slots, enforced per transition by the audit), never
    double-books, and the event log balances."""
    from repro.serve.scheduler import Scheduler

    class _Req:
        class gen:
            max_new_tokens = 4

    sched = Scheduler(n_slots)
    admitted = 0
    for want_admit in choices:
        if want_admit and sched.free:
            slot = sched.admit(admitted, _Req(), n_prompt=3)
            assert slot in sched.active and slot not in sched.free
            admitted += 1
        elif sched.active:
            slot = sorted(sched.active)[0]
            st_ = sched.retire(slot)
            assert slot in sched.free and slot not in sched.active
            assert st_.remaining == 4
    assert len(sched.free) + len(sched.active) == n_slots
    admits = sum(1 for e, _ in sched.events if e == "admit")
    retires = sum(1 for e, _ in sched.events if e == "retire")
    assert admits - retires == len(sched.active)
    assert sched.max_concurrent <= n_slots


# --------------------------------------------------------------------------
# repro.plan: the auto-partitioner's searcher invariants
# --------------------------------------------------------------------------

def _plan_table(units, head=0, tail=0, boundary=None):
    """A ModelCosts table from bare per-unit byte weights (+ optional
    head/tail stage overheads), the searcher's full input surface."""
    from repro.plan.costs import ModelCosts
    n = len(units)
    return ModelCosts(
        kind="mlp", n_units=n, optimizer="sgd",
        unit_param_bytes=tuple(units), unit_param_elems=(0,) * n,
        unit_act_bytes=(0,) * n,
        unit_flops=tuple(float(u) for u in units),
        unit_boundary_bytes=tuple(boundary or (0,) * n),
        head_param_bytes=head, tail_param_bytes=tail)


def _bottleneck(table, bounds):
    from repro.plan.search import stage_objective
    cost = stage_objective(table, "bytes")
    k = len(bounds)
    return max(cost(lo, hi, i, k) for i, (lo, hi) in enumerate(bounds))


@given(units=st.lists(st.integers(1, 1000), min_size=1, max_size=24),
       head=st.integers(0, 5000), tail=st.integers(0, 5000),
       k=st.integers(1, 6))
@settings(**SETTINGS)
def test_plan_solver_invariants(units, head, tail, k):
    """Searched bounds are a contiguous cover with no empty stage and
    strictly increasing cuts; K=1 is the whole model; the predicted
    bottleneck never exceeds the uniform split's."""
    from repro.plan.search import solve, uniform_bounds
    if k > len(units):
        return
    n = len(units)
    table = _plan_table(units, head=head, tail=tail)
    bounds = solve(table, k)
    assert bounds[0][0] == 0 and bounds[-1][1] == n
    for (_, a1), (b0, _) in zip(bounds, bounds[1:]):
        assert a1 == b0
    assert all(hi > lo for lo, hi in bounds)
    cuts = [hi for _, hi in bounds[:-1]]
    assert cuts == sorted(cuts) and len(cuts) == len(set(cuts))
    if k == 1:
        assert bounds == ((0, n),)
    assert _bottleneck(table, bounds) \
        <= _bottleneck(table, uniform_bounds(n, k)) + 1e-9


@given(units=st.lists(st.integers(1, 200), min_size=2, max_size=10),
       head=st.integers(0, 500), tail=st.integers(0, 500),
       k=st.integers(2, 4))
@settings(max_examples=20, deadline=None)
def test_plan_solver_matches_brute_force(units, head, tail, k):
    """The DP's bottleneck equals exhaustive enumeration's optimum."""
    from repro.plan.search import brute_force_bounds, solve
    if k > len(units):
        return
    table = _plan_table(units, head=head, tail=tail)
    best, _ = brute_force_bounds(table, k)
    got = _bottleneck(table, solve(table, k))
    assert abs(got - best) <= 1e-9 * max(1.0, best)


@given(k=st.integers(1, 6), seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_plan_uniform_units_reproduce_divmod_split(k, seed):
    """Exact-tie determinism: over equal-weight units every balanced cut
    ties, and the tie-break must reproduce the divmod hand bounds."""
    from repro.plan.search import solve, uniform_bounds
    rng = np.random.RandomState(seed)
    n = int(rng.randint(max(k, 1), 25))
    w = int(rng.randint(1, 1000))
    table = _plan_table([w] * n)
    assert solve(table, k if k <= n else n) \
        == uniform_bounds(n, k if k <= n else n)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_plan_invariants_hold_for_every_arch(arch):
    """auto_plan produces a valid PartitionPlan with a bottleneck <= the
    uniform split's for every assigned architecture."""
    from repro import plan as plan_lib
    from repro.configs import get
    from repro.models import model as M
    from repro.plan.search import uniform_bounds
    cfg = get(arch)
    g = M.n_groups(cfg)
    k = min(4, g)
    table = plan_lib.lm_costs(cfg)
    bounds = plan_lib.auto_bounds(table, k)
    assert bounds[0][0] == 0 and bounds[-1][1] == g
    assert all(hi > lo for lo, hi in bounds)
    for (_, a1), (b0, _) in zip(bounds, bounds[1:]):
        assert a1 == b0
    assert _bottleneck(table, bounds) \
        <= _bottleneck(table, uniform_bounds(g, k)) + 1e-9


@given(n_blocks=st.integers(2, 12),
       ops=st.lists(st.tuples(st.integers(0, 2), st.integers(0, 4),
                              st.integers(0, 6)),
                    min_size=1, max_size=80))
@settings(max_examples=40, deadline=None)
def test_block_allocator_random_interleavings(n_blocks, ops):
    """Any interleaving of alloc / share (incref) / release over the paged
    BlockAllocator preserves the partition invariant (every non-garbage
    block is free with 0 refs or live with > 0, exactly once), never
    double-owns a block across live allocations' fresh sets, and returns
    blocks to the free pool exactly when the LAST owner retires."""
    from repro.serve.kv_cache import BlockAllocator, GARBAGE_BLOCK
    a = BlockAllocator(n_blocks, block_size=4)
    live = []                                  # [(ids, owners)]
    for kind, n, pick in ops:
        if kind == 0:                          # alloc n fresh blocks
            ids = a.alloc(n)
            if n > a.n_free + (len(ids) if ids else 0):
                assert ids is None             # all-or-nothing
            if ids is not None:
                assert GARBAGE_BLOCK not in ids
                owned = {i for blk, _ in live for i in blk}
                assert not owned & set(ids)    # never double-owned
                live.append((tuple(ids), 1))
        elif kind == 1 and live:               # share an existing alloc
            ids, owners = live[pick % len(live)]
            a.incref(ids)
            live[pick % len(live)] = (ids, owners + 1)
        elif kind == 2 and live:               # release one owner
            j = pick % len(live)
            ids, owners = live.pop(j)
            released = a.free(ids)
            if owners > 1:
                assert released == []          # co-owners keep it live
                live.append((ids, owners - 1))
            else:
                assert set(released) == set(ids)   # last retire frees all
                assert all(a.refcount[i] == 0 for i in ids)
        a.check()
    held = sum(len(ids) for ids, _ in live)
    # distinct blocks, since shares reuse the same tuple
    assert a.n_used == len({i for ids, _ in live for i in ids})
    assert a.peak_used <= a.n_blocks - 1 and held >= a.n_used
    for ids, owners in live:
        a.free(ids * owners) if owners > 1 else a.free(ids)
    assert a.n_used == 0 and a.n_free == a.n_blocks - 1
    a.check()


@given(seq=st.integers(1, 64), window=st.sampled_from([0, 8, 16]))
@settings(max_examples=15, deadline=None)
def test_chunked_attention_matches_naive(seq, window):
    from repro.kernels.flash_attention import ref
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, seq, 2, 8))
    k = jax.random.normal(ks[1], (1, seq, 2, 8))
    v = jax.random.normal(ks[2], (1, seq, 2, 8))
    a = ref.chunked_attention(q, k, v, causal=True, window=window, chunk=16)
    b = ref.naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)
