"""repro.resilience: deterministic fault injection, self-healing stage
execution, atomic checkpoints, the NaN/inf step guard, and serve-side
graceful degradation.

The recovery contract under test is the paper's zero-communication
property: a stage failure is local, so an injected fault plus a correct
recovery must reproduce the fault-free run **bitwise** (see the
``resilience/crash_equivalence`` oracle for the conformance-level pin).

Multi-device cases follow the test_dist convention; run them with

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q tests/test_resilience.py
"""
import os
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import (CheckpointCorruptError, available_steps,
                              restore_checkpoint, restore_latest_valid,
                              save_checkpoint)
from repro.dist import StageExecutor, placement as P
from repro.optim import read_skipped, sgd_momentum, step_guard
from repro.resilience import (CheckpointCorruption, FakeClock, FaultSchedule,
                              NaNInjection, RetryPolicy, StageCrash,
                              StragglerDelay, SupervisedExecutor,
                              TransientError, UnrecoveredFaultError)
from repro.resilience.faults import poison_batch
from repro.train.backends import MLPBackend, balanced_bounds, \
    make_optimizer_for

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 4, reason="needs >=4 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

N_TICKS = 3


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ==========================================================================
# fixtures: one tiny 2-stage MLP world, one fault-free reference run
# ==========================================================================

@pytest.fixture(scope="module")
def mlp_world(tiny_mlp):
    """Factory: (backend, stage_params, sils, hps, spec) — identical per
    call, so a fault schedule is the only thing that varies between runs."""
    def build(nan_guard=False):
        from repro.models import mlp as MLP
        cfg, data, spec = tiny_mlp(n_stages=2, epochs=(N_TICKS, N_TICKS),
                                   n_train=256, batch_size=64)
        if nan_guard:
            spec = replace(spec, nan_guard=True)
        be = MLPBackend(cfg, data, spec, bounds=balanced_bounds(cfg, 2))
        params = MLP.init_params(cfg, jax.random.PRNGKey(0))
        sils = be.make_sils(jax.random.PRNGKey(3), spec.kappa)
        hps = [spec.stage(k) for k in range(2)]
        return be, be.split(params), sils, hps, spec
    return build


def _executor(world, root):
    be, sp0, sils, hps, spec = world
    opts = [make_optimizer_for(hp, spec) for hp in hps]
    return StageExecutor(be, P.round_robin(2), sp0, sils, opts, hps,
                         shuffle=True, ckpt_dir=root)


@pytest.fixture(scope="module")
def ref_params(mlp_world, tmp_path_factory):
    """Fault-free gather() — the bitwise target every recovery must hit."""
    ex = _executor(mlp_world(), str(tmp_path_factory.mktemp("ref")))
    ex.run(N_TICKS)
    return ex.gather()


def _supervised(world, root, schedule, *, policy=None, strict=True):
    ex = _executor(world, root)
    clk = FakeClock()
    sup = SupervisedExecutor(
        ex, schedule=schedule, clock=clk.monotonic, sleep=clk.sleep,
        policy=policy or RetryPolicy(max_retries=4), strict=strict)
    sup.run(N_TICKS)
    return ex, sup


# ==========================================================================
# fault primitives (pure — no training)
# ==========================================================================

def test_fault_schedule_sample_deterministic():
    def shape(s):
        # repr, not ==: a sampled NaNInjection(value=nan) breaks dataclass
        # equality (nan != nan) while still being the same fault
        return [repr(f) for f in s.faults]

    a = FaultSchedule.sample(7, n_stages=3, n_ticks=5, n_faults=4)
    b = FaultSchedule.sample(7, n_stages=3, n_ticks=5, n_faults=4)
    assert shape(a) == shape(b) and a.seed == 7
    assert shape(a) != shape(FaultSchedule.sample(8, n_stages=3, n_ticks=5,
                                                  n_faults=4))
    coords = [(f.stage, f.tick) for f in a.faults]
    assert len(set(coords)) == len(coords)          # distinct (stage, tick)
    assert all(f.tick >= 1 for f in a.faults)       # tick 0 always completes
    with pytest.raises(ValueError, match="unknown fault kinds"):
        FaultSchedule.sample(0, n_stages=2, n_ticks=3, kinds=("meteor",))


def test_fault_consumption_prevents_replay_refire():
    sched = FaultSchedule([StageCrash(stage=0, tick=1)])
    f = sched.crash_at(0, 1)
    assert f is not None
    sched.consume(f)
    assert sched.crash_at(0, 1) is None             # replayed tick: no refire
    assert sched.unconsumed() == []


def test_transient_failing_counts_down():
    sched = FaultSchedule([TransientError(stage=0, tick=2, failures=2)])
    assert sched.transient_failing(0, 2)
    assert sched.transient_failing(0, 2)
    assert not sched.transient_failing(0, 2)        # cleared
    assert sched.unconsumed() == []


def test_poison_batch_tuple_dict_and_int_only():
    x = np.ones((4, 3), np.float32)
    y = np.zeros((4,), np.int32)
    px, py = poison_batch((x, y), float("inf"))
    assert np.isinf(px.reshape(-1)[0]) and np.array_equal(py, y)
    assert np.isfinite(x).all()                     # original untouched
    d = poison_batch({"labels": y, "x": x}, float("nan"))
    assert np.isnan(d["x"].reshape(-1)[0])
    with pytest.raises(ValueError, match="no floating-point"):
        poison_batch((y,), 1.0)


def test_fake_clock_sleep_advances():
    clk = FakeClock(10.0)
    clk.sleep(0.5)
    clk.advance(0.25)
    assert clk.monotonic() == 10.75 and clk.sleeps == [0.5]


def test_retry_policy_deterministic_per_stage_jitter():
    pol = RetryPolicy(max_retries=3, base=0.1, factor=2.0, seed=5)
    d0 = list(pol.delays(0))
    assert d0 == list(pol.delays(0))                # replayable
    assert d0 != list(pol.delays(1))                # desynchronized stages
    assert len(d0) == 3 and d0[0] < d0[1] < d0[2]   # exponential growth


# ==========================================================================
# atomic checkpoints: durability + fallback (repro.checkpoint)
# ==========================================================================

def _tree(v=0.0):
    return {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3) + v,
            "b": jnp.ones((3,), jnp.bfloat16) * (1.5 + v)}


def test_atomic_save_leaves_no_temp_files(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    assert not [f for f in os.listdir(d) if ".tmp" in f]
    _leaves_equal(restore_checkpoint(d, _tree()), _tree())


def test_keep_last_prunes_old_steps(tmp_path):
    d = str(tmp_path)
    for s in range(1, 6):
        save_checkpoint(d, s, _tree(s), keep_last=2)
    assert available_steps(d) == [4, 5]
    _leaves_equal(restore_checkpoint(d, _tree()), _tree(5))


def test_checksum_detects_bit_rot_and_fallback_cures_it(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1))
    save_checkpoint(d, 2, _tree(2))
    npz = os.path.join(d, "ckpt_00000002.npz")
    data = bytearray(open(npz, "rb").read())
    data[len(data) // 2 + len(data) // 4] ^= 0xFF
    open(npz, "wb").write(bytes(data))
    # pinned step: corruption raises, never substitutes other state
    with pytest.raises(CheckpointCorruptError):
        restore_checkpoint(d, _tree(), step=2)
    # latest-valid: falls back to step 1
    tree, step = restore_latest_valid(d, _tree())
    assert step == 1
    _leaves_equal(tree, _tree(1))


def test_torn_write_is_skipped_not_fatal(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1))
    # a crash mid-save leaves arrays without the committing manifest
    save_checkpoint(d, 2, _tree(2))
    os.remove(os.path.join(d, "ckpt_00000002.json"))
    tree, step = restore_latest_valid(d, _tree())
    assert step == 1
    _leaves_equal(tree, _tree(1))


def test_all_steps_invalid_reports_count(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1))
    save_checkpoint(d, 2, _tree(2))
    for s in (1, 2):
        os.remove(os.path.join(d, f"ckpt_0000000{s}.json"))
    with pytest.raises(CheckpointCorruptError, match="older step"):
        restore_latest_valid(d, _tree())
    with pytest.raises(FileNotFoundError, match="no checkpoints"):
        restore_latest_valid(str(tmp_path / "empty"), _tree())


def test_like_mismatch_is_not_curable(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    with pytest.raises(ValueError, match="does not match") as ei:
        restore_checkpoint(d, {"other": jnp.zeros((2,))}, step=1)
    assert not isinstance(ei.value, CheckpointCorruptError)


# ==========================================================================
# supervised recovery: every fault kind fires AND the run stays bitwise
# ==========================================================================

def test_crash_recovery_bitwise_and_others_keep_ticking(
        mlp_world, ref_params, tmp_path):
    sched = FaultSchedule([StageCrash(stage=1, tick=1)])
    ex, sup = _supervised(mlp_world(), str(tmp_path), sched)
    assert ("fault", "crash", 1, 1) in sup.events
    assert any(e[0] == "recover" and e[1] == 1 for e in sup.events)
    # zero-communication payoff: stage 0 advanced while stage 1 was down
    i_fault = sup.events.index(("fault", "crash", 1, 1))
    i_rec = next(i for i, e in enumerate(sup.events)
                 if e[0] == "recover" and e[1] == 1)
    assert any(e[0] == "tick" and e[1] == 0
               for e in sup.events[i_fault:i_rec]), sup.events
    assert not sup.unrecovered and sup.report()["never_fired"] == []
    _leaves_equal(ref_params, ex.gather())


def test_transient_retries_in_place_without_restore(
        mlp_world, ref_params, tmp_path):
    sched = FaultSchedule([TransientError(stage=0, tick=1, failures=2)])
    ex, sup = _supervised(mlp_world(), str(tmp_path), sched)
    assert sup.faults_seen.count(("transient", 0, 1)) == 2
    assert not any(e[0] == "recover" for e in sup.events)  # state survived
    _leaves_equal(ref_params, ex.gather())


@pytest.mark.parametrize("mode", ["truncate_manifest", "truncate_npz",
                                  "flip_bytes"])
def test_corruption_recovery_routes_around_bad_file(
        mlp_world, ref_params, tmp_path, mode):
    sched = FaultSchedule([CheckpointCorruption(stage=0, tick=2, mode=mode)])
    ex, sup = _supervised(mlp_world(), str(tmp_path), sched)
    assert ("fault", "ckpt_corruption", 0, 2) in sup.events
    # the newest ckpt was damaged: recovery restored an OLDER tick and
    # replayed further than a plain crash would
    rec = next(e for e in sup.events if e[0] == "recover" and e[1] == 0)
    assert rec[2] < 2
    assert not sup.unrecovered
    _leaves_equal(ref_params, ex.gather())


def test_straggler_defers_stage_without_stalling_others(
        mlp_world, ref_params, tmp_path):
    sched = FaultSchedule([StragglerDelay(stage=1, tick=1, delay=2.0)])
    ex, sup = _supervised(mlp_world(), str(tmp_path), sched)
    i_fault = sup.events.index(("fault", "straggler", 1, 1))
    i_next = next(i for i, e in enumerate(sup.events)
                  if i > i_fault and e[:2] == ("tick", 1))
    assert any(e[:2] == ("tick", 0)
               for e in sup.events[i_fault:i_next]), sup.events
    assert not any(e[0] == "recover" for e in sup.events)  # just late
    _leaves_equal(ref_params, ex.gather())


def test_sampled_mixed_schedule_recovers(mlp_world, ref_params, tmp_path):
    sched = FaultSchedule.sample(
        0, n_stages=2, n_ticks=N_TICKS, n_faults=3,
        kinds=("crash", "transient", "ckpt_corruption", "straggler"))
    ex, sup = _supervised(mlp_world(), str(tmp_path), sched)
    assert not sup.unrecovered and sup.report()["never_fired"] == []
    _leaves_equal(ref_params, ex.gather())


def test_retry_budget_exhaustion_strict_raises(mlp_world, tmp_path):
    sched = FaultSchedule([TransientError(stage=1, tick=1, failures=99)])
    with pytest.raises(UnrecoveredFaultError, match="stage 1"):
        _supervised(mlp_world(), str(tmp_path), sched,
                    policy=RetryPolicy(max_retries=2))


def test_retry_budget_exhaustion_lenient_isolates_failure(
        mlp_world, tmp_path):
    sched = FaultSchedule([TransientError(stage=1, tick=1, failures=99)])
    ex, sup = _supervised(mlp_world(), str(tmp_path), sched,
                          policy=RetryPolicy(max_retries=2), strict=False)
    assert sup.unrecovered and sup.unrecovered[0][0] == 1
    assert sup.report()["health"][1] == "failed"
    assert ex.ticks[0] == N_TICKS                   # stage 0 finished anyway


def test_supervisor_requires_ckpt_dir(mlp_world):
    be, sp0, sils, hps, spec = mlp_world()
    opts = [make_optimizer_for(hp, spec) for hp in hps]
    ex = StageExecutor(be, P.round_robin(2), sp0, sils, opts, hps)
    with pytest.raises(ValueError, match="ckpt_dir"):
        SupervisedExecutor(ex)


# ==========================================================================
# NaN/inf step guard
# ==========================================================================

def test_step_guard_skips_nonfinite_and_counts():
    opt = step_guard(sgd_momentum(0.5, momentum=0.0))
    p = {"w": jnp.asarray([1.0, 2.0])}
    st = opt.init(p)
    p1, st1 = opt.update({"w": jnp.asarray([0.1, 0.1])}, st, p)
    np.testing.assert_allclose(np.asarray(p1["w"]), [0.95, 1.95])
    p2, st2 = opt.update({"w": jnp.asarray([jnp.inf, 0.1])}, st1, p1)
    _leaves_equal(p2, p1)                           # step skipped wholesale
    assert int(read_skipped(st2)) == 1
    p3, st3 = opt.update({"w": jnp.asarray([jnp.nan, 0.1])}, st2, p2)
    _leaves_equal(p3, p2)
    assert int(read_skipped(st3)) == 2
    assert read_skipped({"no": 1}) is None and read_skipped(0.0) is None


def test_nan_injection_guard_skips_and_stays_finite(mlp_world, tmp_path):
    sched = FaultSchedule([NaNInjection(stage=0, tick=1)])
    ex, sup = _supervised(mlp_world(nan_guard=True), str(tmp_path), sched)
    assert int(jax.device_get(read_skipped(ex.opt_states[0]))) == 1
    assert int(jax.device_get(read_skipped(ex.opt_states[1]))) == 0
    for leaf in jax.tree_util.tree_leaves(ex.gather()):
        assert np.isfinite(np.asarray(leaf)).all()


def test_nan_without_guard_poisons_params(mlp_world, tmp_path):
    sched = FaultSchedule([NaNInjection(stage=0, tick=1)])
    ex, sup = _supervised(mlp_world(), str(tmp_path), sched)
    leaves = jax.tree_util.tree_leaves(ex.gather()[0])
    assert any(not np.isfinite(np.asarray(x)).all() for x in leaves)


def test_trainer_skipped_budget_aborts(mlp_world):
    from repro.train.trainer import (SkippedStepBudgetExceeded, Trainer,
                                     TrainState)
    be, _, _, _, spec = mlp_world()
    tr = Trainer(be, replace(spec, max_skipped_steps=1))
    state = TrainState(stage_params=[])
    tr.note_skipped(state, {"skipped": jnp.int32(1), "inner": ()}, "p", 0)
    assert state.skipped_steps == 1                 # at budget: fine
    with pytest.raises(SkippedStepBudgetExceeded, match="> budget 1"):
        tr.note_skipped(state, {"skipped": jnp.int32(2), "inner": ()},
                        "p", 1)
    # high-water: re-reading the same cumulative counter never double-counts
    state2 = TrainState(stage_params=[])
    tr2 = Trainer(be, spec)                         # no budget
    for _ in range(3):
        tr2.note_skipped(state2, {"skipped": jnp.int32(2), "inner": ()},
                         "p", 0)
    assert state2.skipped_steps == 2
    assert state2.history.meta["skipped_steps"] == {"p[0]": 2}


# ==========================================================================
# serve: graceful degradation (deadlines, queue limits, cache pressure)
# ==========================================================================

def _ticking(dt=0.1):
    clk = FakeClock()

    def tick():
        t = clk.monotonic()
        clk.advance(dt)
        return t
    return tick


def test_serve_queue_timeout_rejects_waiter(serve_world):
    from repro.serve import Engine
    from repro.verify.scenarios import greedy_reference, serve_requests
    cfg, params = serve_world()
    reqs = serve_requests(cfg, lens=(8, 8), news=(8, 8))
    eng = Engine(cfg, params, max_slots=1, decode_block=4,
                 max_queue_wait_ms=250, clock=_ticking())
    a, b = eng.generate(reqs)
    assert a.finish_reason == "length"
    assert a.tokens == greedy_reference(cfg, params, reqs[0])
    assert b.finish_reason == "rejected" and b.tokens == ()
    assert eng.stats["rejected_queue"] == 1
    assert ("reject", 1) in eng.scheduler.events


def test_serve_deadline_sheds_mid_decode(serve_world):
    from repro.serve import Engine
    from repro.verify.scenarios import greedy_reference, serve_requests
    cfg, params = serve_world()
    (r,) = serve_requests(cfg, lens=(8,), news=(8,))
    r = replace(r, deadline_ms=150.0)
    eng = Engine(cfg, params, max_slots=1, decode_block=4, clock=_ticking())
    (c,) = eng.generate([r])
    assert c.finish_reason == "rejected"
    assert 0 < c.n_generated < 8                    # partial tokens kept
    ref = greedy_reference(cfg, params, r)
    assert c.tokens == ref[:c.n_generated]          # and they're the real ones
    assert eng.stats["rejected_deadline"] == 1


def test_serve_cache_pressure_admission_control(serve_world):
    from repro.serve import Engine
    from repro.verify.scenarios import greedy_reference, serve_requests
    cfg, params = serve_world()
    reqs = serve_requests(cfg, lens=(8, 8), news=(6, 60))
    eng = Engine(cfg, params, max_slots=2, decode_block=4,
                 max_cache_tokens=16)
    a, b = eng.generate(reqs)
    assert a.finish_reason == "length"
    assert a.tokens == greedy_reference(cfg, params, reqs[0])
    assert b.finish_reason == "rejected" and b.tokens == ()
    assert eng.stats["rejected_cache"] == 1
    # the grow-only pool was sized for the ACCEPTED span (one 32-token
    # bucket), never for the 68-token request the cap shed
    assert eng._pool.cache_len == 32


def test_serve_knobs_off_is_legacy_and_loose_limits_are_noop(serve_world):
    from repro.serve import Engine
    from repro.verify.scenarios import serve_requests
    cfg, params = serve_world()
    reqs = serve_requests(cfg, lens=(8, 6), news=(6, 8))
    legacy = Engine(cfg, params, max_slots=2, decode_block=4).generate(reqs)
    shed = Engine(cfg, params, max_slots=2, decode_block=4,
                  max_queue_wait_ms=1e9, clock=_ticking()).generate(reqs)
    assert [c.tokens for c in legacy] == [c.tokens for c in shed]
    assert all(c.finish_reason == "length" for c in shed)


# ==========================================================================
# multi-device: recovery with stages pinned on distinct devices
# ==========================================================================

@multi_device
def test_crash_recovery_bitwise_multi_device(mlp_world, ref_params,
                                             tmp_path):
    sched = FaultSchedule([StageCrash(stage=0, tick=1),
                           StageCrash(stage=1, tick=2)])
    ex, sup = _supervised(mlp_world(), str(tmp_path), sched)
    assert not sup.unrecovered
    _leaves_equal(ref_params, ex.gather())
    # restored buffers live on each stage's ASSIGNED device, not device 0
    for k in range(2):
        for leaf in jax.tree_util.tree_leaves(ex.params[k]):
            assert leaf.devices() == {ex.devices[k]}


@multi_device
def test_mixed_faults_multi_device(mlp_world, ref_params, tmp_path):
    sched = FaultSchedule.sample(
        3, n_stages=2, n_ticks=N_TICKS, n_faults=3,
        kinds=("crash", "transient", "ckpt_corruption", "straggler"))
    ex, sup = _supervised(mlp_world(), str(tmp_path), sched)
    assert not sup.unrecovered and sup.report()["never_fired"] == []
    _leaves_equal(ref_params, ex.gather())
