"""Serving-path integration: prefill + decode_step must agree with the full
(training) forward at the next-token position, for every cache family
(KV attention, sliding-window ring, mamba conv/ssm state, xLSTM states,
whisper cross-attention, VLM image prefix)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import ARCH_NAMES, get
from repro.models import model as M

DECODE_ARCHS = [n for n in ARCH_NAMES]


@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_prefill_decode_matches_full(name, smoke_params_cache):
    cfg, params = smoke_params_cache(name)
    if cfg.moe is not None:
        # exact equivalence needs no capacity drops: token-choice routing is
        # batch-dependent by design (GShard capacity), so give it headroom
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=16.0))
    # fp32 activations: this test checks cache/state logic, not bf16 noise
    # (smoke params are float32 already)
    cfg = cfg.replace(dtype="float32")
    b, s = 2, 24
    batch = make_batch(cfg, b=b, s=s + 1, key=7)
    full_logits, _ = M.forward(cfg, params, batch, remat=False)

    pre = {k: (v[:, :s] if k in ("tokens", "labels") else v)
           for k, v in batch.items()}
    pre.pop("labels")
    # the KV cache must cover the vision prefix too
    lc = s + 8 + (cfg.vision_tokens if cfg.frontend == "vision" else 0)
    logits0, cache, pos = M.prefill(cfg, params, pre, cache_len=lc)
    # prefill last-token logits == full forward at position s-1 (text)
    off = cfg.vision_tokens if cfg.frontend == "vision" else 0
    np.testing.assert_allclose(
        np.asarray(logits0, np.float32),
        np.asarray(full_logits[:, off + s - 1], np.float32),
        rtol=3e-2, atol=3e-2)

    # decode the (s+1)-th token; compare with full forward's last position
    tok = batch["tokens"][:, s]
    logits1, _ = M.decode_step(cfg, params, cache, tok, pos)
    np.testing.assert_allclose(
        np.asarray(logits1, np.float32),
        np.asarray(full_logits[:, off + s], np.float32),
        rtol=3e-2, atol=3e-2)


def test_sliding_window_ring_decode():
    """Windowed variant: decode with a ring cache matches the windowed full
    forward."""
    cfg = get("qwen2-1.5b", smoke=True).replace(sliding_window=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 1, 20
    batch = make_batch(cfg, b=b, s=s + 1, key=3)
    full_logits, _ = M.forward(cfg, params, batch, remat=False)
    pre = {"tokens": batch["tokens"][:, :s]}
    _, cache, pos = M.prefill(cfg, params, pre, cache_len=s)
    # ring cache is window-sized
    assert cache["slot_0"]["k"].shape[2] == 8
    tok = batch["tokens"][:, s]
    logits1, _ = M.decode_step(cfg, params, cache, tok, pos)
    np.testing.assert_allclose(
        np.asarray(logits1, np.float32),
        np.asarray(full_logits[:, s], np.float32), rtol=3e-2, atol=3e-2)


def test_multi_step_decode_consistency():
    """Greedy decode 4 steps == teacher-forced full forwards."""
    cfg = get("xlstm-125m", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s, extra = 1, 12, 4
    batch = make_batch(cfg, b=b, s=s + extra, key=5)
    toks = batch["tokens"]
    _, cache, pos = M.prefill(cfg, params, {"tokens": toks[:, :s]},
                              cache_len=s + extra)
    for i in range(extra):
        full_logits, _ = M.forward(cfg, params,
                                   {"tokens": toks[:, : s + i + 1]},
                                   remat=False)
        step_logits, cache = M.decode_step(cfg, params, cache, toks[:, s + i],
                                           pos + i)
        np.testing.assert_allclose(
            np.asarray(step_logits, np.float32),
            np.asarray(full_logits[:, s + i], np.float32),
            rtol=3e-2, atol=3e-2)


def test_ragged_batch_decode_per_request_positions():
    """Per-request position vectors: a batch of requests at DIFFERENT
    positions must decode identically to each request alone (continuous-
    batching prerequisite)."""
    cfg = get("qwen2-1.5b", smoke=True).replace(dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    lens = [10, 16]
    batch = make_batch(cfg, b=2, s=20, key=11)
    toks = batch["tokens"]
    lc = 24

    # per-request singleton prefills at different lengths
    caches, logits_solo = [], []
    for i, ln in enumerate(lens):
        lg, c, pos = M.prefill(cfg, params,
                               {"tokens": toks[i:i+1, :ln]}, cache_len=lc)
        l1, c1 = M.decode_step(cfg, params, c, toks[i:i+1, ln],
                               jnp.int32(ln))
        caches.append(c1)
        logits_solo.append(l1)

    # batched: concat pre-decode caches along batch dim, decode with pos VECTOR
    caches0 = []
    for i, ln in enumerate(lens):
        _, c, _ = M.prefill(cfg, params, {"tokens": toks[i:i+1, :ln]},
                            cache_len=lc)
        caches0.append(c)
    cache0 = jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a, b], axis=1), *caches0)
    tok_vec = jnp.stack([toks[0, lens[0]], toks[1, lens[1]]])
    pos_vec = jnp.asarray(lens, jnp.int32)
    logits_batched, _ = M.decode_step(cfg, params, cache0, tok_vec, pos_vec)

    for i in range(2):
        np.testing.assert_allclose(
            np.asarray(logits_batched[i], np.float32),
            np.asarray(logits_solo[i][0], np.float32), rtol=2e-4, atol=2e-4)


def test_scalar_pos_still_exact():
    """The scalar-pos path is unchanged by the ragged-batch support."""
    cfg = get("xlstm-125m", smoke=True).replace(dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, b=2, s=13, key=2)
    full, _ = M.forward(cfg, params, batch, remat=False)
    _, cache, pos = M.prefill(cfg, params, {"tokens": batch["tokens"][:, :12]},
                              cache_len=16)
    l1, _ = M.decode_step(cfg, params, cache, batch["tokens"][:, 12], pos)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(full[:, 12], np.float32),
                               rtol=2e-4, atol=2e-4)
