"""Paged KV cache: block allocator, shared-prefix reuse, streaming, and the
serve-layer bugfix sweep.

The tentpole contract: ``Engine(..., paged=True)`` — block-grained K/V
allocation with per-request block tables, copy-on-write shared prefixes,
and a garbage block absorbing masked writes — is a pure *capacity*
optimization, never a tokens change.  Every test here compares against the
contiguous pool or the sequential greedy reference.

Also pinned: the paged Pallas decode kernel (scalar-prefetched block
table) against the gather reference, exact ``max_cache_tokens`` budget
enforcement, the streaming API's delta/done protocol, the oversized-
request safety valve, and the scheduler fixes (head-of-line blocking in
``take(now=)``, ``min_remaining`` on an empty active set).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import (BlockAllocator, Engine, GenerationConfig,
                         PagedCachePool, Request, Scheduler)
from repro.serve.kv_cache import GARBAGE_BLOCK
from repro.verify.scenarios import greedy_reference, serve_requests


# -- paged == contiguous / sequential, token-identical ----------------------

@pytest.mark.parametrize("name,window", [
    ("qwen2-1.5b", 0),      # standard decoder
    ("qwen2-1.5b", 8),      # sliding-window ring over padded blocks
    ("xlstm-125m", 0),      # recurrent carries stay slot-resident
])
def test_paged_token_identical(serve_world, name, window):
    cfg, params = serve_world(name, window)
    reqs = serve_requests(cfg)
    outs = Engine(cfg, params, max_slots=2, decode_block=4, paged=True,
                  block_size=4).generate(reqs)
    for req, c in zip(reqs, outs):
        assert c.tokens == greedy_reference(cfg, params, req), c
        assert c.finish_reason == "length"


def test_paged_equals_contiguous_mixed_lengths(serve_world):
    cfg, params = serve_world()
    reqs = serve_requests(cfg, lens=(8, 5, 8, 5, 7, 8),
                          news=(1, 5, 3, 7, 2, 4))
    ctg = Engine(cfg, params, max_slots=3, decode_block=4).generate(reqs)
    pgd = Engine(cfg, params, max_slots=3, decode_block=4, paged=True,
                 block_size=4).generate(reqs)
    assert [c.tokens for c in pgd] == [c.tokens for c in ctg]
    assert all(c.finish_reason == "length" for c in pgd)


# -- shared-prefix reuse -----------------------------------------------------

def test_shared_prefix_reuse_identical_and_counted(serve_world):
    """Requests sharing a block-aligned prompt prefix reuse the first
    writer's physical blocks (prefix_hits > 0) and still decode the exact
    greedy-reference tokens — first-writer-wins is invisible."""
    cfg, params = serve_world()
    base = serve_requests(cfg, lens=(8, 8, 8), news=(6, 6, 6))
    t0 = np.asarray(base[0].tokens, np.int32)
    reqs = [base[0],
            Request(tokens=t0.copy(), gen=GenerationConfig(max_new_tokens=6),
                    id="twin"),
            Request(tokens=np.concatenate([t0[:4],
                                           np.asarray(base[2].tokens)[:4]]),
                    gen=GenerationConfig(max_new_tokens=6), id="halfshare")]
    eng = Engine(cfg, params, max_slots=3, decode_block=4, paged=True,
                 block_size=4)
    outs = eng.generate(reqs)
    for req, c in zip(reqs, outs):
        assert c.tokens == greedy_reference(cfg, params, req)
    pool = eng._pool
    # twin shares both 4-token prompt blocks, halfshare only the first
    assert pool.prefix_hits == 3
    assert pool.prefix_lookups == 3
    assert pool.allocator.n_used == 0        # everything released


def test_shared_prefix_disabled_for_windowed(serve_world):
    cfg, params = serve_world("qwen2-1.5b", 8)
    pool = PagedCachePool(cfg, 2, 32, block_size=4)
    assert not pool.share_prefixes
    a = pool.allocate(list(range(8)), 12)
    b = pool.allocate(list(range(8)), 12)
    assert a.n_shared == 0 and b.n_shared == 0
    assert pool.prefix_lookups == 0


# -- block allocator invariants ---------------------------------------------

def test_block_allocator_invariants():
    a = BlockAllocator(6, block_size=4)      # 5 usable + garbage block
    assert a.n_free == 5 and a.n_used == 0
    x = a.alloc(2)
    y = a.alloc(3)
    assert a.alloc(1) is None                # all-or-nothing exhaustion
    assert a.n_used == 5 and a.peak_used == 5
    a.incref(x)                              # second owner of x
    assert a.free(x) == []                   # first release frees nothing
    gen0 = [a.gen[i] for i in x]
    assert a.free(x) == x                    # last owner returns the blocks
    assert [a.gen[i] for i in x] == [g + 1 for g in gen0]   # gen bumped
    z = a.alloc(2)                           # recycled from the free pool
    assert set(z) <= set(x)
    a.free(y)
    a.free(z)
    a.check()
    assert a.n_used == 0
    with pytest.raises(AssertionError, match="double free"):
        a.free(z)
    with pytest.raises(ValueError, match="garbage"):
        BlockAllocator(1, block_size=4)


def test_paged_pool_budget_and_table_rows(serve_world):
    cfg, params = serve_world()
    pool = PagedCachePool(cfg, 4, 32, block_size=8, max_tokens=32)
    assert pool.allocator.n_blocks == 5      # 32 // 8 usable + garbage
    al = pool.allocate(list(range(16)), 20)  # 3 blocks
    assert al is not None and len(al.ids) == 3
    # an unrelated prompt needs 2 fresh blocks, only 1 left: budget hit
    assert pool.allocate(list(range(100, 108)), 12) is None
    row = pool.table_row(al)
    assert len(row) == pool.blocks_per_slot
    assert row[3:] == [GARBAGE_BLOCK]        # garbage-padded tail
    # the twin shares the 2 full prompt blocks -> needs only 1 fresh
    twin = pool.allocate(list(range(16)), 20)
    assert twin is not None and twin.n_shared == 2
    assert pool.write_row(twin)[:2] == [GARBAGE_BLOCK, GARBAGE_BLOCK]
    pool.release(al.ids)
    pool.release(twin.ids)
    assert pool.allocator.n_used == 0


# -- exact token budget => higher admission concurrency ---------------------

def test_block_budget_bounds_concurrency_not_tokens(serve_world):
    """Same ``max_cache_tokens``: the paged engine admits as many requests
    as fit the block budget (not one full row each), and the budget is
    exact — concurrency is capped right where blocks run out."""
    cfg, params = serve_world()
    reqs = serve_requests(cfg, lens=(8, 8, 8, 8), news=(4, 4, 4, 4))
    free = Engine(cfg, params, max_slots=4, decode_block=4, paged=True,
                  block_size=4).generate(reqs)
    eng = Engine(cfg, params, max_slots=4, decode_block=4, paged=True,
                 block_size=4, max_cache_tokens=24)
    outs = eng.generate(reqs)
    assert [c.tokens for c in outs] == [c.tokens for c in free]
    # 24-token budget / (12-token span -> 3 blocks) = 2 concurrent
    assert eng.scheduler.max_concurrent == 2
    assert eng._pool.allocator.peak_used == 6
    assert eng._pool.allocator.n_used == 0


def test_oversized_paged_request_rejected_not_deadlocked(serve_world):
    """A request that can NEVER fit the block budget is rejected with
    reason "cache" even though slots are free — the admission safety valve
    (alloc failed with zero blocks in use) instead of an infinite stall."""
    cfg, params = serve_world()
    reqs = serve_requests(cfg, lens=(8, 28), news=(4, 8))
    eng = Engine(cfg, params, max_slots=2, decode_block=4, paged=True,
                 block_size=4, max_cache_tokens=24)
    outs = eng.generate(reqs)
    assert outs[0].finish_reason == "length"
    assert outs[0].tokens == greedy_reference(cfg, params, reqs[0])
    assert outs[1].finish_reason == "rejected"
    assert eng.stats["rejected_cache"] == 1


# -- streaming ---------------------------------------------------------------

def test_stream_deltas_match_generate(serve_world):
    cfg, params = serve_world()
    reqs = serve_requests(cfg, lens=(8, 5, 10), news=(6, 4, 3))
    outs = Engine(cfg, params, max_slots=2, decode_block=4).generate(reqs)
    eng = Engine(cfg, params, max_slots=2, decode_block=4)
    deltas = {i: [] for i in range(len(reqs))}
    done = {}
    for ev in eng.stream(reqs):
        if ev.kind == "delta":
            assert ev.id == reqs[ev.req_idx].id
            deltas[ev.req_idx].append(ev.token)
        else:
            assert ev.kind == "done"
            assert ev.req_idx not in done    # exactly one done per request
            done[ev.req_idx] = ev.completion
    for i, c in enumerate(outs):
        assert tuple(deltas[i]) == c.tokens
        assert done[i].tokens == c.tokens
        assert done[i].finish_reason == c.finish_reason
    assert set(done) == set(range(len(reqs)))


def test_stream_rejected_request_yields_done_without_deltas(serve_world):
    cfg, params = serve_world()
    reqs = serve_requests(cfg, lens=(8, 28), news=(4, 4))
    eng = Engine(cfg, params, max_slots=2, decode_block=4,
                 max_cache_tokens=16)
    evs = list(eng.stream(reqs))
    by_req = {}
    for ev in evs:
        by_req.setdefault(ev.req_idx, []).append(ev.kind)
    assert by_req[1] == ["done"]             # rejected: no deltas, one done
    assert by_req[0][-1] == "done" and "delta" in by_req[0]


def test_paged_stream_equals_paged_generate(serve_world):
    cfg, params = serve_world()
    reqs = serve_requests(cfg, lens=(8, 8), news=(6, 6))
    outs = Engine(cfg, params, max_slots=2, decode_block=4, paged=True,
                  block_size=4).generate(reqs)
    eng = Engine(cfg, params, max_slots=2, decode_block=4, paged=True,
                 block_size=4)
    got = {ev.req_idx: ev.completion for ev in eng.stream(reqs)
           if ev.kind == "done"}
    assert [got[i].tokens for i in range(2)] == [c.tokens for c in outs]


# -- paged decode kernel (interpret mode) == gather reference ---------------

def test_paged_decode_kernel_matches_ref():
    from repro.kernels.flash_attention import kernel as K, ref as R
    rng = np.random.default_rng(0)
    b, h, kv, d, bs, nb, n_blocks, lc = 3, 4, 2, 8, 4, 3, 10, 12
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    k_pages = jnp.asarray(rng.normal(size=(n_blocks, bs, kv, d)),
                          jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(n_blocks, bs, kv, d)),
                          jnp.float32)
    # distinct physical blocks per slot, never the garbage block
    bt = jnp.asarray(rng.permutation(np.arange(1, 10)).reshape(b, nb),
                     jnp.int32)
    pos = jnp.asarray([3, 7, 11], jnp.int32)
    want = R.paged_decode_attention(q, k_pages, v_pages, bt, pos,
                                    logical_len=lc)
    got = K.paged_decode_attention_tpu(q, k_pages, v_pages, bt, pos,
                                       logical_len=lc, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # windowed: ring over the logical span
    want_w = R.paged_decode_attention(q, k_pages, v_pages, bt, pos,
                                      logical_len=8, window=8)
    got_w = K.paged_decode_attention_tpu(q, k_pages, v_pages, bt[:, :2],
                                         pos, logical_len=8, window=8,
                                         interpret=True)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w),
                               rtol=2e-5, atol=2e-5)


# -- scheduler bugfix sweep --------------------------------------------------

def test_take_head_of_line_blocking_fixed():
    """A future-stamped entry at the queue head must not starve an
    already-arrived entry behind it (the head-of-line bug): ``take(now=)``
    scans the WHOLE queue and returns arrivals in stamp order."""
    s = Scheduler(4)
    s.submit(0, "late", 10.0)            # future-stamped head
    s.submit(1, "early", 1.0)
    s.submit(2, "mid", 3.0)
    got = s.take(2, now=5.0)
    assert [i for i, _, _ in got] == [1, 2]      # stamp order, head skipped
    assert [i for i, _, _ in s.queue] == [0]     # future head still queued
    assert s.take(1, now=5.0) == []
    assert [i for i, _, _ in s.take(1, now=11.0)] == [0]


def test_requeue_front_preserves_order():
    s = Scheduler(4)
    for i in range(4):
        s.submit(i, f"r{i}", float(i))
    got = s.take(4, now=10.0)
    s.requeue_front(got[2:])             # tail goes back to the head
    assert [i for i, _, _ in s.queue] == [2, 3]
    assert [i for i, _, _ in s.take(4, now=10.0)] == [2, 3]


def test_min_remaining_empty_active_returns_zero():
    s = Scheduler(2)
    assert s.min_remaining() == 0        # was: ValueError (min of empty)
    s.admit(0, Request(tokens=[1, 2], gen=GenerationConfig(max_new_tokens=5),
                       deadline_ms=1.0), n_prompt=2)
    assert s.min_remaining() == 5
    s.retire(0)
    assert s.min_remaining() == 0


def test_all_slots_shed_mid_tick_engine_survives(serve_world):
    """Every active slot blows its deadline in the same tick: the engine
    sheds them all and must idle (min_remaining == 0 path) instead of
    crashing — subsequent arrivals still get served."""
    from repro.resilience import FakeClock
    cfg, params = serve_world()
    clk = FakeClock()

    def slow_clock():
        t = clk.monotonic()
        clk.advance(40.0)                # every tick jumps past deadlines
        return t

    reqs = [Request(tokens=np.asarray(r.tokens), gen=r.gen, id=r.id,
                    deadline_ms=1.0)
            for r in serve_requests(cfg, lens=(8, 8), news=(60, 60))]
    ok = serve_requests(cfg, lens=(5,), news=(3,))[0]
    eng = Engine(cfg, params, max_slots=2, decode_block=4,
                 clock=slow_clock, sleep=lambda _s: None)
    outs = eng.generate(list(reqs) + [ok],
                        arrivals=[0.0, 0.0, 500.0])
    assert [c.finish_reason for c in outs[:2]] == ["rejected", "rejected"]
    assert outs[2].finish_reason in ("length", "rejected")
    assert not eng.scheduler.active
