"""repro.obs: metrics core (host + device-resident), structured events,
span timelines, the instrumentation threaded through trainer / executor /
supervisor / engine / checkpoint, and the loadgen + metrics CLIs.

The two contracts that matter most:

* **Zero hot-path cost** — instrumentation lives entirely outside the
  jitted steps (jaxprs byte-identical, trace lint fails clean) and device
  metrics drain only at the flush boundaries the system already has.
* **Replay safety** — draining twice, or replaying executor ticks after
  ``resume_stage``, never double-counts (the same high-water discipline
  PR 8 pinned for loss logging).
"""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.obs import (DEPTH_BUCKETS, LOSS_BUCKETS, TID_LOOP, TID_REQ0,
                       TID_STAGE0, Counter, DeviceCounter, DeviceHistogram,
                       EventLog, Gauge, Histogram, MetricsRegistry, Tracer,
                       default_log, default_registry, set_default_log,
                       set_default_registry)
from repro.obs.registry import SCHEMA
from repro.resilience import FakeClock

# ==========================================================================
# metrics core
# ==========================================================================


def test_counter_labels_total_and_monotonicity():
    c = Counter("reqs")
    c.inc()
    c.inc(2, reason="cache")
    c.inc(3, reason="queue")
    c.inc(1, reason="cache")
    assert c.value() == 1
    assert c.value(reason="cache") == 3
    assert c.total() == 7
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)
    rows = list(c.rows())
    assert {tuple(sorted(r["labels"].items())): r["value"] for r in rows} \
        == {(): 1, (("reason", "cache"),): 3, (("reason", "queue"),): 3}


def test_gauge_set_and_set_max():
    g = Gauge("peak")
    g.set(2.0)
    g.set_max(5.0)
    g.set_max(3.0)
    assert g.value() == 5.0
    g.set(1.0)
    assert g.value() == 1.0
    assert g.value(stage=0) is None


def test_histogram_percentiles_vs_numpy():
    """Bucket-interpolated percentiles stay within the covering bucket's
    width of exact numpy percentiles, and never exceed the tracked max."""
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=3.0, sigma=1.0, size=2000)  # heavy tail, ~ms
    h = Histogram("lat", (1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                          500.0, 1000.0, 2500.0))
    for v in vals:
        h.observe(v)
    edges = (0.0,) + h.edges + (float("inf"),)
    for q in (50, 90, 99):
        est, exact = h.percentile(q), float(np.percentile(vals, q))
        i = np.searchsorted(h.edges, exact, side="left")
        width = edges[i + 1] - edges[i]
        if not np.isinf(width):
            assert abs(est - exact) <= width, (q, est, exact, width)
        assert est <= h.max
    assert h.summary()["count"] == 2000
    assert abs(h.mean - vals.mean()) < 1e-6 * vals.mean() + 1e-9


def test_histogram_underflow_bucket_bounded_by_extrema():
    """Every observation below edges[0] (sub-ms TTFTs under a 1 ms first
    edge): percentiles interpolate inside [min, max] via the tracked
    extrema instead of reporting the unrelated first edge."""
    rng = np.random.default_rng(2)
    vals = rng.uniform(0.05, 0.4, size=500)      # all under the 1.0 edge
    h = Histogram("ttft", (1.0, 2.5, 5.0))
    for v in vals:
        h.observe(v)
    assert h.counts[0] == 500                    # everything underflowed
    for q in (50, 90, 99):
        est, exact = h.percentile(q), float(np.percentile(vals, q))
        assert h.min <= est <= h.max             # bounded by the extrema
        assert abs(est - exact) <= (h.max - h.min)   # one-bucket error
    s = h.summary()
    assert s["min"] == h.min and s["max"] == h.max
    # single observation: every percentile IS that value
    one = Histogram("one", (1.0, 2.5))
    one.observe(0.125)
    assert one.percentile(50) == one.percentile(99) == 0.125
    h = Histogram("x", (1.0, 2.0))
    assert h.percentile(50) is None and h.mean is None
    assert h.summary()["count"] == 0
    with pytest.raises(ValueError, match="ascending"):
        Histogram("bad", (2.0, 1.0))
    with pytest.raises(ValueError, match="ascending"):
        Histogram("bad", ())


def test_device_counter_drain_idempotent():
    c = DeviceCounter("ticks")
    c.add(2)
    c.add(jnp.asarray(3, jnp.int32))     # device scalar, no sync until drain
    c.drain()
    assert c.total() == 5
    c.drain()                            # idempotent: nothing left to fold
    assert c.total() == 5
    c.add(1)
    c.drain()
    assert c.total() == 6


def test_device_histogram_matches_host_histogram():
    rng = np.random.default_rng(1)
    vals = rng.uniform(0.0, 10.0, size=256).astype(np.float32)
    host = Histogram("h", LOSS_BUCKETS)
    dev = DeviceHistogram("d", LOSS_BUCKETS)
    for v in vals:
        host.observe(float(v))
    dev.observe_device(vals[:100])       # batched device observation
    for v in vals[100:]:
        dev.observe_device(jnp.asarray(v))
    dev.drain()
    assert dev.counts == host.counts
    assert dev.total == host.total
    assert abs(dev.sum - host.sum) < 1e-2
    assert abs(dev.max - host.max) < 1e-6
    before = (list(dev.counts), dev.total, dev.sum)
    dev.drain()                          # drain twice never double-counts
    assert (list(dev.counts), dev.total, dev.sum) == before
    dev.observe_device(jnp.zeros((0,)))  # empty observation is a no-op
    dev.drain()
    assert dev.total == host.total


def test_registry_get_or_create_kind_check_and_export():
    reg = MetricsRegistry()
    c = reg.counter("a", help="x")
    assert reg.counter("a") is c
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("a")
    reg.device_histogram("h", DEPTH_BUCKETS).observe_device(
        jnp.asarray([1.0, 3.0]))
    c.inc(2)
    out = reg.export()                   # export drains by default
    assert out["schema"] == SCHEMA
    by_name = {r["name"]: r for r in out["metrics"]}
    assert by_name["a"]["value"] == 2
    assert by_name["h"]["count"] == 2 and by_name["h"]["p50"] is not None
    assert reg.names() == ["a", "h"]


# ==========================================================================
# structured events
# ==========================================================================


def test_event_log_ring_bound_and_monotone_seq():
    log = EventLog(capacity=4, clock=FakeClock(5.0).monotonic)
    for i in range(10):
        log.emit("admit", slot=i)
    assert len(log) == 4
    assert log.dropped == 6
    seqs = [e.seq for e in log.records()]
    assert seqs == [6, 7, 8, 9]          # evicted records keep their numbers
    row = log.rows()[0]
    assert row == {"schema_v": 1, "seq": 6, "t": 5.0, "kind": "admit",
                   "fields": {"slot": 6}}


def test_event_kind_vocabulary_enforced():
    log = EventLog()
    with pytest.raises(ValueError, match="unknown event kind"):
        log.emit("vibes", level=11)
    with pytest.raises(ValueError, match="capacity"):
        EventLog(capacity=0)


def test_event_records_filter_and_clear():
    log = EventLog()
    log.emit("admit", slot=0)
    log.emit("retire", slot=0)
    log.emit("admit", slot=1)
    assert [e.fields["slot"] for e in log.records("admit")] == [0, 1]
    log.clear()
    assert len(log) == 0 and log.dropped == 3


# ==========================================================================
# spans / chrome trace
# ==========================================================================


def test_span_nesting_and_ordering_under_fake_clock():
    clk = FakeClock()
    tr = Tracer(clock=clk.monotonic)
    with tr.span("outer", cat="phase"):
        clk.advance(1.0)
        with tr.span("inner", cat="stage", tid=TID_STAGE0, stage=0):
            clk.advance(2.0)
        clk.advance(0.5)
    tr.instant("marker", tid=TID_STAGE0)
    by_tid = tr.by_tid()
    (outer,) = by_tid[TID_LOOP]
    inner, marker = by_tid[TID_STAGE0]
    assert (outer.ts, outer.dur) == (0.0, 3.5)
    assert (inner.ts, inner.dur) == (1.0, 2.0)
    assert outer.ts <= inner.ts and inner.end <= outer.end   # nested
    assert marker.ts == 3.5 and marker.dur == 0.0
    assert inner.args == {"stage": 0}


def test_chrome_trace_export_shape():
    clk = FakeClock()
    tr = Tracer(clock=clk.monotonic, capacity=2)
    with tr.span("a"):
        clk.advance(0.001)
    tr.instant("b", tid=3)
    tr.instant("overflow")               # past capacity: counted, dropped
    doc = tr.chrome_trace()
    evs = doc["traceEvents"]
    assert len(evs) == 2 and doc["otherData"]["dropped_spans"] == 1
    a, b = evs
    assert a["ph"] == "X" and a["ts"] == 0.0 and a["dur"] == 1000.0  # us
    assert b["ph"] == "i" and b["s"] == "t" and b["tid"] == 3
    assert all("pid" in e and "name" in e for e in evs)


def test_write_chrome_trace_is_valid_json(tmp_path):
    tr = Tracer(clock=FakeClock().monotonic)
    tr.add_span("x", 0.0, 1.0)
    path = str(tmp_path / "trace.json")
    tr.write_chrome_trace(path)
    with open(path) as f:
        assert json.load(f)["traceEvents"][0]["name"] == "x"


# ==========================================================================
# scheduler <-> event log mapping (exactly once), open-loop take
# ==========================================================================


def test_scheduler_take_now_and_next_arrival():
    from repro.serve.scheduler import Scheduler
    s = Scheduler(4, event_log=EventLog())
    s.submit(0, "a", 1.0)
    s.submit(1, "b", 5.0)
    s.submit(2, "c", 9.0)
    assert s.next_arrival() == 1.0
    got = s.take(3, now=6.0)             # head arrived, tail still future
    assert [i for i, _, _ in got] == [0, 1]
    assert s.next_arrival() == 9.0
    assert s.take(3, now=6.0) == []
    assert [i for i, _, _ in s.take(3)] == [2]   # legacy: no arrival gate
    assert s.next_arrival() is None


def _ticking(dt=0.1):
    clk = FakeClock()

    def tick():
        t = clk.monotonic()
        clk.advance(dt)
        return t
    return tick


def test_scheduler_audits_map_to_event_log_exactly_once(serve_world):
    """Every legacy audit tuple has exactly one structured record, in the
    same order, with slot/req fields — including the reject path."""
    from repro.serve import Engine
    from repro.verify.scenarios import serve_requests
    cfg, params = serve_world()
    log = EventLog(clock=FakeClock().monotonic)
    reqs = serve_requests(cfg, lens=(8, 8), news=(8, 8))
    eng = Engine(cfg, params, max_slots=1, decode_block=4,
                 max_queue_wait_ms=250, clock=_ticking(), event_log=log)
    eng.generate(reqs)
    tuples = eng.scheduler.events
    recs = [e for e in log.records()
            if e.kind in ("admit", "retire", "reject")]
    assert len(recs) == len(tuples)
    for (kind, ident), rec in zip(tuples, recs):
        assert rec.kind == kind
        if kind == "reject":
            assert rec.fields == {"req": ident}
        else:
            assert rec.fields["slot"] == ident and "req" in rec.fields
    assert ("reject", 1) in tuples       # the queue-timeout shed happened
    begin, end = log.records("generate_begin"), log.records("generate_end")
    assert len(begin) == 1 and len(end) == 1
    assert begin[0].fields == {"n": 2}


# ==========================================================================
# engine: stats read-through, TTFT, lifecycle spans, open loop
# ==========================================================================


def test_engine_stats_dict_byte_for_byte(serve_world):
    """The legacy ``stats`` dict — now a read-through view over the
    ``serve_rejected_total`` counter — is byte-identical to the old shape."""
    from repro.serve import Engine
    from repro.verify.scenarios import serve_requests
    cfg, params = serve_world()
    eng = Engine(cfg, params, max_slots=2, decode_block=4,
                 max_cache_tokens=16)
    assert json.dumps(eng.stats, sort_keys=False) == \
        '{"rejected_cache": 0, "rejected_queue": 0, "rejected_deadline": 0}'
    reqs = serve_requests(cfg, lens=(8, 8), news=(6, 60))
    eng.generate(reqs)
    assert json.dumps(eng.stats, sort_keys=False) == \
        '{"rejected_cache": 1, "rejected_queue": 0, "rejected_deadline": 0}'
    assert eng.metrics.get("serve_rejected_total").value(reason="cache") == 1


def test_engine_metrics_and_request_spans(serve_world):
    from repro.serve import Engine
    from repro.verify.scenarios import serve_requests
    cfg, params = serve_world()
    reqs = serve_requests(cfg, lens=(8, 12, 5, 10), news=(6, 9, 4, 7))
    eng = Engine(cfg, params, max_slots=2, decode_block=4)
    outs = eng.generate(reqs)
    m = eng.metrics
    n_tok = sum(c.n_generated for c in outs)
    assert m.get("serve_tokens_total").total() == n_tok
    assert m.get("serve_requests_total").value(reason="length") == 4
    ttft = m.get("serve_ttft_ms")
    assert ttft.total == 4 and ttft.percentile(99) is not None
    assert m.get("serve_peak_slots_busy").value() == 2
    assert m.get("serve_cache_tokens").value() == eng._pool.cache_len
    assert m.get("serve_slots_busy").total > 0
    by_tid = eng.tracer.by_tid()
    for i in range(4):
        names = [s.name for s in by_tid[TID_REQ0 + i]]
        assert names == [f"req {i} queued", f"req {i} active"]
        active = by_tid[TID_REQ0 + i][1]
        assert active.args["reason"] == "length"
        assert active.args["tokens"] == outs[i].n_generated
    loop_cats = {s.cat for s in by_tid[TID_LOOP]}
    assert loop_cats == {"serve"}        # admit + decode driving-loop spans


def test_engine_open_loop_arrivals_deterministic(serve_world):
    """Open-loop arrivals with an injected clock+sleep: same tokens as the
    closed-loop run, idle gaps slept (not spun), future requests never
    admitted early."""
    from repro.serve import Engine
    from repro.verify.scenarios import serve_requests
    cfg, params = serve_world()
    reqs = serve_requests(cfg, lens=(8, 8), news=(6, 6))
    closed = Engine(cfg, params, max_slots=1,
                    decode_block=4).generate(reqs)
    clk = FakeClock()
    eng = Engine(cfg, params, max_slots=1, decode_block=4,
                 clock=clk.monotonic, sleep=clk.sleep)
    outs = eng.generate(reqs, arrivals=[0.0, 50.0])
    assert [c.tokens for c in outs] == [c.tokens for c in closed]
    assert all(c.finish_reason == "length" for c in outs)
    assert clk.sleeps and max(clk.sleeps) > 0      # idle gap was slept
    # request 1's queued span starts at its (future) arrival stamp
    q1 = [s for s in eng.tracer.by_tid()[TID_REQ0 + 1]
          if s.name == "req 1 queued"][0]
    assert q1.ts == 50.0
    with pytest.raises(ValueError, match="align"):
        eng.generate(reqs, arrivals=[0.0])


# ==========================================================================
# trainer + executor: flush-boundary publication, replay safety, trace
# ==========================================================================


def test_trainer_parallel_sil_metrics_and_trace(tiny_mlp):
    """The acceptance trace: a 2-stage parallel SIL run yields per-stage
    tick spans on tids 1+k, sequential within a stage, enclosed by the
    phase span on tid 0 — and the loss histogram drains at finalize."""
    from repro.models import mlp as MLP
    from repro.train import MLPBackend, ParallelSilPhase, Trainer
    from repro.train.backends import balanced_bounds
    cfg, data, spec = tiny_mlp(n_stages=2, epochs=(3, 3), n_train=256,
                               batch_size=64)
    be = MLPBackend(cfg, data, spec, bounds=balanced_bounds(cfg, 2))
    params = MLP.init_params(cfg, jax.random.PRNGKey(0))
    tr = Trainer(be, spec)
    tr.run([ParallelSilPhase(plan=[0, 0])], params=params,
           key=jax.random.PRNGKey(3))
    # metrics: 3 epochs x 4 batches x 2 stages, drained at the join
    loss = tr.metrics.get("train_loss")
    n_batches = 256 // 64
    assert loss.total == 3 * n_batches * 2
    assert loss.percentile(50) is not None
    ticks = tr.metrics.get("executor_ticks_total")
    assert ticks.value(stage=0) == 3 and ticks.value(stage=1) == 3
    # trace: tick spans per stage, nested inside the phase span
    by_tid = tr.tracer.by_tid()
    (phase,) = by_tid[TID_LOOP]
    assert phase.name == "ParallelSilPhase"
    for k in range(2):
        spans = by_tid[TID_STAGE0 + k]
        assert [s.name for s in spans] == ["tick 0", "tick 1", "tick 2"]
        assert [s.args["stage"] for s in spans] == [k, k, k]
        for a, b in zip(spans, spans[1:]):      # sequential, no overlap
            assert a.end <= b.ts
        assert phase.ts <= spans[0].ts and spans[-1].end <= phase.end
    doc = tr.tracer.chrome_trace()
    assert {e["tid"] for e in doc["traceEvents"]} \
        == {TID_LOOP, TID_STAGE0, TID_STAGE0 + 1}


def test_executor_replay_does_not_double_count(tmp_path, tiny_mlp):
    """Replayed ticks after resume_stage re-run the math under the metrics
    high-water guard: loss/tick series identical to the unfaulted run."""
    from repro.dist import StageExecutor, placement as P
    from repro.models import mlp as MLP
    from repro.train.backends import MLPBackend, balanced_bounds, \
        make_optimizer_for
    cfg, data, spec = tiny_mlp(n_stages=2, epochs=(3, 3), n_train=256,
                               batch_size=64)
    be = MLPBackend(cfg, data, spec, bounds=balanced_bounds(cfg, 2))
    params = MLP.init_params(cfg, jax.random.PRNGKey(0))
    sils = be.make_sils(jax.random.PRNGKey(3), spec.kappa)
    hps = [spec.stage(k) for k in range(2)]
    opts = [make_optimizer_for(hp, spec) for hp in hps]
    reg = MetricsRegistry()
    ex = StageExecutor(be, P.round_robin(2), be.split(params), sils, opts,
                       hps, shuffle=True, ckpt_dir=str(tmp_path / "ck"),
                       ckpt_every=1, metrics=reg)
    ex.run(3)
    reg.drain()
    loss, ticks = reg.get("train_loss"), reg.get("executor_ticks_total")
    base = (loss.total, loss.sum, ticks.value(stage=1))
    assert base[0] == 3 * (256 // 64) * 2 and base[2] == 3
    ex.resume_stage(1, step=1)           # roll stage 1 back two ticks...
    ex.run(3, stages=[1])                # ...and replay them
    reg.drain()
    assert (loss.total, loss.sum, ticks.value(stage=1)) == base


def test_trainer_skipped_steps_counter_high_water(monkeypatch, tiny_mlp):
    """note_skipped publishes counter DELTAS against the high-water mark:
    re-reading the same cumulative device counter adds nothing."""
    from repro.train import MLPBackend, Trainer, trainer as trainer_mod
    from repro.train.trainer import TrainState
    cfg, data, spec = tiny_mlp(n_stages=2)
    tr = Trainer(MLPBackend(cfg, data, spec), spec)
    state = TrainState(stage_params=[])
    reads = iter([2, 2, 5])
    monkeypatch.setattr(trainer_mod, "read_skipped",
                        lambda _s: jnp.asarray(next(reads), jnp.int32))
    for _ in range(3):
        tr.note_skipped(state, object(), "p", 0)
    assert state.history.meta["skipped_steps"] == {"p[0]": 5}
    assert tr.metrics.get("train_skipped_steps_total").value(
        phase="p[0]") == 5
    assert state.skipped_steps == 5


# ==========================================================================
# supervisor: health transitions + fault record mapping
# ==========================================================================


def test_supervisor_structured_events_and_health(tmp_path, tiny_mlp):
    from repro.dist import StageExecutor, placement as P
    from repro.models import mlp as MLP
    from repro.resilience import (FaultSchedule, StageCrash,
                                  SupervisedExecutor, TransientError)
    from repro.train.backends import MLPBackend, balanced_bounds, \
        make_optimizer_for
    cfg, data, spec = tiny_mlp(n_stages=2, epochs=(3, 3), n_train=256,
                               batch_size=64)
    be = MLPBackend(cfg, data, spec, bounds=balanced_bounds(cfg, 2))
    params = MLP.init_params(cfg, jax.random.PRNGKey(0))
    sils = be.make_sils(jax.random.PRNGKey(3), spec.kappa)
    hps = [spec.stage(k) for k in range(2)]
    opts = [make_optimizer_for(hp, spec) for hp in hps]
    ex = StageExecutor(be, P.round_robin(2), be.split(params), sils, opts,
                       hps, shuffle=True, ckpt_dir=str(tmp_path / "ck"))
    clk = FakeClock()
    log = EventLog(clock=clk.monotonic)
    sched = FaultSchedule([StageCrash(stage=0, tick=1),
                           TransientError(stage=1, tick=1, failures=1)])
    sup = SupervisedExecutor(ex, schedule=sched, clock=clk.monotonic,
                             sleep=clk.sleep, event_log=log)
    sup.run()
    assert ex.ticks == [3, 3]
    # exactly-once mapping: every legacy fault tuple has one record
    fault_tuples = [e for e in sup.events if e[0] == "fault"]
    fault_recs = log.records("fault")
    assert len(fault_recs) == len(fault_tuples) == 2
    for (_, kind, k, i, *_), rec in zip(fault_tuples, fault_recs):
        assert rec.fields["fault"] == kind
        assert (rec.fields["stage"], rec.fields["tick"]) == (k, i)
    # the crash recovered from checkpoint -> one recover record
    assert [(e.fields["stage"], e.fields["tick"])
            for e in log.records("recover")] == [(0, 1)]
    # health transitions: crash drives 0 through recovering->ok, the
    # transient drives 1 through retrying->ok
    hs = [(e.fields["stage"], e.fields["old"], e.fields["new"])
          for e in log.records("health")]
    assert (0, "ok", "recovering") in hs and (0, "recovering", "ok") in hs
    assert (1, "ok", "retrying") in hs and (1, "retrying", "ok") in hs
    assert sup.metrics.get("supervisor_faults_total").value(kind="crash") == 1
    assert sup.metrics.get("supervisor_recoveries_total").total() == 1


# ==========================================================================
# checkpoint events (module-level -> process-wide default log/registry)
# ==========================================================================


@pytest.fixture
def fresh_defaults():
    log, reg = EventLog(clock=FakeClock().monotonic), MetricsRegistry()
    set_default_log(log)
    set_default_registry(reg)
    yield log, reg
    set_default_log(None)
    set_default_registry(None)


def test_checkpoint_save_restore_events(tmp_path, fresh_defaults):
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    log, reg = fresh_defaults
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    d = str(tmp_path)
    save_checkpoint(d, 1, tree)
    save_checkpoint(d, 2, tree)
    restore_checkpoint(d, tree)
    saves = log.records("checkpoint_save")
    assert [e.fields["step"] for e in saves] == [1, 2]
    assert saves[0].fields["leaves"] == 1
    (restore,) = log.records("checkpoint_restore")
    assert restore.fields["step"] == 2 and restore.fields["skipped"] == 0
    assert reg.counter("checkpoint_saves_total").total() == 2
    assert reg.counter("checkpoint_restores_total").total() == 1
    # corrupt the newest step: the fallback restore reports what it skipped
    os.remove(os.path.join(d, "ckpt_00000002.json"))
    restore_checkpoint(d, tree)
    assert log.records("checkpoint_restore")[-1].fields \
        == {"step": 1, "directory": d, "skipped": 1}


def test_default_log_and_registry_singletons():
    set_default_log(None)
    set_default_registry(None)
    try:
        assert default_log() is default_log()
        assert default_registry() is default_registry()
    finally:
        set_default_log(None)
        set_default_registry(None)


# ==========================================================================
# zero hot-path cost: jaxpr identity + trace lint fail-clean
# ==========================================================================


def _decode_jaxpr(eng):
    n_slots = eng.max_slots
    pool = eng._pool_for(16)
    args = (eng.params, pool.cache, jnp.zeros((n_slots,), jnp.int32),
            jnp.zeros((n_slots,), jnp.int32),
            jnp.zeros((n_slots, 2), jnp.uint32),
            jnp.zeros((n_slots,), jnp.float32),
            jnp.zeros((n_slots,), jnp.int32),
            jnp.ones((n_slots,), jnp.float32))
    return str(jax.make_jaxpr(eng._decode_chunk(2, "greedy"))(*args))


def test_decode_jaxpr_identical_under_instrumentation(serve_world):
    """The jitted decode chunk is byte-identical whether the engine carries
    default obs objects or injected ones that have already collected data —
    instrumentation never reaches inside jit."""
    from repro.serve import Engine
    from repro.verify.scenarios import serve_requests
    cfg, params = serve_world()
    plain = Engine(cfg, params, max_slots=2, decode_block=4)
    log = EventLog()
    inst = Engine(cfg, params, max_slots=2, decode_block=4,
                  metrics=MetricsRegistry(), tracer=Tracer(), event_log=log)
    inst.generate(serve_requests(cfg, lens=(8,), news=(4,)))  # collect data
    assert _decode_jaxpr(plain) == _decode_jaxpr(inst)


def test_trace_lint_fail_clean_on_instrumented_entrypoints():
    """The registered hot paths — built through the instrumented classes —
    carry zero host callbacks: the host_transfer rule reports no failures
    for the guarded MLP epoch, the parallel LM stage step, or the fused
    decode chunk."""
    from repro.analysis import AnalysisContext, entrypoints, get_rule, \
        run_rule
    from repro.analysis.rules_trace import host_transfer  # noqa: F401
    from repro.analysis.trace import trace
    names = {"train/mlp_guarded_epoch": "paper_mlp",
             "train/lm_parallel_stage_step": "qwen2-1.5b",
             "serve/decode_chunk": "qwen2-1.5b"}
    for arch in sorted(set(names.values())):
        ctx = AnalysisContext(arch=arch)
        targets = [t for t in entrypoints.build_targets(ctx)
                   if names.get(t.name) == arch]
        assert targets, f"entry points missing on {arch}"
        ctx.cache[entrypoints.cache_key(ctx)] = {t.name: trace(t)
                                                 for t in targets}
        res = run_rule(get_rule("trace/host_transfer"), ctx)
        assert res.error is None, res.error
        fails = [f for f in res.findings if f.severity == "fail"]
        assert fails == [], fails


# ==========================================================================
# loadgen + metrics CLI
# ==========================================================================


def test_loadgen_tiny_report_and_metrics_cli(tmp_path):
    from repro.launch.loadgen import run_loadgen, summarize
    from repro.launch.metrics import main as metrics_main, validate_report
    report = run_loadgen("tiny", seed=0, n=4, rate=50.0,
                         trace_path=str(tmp_path / "trace.json"))
    assert validate_report(report) == []
    slo = report["slo"]
    assert slo["ttft_ms"]["count"] == 3          # 4 requests, 1 oversized
    assert slo["ttft_ms"]["p50"] is not None
    assert slo["ttft_ms"]["p99"] is not None
    assert slo["tokens_per_s"] > 0
    assert slo["shed"]["rejected_cache"] == 1    # deterministic cache shed
    assert slo["shed"]["rate"] == pytest.approx(0.25)
    assert slo["completed"] == 3
    assert report["events"]["by_kind"]["admit"] == 3
    assert "tok/s" in summarize(report)
    with open(tmp_path / "trace.json") as f:
        assert json.load(f)["traceEvents"]
    path = str(tmp_path / "BENCH.json")
    with open(path, "w") as f:
        json.dump(report, f)
    assert metrics_main(["--check", path]) == 0
    assert metrics_main([path]) == 0             # summary mode
    assert metrics_main(["--dump", path]) == 0


def test_metrics_cli_check_fails_on_violations(tmp_path):
    from repro.launch.metrics import main as metrics_main, validate_report
    bad = {"schema": "nope", "metrics": [
        {"name": "h", "kind": "histogram", "count": 5,
         "p50": None, "p90": None, "p99": None},
        {"name": "c", "kind": "counter"},
    ]}
    errs = validate_report(bad)
    assert any("schema" in e for e in errs)
    assert any("empty percentile" in e for e in errs)
    assert any("lacks value" in e for e in errs)
    path = str(tmp_path / "bad.json")
    with open(path, "w") as f:
        json.dump(bad, f)
    assert metrics_main(["--check", path]) == 1
