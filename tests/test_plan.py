"""repro.plan unit tests: the cost model's numbers, the bottleneck DP
against brute force, tie-break determinism, the CLI surfaces, and the
placement dedup regression.

These mirror the hypothesis properties in test_property.py with seeded
cases so the invariants are exercised even where hypothesis isn't
installed (it's a CI-only dependency).
"""
import json

import numpy as np
import pytest

from repro import plan as plan_lib
from repro.configs import ARCH_NAMES, get
from repro.models.mlp import MLPConfig
from repro.plan.costs import ModelCosts
from repro.plan.search import (brute_force_bounds, searched_bounds_for_sequence,
                               solve, stage_objective, uniform_bounds)


def table(units, head=0, tail=0, boundary=None, optimizer="sgd"):
    n = len(units)
    return ModelCosts(
        kind="mlp", n_units=n, optimizer=optimizer,
        unit_param_bytes=tuple(units), unit_param_elems=(0,) * n,
        unit_act_bytes=(0,) * n,
        unit_flops=tuple(float(u) for u in units),
        unit_boundary_bytes=tuple(boundary or (0,) * n),
        head_param_bytes=head, tail_param_bytes=tail)


def bottleneck(tab, bounds, objective="bytes"):
    cost = stage_objective(tab, objective)
    k = len(bounds)
    return max(cost(lo, hi, i, k) for i, (lo, hi) in enumerate(bounds))


# ==========================================================================
# the searcher
# ==========================================================================

def test_solver_matches_brute_force_randomized():
    rng = np.random.RandomState(0)
    for trial in range(40):
        n = int(rng.randint(2, 11))
        k = int(rng.randint(1, min(n, 4) + 1))
        units = rng.randint(1, 200, size=n).tolist()
        tab = table(units, head=int(rng.randint(0, 500)),
                    tail=int(rng.randint(0, 500)))
        best, _ = brute_force_bounds(tab, k)
        got = bottleneck(tab, solve(tab, k))
        assert abs(got - best) <= 1e-9 * max(1.0, best), \
            (trial, units, k, got, best)


def test_solver_bounds_are_valid_partitions():
    rng = np.random.RandomState(1)
    for _ in range(40):
        n = int(rng.randint(1, 30))
        k = int(rng.randint(1, n + 1))
        tab = table(rng.randint(1, 1000, size=n).tolist(),
                    head=int(rng.randint(0, 5000)),
                    tail=int(rng.randint(0, 5000)))
        bounds = solve(tab, k)
        assert bounds[0][0] == 0 and bounds[-1][1] == n
        assert all(hi > lo for lo, hi in bounds)          # no empty stages
        for (_, a1), (b0, _) in zip(bounds, bounds[1:]):  # contiguous
            assert a1 == b0
        cuts = [hi for _, hi in bounds[:-1]]
        assert cuts == sorted(cuts) and len(set(cuts)) == len(cuts)
        assert bottleneck(tab, bounds) \
            <= bottleneck(tab, uniform_bounds(n, k)) + 1e-9


def test_k1_is_the_whole_model():
    tab = table([5, 1, 9, 2])
    assert solve(tab, 1) == ((0, 4),)


def test_uniform_units_reproduce_divmod_bounds():
    """Exact-tie determinism: equal units -> the hand (divmod) split."""
    for n in (4, 6, 7, 12):
        for k in (1, 2, 3, 4):
            assert solve(table([64] * n), k) == uniform_bounds(n, k)


def test_head_overhead_shrinks_stage_zero():
    # 8 equal units + a head 3 units heavy: stage 0 should take fewer units
    tab = table([100] * 8, head=300)
    bounds = solve(tab, 2)
    assert bounds[0][1] < 4
    assert bottleneck(tab, bounds) < bottleneck(tab, uniform_bounds(8, 2))


def test_searched_bounds_for_sequence():
    # classic chains-on-chains: [9,1,1,1,9] at K=2 must cut after unit 0
    # ... no — bottleneck optimum puts the two 9s apart: cut in the middle
    bounds = searched_bounds_for_sequence([9, 1, 1, 1, 9], 2)
    assert bounds in (((0, 1), (1, 5)), ((0, 4), (4, 5)),
                      ((0, 2), (2, 5)), ((0, 3), (3, 5)))
    sizes = [sum([9, 1, 1, 1, 9][lo:hi]) for lo, hi in bounds]
    assert max(sizes) <= 12  # never both 9s in one stage


def test_frontier_records_rejected_alternatives():
    tab = table([10, 20, 30, 40, 50])
    chosen = solve(tab, 2)
    rows = plan_lib.frontier(tab, 2, chosen)
    assert rows, "frontier must not be empty on a 5-unit lattice"
    assert all(tuple(map(tuple, r["bounds"])) != chosen for r in rows)
    assert all(r["vs_chosen"] >= 1.0 - 1e-9 for r in rows)
    assert rows == sorted(rows, key=lambda r: (r["bottleneck"], r["bounds"]))


def test_search_report_shape():
    rep = plan_lib.search_report(table([10, 20, 30, 40]), 2)
    for key in ("objective", "n_units", "n_stages", "optimizer", "auto",
                "uniform", "auto_le_uniform", "rejected_frontier"):
        assert key in rep
    assert rep["auto_le_uniform"] is True
    assert len(rep["auto"]["stages"]) == 2


# ==========================================================================
# the cost model
# ==========================================================================

def test_mlp_cost_numbers():
    cfg = MLPConfig()        # sizes (784, 80, 60, 60, 60, 47)
    tab = plan_lib.mlp_costs(cfg, batch_size=1410, optimizer="sgdm")
    assert tab.n_units == cfg.n_layers == 5
    # layer 0: 784*80 weights + 80 bias, fp32
    assert tab.unit_param_bytes[0] == (784 * 80 + 80) * 4
    assert tab.unit_flops[0] == 6.0 * 1410 * 784 * 80
    assert tab.unit_boundary_bytes[0] == 1410 * 80 * 4
    # sgdm: 1 fp32 slot per trainable element
    sc = tab.stage_cost(0, 1, 0, 2)
    assert sc.opt_bytes == (784 * 80 + 80) * 4
    assert sc.boundary_bytes == 1410 * 80 * 4


def test_lm_cost_model_accounts_head_and_tail():
    cfg = get("qwen2-1.5b")
    tab = plan_lib.lm_costs(cfg)
    assert tab.kind == "lm"
    # tied embeddings: the tail carries a FROZEN snapshot (param bytes,
    # no optimizer slots), the head carries the trainable table
    assert cfg.tie_embeddings
    assert tab.tail_frozen_bytes > 0
    assert tab.head_param_bytes >= tab.tail_frozen_bytes
    first = tab.stage_cost(0, 1, 0, 2)
    last = tab.stage_cost(1, tab.n_units, 1, 2)
    interior = tab.stage_cost(1, 2, 1, 3)
    # head/tail overheads only land on their stages
    assert first.params_bytes > interior.params_bytes
    assert last.boundary_bytes == 0 and first.boundary_bytes > 0
    # frozen snapshot contributes zero slot bytes: opt bytes of the last
    # stage equal slots * (groups-elems + trainable tail elems) * 4
    g_elems = tab.unit_param_elems[0] * (tab.n_units - 1)
    assert last.opt_bytes == tab.slots * (g_elems + tab.tail_param_elems) * 4


def test_estimate_stage_bytes_excludes_frozen_snapshot_slots():
    import jax.numpy as jnp
    sp = {"groups": jnp.zeros((4, 8), jnp.float32),
          "tied_unembed": jnp.zeros((16, 8), jnp.float32)}
    got = plan_lib.estimate_stage_bytes(sp, optimizer="adamw")
    assert got == (4 * 8 + 16 * 8) * 4 + 2 * (4 * 8) * 4


def test_auto_plan_beats_uniform_on_qwen():
    cfg = get("qwen2-1.5b")
    tab = plan_lib.lm_costs(cfg)
    auto = plan_lib.auto_bounds(tab, 4)
    uni = uniform_bounds(tab.n_units, 4)
    assert auto != uni
    assert bottleneck(tab, auto) < bottleneck(tab, uni)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_every_arch_gets_a_valid_auto_plan(arch):
    from repro.core import partition
    from repro.models import model as M
    cfg = get(arch)
    g = M.n_groups(cfg)
    k = min(4, g)
    plan = partition.make_plan(cfg, k, strategy="auto")
    assert isinstance(plan, partition.PartitionPlan)
    assert plan.n_stages == k
    assert plan.bounds[0][0] == 0 and plan.bounds[-1][1] == g
    assert all(hi > lo for lo, hi in plan.bounds)


def test_make_plan_rejects_unknown_strategy():
    with pytest.raises(ValueError, match="strategy"):
        from repro.core import partition
        partition.make_plan(get("qwen2-1.5b"), 2, strategy="greedy")


# ==========================================================================
# wiring: parse_stages, balanced_bounds costs=, placement dedup
# ==========================================================================

def test_parse_stages():
    assert plan_lib.parse_stages("3") == ("uniform", 3)
    assert plan_lib.parse_stages(4) == ("uniform", 4)
    assert plan_lib.parse_stages("auto") == ("auto", 2)
    assert plan_lib.parse_stages("AUTO:5") == ("auto", 5)
    for bad in ("auto:", "auto:x", "fast", "-1", "2.5"):
        with pytest.raises(ValueError):
            plan_lib.parse_stages(bad)


def test_balanced_bounds_costs_routes():
    from repro.train.backends import balanced_bounds
    cfg = MLPConfig()
    legacy = balanced_bounds(cfg, 2)
    assert balanced_bounds(cfg, 2, costs=None) == legacy
    auto = balanced_bounds(cfg, 2, costs="auto")
    assert auto == plan_lib.auto_mlp_bounds(cfg, 2)
    seq = balanced_bounds(cfg, 2, costs=[9, 1, 1, 1, 9])
    assert seq == searched_bounds_for_sequence([9, 1, 1, 1, 9], 2)
    tab = plan_lib.mlp_costs(cfg)
    assert balanced_bounds(cfg, 2, costs=tab) == solve(tab, 2)
    with pytest.raises(ValueError):
        balanced_bounds(cfg, 2, costs="magic")


def test_placement_packing_unchanged_after_dedup():
    """Regression: memory_balanced on the PR-4 fixture sizes must pack
    exactly as before _OPT_SLOTS moved into repro.plan."""
    from repro.dist.placement import memory_balanced
    pl = memory_balanced([100, 60, 40, 30, 30, 10],
                         devices=(0, 1, 2))
    assert pl.assignments == (0, 1, 2, 2, 1, 2)
    assert pl.loads == (100, 90, 80)
    from repro.dist import placement
    from repro.plan.costs import OPT_SLOTS
    assert placement._OPT_SLOTS is OPT_SLOTS


def test_resolve_plan_accepts_specs():
    from repro.core import partition
    from repro.train.recipes import resolve_plan
    cfg = get("qwen2-1.5b", smoke=True)
    p1 = resolve_plan(cfg, 2)
    assert p1.n_stages == 2
    p2 = resolve_plan(cfg, "auto:2")
    assert isinstance(p2, partition.PartitionPlan) and p2.n_stages == 2
    assert resolve_plan(cfg, p2) is p2


# ==========================================================================
# the plan CLI (results/PLAN_7.json)
# ==========================================================================

def test_plan_cli_writes_schema_versioned_report(tmp_path):
    from repro.launch import plan as plan_cli
    out = tmp_path / "PLAN_7.json"
    rc = plan_cli.main(["--arch", "qwen2-1.5b", "--stages", "4",
                        "--out", str(out), "--assert-nonuniform"])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["schema"] == 1 and rep["n_stages"] == 4
    arch = rep["archs"]["qwen2-1.5b"]
    assert arch["auto_le_uniform"] is True
    assert arch["auto"]["cuts"] != arch["uniform"]["cuts"]
    assert arch["auto"]["imbalance"] <= arch["uniform"]["imbalance"]
    assert arch["rejected_frontier"]


def test_plan_cli_assert_flag_fails_on_degenerate_cut(tmp_path):
    # grok's groups are so uniform the searched cut IS the uniform split;
    # the CI assert flag must flag that loudly rather than pass vacuously
    from repro.launch import plan as plan_cli
    rc = plan_cli.main(["--arch", "grok-1-314b", "--stages", "4",
                        "--out", str(tmp_path / "p.json"),
                        "--assert-nonuniform"])
    assert rc == 1
