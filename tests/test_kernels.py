"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_attention.kernel import flash_attention_tpu
from repro.kernels.selective_scan import ref as ss_ref
from repro.kernels.selective_scan.kernel import selective_scan_tpu
from repro.kernels.sil_mse import ref as sm_ref
from repro.kernels.sil_mse.kernel import sil_mse_fwd_tpu

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("b,s,h,kv,d", [
    (2, 256, 4, 2, 64), (1, 128, 4, 4, 64), (2, 200, 8, 2, 128),
    (1, 384, 6, 6, 64), (1, 96, 12, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(b, s, h, kv, d, dtype, causal, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    out = flash_attention_tpu(q, k, v, causal=causal, window=window)
    exp = fa_ref.naive_attention(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,lc,h,kv,d", [
    (2, 32, 4, 2, 64), (1, 100, 8, 2, 128), (3, 16, 6, 6, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_kernel_sweep(b, lc, h, kv, d, dtype):
    from repro.kernels.flash_attention.kernel import decode_attention_tpu
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), dtype)
    k = jax.random.normal(ks[1], (b, lc, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, lc, kv, d), dtype)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    for pos in (lc // 2,                                   # partial cache
                jnp.arange(b, dtype=jnp.int32) + 3,        # ragged batch
                2 * lc):                                   # ring: all valid
        out = decode_attention_tpu(q, k, v, pos, bk=16)
        exp = fa_ref.decode_attention(q, k, v, pos)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(exp, np.float32),
                                   rtol=tol, atol=tol)


def test_attention_dispatch_force_ref(monkeypatch):
    """REPRO_FORCE_REF=1 pins the jnp reference even when the backend
    reports TPU; without it the TPU path takes the Pallas kernels."""
    from repro.kernels import dispatch
    from repro.kernels.flash_attention import kernel as fa_kernel
    from repro.kernels.flash_attention import ops
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 1, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 16, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 16, 2, 64), jnp.float32)
    monkeypatch.setattr(dispatch, "on_tpu", lambda: True)
    hits = []
    monkeypatch.setattr(fa_kernel, "decode_attention_tpu",
                        lambda *a, **kw: hits.append("decode") or
                        fa_ref.decode_attention(a[0], a[1], a[2], a[3]))
    monkeypatch.setattr(fa_kernel, "flash_attention_tpu",
                        lambda *a, **kw: hits.append("flash") or
                        fa_ref.naive_attention(a[0], a[1], a[2]))
    monkeypatch.setenv("REPRO_FORCE_REF", "1")
    ops.decode_attention(q, k, v, 7)
    ops.flash_attention(q, k, v)
    assert hits == []                      # forced to the reference path
    monkeypatch.delenv("REPRO_FORCE_REF")
    ops.decode_attention(q, k, v, 7)
    ops.flash_attention(q, k, v)
    assert hits == ["decode", "flash"]     # TPU path dispatches the kernels


def test_flash_vs_chunked_ref_agree():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 160, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 160, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 160, 2, 64), jnp.float32)
    a = fa_ref.chunked_attention(q, k, v, causal=True, chunk=64)
    b = fa_ref.naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("ba,s,di,n", [
    (2, 64, 32, 8), (1, 100, 64, 16), (2, 256, 128, 16), (1, 33, 48, 4),
])
def test_selective_scan_sweep(ba, s, di, n):
    ks = jax.random.split(KEY, 6)
    u = jax.random.normal(ks[0], (ba, s, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (ba, s, di)))
    A = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.5)
    B = jax.random.normal(ks[3], (ba, s, n))
    C = jax.random.normal(ks[4], (ba, s, n))
    D = jax.random.normal(ks[5], (di,))
    y, h = selective_scan_tpu(u, dt, A, B, C, D, chunk=32, bd=32)
    ey, eh = ss_ref.selective_scan(u, dt, A, B, C, D, chunk=32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ey), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(eh), rtol=1e-4,
                               atol=1e-4)


def test_selective_scan_step_matches_full():
    """Sequential decode steps reproduce the full scan."""
    ks = jax.random.split(KEY, 6)
    ba, s, di, n = 2, 16, 8, 4
    u = jax.random.normal(ks[0], (ba, s, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (ba, s, di)))
    A = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.5)
    B = jax.random.normal(ks[3], (ba, s, n))
    C = jax.random.normal(ks[4], (ba, s, n))
    D = jax.random.normal(ks[5], (di,))
    y_full, h_full = ss_ref.selective_scan(u, dt, A, B, C, D, chunk=8)
    h = jnp.zeros((ba, di, n))
    ys = []
    for t in range(s):
        y, h = ss_ref.selective_scan_step(u[:, t], dt[:, t], A, B[:, t],
                                          C[:, t], D, h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("t,d,m", [(64, 128, 47), (100, 96, 512),
                                   (256, 512, 1000), (37, 60, 47)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sil_mse_sweep(t, d, m, dtype):
    ks = jax.random.split(KEY, 3)
    act = jax.random.normal(ks[0], (t, d), dtype)
    sil = jax.random.uniform(ks[1], (d, m), jnp.float32) * 10
    lab = jax.random.randint(ks[2], (t,), 0, m)
    loss, grad = sil_mse_fwd_tpu(act, sil, lab, bt=32, bd=64)
    eloss = sm_ref.sil_mse(act, sil, lab)
    egrad = sm_ref.sil_mse_grad_act(act, sil, lab)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    assert abs(float(loss) - float(eloss)) <= tol * max(1.0, float(eloss))
    np.testing.assert_allclose(np.asarray(grad, np.float32), np.asarray(
        egrad * 1.0, np.float32), rtol=5e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=1e-4)


def test_sil_mse_custom_vjp_grad():
    """ops.sil_mse custom VJP == autodiff through the reference."""
    from repro.kernels.sil_mse import sil_mse
    ks = jax.random.split(KEY, 3)
    act = jax.random.normal(ks[0], (40, 24), jnp.float32)
    sil = jax.random.uniform(ks[1], (24, 10)) * 5
    lab = jax.random.randint(ks[2], (40,), 0, 10)
    g1 = jax.grad(lambda a: sil_mse(a, sil, lab))(act)
    g2 = jax.grad(lambda a: sm_ref.sil_mse(a, sil, lab))(act)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5,
                               atol=1e-7)
