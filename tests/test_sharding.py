"""Sharding-policy invariants (mesh stubbed — no 512-device init here)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get
from repro.launch.sharding import Policy, _pad_spec
from repro.launch import specs as S
from repro.configs.base import INPUT_SHAPES
from repro.models import model as M


class FakeMesh:
    """Duck-typed stand-in exposing shape/axis_names (enough for pspecs)."""
    def __init__(self, multi_pod=False):
        self.shape = ({"pod": 2, "data": 16, "model": 16} if multi_pod
                      else {"data": 16, "model": 16})
        self.axis_names = tuple(self.shape)
        self.size = 512 if multi_pod else 256


@pytest.mark.parametrize("name", ARCH_NAMES)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(name, multi_pod):
    """Every sharded dim must divide by its mesh axes — the policy's core
    contract (fallback to replication otherwise)."""
    cfg = get(name)
    mesh = FakeMesh(multi_pod)
    pol = Policy(cfg, mesh)
    struct = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = pol.params_pspecs(struct)
    flat_s, _ = jax.tree_util.tree_flatten_with_path(struct)
    flat_p = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: hasattr(x, "_normalized_spec")
        or type(x).__name__ == "PartitionSpec")
    assert len(flat_s) == len(flat_p)
    for (path, leaf), spec in zip(flat_s, flat_p):
        ent = _pad_spec(spec, len(leaf.shape))
        for dim, ax in zip(leaf.shape, ent):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = 1
            for a in axes:
                total *= mesh.shape[a]
            assert dim % total == 0, (path, leaf.shape, spec)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_batch_entry_divides(name):
    cfg = get(name)
    pol = Policy(cfg, FakeMesh())
    for shape in INPUT_SHAPES.values():
        ent = pol.batch_entry(shape.global_batch)
        total = 1
        for ax in ent:
            total *= pol.mesh.shape[ax]
        assert shape.global_batch % total == 0


def test_decisions_recorded_for_fallbacks():
    cfg = get("qwen2-1.5b")  # 12 heads on a 16-way axis -> fallback
    pol = Policy(cfg, FakeMesh())
    struct = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    pol.params_pspecs(struct)
    assert "replicated" in pol.explain()["attn_q_heads"]


def test_pipeline_policy_shards_group_stack():
    """Pipeline mode: the stacked group dim shards over 'pod' when it
    divides; batch excludes the pod axis."""
    cfg = get("mistral-large-123b")  # 88 groups % 2 pods == 0
    pol = Policy(cfg, FakeMesh(multi_pod=True), pipeline=True)
    struct = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = pol.params_pspecs(struct)
    flat, _ = jax.tree_util.tree_flatten_with_path(struct)
    flat_p = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: type(x).__name__ == "PartitionSpec")
    saw_pod = False
    for (path, leaf), spec in zip(flat, flat_p):
        ent = _pad_spec(spec, len(leaf.shape))
        if str(path[0].key) == "groups":
            assert ent[0] in ("pod", None)
            saw_pod |= ent[0] == "pod"
    assert saw_pod
    assert pol.dp == ("data",)
    # jamba has 9 groups -> replication fallback, recorded
    cfg2 = get("jamba-1.5-large-398b")
    pol2 = Policy(cfg2, FakeMesh(multi_pod=True), pipeline=True)
    pol2.params_pspecs(jax.eval_shape(
        lambda: M.init_params(cfg2, jax.random.PRNGKey(0))))
    assert "replicated" in pol2.explain()["pipeline_groups"]


def test_applicability_matrix():
    """39 of 40 pairs run; whisper x long_500k is the documented skip."""
    n_ok, skips = 0, []
    for name in ARCH_NAMES:
        cfg = get(name)
        for shape in INPUT_SHAPES.values():
            ok, why = S.applicable(cfg, shape)
            if ok:
                n_ok += 1
            else:
                skips.append((name, shape.name))
    assert n_ok == 39
    assert skips == [("whisper-tiny", "long_500k")]
