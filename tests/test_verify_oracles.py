"""The conformance-oracle collector: every oracle registered in
`repro.verify` is auto-parametrized into pytest, so a new equivalence
contract becomes a test by registration alone.

Plus unit tests for the comparison-policy tiers themselves (the judges
must be trustworthy before the judged).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.verify import (AccuracyGap, Allclose, Bitwise, Context,
                          TokensEqual, all_oracles, build_report, get,
                          run_oracle, tolerance_for)

ORACLE_NAMES = [o.name for o in all_oracles()]


# ==========================================================================
# the collector: one pytest item per registered oracle
# ==========================================================================

def test_registry_covers_the_contract_surface():
    """The ISSUE-5 floor: >= 7 oracles, spanning every subsystem group."""
    assert len(ORACLE_NAMES) >= 7
    groups = {n.split("/")[0] for n in ORACLE_NAMES}
    assert {"kernel", "train", "serve", "precision", "checkpoint",
            "paper"} <= groups


def test_every_kernel_family_has_an_oracle():
    """Adding a Pallas kernel without registering its kernel-vs-reference
    contract must fail here, not rot silently."""
    from repro.kernels import FAMILIES
    kernel_oracles = {n.split("/", 1)[1] for n in ORACLE_NAMES
                      if n.startswith("kernel/")}
    for family, entry_points in FAMILIES.items():
        for entry in entry_points:
            assert entry in kernel_oracles, \
                f"kernel entry point {family}/{entry} has no oracle"


@pytest.mark.parametrize("name", ORACLE_NAMES)
def test_oracle_conformance(name, tmp_path):
    oracle = get(name)
    res = run_oracle(oracle, Context(preset="tiny", workdir=str(tmp_path)))
    detail = res.error or (res.verdict.detail if res.verdict else "")
    assert res.ok, f"{name} violated its contract: {detail}"


def test_run_oracle_captures_exceptions():
    from repro.verify.oracle import Oracle

    def boom(ctx):
        raise RuntimeError("injected failure")
    o = Oracle(name="x/boom", contract="always fails", run=boom,
               policy=Bitwise())
    res = run_oracle(o)
    assert not res.ok and "injected failure" in res.error
    assert "error" in res.row()


def test_report_schema_and_write(tmp_path):
    import json

    from repro.verify import write_report
    res = run_oracle(get("kernel/sil_mse"), Context(preset="tiny"))
    report = build_report([res], preset="tiny", arch="qwen2-1.5b")
    assert report["schema"] == "repro.verify/1"
    assert report["n_oracles"] == 1
    assert report["n_passed"] + report["n_failed"] == 1
    row = report["oracles"][0]
    assert {"name", "ok", "seconds"} <= set(row)
    path = str(tmp_path / "CONFORMANCE.json")
    write_report(path, [res], preset="tiny", arch="qwen2-1.5b",
                 extra={"note": "unit"})
    with open(path) as f:
        on_disk = json.load(f)
    assert on_disk["oracles"] == report["oracles"]
    assert on_disk["note"] == "unit"


# ==========================================================================
# the comparison policies
# ==========================================================================

def test_bitwise_catches_single_bit():
    a = {"w": np.arange(8, dtype=np.float32)}
    assert Bitwise().compare(a, {"w": a["w"].copy()}).ok
    b = a["w"].copy()
    b[3] = np.nextafter(b[3], np.inf)
    v = Bitwise().compare(a, {"w": b})
    assert not v.ok and v.metrics["n_diff"] == 1


def test_allclose_tolerance_is_dtype_aware():
    assert tolerance_for(jnp.float32) == (1e-5, 1e-6)
    assert tolerance_for(jnp.bfloat16) == (2e-2, 2e-2)
    # the WIDEST dtype on either side decides
    assert tolerance_for(jnp.float32, jnp.bfloat16) == (2e-2, 2e-2)
    a32 = np.ones((4,), np.float32)
    # a 1e-3 error fails at fp32 tolerance...
    v = Allclose().compare({"x": a32}, {"x": a32 + 1e-3})
    assert not v.ok and v.metrics["rtol"] == 1e-5
    # ...but the same arrays in bf16 are judged at bf16 tolerance
    a16 = jnp.ones((4,), jnp.bfloat16)
    assert Allclose().compare({"x": a16}, {"x": a16 + 1e-3}).ok


def test_allclose_int_leaves_must_match_exactly():
    assert not Allclose().compare({"i": np.array([1, 2])},
                                  {"i": np.array([1, 3])}).ok


def test_accuracy_gap_budget_and_floor():
    p = AccuracyGap(budget=0.02, floor=0.5)
    assert p.compare(0.90, 0.89).ok
    assert not p.compare(0.90, 0.85).ok        # gap over budget
    assert not p.compare(0.10, 0.10).ok        # both at chance: not parity


def test_tokens_equal():
    assert TokensEqual().compare([(1, 2, 3)], [(1, 2, 3)]).ok
    assert not TokensEqual().compare([(1, 2, 3)], [(1, 2, 4)]).ok
    assert not TokensEqual().compare([(1, 2)], [(1, 2), (3,)]).ok
