"""MoE dispatch unit tests: capacity semantics, grouped-dispatch
equivalence, aux-loss sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models import layers as L


class _Cfg:
    d_model, d_ff, mlp_type = 64, 128, "swiglu"
    moe = MoEConfig(num_experts=4, top_k=2)


@pytest.fixture(scope="module")
def moe_setup():
    key = jax.random.PRNGKey(0)
    p = L.moe_init(key, _Cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 64))
    return p, x


def test_grouped_equals_global_with_ample_capacity(moe_setup):
    """Routing is per-token deterministic; with no capacity drops the
    grouped dispatch must be numerically identical to the global one."""
    p, x = moe_setup
    o1, a1 = L.moe_apply(p, x, _Cfg.moe, capacity=128, groups=1)
    o2, a2 = L.moe_apply(p, x, _Cfg.moe, capacity=64, groups=4)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5,
                               atol=1e-5)
    assert abs(float(a1["z_loss"]) - float(a2["z_loss"])) < 1e-4


def test_capacity_drops_tokens(moe_setup):
    """Tiny capacity must drop tokens (output partially zeroed), not crash."""
    p, x = moe_setup
    o_small, _ = L.moe_apply(p, x, _Cfg.moe, capacity=8)
    o_big, _ = L.moe_apply(p, x, _Cfg.moe, capacity=256)
    # some tokens differ (dropped -> zero contribution from that expert)
    assert float(jnp.abs(o_small - o_big).max()) > 1e-6
    assert bool(jnp.isfinite(o_small).all())


def test_weight_gather_flag_is_numerically_neutral(moe_setup):
    p, x = moe_setup
    o1, _ = L.moe_apply(p, x, _Cfg.moe, capacity=64)
    o2, _ = L.moe_apply(p, x, _Cfg.moe, capacity=64, gather_weights=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-6,
                               atol=1e-6)


def test_load_balance_loss_prefers_uniform():
    """lb loss is ~1 for uniform routing and larger for a collapsed router."""
    e, t, k = 4, 256, 1
    moe = MoEConfig(num_experts=e, top_k=k)
    probs_uniform = jnp.full((t, e), 1 / e)
    # emulate the loss formula directly
    def lb(probs, eid):
        onehot = jax.nn.one_hot(eid, e)
        me = probs.mean(0)
        ce = onehot.mean(0)
        return float(e * jnp.sum(me * ce) / k)
    uniform = lb(probs_uniform, jnp.arange(t) % e)
    collapsed = lb(jnp.eye(e)[jnp.zeros(t, jnp.int32)],
                   jnp.zeros(t, jnp.int32))
    assert abs(uniform - 1.0) < 1e-5
    assert collapsed > 3.0


def test_moe_capacity_formula():
    from repro.models.layers import moe_capacity
    moe = MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25)
    c = moe_capacity(65536, moe)
    assert c % 8 == 0
    assert c >= 1.25 * 65536 * 2 / 8
