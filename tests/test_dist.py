"""repro.dist: placement plans, the concurrent stage executor, and
per-stage checkpoint/resume lifecycle.

The multi-device tests need forced host devices; run the full set with

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q tests/test_dist.py

(the CI "dist smoke" step).  Under tier-1's single real device the
multi-device tests skip and the pure placement/lifecycle logic still runs.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.dist import placement as P
from repro.dist import (StageExecutor, join_from_checkpoints, lifecycle,
                        load_stage_params)
from repro.train.backends import make_optimizer_for

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 4, reason="needs >=4 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _leaves_equal(a, b, **tol):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if tol:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), **tol)
        else:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ==========================================================================
# placement (pure — run anywhere)
# ==========================================================================

def test_round_robin_assignment():
    plan = P.round_robin(5, devices=("a", "b", "c"))
    assert plan.assignments == (0, 1, 2, 0, 1)
    assert plan.device_for(3) == "a"
    assert plan.strategy == "round_robin"


def test_explicit_validates_range():
    plan = P.explicit([1, 0, 1], devices=("a", "b"))
    assert plan.device_for(0) == "b"
    with pytest.raises(ValueError):
        P.explicit([0, 2], devices=("a", "b"))
    with pytest.raises(ValueError):
        plan.validate(5)   # wrong stage count


def test_memory_balanced_packing_invariants():
    sizes = [100, 60, 40, 30, 30, 10]
    devs = (0, 1, 2)
    plan = P.memory_balanced(sizes, devices=devs)
    # every stage assigned, loads are exact per-device sums
    assert len(plan.assignments) == len(sizes)
    loads = [0, 0, 0]
    for k, a in enumerate(plan.assignments):
        loads[a] += sizes[k]
    assert tuple(loads) == plan.loads
    assert sum(plan.loads) == sum(sizes)
    # LPT never packs worse than round-robin
    rr = P.round_robin(len(sizes), devices=devs)
    rr_loads = [0, 0, 0]
    for k, a in enumerate(rr.assignments):
        rr_loads[a] += sizes[k]
    assert max(plan.loads) <= max(rr_loads)
    # deterministic
    assert plan.assignments == P.memory_balanced(sizes,
                                                 devices=devs).assignments


def test_resolve_strategies():
    assert P.resolve("round_robin", 4,
                     devices=(0, 1)).strategy == "round_robin"
    assert P.resolve([0, 0, 1], 3, devices=(0, 1)).strategy == "explicit"
    mem = P.resolve("memory", 2, devices=(0, 1),
                    stage_bytes=lambda: [10, 20])
    assert mem.strategy == "memory"
    with pytest.raises(ValueError):
        P.resolve("memory", 2, devices=(0, 1))     # no byte estimates
    with pytest.raises(ValueError):
        P.resolve("warp_speed", 2, devices=(0, 1))


def test_estimate_stage_bytes():
    params = [{"w": jnp.zeros((4, 4), jnp.float32),
               "b": jnp.zeros((4,), jnp.float32)}]
    pb = 20 * 4
    assert P.estimate_stage_bytes(params, "sgd") == pb
    assert P.estimate_stage_bytes(params, "sgdm") == pb + 20 * 4
    assert P.estimate_stage_bytes(params, "adamw") == pb + 2 * 20 * 4
    half = [{"w": jnp.zeros((4, 4), jnp.bfloat16)}]
    # bf16 params, fp32 optimizer slots
    assert P.estimate_stage_bytes(half, "sgdm") == 16 * 2 + 16 * 4


# ==========================================================================
# checkpoint restore placement (single device is enough)
# ==========================================================================

def test_restore_checkpoint_single_device_broadcast(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "h": jnp.ones((3,), jnp.bfloat16) * 1.5,
            "nested": [{"b": jnp.zeros((2,), jnp.float32)}]}
    save_checkpoint(str(tmp_path), 3, tree)
    dev = jax.devices()[-1]
    # a bare Device (not a shardings pytree) broadcasts to every leaf
    out = restore_checkpoint(str(tmp_path), tree, shardings=dev)
    for leaf in jax.tree_util.tree_leaves(out):
        assert isinstance(leaf, jax.Array)
        assert leaf.devices() == {dev}
    # bf16 survives the uint16 storage view round-trip onto the device
    assert out["h"].dtype == jnp.bfloat16
    _leaves_equal(out, tree)
    # mismatched shardings trees still fail loudly
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), tree, shardings={"w": dev})


# ==========================================================================
# fixtures for the executor tests
# ==========================================================================

# setup comes from the shared conftest fixtures (`tiny_mlp` / `tiny_lm` —
# the same repro.verify.scenarios builders the conformance oracles use)


# ==========================================================================
# concurrent-vs-sequential equivalence (the Fig.-5 placement contract)
# ==========================================================================

@multi_device
def test_mlp_concurrent_matches_sequential(tiny_mlp):
    from repro.train import recipes
    cfg, data, spec = tiny_mlp()
    key = jax.random.PRNGKey(0)
    p_seq, _ = recipes.run_mlp_fig5(cfg, data, spec, key, n_stages=3)
    p_con, _ = recipes.run_mlp_fig5(cfg, data, spec, key, n_stages=3,
                                    dist="round_robin")
    _leaves_equal(p_seq, p_con, rtol=1e-5, atol=1e-6)


@multi_device
def test_mlp_memory_placement_matches_sequential(tiny_mlp):
    from repro.train import recipes
    cfg, data, spec = tiny_mlp(epochs=(1, 1, 1))
    key = jax.random.PRNGKey(2)
    p_seq, _ = recipes.run_mlp_fig5(cfg, data, spec, key, n_stages=3)
    p_con, _ = recipes.run_mlp_fig5(cfg, data, spec, key, n_stages=3,
                                    dist="memory")
    _leaves_equal(p_seq, p_con, rtol=1e-5, atol=1e-6)


@multi_device
def test_lm_concurrent_matches_sequential(tiny_lm):
    from repro.train import recipes
    # accum=2: both paths must microbatch identically (the sequential path
    # used to drop StageSpec.accum in ParallelSil)
    cfg, plan, batch_fn, spec, params = tiny_lm(accum=2)
    key = jax.random.PRNGKey(1)
    p_seq, h_seq = recipes.run_lm_parallel(cfg, plan, params, batch_fn,
                                           spec, key)
    p_con, h_con = recipes.run_lm_parallel(cfg, plan, params, batch_fn,
                                           spec, key, dist="round_robin")
    _leaves_equal(p_seq, p_con, rtol=1e-5, atol=1e-6)
    # loss curves drain identically (same interleaving, one transfer)
    np.testing.assert_allclose(h_seq.column("loss"), h_con.column("loss"),
                               rtol=1e-5)


@multi_device
def test_frozen_prefix_producer_consumer_devices(tiny_lm):
    """BoundaryMaterialize/FrozenPrefix route producer and consumer to
    distinct devices without changing the math."""
    from repro.train import (FrozenPrefixPhase, LMBackend, SilStagePhase,
                             Trainer)
    cfg, plan, batch_fn, spec, params = tiny_lm(steps=2)

    def run(dist_plan):
        be = LMBackend(cfg, plan, batch_fn, spec)
        phases = [SilStagePhase(stage=0, steps=2),
                  FrozenPrefixPhase(stage=1, source="live", steps=2,
                                    plan=dist_plan)]
        return Trainer(be, spec).run(phases, params=params,
                                     key=jax.random.PRNGKey(1))

    p_seq, _ = run(None)
    p_con, _ = run(P.round_robin(plan.n_stages))
    _leaves_equal(p_seq, p_con, rtol=1e-5, atol=1e-6)


# ==========================================================================
# lifecycle: per-stage checkpoint -> failure -> resume -> join
# ==========================================================================

@multi_device
def test_stage_failure_resume_join_bit_consistent(tmp_path, tiny_lm):
    from repro.train import LMBackend
    root = str(tmp_path / "stages")
    cfg, plan, batch_fn, spec, params = tiny_lm(steps=4)
    be = LMBackend(cfg, plan, batch_fn, spec)
    sils = be.make_sils(jax.random.PRNGKey(1), spec.kappa)
    sp0 = be.split(params)
    hps = [spec.stage(k) for k in range(2)]
    pl = P.round_robin(2)

    def make_ex(ckpt_every):
        opts = [make_optimizer_for(hp, spec) for hp in hps]
        return StageExecutor(be, pl, sp0, sils, opts, hps,
                             ckpt_dir=root, ckpt_every=ckpt_every)

    # uninterrupted reference run, checkpointing every 2 ticks + at the end
    ref_ex = make_ex(ckpt_every=2)
    ref_ex.run(4)
    ref_ex.checkpoint()
    ref = ref_ex.gather()
    assert ref_ex.ticks == [4, 4]
    assert lifecycle.stage_ticks(root, 2) == [4, 4]

    # second run: stage 1 "dies" at tick 2 and resumes from ITS OWN
    # checkpoint; stage 0 is never touched by the recovery
    ex = make_ex(ckpt_every=0)
    ex.run(2)
    ex.params[1] = jax.tree_util.tree_map(jnp.zeros_like, ex.params[1])
    assert ex.resume_stage(1, step=2) == 2
    ex.run(4, stages=[1])
    ex.run(4, stages=[0])
    got = ex.gather()
    for k in range(2):
        _leaves_equal(ref[k], got[k])   # bitwise
    # replayed ticks re-run the math but must NOT re-log metrics: the
    # pending loss list matches the uninterrupted run's (4 ticks x 2 stages)
    assert len(ex._pending) == len(ref_ex._pending) == 8

    # join_from_checkpoints rebuilds the exact live join for eval
    joined = join_from_checkpoints(root, sp0, be.join)
    _leaves_equal(joined, be.join(ref))

    # per-stage restore onto one pinned device (the dist per-stage case)
    dev = jax.devices()[-1]
    placed = load_stage_params(root, sp0, devices=[dev, dev])
    for leaf in jax.tree_util.tree_leaves(placed):
        assert leaf.devices() == {dev}

    # staged serving deploys straight from the per-stage manifests,
    # without joining
    from repro.serve.staged import stage_params_from_checkpoints
    sps = stage_params_from_checkpoints(cfg, plan, root)
    for k in range(2):
        _leaves_equal(sps[k], ref[k])


def test_dist_rejects_mesh_sharding_hooks(tiny_lm):
    """plan= must fail loudly when the backend carries Policy sharding
    hooks — the executor would silently skip the caller's
    with_sharding_constraint pass otherwise."""
    from repro.train import LMBackend, ParallelSilPhase, Trainer
    cfg, plan, batch_fn, spec, params = tiny_lm(steps=1)
    be = LMBackend(cfg, plan, batch_fn, spec,
                   grad_pspecs_fn=lambda tree: tree)
    with pytest.raises(ValueError, match="sharding hooks"):
        Trainer(be, spec).run([ParallelSilPhase(plan=[0] * plan.n_stages)],
                              params=params, key=jax.random.PRNGKey(1))


def test_lm_batch_at_is_pure():
    from repro.data.lm import lm_batch_at, synthetic_token_stream
    stream = synthetic_token_stream(10_000, 128, seed=0)
    a = lm_batch_at(stream, 4, 32, step=7)
    _ = lm_batch_at(stream, 4, 32, step=3)      # interleaved call
    b = lm_batch_at(stream, 4, 32, step=7)      # must not care
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    c = lm_batch_at(stream, 4, 32, step=8)
    assert not np.array_equal(a["tokens"], c["tokens"])


@multi_device
def test_parallel_phase_dist_checkpoints_independent_ticks(tmp_path, tiny_mlp):
    """ParallelSilPhase(plan=..., ckpt_dir=...) leaves one manifest per
    stage with that stage's OWN tick counter (heterogeneous durations)."""
    from repro.models import mlp as MLP
    from repro.train import MLPBackend, ParallelSilPhase, Trainer
    from repro.train.backends import balanced_bounds
    root = str(tmp_path / "mlp_stages")
    cfg, data, spec = tiny_mlp(epochs=(1, 2, 3))
    be = MLPBackend(cfg, data, spec, bounds=balanced_bounds(cfg, 3))
    params = MLP.init_params(cfg, jax.random.PRNGKey(0))
    phase = ParallelSilPhase(plan="round_robin", ckpt_dir=root)
    Trainer(be, spec).run([phase], params=params,
                          key=jax.random.PRNGKey(3))
    assert lifecycle.stage_ticks(root, 3) == [1, 2, 3]
