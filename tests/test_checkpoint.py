"""Checkpoint restore error paths + dist lifecycle failure modes.

A restore that cannot succeed must fail loudly and say why: a truncated
manifest, a `like` tree that does not match the saved arrays, a missing
stage directory, and a mismatched shardings tree each get their own
message instead of a stray KeyError/JSONDecodeError deep in numpy.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.dist import lifecycle

TREE = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "h": jnp.ones((3,), jnp.bfloat16) * 1.5,
        "nested": [{"b": jnp.zeros((2,), jnp.float32)}]}


def test_roundtrip_and_latest_step(tmp_path):
    save_checkpoint(str(tmp_path), 3, TREE)
    save_checkpoint(str(tmp_path), 7, TREE)
    assert latest_step(str(tmp_path)) == 7
    out = restore_checkpoint(str(tmp_path), TREE)
    assert out["h"].dtype == jnp.bfloat16       # uint16-view round trip
    for a, b in zip(jax.tree_util.tree_leaves(TREE),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError, match="no checkpoints"):
        restore_checkpoint(str(tmp_path / "nowhere"), TREE)


def test_restore_truncated_manifest_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, TREE)
    manifest = tmp_path / "ckpt_00000001.json"
    text = manifest.read_text()
    manifest.write_text(text[: len(text) // 2])      # simulated torn write
    with pytest.raises(ValueError, match="corrupt/truncated manifest"):
        restore_checkpoint(str(tmp_path), TREE)


def test_restore_mismatched_like_tree_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": TREE["w"]})
    bigger = {"w": TREE["w"], "extra": jnp.zeros((2,), jnp.float32)}
    with pytest.raises(ValueError, match="lacks arrays for"):
        restore_checkpoint(str(tmp_path), bigger)


def test_restore_mismatched_shardings_tree_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, TREE)
    dev = jax.devices()[0]
    with pytest.raises(ValueError, match="shardings tree lacks leaves"):
        restore_checkpoint(str(tmp_path), TREE, shardings={"w": dev})


def test_restore_single_device_broadcast(tmp_path):
    save_checkpoint(str(tmp_path), 1, TREE)
    dev = jax.devices()[-1]
    out = restore_checkpoint(str(tmp_path), TREE, shardings=dev)
    for leaf in jax.tree_util.tree_leaves(out):
        assert isinstance(leaf, jax.Array)
        assert leaf.devices() == {dev}


# -- dist lifecycle ---------------------------------------------------------

def test_restore_stage_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError, match="no checkpoints for stage"):
        lifecycle.restore_stage(str(tmp_path), 2, like_params=TREE)


def test_stage_ticks_reports_missing_stages(tmp_path):
    lifecycle.save_stage(str(tmp_path), 0, 4, {"w": TREE["w"]})
    assert lifecycle.stage_ticks(str(tmp_path), 3) == [4, None, None]


def test_save_stage_manifest_metadata(tmp_path):
    lifecycle.save_stage(str(tmp_path), 1, 5, {"w": TREE["w"]},
                         metadata={"kind": "mlp"})
    d = lifecycle.stage_dir(str(tmp_path), 1)
    with open(os.path.join(d, "ckpt_00000005.json")) as f:
        manifest = json.load(f)
    assert manifest["metadata"]["stage"] == 1
    assert manifest["metadata"]["tick"] == 5
    assert manifest["metadata"]["kind"] == "mlp"
    params, opt, tick = lifecycle.restore_stage(
        str(tmp_path), 1, like_params={"w": TREE["w"]})
    assert tick == 5 and opt is None
    np.testing.assert_array_equal(np.asarray(params["w"]),
                                  np.asarray(TREE["w"]))
